"""End-to-end training driver: the full mamba2-130m (130M-parameter)
config for a few hundred steps on the deterministic synthetic corpus,
with checkpointing and fault tolerance wired in.

  PYTHONPATH=src python examples/train_lm.py                # full 130M run
  PYTHONPATH=src python examples/train_lm.py --quick        # CI-sized

The full run is CPU-heavy (~100M params on one core); --steps/--batch/--seq
trade fidelity for time. Loss descends visibly either way: the corpus is
an increment-rule language with a ~5% jump floor (data/tokens.py).
"""

import argparse

from repro.configs import TrainConfig, get_config, get_smoke
from repro.launch.train import train_loop
from repro.runtime.fault_tolerance import HangWatchdog, PreemptionHandler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    if args.quick:
        cfg = get_smoke(args.arch)
        steps, batch, seq = 60, 4, 64
    else:
        cfg = get_config(args.arch).with_(
            param_dtype="float32", compute_dtype="float32")
        steps, batch, seq = args.steps, args.batch, args.seq

    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=20,
                       total_steps=steps, checkpoint_every=100, seed=0)
    watchdog = HangWatchdog(timeout_s=3600).start()
    with PreemptionHandler() as pre:
        metrics = []
        train_loop(cfg, tcfg, batch=batch, seq=seq, steps=steps,
                   ckpt_dir=args.ckpt_dir, preemption=pre,
                   watchdog=watchdog, metrics_out=metrics, log_every=10)
    watchdog.stop()
    if metrics:
        print(f"\nfirst-10 loss {sum(m['loss'] for m in metrics[:10]) / 10:.4f}"
              f" -> last-10 loss "
              f"{sum(m['loss'] for m in metrics[-10:]) / 10:.4f}")


if __name__ == "__main__":
    main()
