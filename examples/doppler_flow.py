"""Doppler validation: estimate a known flow velocity from synthetic RF.

Scatterers move axially at a programmed velocity; the Kasai autocorrelator
in the Color-Doppler pipeline must recover it (sign and magnitude), and
all three implementation variants must agree — the paper's determinism
claim, demonstrated on physics rather than random tensors.

  PYTHONPATH=src python examples/doppler_flow.py
"""

import numpy as np

import jax.numpy as jnp

from repro.core import Modality, UltrasoundPipeline, Variant, tiny_config
from repro.data.rf_data import synth_rf


def main():
    cfg0 = tiny_config(n_f=24, nz=32, nx=16, modality=Modality.DOPPLER)
    lam = cfg0.c_sound / cfg0.f0

    # programmed axial displacement per frame, in wavelengths
    for flow in [0.05, 0.12, -0.08]:
        rf = synth_rf(cfg0, seed=11, n_scatter=16, flow_fraction=1.0,
                      flow_speed=flow)
        # ground truth Nyquist-normalized velocity: the two-way path grows
        # by 2*dz per frame, so the residual IQ phase per frame is
        # -4*pi*f0*(dz/c) * ... = -4*pi*flow (dz = flow*lambda); vn =
        # phase/pi = -4*flow. Sign convention: positive = toward probe.
        expected = -4.0 * flow
        est = {}
        for v in Variant:
            if not v.concrete:      # AUTO: planner token, not a formulation
                continue
            img = np.asarray(UltrasoundPipeline(
                cfg0.with_(variant=v))(jnp.asarray(rf)))
            # velocity where signal exists (central region)
            est[v.value] = float(np.median(img[8:24, 4:12]))
        line = "  ".join(f"{k}={val:+.3f}" for k, val in est.items())
        print(f"flow={flow:+.2f} lam/frame  expected_vn={expected:+.3f}  "
              f"estimated: {line}")
        for val in est.values():
            assert abs(val - expected) < 0.15, (flow, est)
    print("Kasai velocity estimates match programmed flow for all "
          "variants.")


if __name__ == "__main__":
    main()
