"""Quickstart: build a deterministic RF-to-image pipeline, run all three
modalities in all three implementation variants, print metrics + an ASCII
B-mode image.

  PYTHONPATH=src python examples/quickstart.py
"""

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import (Modality, UltrasoundPipeline, Variant, tiny_config)
from repro.data import synth_rf


def ascii_image(img: np.ndarray, width: int = 48) -> str:
    shades = " .:-=+*#%@"
    h = img.shape[0]
    rows = []
    for r in range(0, h, max(h // 16, 1)):
        row = img[r]
        idx = (row * (len(shades) - 1)).astype(int).clip(0, len(shades) - 1)
        rows.append("".join(shades[i] for i in idx))
    return "\n".join(rows)


def main():
    cfg0 = tiny_config(nz=32, nx=48, n_f=8, n_c=16)
    rf = jnp.asarray(synth_rf(cfg0, seed=0, n_scatter=12))
    print(f"RF input: {cfg0.rf_shape} {cfg0.rf_dtype} "
          f"({cfg0.input_bytes / 1e6:.3f} MB per forward pass)\n")

    for modality in Modality:
        for variant in Variant:
            if not variant.concrete:           # AUTO demoed below
                continue
            cfg = cfg0.with_(modality=modality, variant=variant)
            pipe = UltrasoundPipeline(cfg)     # init: precompute (untimed)
            out = pipe(rf)                     # warm-up / compile
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            out = pipe(rf)
            jax.block_until_ready(out)
            dt = time.perf_counter() - t0
            print(f"{cfg.name:24s} {variant.value:8s} "
                  f"T={dt * 1e3:7.2f} ms  FPS={1 / dt:7.1f}  "
                  f"MB/s={cfg.input_bytes / dt / 1e6:8.2f}")

    # Variant.AUTO: let the backend-aware planner pick the formulation
    # (policy="autotune" would measure instead of consulting the registry).
    auto = UltrasoundPipeline(cfg0.with_(variant=Variant.AUTO))
    print(f"\nplanner: {auto.plan.provenance} "
          f"(policy={auto.plan.policy}, backend={auto.plan.backend})")

    print("\nB-mode (dynamic variant, frame 0):\n")
    img = np.asarray(UltrasoundPipeline(
        cfg0.with_(modality=Modality.BMODE))(rf))[..., 0]
    print(ascii_image(img))


if __name__ == "__main__":
    main()
