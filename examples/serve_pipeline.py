"""Serving drivers for both system halves:

1. Ultrasound: stream RF acquisitions through a fixed, fully-initialized
   pipeline (the paper's execution model) and report steady-state FPS /
   MB/s per modality.
2. LM: slot-batched greedy decoding with prefill + KV cache (qwen3 smoke
   config) — the decode-cell path of the dry-run, runnable on CPU.

  PYTHONPATH=src python examples/serve_pipeline.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import Modality, UltrasoundPipeline, tiny_config
from repro.data import synth_rf


def serve_ultrasound(n_acquisitions: int = 12):
    cfg = tiny_config(nz=32, nx=32, n_f=8, n_c=16)
    pipe = UltrasoundPipeline(cfg)
    # distinct acquisitions (e.g. a probe sweep), fixed shapes
    frames = [jnp.asarray(synth_rf(cfg, seed=s)) for s in
              range(n_acquisitions)]
    jax.block_until_ready(pipe(frames[0]))   # warm-up

    t0 = time.perf_counter()
    for rf in frames:
        jax.block_until_ready(pipe(rf))
    dt = (time.perf_counter() - t0) / n_acquisitions
    print(f"ultrasound {cfg.name}: T_avg={dt * 1e3:.2f} ms "
          f"FPS={1 / dt:.1f} MB/s={cfg.input_bytes / dt / 1e6:.2f} "
          f"(x{cfg.n_f} images per pass)")


def serve_lm():
    from repro.configs import get_smoke
    from repro.launch.serve import serve_session
    cfg = get_smoke("qwen3-8b")
    out, stats = serve_session(cfg, requests=8, batch=4, prompt_len=32,
                               max_new=16)
    print(f"lm qwen3-8b(smoke): {stats['tokens']} tokens in "
          f"{stats['wall_s']:.2f}s = {stats['tok_per_s']:.0f} tok/s, "
          f"outputs {out.shape}")


if __name__ == "__main__":
    serve_ultrasound()
    serve_lm()
