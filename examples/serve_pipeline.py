"""Serving drivers for both system halves:

1. Ultrasound: stream batched RF acquisitions through the stage-graph
   engine (serve_ultrasound_stream, 2 batches in flight) and report
   sustained FPS / MB/s plus the completion-latency distribution.
2. LM: slot-batched greedy decoding with prefill + KV cache (qwen3 smoke
   config) — the decode-cell path of the dry-run, runnable on CPU.

  PYTHONPATH=src python examples/serve_pipeline.py
"""

from repro.core import tiny_config
from repro.launch.serve import serve_ultrasound_stream


def serve_ultrasound(n_batches: int = 8, batch: int = 4):
    cfg = tiny_config(nz=32, nx=32, n_f=8, n_c=16)
    stats = serve_ultrasound_stream(
        cfg, batch=batch, n_batches=n_batches, depth=2, deadline_s=0.05)
    lat = stats["latency"]
    print(f"ultrasound {stats['name']}: {stats['acquisitions']} acquisitions "
          f"({stats['frames']} frames) FPS={stats['fps']:.1f} "
          f"MB/s={stats['sustained_mbps']:.2f} "
          f"p50={lat.p50_s * 1e3:.2f}ms p95={lat.p95_s * 1e3:.2f}ms "
          f"miss_rate={lat.miss_rate:.2f}")


def serve_lm():
    from repro.configs import get_smoke
    from repro.launch.serve import serve_session
    cfg = get_smoke("qwen3-8b")
    out, stats = serve_session(cfg, requests=8, batch=4, prompt_len=32,
                               max_new=16)
    print(f"lm qwen3-8b(smoke): {stats['tokens']} tokens in "
          f"{stats['wall_s']:.2f}s = {stats['tok_per_s']:.0f} tok/s, "
          f"outputs {out.shape}")


if __name__ == "__main__":
    serve_ultrasound()
    serve_lm()
