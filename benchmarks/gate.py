"""Statistical throughput regression gate: CI smoke rows vs baseline.

`BENCH_cpu.json` is the committed CPU reference (regenerated with the
commands in its provenance note, ``--repeats >= 3`` so every row carries
a real bootstrap confidence interval). CI re-runs the same smoke
commands on whatever runner it lands on and this gate compares the two
with the CI-exclusion rule from `repro.bench.stats`: a cell FAILS only
when the bootstrap interval of the current/baseline ratio *excludes*
the allowed factor. A point estimate beyond the factor whose interval
still straddles it is runner noise and passes; an interval entirely
beyond it is a regression no rerun will undo. Rows without run-level
data (``--repeats 1`` artifacts, pre-CI baselines) degrade to the
legacy strict mean-factor comparison, annotated ``(mean-only)``.

Two row families are gated:

  * table1 summary rows (``benchmarks.run --fast --repeats 3 --json``):
    matched by the full cell key — ``name`` already encodes
    (pipeline, variant, lowering, fusion, precision) and the stamped
    plan contributes the device count. Time-like: FAIL when the
    t_avg ratio CI sits entirely above ``factor``. ``--current`` is
    repeatable so the default, pallas-lowering and fused-precision
    smoke artifacts are all gated against the one baseline.
  * multitenant rows (``benchmarks.multitenant`` NDJSON): matched by
    the sweep cell key (clients, max_batch, max_queue_delay_ms,
    in_flight, load_profile, drain — a burst window never gates
    against a steady baseline, an async-drain window never against a
    blocking one); throughput-like: FAIL when the acq/s ratio CI sits
    entirely below ``1/factor``. Gating acq/s per in-flight depth
    keeps the async scheduler's overlap win (depth 2 > depth 1 in the
    baseline) from regressing back to synchronous throughput
    unnoticed. ``device_busy_frac`` and ``overlap_frac`` are gated the
    same way (their own CI blocks, higher is better) so the overlap
    machinery itself cannot silently decay while acq/s hides it behind
    arrival-rate slack; a baseline cell whose metric has a zero run
    mean (a legitimately synchronous depth-1 cell) is skipped for that
    metric — the ratio is undefined there, not regressed.

A baseline row with no current counterpart fails loudly (a renamed or
dropped row is a silent gate hole); extra current rows are ignored so
new benchmarks can land before the baseline is regenerated. A record
missing its identity keys (e.g. a multitenant row without ``in_flight``)
is a *named* gate failure identifying the offending record — never a
bare KeyError traceback.

  PYTHONPATH=src python -m benchmarks.gate \
      --baseline BENCH_cpu.json --current BENCH_ci.json \
      --current BENCH_lowering.json --current BENCH_fused.json \
      --multitenant MULTITENANT_ci.ndjson
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.bench.stats import GateDecision, gate_ratio

MtKey = Tuple[int, int, float, int, str, str]
T1Key = Tuple[str, int]


class GateRecordError(ValueError):
    """A benchmark record too malformed to gate (missing identity or
    metric keys). Callers turn it into a named gate failure pointing at
    the offending record instead of a raw KeyError traceback."""


def _ident(rec: dict) -> str:
    """Best-effort identification of a malformed record for failure
    messages: its name if present, else its keys."""
    if isinstance(rec, dict) and rec.get("name"):
        return f"record {rec['name']!r}"
    keys = sorted(rec.keys()) if isinstance(rec, dict) else type(rec)
    return f"record with keys {keys}"


def t1_key(rec: dict) -> T1Key:
    """A table1 summary row's gate-cell identity.

    ``name`` already encodes (pipeline, variant, lowering,
    fusion@precision); the stamped plan contributes the device count so
    a multi-device row never masks its single-device counterpart.
    """
    try:
        name = rec["name"]
    except (TypeError, KeyError):
        raise GateRecordError(
            f"table1 {_ident(rec)}: missing 'name' (not a summary row?)")
    devices = (rec.get("plan") or {}).get("devices") or 1
    return (name, int(devices))


def mt_key(rec: dict) -> MtKey:
    """A multitenant record's sweep-cell identity.

    ``load_profile`` is part of the identity — a burst or churn window
    must never gate against a steady baseline cell. Pre-profile records
    (old baselines) default to "steady", which is exactly the schedule
    they ran. ``drain`` (the host-transfer retirement mode) is likewise
    part of the identity — an async-drain window must never gate
    against a blocking baseline — and pre-drain records default to
    "block", the only retirement path that existed when they ran.
    """
    try:
        return (rec["clients"], rec["policy"]["max_batch"],
                rec["policy"]["max_queue_delay_ms"], rec["in_flight"],
                rec.get("load_profile", "steady"),
                rec.get("drain", "block"))
    except (TypeError, KeyError) as e:
        raise GateRecordError(
            f"multitenant {_ident(rec)}: missing cell-identity key "
            f"{e} (need clients, policy.max_batch, "
            f"policy.max_queue_delay_ms, in_flight)")


def _metric_runs(rec: dict, metric: str, ci_key: str,
                 family: str) -> Tuple[List[float], bool]:
    """(run-level means for the metric, whether they are real repeats).

    A row whose ``ci_key`` block carries ``run_means`` with more than
    one entry contributes its full level-one data (the gate can
    re-bootstrap it); anything else degrades to the single mean —
    flagged so the verdict is annotated ``(mean-only)``.
    """
    ci = rec.get(ci_key)
    if isinstance(ci, dict):
        means = ci.get("run_means")
        if isinstance(means, list) and len(means) > 1:
            return [float(m) for m in means], True
    try:
        return [float(rec[metric])], False
    except (TypeError, KeyError):
        raise GateRecordError(
            f"{family} {_ident(rec)}: missing metric {metric!r}")


def _gate_cell(base: dict, cur: dict, *, metric: str, ci_key: str,
               family: str, factor: float,
               higher_is_better: bool) -> Tuple[GateDecision, bool]:
    """(decision, statistical) for one matched baseline/current pair.

    ``statistical`` is False when either side lacked run-level data and
    the CI-exclusion rule therefore collapsed to the legacy strict mean
    comparison (degenerate zero-width intervals).
    """
    base_runs, base_real = _metric_runs(base, metric, ci_key, family)
    cur_runs, cur_real = _metric_runs(cur, metric, ci_key, family)
    decision = gate_ratio(base_runs, cur_runs, factor=factor,
                          higher_is_better=higher_is_better)
    return decision, base_real and cur_real


def gate_table1(baseline: List[dict], current: List[dict], *,
                factor: float) -> List[str]:
    """Failures: table1 cells whose t_avg ratio CI excludes the factor."""
    failures: List[str] = []
    cur: Dict[T1Key, dict] = {}
    for rec in current:
        try:
            cur[t1_key(rec)] = rec
        except GateRecordError as e:
            failures.append(str(e))
    for base in baseline:
        try:
            key = t1_key(base)
            row = cur.get(key)
            cell = f"{key[0]} devices={key[1]}"
            if row is None:
                failures.append(
                    f"table1 row {cell!r}: missing from current")
                continue
            dec, statistical = _gate_cell(
                base, row, metric="t_avg_s", ci_key="ci", family="table1",
                factor=factor, higher_is_better=False)
        except GateRecordError as e:
            failures.append(str(e))
            continue
        if not dec.ok:
            note = "" if statistical else " (mean-only)"
            failures.append(
                f"table1 row {cell!r}: t_avg {dec.reason}{note}")
    return failures


# Overlap-telemetry metrics gated alongside acq/s: each is
# throughput-like (higher is better), each carries its own bootstrap CI
# block. A baseline cell with any zero run mean is skipped for that
# metric — the ratio is undefined, and a legitimately synchronous cell
# (depth-1 overlap_frac == 0) must not wedge the gate.
_MT_FRAC_METRICS = (("device_busy_frac", "device_busy_frac_ci"),
                    ("overlap_frac", "overlap_frac_ci"))


def gate_multitenant(baseline: List[dict], current: List[dict], *,
                     factor: float) -> List[str]:
    """Failures: multitenant cells whose acq/s — or overlap-telemetry
    (device_busy_frac / overlap_frac) — ratio CI excludes the allowed
    floor."""
    failures: List[str] = []
    cur: Dict[MtKey, dict] = {}
    for rec in current:
        try:
            cur[mt_key(rec)] = rec
        except GateRecordError as e:
            failures.append(str(e))
    for base in baseline:
        try:
            key = mt_key(base)
            row = cur.get(key)
            cell = (f"clients={key[0]} max_batch={key[1]} "
                    f"delay_ms={key[2]:g} in_flight={key[3]} "
                    f"profile={key[4]} drain={key[5]}")
            if row is None:
                failures.append(f"multitenant cell [{cell}]: missing "
                                f"from current")
                continue
            dec, statistical = _gate_cell(
                base, row, metric="acq_per_s", ci_key="acq_per_s_ci",
                family="multitenant", factor=factor,
                higher_is_better=True)
        except GateRecordError as e:
            failures.append(str(e))
            continue
        if not dec.ok:
            note = "" if statistical else " (mean-only)"
            failures.append(
                f"multitenant cell [{cell}]: acq_per_s "
                f"{dec.reason}{note}")
        for metric, ci_key in _MT_FRAC_METRICS:
            if metric not in base:
                continue    # pre-telemetry baseline: nothing to hold
            try:
                base_runs, base_real = _metric_runs(
                    base, metric, ci_key, "multitenant")
                if any(b == 0.0 for b in base_runs):
                    continue    # ratio undefined (synchronous cell)
                cur_runs, cur_real = _metric_runs(
                    row, metric, ci_key, "multitenant")
                dec = gate_ratio(base_runs, cur_runs, factor=factor,
                                 higher_is_better=True)
            except GateRecordError as e:
                failures.append(str(e))
                continue
            if not dec.ok:
                note = "" if (base_real and cur_real) else " (mean-only)"
                failures.append(
                    f"multitenant cell [{cell}]: {metric} "
                    f"{dec.reason}{note}")
    return failures


def run_gate(baseline_path: str, *,
             current_path: Union[str, Sequence[str], None] = None,
             multitenant_path: Union[str, Sequence[str], None] = None,
             factor: float = 2.0) -> List[str]:
    """All gate failures for the given artifact files (empty = pass).

    ``current_path`` and ``multitenant_path`` each accept one path or a
    sequence of paths — the CI workflow gates the default, lowering and
    fused smoke artifacts (and the steady + transfer-telemetry
    multitenant NDJSON artifacts) against the one baseline in a single
    invocation, so every baseline cell must be covered by the union of
    the current artifacts.
    """
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    if current_path is not None:
        paths = ([current_path] if isinstance(current_path, str)
                 else list(current_path))
        current: List[dict] = []
        for path in paths:
            with open(path) as f:
                current += json.load(f)["results"]
        failures += gate_table1(baseline["results"], current,
                                factor=factor)
    mt_base = baseline.get("multitenant", [])
    if multitenant_path is not None and mt_base:
        mt_paths = ([multitenant_path]
                    if isinstance(multitenant_path, str)
                    else list(multitenant_path))
        mt_cur: List[dict] = []
        for path in mt_paths:
            with open(path) as f:
                mt_cur += [json.loads(line) for line in f
                           if line.strip()]
        mt_cur = [r for r in mt_cur if r.get("kind") == "multitenant"]
        failures += gate_multitenant(mt_base, mt_cur, factor=factor)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Compare CI smoke benchmark rows against the "
                    "checked-in baseline (bootstrap-CI regression gate).")
    ap.add_argument("--baseline", default="BENCH_cpu.json",
                    help="committed reference JSON (table1 results + "
                         "multitenant rows)")
    ap.add_argument("--current", action="append", default=None,
                    help="benchmarks.run --json artifact to gate "
                         "(repeatable; the union of rows must cover "
                         "every baseline cell)")
    ap.add_argument("--multitenant", action="append", default=None,
                    help="benchmarks.multitenant --ndjson artifact to "
                         "gate (repeatable; the union of rows must "
                         "cover every baseline multitenant cell)")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="allowed slowdown factor (default 2.0); FAIL "
                         "only when the ratio CI excludes it")
    args = ap.parse_args()
    if args.current is None and args.multitenant is None:
        ap.error("nothing to gate: pass --current and/or --multitenant")

    failures = run_gate(args.baseline, current_path=args.current,
                        multitenant_path=args.multitenant,
                        factor=args.factor)
    for msg in failures:
        print(f"gate failure: {msg}", file=sys.stderr)
    if not failures:
        print(f"gate ok (factor {args.factor:g}, CI-exclusion rule, "
              f"baseline {args.baseline})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
