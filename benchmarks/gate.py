"""Throughput regression gate: CI smoke rows vs the checked-in baseline.

`BENCH_cpu.json` is the committed CPU reference (regenerated with the
commands in its provenance note). CI re-runs the same smoke commands on
whatever runner it lands on and this gate compares the two, with a
deliberately loose factor (default 2x) that absorbs runner-to-runner
variance but still catches the failure mode benchmarks exist to catch:
a change that silently halves throughput while every correctness test
stays green.

Two row families are gated:

  * table1 summary rows (``benchmarks.run --fast --json``): matched by
    ``name``; FAIL when current ``t_avg_s`` exceeds ``factor`` x the
    baseline's.
  * multitenant rows (``benchmarks.multitenant`` NDJSON): matched by
    the sweep cell key (clients, max_batch, max_queue_delay_ms,
    in_flight); FAIL when current ``acq_per_s`` falls below the
    baseline's / ``factor``. Gating acq/s per in-flight depth keeps
    the async scheduler's overlap win (depth 2 > depth 1 in the
    baseline) from regressing back to synchronous throughput
    unnoticed.

A baseline row with no current counterpart fails loudly (a renamed or
dropped row is a silent gate hole); extra current rows are ignored so
new benchmarks can land before the baseline is regenerated.

  PYTHONPATH=src python -m benchmarks.gate \
      --baseline BENCH_cpu.json --current BENCH_ci.json \
      --multitenant MULTITENANT_ci.ndjson
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple

MtKey = Tuple[int, int, float, int]


def mt_key(rec: dict) -> MtKey:
    """A multitenant record's sweep-cell identity."""
    return (rec["clients"], rec["policy"]["max_batch"],
            rec["policy"]["max_queue_delay_ms"], rec["in_flight"])


def gate_table1(baseline: List[dict], current: List[dict], *,
                factor: float) -> List[str]:
    """Failures: current table1 rows slower than factor x baseline."""
    cur = {r["name"]: r for r in current}
    failures = []
    for base in baseline:
        name = base["name"]
        row = cur.get(name)
        if row is None:
            failures.append(f"table1 row {name!r}: missing from current")
            continue
        if row["t_avg_s"] > factor * base["t_avg_s"]:
            failures.append(
                f"table1 row {name!r}: t_avg_s {row['t_avg_s']:.4f}s > "
                f"{factor:g}x baseline {base['t_avg_s']:.4f}s")
    return failures


def gate_multitenant(baseline: List[dict], current: List[dict], *,
                     factor: float) -> List[str]:
    """Failures: current multitenant cells below baseline / factor."""
    cur: Dict[MtKey, dict] = {mt_key(r): r for r in current}
    failures = []
    for base in baseline:
        key = mt_key(base)
        row = cur.get(key)
        cell = (f"clients={key[0]} max_batch={key[1]} "
                f"delay_ms={key[2]:g} in_flight={key[3]}")
        if row is None:
            failures.append(f"multitenant cell [{cell}]: missing from "
                            f"current")
            continue
        if row["acq_per_s"] < base["acq_per_s"] / factor:
            failures.append(
                f"multitenant cell [{cell}]: acq_per_s "
                f"{row['acq_per_s']:.1f} < baseline "
                f"{base['acq_per_s']:.1f} / {factor:g}")
    return failures


def run_gate(baseline_path: str, *, current_path: Optional[str] = None,
             multitenant_path: Optional[str] = None,
             factor: float = 2.0) -> List[str]:
    """All gate failures for the given artifact files (empty = pass)."""
    with open(baseline_path) as f:
        baseline = json.load(f)
    failures = []
    if current_path is not None:
        with open(current_path) as f:
            current = json.load(f)
        failures += gate_table1(baseline["results"], current["results"],
                                factor=factor)
    mt_base = baseline.get("multitenant", [])
    if multitenant_path is not None and mt_base:
        with open(multitenant_path) as f:
            mt_cur = [json.loads(line) for line in f if line.strip()]
        mt_cur = [r for r in mt_cur if r.get("kind") == "multitenant"]
        failures += gate_multitenant(mt_base, mt_cur, factor=factor)
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Compare CI smoke benchmark rows against the "
                    "checked-in baseline (loose-factor regression gate).")
    ap.add_argument("--baseline", default="BENCH_cpu.json",
                    help="committed reference JSON (table1 results + "
                         "multitenant rows)")
    ap.add_argument("--current", default=None,
                    help="benchmarks.run --json artifact to gate")
    ap.add_argument("--multitenant", default=None,
                    help="benchmarks.multitenant --ndjson artifact to "
                         "gate")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="allowed slowdown factor (default 2.0)")
    args = ap.parse_args()
    if args.current is None and args.multitenant is None:
        ap.error("nothing to gate: pass --current and/or --multitenant")

    failures = run_gate(args.baseline, current_path=args.current,
                        multitenant_path=args.multitenant,
                        factor=args.factor)
    for msg in failures:
        print(f"gate failure: {msg}", file=sys.stderr)
    if not failures:
        print(f"gate ok (factor {args.factor:g}, "
              f"baseline {args.baseline})")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
