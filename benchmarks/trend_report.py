"""HTML benchmark trend report: per-cell sparklines, CI bands, verdicts.

The statistical gate (`benchmarks.gate`) answers "did this commit
regress?"; this module answers the question a binary gate cannot —
"where has this cell been drifting?". Each invocation appends the
current run's per-cell means + bootstrap intervals + gate verdicts to
an NDJSON *history* file (one record per cell per run, carried between
CI runs as a restored artifact) and renders the whole history as a
self-contained HTML page: one row per gated cell with an inline SVG
sparkline of the mean over time inside its CI band, the latest
mean ± CI, the gate verdict badge, the worst-stage % -of-roofline
when the row carries a stamp, and a host-cost diagnostic — the
multitenant row's ``transfer_frac`` (staging + H2D + D2H share of
wall) or the summary row's variance-decomposition split (between-run
vs within-run noise share, which says whether more ``--repeats`` or
longer runs buy precision). No external assets — the page is a
single file CI can upload as an artifact.

  PYTHONPATH=src python -m benchmarks.trend_report \
      --baseline BENCH_cpu.json --current BENCH_ci.json \
      --current BENCH_lowering.json --multitenant MULTITENANT_ci.ndjson \
      --history TREND_history.ndjson --out TREND_report.html
"""

from __future__ import annotations

import argparse
import html
import json
import time
from typing import Dict, List, Optional, Tuple

from benchmarks.gate import (GateRecordError, _gate_cell, mt_key, t1_key)

SPARK_W, SPARK_H, PAD = 240, 42, 4


def worst_roofline(row: dict) -> Optional[Tuple[str, float]]:
    roof = row.get("roofline")
    if not roof:
        return None
    stage = min(roof, key=lambda s: roof[s]["pct_roofline"])
    return stage, roof[stage]["pct_roofline"]


def _ci_of(rec: dict, metric: str, ci_key: str) -> Tuple[float, float,
                                                         float]:
    ci = rec.get(ci_key) or {}
    mean = float(ci.get("mean", rec.get(metric, 0.0)))
    return (mean, float(ci.get("ci_lo", mean)),
            float(ci.get("ci_hi", mean)))


def collect_cells(baseline: dict, current_rows: List[dict],
                  mt_current: List[dict], *,
                  factor: float) -> List[dict]:
    """One record per gated cell: identity, latest stats, verdict."""
    cells: List[dict] = []

    cur: Dict = {}
    for rec in current_rows:
        try:
            cur[t1_key(rec)] = rec
        except GateRecordError:
            continue
    for base in baseline.get("results", []):
        try:
            key = t1_key(base)
        except GateRecordError:
            continue
        row = cur.get(key)
        cell = {"family": "table1", "cell": f"{key[0]} dev={key[1]}"}
        if row is None:
            cell.update(verdict="missing", reason="no current row",
                        mean=None, ci_lo=None, ci_hi=None, roof=None)
        else:
            try:
                dec, _ = _gate_cell(base, row, metric="t_avg_s",
                                    ci_key="ci", family="table1",
                                    factor=factor,
                                    higher_is_better=False)
                verdict, reason = ("pass" if dec.ok else "FAIL",
                                   dec.reason)
            except GateRecordError as e:
                verdict, reason = "FAIL", str(e)
            mean, lo, hi = _ci_of(row, "t_avg_s", "ci")
            cell.update(verdict=verdict, reason=reason, mean=mean,
                        ci_lo=lo, ci_hi=hi,
                        roof=worst_roofline(row) or worst_roofline(base),
                        variance=row.get("variance"))
        cells.append(cell)

    mt_cur: Dict = {}
    for rec in mt_current:
        try:
            mt_cur[mt_key(rec)] = rec
        except GateRecordError:
            continue
    for base in baseline.get("multitenant", []):
        try:
            key = mt_key(base)
        except GateRecordError:
            continue
        row = mt_cur.get(key)
        cell = {"family": "multitenant",
                "cell": (f"clients={key[0]} max_batch={key[1]} "
                         f"delay={key[2]:g}ms in_flight={key[3]} "
                         f"profile={key[4]} drain={key[5]}")}
        if row is None:
            cell.update(verdict="missing", reason="no current row",
                        mean=None, ci_lo=None, ci_hi=None, roof=None)
        else:
            try:
                dec, _ = _gate_cell(base, row, metric="acq_per_s",
                                    ci_key="acq_per_s_ci",
                                    family="multitenant", factor=factor,
                                    higher_is_better=True)
                verdict, reason = ("pass" if dec.ok else "FAIL",
                                   dec.reason)
            except GateRecordError as e:
                verdict, reason = "FAIL", str(e)
            mean, lo, hi = _ci_of(row, "acq_per_s", "acq_per_s_ci")
            cell.update(verdict=verdict, reason=reason, mean=mean,
                        ci_lo=lo, ci_hi=hi, roof=None,
                        transfer_frac=row.get("transfer_frac"))
        cells.append(cell)
    return cells


def _diag(cell: dict) -> str:
    """The cell's host-cost diagnostic: transfer share for multitenant
    rows (how much wall the staging/H2D/D2H copies cost), the
    variance-decomposition split for summary rows that carry one (is
    the noise between-run — more --repeats — or within-run)."""
    xfer = cell.get("transfer_frac")
    if xfer is not None:
        return f"xfer {100 * xfer:.0f}%"
    var = cell.get("variance")
    if var:
        return (f"between-run {100 * var['between_share']:.0f}% / "
                f"within {100 * var['within_share']:.0f}%")
    return "—"


def append_history(path: str, cells: List[dict], *, ts: float,
                   label: str) -> List[dict]:
    """Append this run's cells to the NDJSON history; returns the full
    history (old + new) for rendering."""
    history: List[dict] = []
    try:
        with open(path) as f:
            history = [json.loads(line) for line in f if line.strip()]
    except FileNotFoundError:
        pass
    fresh = [{"ts": ts, "label": label, "family": c["family"],
              "cell": c["cell"], "mean": c["mean"],
              "ci_lo": c["ci_lo"], "ci_hi": c["ci_hi"],
              "verdict": c["verdict"]} for c in cells]
    with open(path, "a") as f:
        for rec in fresh:
            f.write(json.dumps(rec) + "\n")
    return history + fresh


_VERDICT_COLOR = {"pass": "#2da44e", "FAIL": "#cf222e",
                  "missing": "#9a6700"}


def sparkline(points: List[dict]) -> str:
    """Inline SVG: mean polyline inside its CI band, one x per run."""
    pts = [p for p in points if p.get("mean") is not None]
    if not pts:
        return "<svg width='%d' height='%d'></svg>" % (SPARK_W, SPARK_H)
    los = [p.get("ci_lo", p["mean"]) or p["mean"] for p in pts]
    his = [p.get("ci_hi", p["mean"]) or p["mean"] for p in pts]
    lo, hi = min(los), max(his)
    span = (hi - lo) or max(abs(hi), 1e-12)

    def x(i: int) -> float:
        n = max(len(pts) - 1, 1)
        return PAD + (SPARK_W - 2 * PAD) * i / n

    def y(v: float) -> float:
        return PAD + (SPARK_H - 2 * PAD) * (1.0 - (v - lo) / span)

    band = " ".join(f"{x(i):.1f},{y(h):.1f}"
                    for i, h in enumerate(his))
    band += " " + " ".join(f"{x(i):.1f},{y(lo_):.1f}" for i, lo_ in
                           reversed(list(enumerate(los))))
    line = " ".join(f"{x(i):.1f},{y(p['mean']):.1f}"
                    for i, p in enumerate(pts))
    last = pts[-1]
    color = _VERDICT_COLOR.get(last.get("verdict", "pass"), "#57606a")
    dot = (f"<circle cx='{x(len(pts) - 1):.1f}' "
           f"cy='{y(last['mean']):.1f}' r='2.5' fill='{color}'/>")
    return (f"<svg width='{SPARK_W}' height='{SPARK_H}' "
            f"viewBox='0 0 {SPARK_W} {SPARK_H}'>"
            f"<polygon points='{band}' fill='#0969da22' stroke='none'/>"
            f"<polyline points='{line}' fill='none' stroke='#0969da' "
            f"stroke-width='1.2'/>{dot}</svg>")


def _fmt(cell: dict) -> str:
    if cell["mean"] is None:
        return "—"
    unit = "ms" if cell["family"] == "table1" else "acq/s"
    scale = 1e3 if cell["family"] == "table1" else 1.0
    return (f"{cell['mean'] * scale:.2f} "
            f"[{cell['ci_lo'] * scale:.2f}, "
            f"{cell['ci_hi'] * scale:.2f}] {unit}")


def render_html(cells: List[dict], history: List[dict], *,
                factor: float, label: str) -> str:
    by_cell: Dict[Tuple[str, str], List[dict]] = {}
    for rec in history:
        by_cell.setdefault((rec["family"], rec["cell"]), []).append(rec)
    for series in by_cell.values():
        series.sort(key=lambda r: r.get("ts", 0.0))

    rows = []
    for cell in cells:
        series = by_cell.get((cell["family"], cell["cell"]), [])
        color = _VERDICT_COLOR.get(cell["verdict"], "#57606a")
        badge = (f"<span class='badge' style='background:{color}'>"
                 f"{html.escape(cell['verdict'])}</span>")
        roof = cell.get("roof")
        roof_txt = (f"{html.escape(roof[0])} {100 * roof[1]:.0f}%"
                    if roof else "—")
        rows.append(
            "<tr>"
            f"<td class='mono'>{html.escape(cell['cell'])}</td>"
            f"<td>{sparkline(series)}</td>"
            f"<td class='mono'>{html.escape(_fmt(cell))}</td>"
            f"<td>{badge}</td>"
            f"<td class='mono'>{roof_txt}</td>"
            f"<td class='mono'>{html.escape(_diag(cell))}</td>"
            f"<td class='reason'>{html.escape(cell['reason'])}</td>"
            "</tr>")

    n_fail = sum(1 for c in cells if c["verdict"] == "FAIL")
    status = (f"{n_fail} FAILING" if n_fail
              else f"all {len(cells)} cells pass")
    return f"""<!DOCTYPE html>
<html><head><meta charset="utf-8">
<title>benchmark trends — {html.escape(label)}</title>
<style>
body {{ font: 14px/1.5 -apple-system, 'Segoe UI', sans-serif;
       margin: 2em; color: #1f2328; }}
table {{ border-collapse: collapse; width: 100%; }}
th, td {{ border-bottom: 1px solid #d0d7de; padding: 4px 10px;
          text-align: left; vertical-align: middle; }}
th {{ background: #f6f8fa; }}
.mono {{ font-family: ui-monospace, monospace; font-size: 12px; }}
.reason {{ font-size: 12px; color: #57606a; max-width: 28em; }}
.badge {{ color: #fff; border-radius: 10px; padding: 1px 8px;
          font-size: 12px; }}
</style></head><body>
<h1>Benchmark trends</h1>
<p>run <b>{html.escape(label)}</b> · gate factor {factor:g}
(CI-exclusion rule) · {status} · sparkline = mean over runs inside its
bootstrap CI band (latest dot colored by verdict; time-like cells
trend down-is-good, throughput cells up-is-good)</p>
<table>
<tr><th>cell</th><th>trend</th><th>latest mean [CI]</th>
<th>verdict</th><th>worst-stage roof</th>
<th>transfer / noise split</th><th>gate reason</th></tr>
{''.join(rows)}
</table></body></html>
"""


def main() -> int:
    ap = argparse.ArgumentParser(
        description="Append the current benchmark run to the trend "
                    "history and render the HTML trend report.")
    ap.add_argument("--baseline", default="BENCH_cpu.json")
    ap.add_argument("--current", action="append", default=None,
                    help="benchmarks.run --json artifact (repeatable)")
    ap.add_argument("--multitenant", action="append", default=None,
                    help="benchmarks.multitenant --ndjson artifact "
                         "(repeatable)")
    ap.add_argument("--history", default="TREND_history.ndjson",
                    help="NDJSON trend history (appended; restore it "
                         "across CI runs to accumulate the trend)")
    ap.add_argument("--out", default="TREND_report.html")
    ap.add_argument("--factor", type=float, default=2.0)
    ap.add_argument("--label", default=None,
                    help="run label in the history/page (default: "
                         "UTC timestamp)")
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    current_rows: List[dict] = []
    for path in args.current or []:
        with open(path) as f:
            current_rows += json.load(f)["results"]
    mt_current: List[dict] = []
    for path in args.multitenant or []:
        with open(path) as f:
            mt_current += [json.loads(line) for line in f
                           if line.strip()]
    mt_current = [r for r in mt_current
                  if r.get("kind") == "multitenant"]

    ts = time.time()
    label = args.label or time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                        time.gmtime(ts))
    cells = collect_cells(baseline, current_rows, mt_current,
                          factor=args.factor)
    history = append_history(args.history, cells, ts=ts, label=label)
    page = render_html(cells, history, factor=args.factor, label=label)
    with open(args.out, "w") as f:
        f.write(page)
    print(f"{args.out}: {len(cells)} cells, "
          f"{len(history)} history records")
    for row in current_rows:
        var = row.get("variance")
        if var:
            print(f"variance {row.get('name', '?')}: "
                  f"between-run {100 * var['between_share']:.0f}% / "
                  f"within-run {100 * var['within_share']:.0f}% "
                  f"(n_runs={var['n_runs']}, "
                  f"mean_iters={var['mean_iters']:g}) — "
                  f"{'more --repeats' if var['between_share'] >= 0.5 else 'longer runs'}"
                  f" reduce this cell's noise fastest")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
