"""Beyond-paper: the paper's V1/V2/V3 taxonomy at LM scale.

Lowers granite-moe-3b train_4k (1M tokens/step, 256 chips) once per MoE
dispatch variant and reports the roofline terms + gather census — the
LM-scale analogue of the paper's Table II (results table in
EXPERIMENTS.md §Perf).

  PYTHONPATH=src python -m benchmarks.moe_variants_dryrun
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

from repro.core.config import Variant
from repro.launch import cells as cells_lib
from repro.launch import hlo_cost
from repro.launch.hlo_analysis import HBM_BW, PEAK_FLOPS, ICI_BW
from repro.launch.mesh import make_production_mesh

GATHER_RATE = 0.7e9  # elem/s, calibrated in benchmarks/table2_portability


def main():
    mesh = make_production_mesh()
    print(f"{'variant':10s} {'t_comp':>8s} {'t_mem':>9s} {'t_coll':>9s} "
          f"{'t_gather':>9s} {'gather_elems':>13s}")
    for v in [Variant.DYNAMIC, Variant.CNN, Variant.SPARSE]:
        cell = cells_lib.build_cell(
            "granite-moe-3b-a800m", "train_4k", mesh,
            overrides={"moe_variant": v})
        compiled = cells_lib.lower_cell(cell, mesh).compile()
        c = hlo_cost.analyze(compiled.as_text())
        print(f"{v.value:10s} {c.flops / PEAK_FLOPS:8.2f} "
              f"{c.bytes_min / HBM_BW:9.2f} {c.coll_bytes / ICI_BW:9.2f} "
              f"{c.gather_elems / GATHER_RATE:9.2f} {c.gather_elems:13.3g}")


if __name__ == "__main__":
    main()
