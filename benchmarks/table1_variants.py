"""Paper Table I: all three implementation variants x all three modalities.

End-to-end RF-to-image timing (every stage inside one forward pass),
reporting T_avg, FPS, MB/s, modeled J/run, peak memory — the paper's exact
column set. CPU stand-in for the RTX 5090 rows; relative variant structure
(dynamic fastest on gather-friendly hardware, CNN heavier but portable,
sparse in between with higher memory) is the validated claim.

Every row is measured through an explicit `PipelinePlan` and the resolved
plan is stamped into the BenchResult, so each number is attributable to an
exact (backend, variant, exec_map, policy, stage_lowerings, fusion,
precision) decision. `variant="auto"` + a policy runs a single
planner-resolved row instead of the full sweep; ``lowering="pallas"``
pins the beamform stage to its Pallas kernel; ``fusion="fused"`` routes
the demod+beamform+head span through the fused megakernel (``"both"``
sweeps unfused and fused per cell); ``precision`` selects the
mixed-precision contract tier.

``run`` returns ``(results, skipped)``: every requested
(variant, modality, lowering, fusion) cell is either measured or
accounted for as a ``(cell_name, reason)`` pair — a sweep's coverage is
auditable from its output alone, never silently narrowed.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

import jax.numpy as jnp

import jax

from repro.bench import BenchResult, bench_callable, bench_stages
from repro.core import (Modality, UltrasoundPipeline, Variant,
                        available_lowerings, plan_pipeline)
from repro.core import lowering as lowering_lib
from repro.data import synth_rf

from benchmarks.common import bench_config


MODALITIES = [Modality.DOPPLER, Modality.POWER_DOPPLER, Modality.BMODE]
VARIANTS = [Variant.DYNAMIC, Variant.CNN, Variant.SPARSE]


def run(paper_scale: bool = False, runs: int = 5,
        repeats: int = 1,
        deadline_s: float = None,
        stage_breakdown: bool = False,
        roofline: bool = False,
        policy: str = "fixed",
        variant: Optional[Variant] = None,
        lowering: Optional[str] = None,
        fusion: str = "none",
        precision: str = "f32") -> Tuple[List[BenchResult],
                                         List[Tuple[str, str]]]:
    base = bench_config(paper_scale)
    rf = jnp.asarray(synth_rf(base, seed=0))
    backend = jax.default_backend()
    variants = VARIANTS if variant is None else [variant]
    fusions = ["none", "fused"] if fusion == "both" else [fusion]
    results: List[BenchResult] = []
    skipped: List[Tuple[str, str]] = []
    for v in variants:
        for modality in MODALITIES:
            for fus in fusions:
                cfg = base.with_(variant=v, modality=modality,
                                 fusion=fus, precision=precision)
                cell = (f"table1/{cfg.name}/{v.value}/"
                        f"{lowering or 'auto'}/{fus}@{precision}")
                if lowering is not None and v.concrete and \
                        lowering not in available_lowerings(
                            cfg.with_(fusion="none", precision="f32"),
                            "beamform", backend):
                    # Registered AND available (capability predicates can
                    # reject a backend/geometry): absent cells are
                    # accounted for, never crashed into. AUTO pins
                    # directly — the planner restricts its variant search
                    # to pin-honoring candidates.
                    skipped.append((cell, (
                        f"no {lowering!r} beamform lowering for variant "
                        f"{v.value!r} on backend {backend!r}")))
                    continue
                if fus == "fused":
                    try:
                        lowering_lib.resolve_fused(
                            cfg if v.concrete
                            else cfg.with_(variant=Variant.DYNAMIC),
                            backend)
                    except ValueError as e:
                        skipped.append((cell, str(e)))
                        continue
                elif precision != "f32":
                    # The xla references are f32-only, so an unfused
                    # reduced-precision plan cannot cover every stage.
                    skipped.append((cell, (
                        f"unfused precision={precision!r} has no lowering "
                        "for every stage (the xla references compute in "
                        "f32 only; use fusion='fused')")))
                    continue
                if lowering is not None:
                    cfg = cfg.with_(stage_lowerings={"beamform": lowering})
                plan = plan_pipeline(cfg, policy=policy)
                pipe = UltrasoundPipeline(cfg, plan=plan)
                cfg = pipe.cfg             # plan-resolved (AUTO -> concrete)
                low = dict(plan.stage_lowerings)["beamform"]
                name = f"table1/{cfg.name}/{cfg.variant.value}/{low}"
                if fus != "none" or precision != "f32":
                    name += f"/{fus}@{precision}"
                res = bench_callable(
                    name, None, (pipe.consts, rf),
                    input_bytes=cfg.input_bytes, runs=runs,
                    repeats=repeats, deadline_s=deadline_s,
                    jitted=pipe.jitted, plan=plan)
                if stage_breakdown:
                    res.stage_breakdown = bench_stages(
                        cfg, rf, runs=min(runs, 3))
                if roofline and stage_breakdown and fus == "none":
                    # Fused spans time as one unit; the per-stage HLO
                    # cost split does not apply to them.
                    from benchmarks.roofline_report import attach_roofline
                    attach_roofline(res, cfg)
                results.append(res)
    return results, skipped


if __name__ == "__main__":
    rows, skipped_cells = run()
    for r in rows:
        print(r.csv())
    for cell, reason in skipped_cells:
        print(f"{cell},skipped,reason={reason}")
