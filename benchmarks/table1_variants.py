"""Paper Table I: all three implementation variants x all three modalities.

End-to-end RF-to-image timing (every stage inside one forward pass),
reporting T_avg, FPS, MB/s, modeled J/run, peak memory — the paper's exact
column set. CPU stand-in for the RTX 5090 rows; relative variant structure
(dynamic fastest on gather-friendly hardware, CNN heavier but portable,
sparse in between with higher memory) is the validated claim.

Every row is measured through an explicit `PipelinePlan` and the resolved
plan is stamped into the BenchResult, so each number is attributable to an
exact (backend, variant, exec_map, policy, stage_lowerings) decision.
`variant="auto"` + a policy runs a single planner-resolved row instead of
the full sweep; ``lowering="pallas"`` pins the beamform stage to its
Pallas kernel, sweeping only the variants that register one (the
variant x lowering matrix, end to end).
"""

from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp

import jax

from repro.bench import BenchResult, bench_callable, bench_stages
from repro.core import (Modality, UltrasoundPipeline, Variant,
                        available_lowerings, plan_pipeline)
from repro.data import synth_rf

from benchmarks.common import bench_config


MODALITIES = [Modality.DOPPLER, Modality.POWER_DOPPLER, Modality.BMODE]
VARIANTS = [Variant.DYNAMIC, Variant.CNN, Variant.SPARSE]


def run(paper_scale: bool = False, runs: int = 5,
        deadline_s: float = None,
        stage_breakdown: bool = False,
        policy: str = "fixed",
        variant: Optional[Variant] = None,
        lowering: Optional[str] = None) -> List[BenchResult]:
    base = bench_config(paper_scale)
    rf = jnp.asarray(synth_rf(base, seed=0))
    variants = VARIANTS if variant is None else [variant]
    results = []
    for v in variants:
        for modality in MODALITIES:
            cfg = base.with_(variant=v, modality=modality)
            if lowering is not None:
                # Registered AND available (capability predicates can
                # reject a backend/geometry): absent cells are skipped,
                # never crashed into. AUTO pins directly — the planner
                # restricts its variant search to pin-honoring candidates.
                if (v.concrete and lowering not in available_lowerings(
                        cfg, "beamform", jax.default_backend())):
                    continue     # no such cell in the variant x lowering grid
                cfg = cfg.with_(stage_lowerings={"beamform": lowering})
            plan = plan_pipeline(cfg, policy=policy)
            pipe = UltrasoundPipeline(cfg, plan=plan)
            cfg = pipe.cfg                 # plan-resolved (AUTO -> concrete)
            low = dict(plan.stage_lowerings)["beamform"]
            res = bench_callable(
                f"table1/{cfg.name}/{cfg.variant.value}/{low}",
                None, (pipe.consts, rf),
                input_bytes=cfg.input_bytes, runs=runs,
                deadline_s=deadline_s,
                jitted=pipe.jitted, plan=plan)
            if stage_breakdown:
                res.stage_breakdown = bench_stages(
                    cfg, rf, runs=min(runs, 3))
            results.append(res)
    return results


if __name__ == "__main__":
    for r in run():
        print(r.csv())
