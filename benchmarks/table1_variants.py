"""Paper Table I: all three implementation variants x all three modalities.

End-to-end RF-to-image timing (every stage inside one forward pass),
reporting T_avg, FPS, MB/s, modeled J/run, peak memory — the paper's exact
column set. CPU stand-in for the RTX 5090 rows; relative variant structure
(dynamic fastest on gather-friendly hardware, CNN heavier but portable,
sparse in between with higher memory) is the validated claim.
"""

from __future__ import annotations

from typing import List

import jax.numpy as jnp

from repro.bench import BenchResult, bench_callable, bench_stages
from repro.core import (Modality, UltrasoundPipeline, Variant)
from repro.data import synth_rf

from benchmarks.common import bench_config


MODALITIES = [Modality.DOPPLER, Modality.POWER_DOPPLER, Modality.BMODE]
VARIANTS = [Variant.DYNAMIC, Variant.CNN, Variant.SPARSE]


def run(paper_scale: bool = False, runs: int = 5,
        deadline_s: float = None,
        stage_breakdown: bool = False) -> List[BenchResult]:
    base = bench_config(paper_scale)
    rf = jnp.asarray(synth_rf(base, seed=0))
    results = []
    for variant in VARIANTS:
        for modality in MODALITIES:
            cfg = base.with_(variant=variant, modality=modality)
            pipe = UltrasoundPipeline(cfg)     # init excluded from timing
            res = bench_callable(
                f"table1/{cfg.name}/{variant.value}",
                None, (pipe.consts, rf),
                input_bytes=cfg.input_bytes, runs=runs,
                deadline_s=deadline_s,
                jitted=pipe._fn)
            if stage_breakdown:
                res.stage_breakdown = bench_stages(
                    cfg, rf, runs=min(runs, 3))
            results.append(res)
    return results


if __name__ == "__main__":
    for r in run():
        print(r.csv())
