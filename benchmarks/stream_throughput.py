"""Streaming throughput: the batched stage-graph engine under queue pressure.

Beyond the paper's one-acquisition-per-call Table I: stream RF batches
through `serve_ultrasound_stream` with `depth` batches in flight and report
*sustained* MB/s and effective FPS for increasing batch sizes, plus the
batch-completion latency distribution (p50/p95/p99, jitter, deadline-miss
rate). Batch 1 is the paper's execution model measured through the same
loop; larger batches amortize dispatch and host->device overhead, so
sustained MB/s should be monotone non-decreasing in batch on every backend.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core import Variant
from repro.launch.serve import serve_ultrasound_stream

from benchmarks.common import stream_config

BATCH_SIZES = [1, 4]


def run(paper_scale: bool = False, fast: bool = False,
        deadline_ms: float = 100.0, policy: Optional[str] = None,
        variant: Optional[Variant] = None, cfg=None,
        lowering: Optional[str] = None,
        fusion: str = "none", precision: str = "f32"
        ) -> Tuple[List[str], List[dict]]:
    """Returns (csv lines, json-ready records), one per batch size.

    ``cfg`` overrides the streaming geometry (tests pass tiny configs
    to exercise the emitter cheaply); default is `stream_config`.
    ``fusion``/``precision`` ride the config straight into the planner
    — a fused or reduced-precision stream that cannot plan fails
    loudly here (the scheduler must never silently fall back to a
    different program than the one requested).
    """
    # Default: DYNAMIC, the fast variant on the gather-friendly CPU
    # stand-in (paper GPU rows) — stream the heaviest realistic path,
    # B-mode. `variant=Variant.AUTO` + a policy delegates to the planner;
    # the resolved plan rides along in every record.
    if cfg is None:
        cfg = stream_config(paper_scale).with_(variant=Variant.DYNAMIC)
    if variant is not None:
        cfg = cfg.with_(variant=variant)   # explicit ask beats cfg's own
    cfg = cfg.with_(fusion=fusion, precision=precision)
    if lowering is not None:
        # Concrete variants without the lowering (registered AND
        # available on this backend) stream the xla reference instead of
        # crashing the sweep (table1 skips the same cells); AUTO pins
        # directly — the planner restricts its variant search to
        # pin-honoring candidates.
        import jax
        from repro.core import available_lowerings
        if (not cfg.variant.concrete or
                lowering in available_lowerings(cfg, "beamform",
                                                jax.default_backend())):
            cfg = cfg.with_(stage_lowerings={"beamform": lowering})
    n_batches = 8 if fast else 24
    deadline_s = deadline_ms / 1e3

    lines, records = [], []
    for batch in BATCH_SIZES:
        # batch=1 depth=1 IS the paper's synchronous single-frame model,
        # measured through the same loop; batched runs keep 2 in flight.
        stats = serve_ultrasound_stream(
            cfg, batch=batch, n_batches=n_batches,
            depth=1 if batch == 1 else 2,
            deadline_s=deadline_s, policy=policy)
        lat = stats["latency"]
        t_acq_us = 1e6 / stats["acq_per_s"]
        lines.append(
            f"{stats['name']},{t_acq_us:.1f},"
            f"mbps={stats['sustained_mbps']:.2f};fps={stats['fps']:.2f};"
            f"p50_ms={lat.p50_s * 1e3:.2f};p95_ms={lat.p95_s * 1e3:.2f};"
            f"p99_ms={lat.p99_s * 1e3:.2f};"
            f"jitter_ms={lat.jitter_s * 1e3:.2f};"
            f"miss_rate={lat.miss_rate:.3f}")
        rec = dict(stats)
        rec["kind"] = "stream"
        rec["latency"] = lat.json_dict()
        records.append(rec)
    return lines, records


if __name__ == "__main__":
    for line in run()[0]:
        print(line)
