"""Paper Table II: GPU-vs-TPU portability of the variants.

No TPU exists in this container, so the TPU half is *predicted* from the
lowered HLO of each variant with a three-term device model:

    T_tpu = max( flops / 197e12 ,  bytes_min / 819e9 ,  gathers / G )

where G ~ 1e9 gathered elements/s models the TPU's scalar/irregular-access
path. G is calibrated once against the paper's own Table II (dynamic
variant: 1.3e8 gathered elements per pass / 0.181 s ≈ 0.7e9 elem/s) and
then applied uniformly — the *prediction* is the CNN:dynamic ratio, which
the paper measured as ~17x. The CNN variant executes zero gather ops (all
dots), so its prediction comes from the MXU/HBM terms alone.

Also reports measured CPU wall-clock (the gather-friendly stand-in, like
the paper's GPU rows) for the same code.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from repro.bench import BenchResult, bench_callable
from repro.core import Modality, UltrasoundPipeline, Variant
from repro.data import synth_rf
from repro.launch import hlo_cost

from benchmarks.common import bench_config

GATHER_RATE = 0.7e9       # elements/s — calibrated vs paper Table II
PEAK_FLOPS = 197e12
HBM_BW = 819e9


def predicted_tpu_time(pipe: UltrasoundPipeline, rf) -> dict:
    compiled = pipe.jitted.lower(pipe.consts, rf).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    t_gather = cost.gather_elems / GATHER_RATE
    t = max(cost.flops / PEAK_FLOPS, cost.bytes_min / HBM_BW, t_gather)
    return {
        "t_pred_s": t,
        "t_gather_s": t_gather,
        "gather_elems": cost.gather_elems,
        "flops": cost.flops,
        "bytes_min": cost.bytes_min,
    }


def run(paper_scale: bool = False, runs: int = 3) -> List[str]:
    base = bench_config(paper_scale)
    rf = jnp.asarray(synth_rf(base, seed=0))
    lines = []
    for variant in [Variant.DYNAMIC, Variant.CNN]:
        for modality in [Modality.DOPPLER, Modality.POWER_DOPPLER,
                         Modality.BMODE]:
            cfg = base.with_(variant=variant, modality=modality)
            pipe = UltrasoundPipeline(cfg)
            cpu = bench_callable(
                f"table2/{cfg.name}/{variant.value}/cpu",
                None, (pipe.consts, rf),
                input_bytes=cfg.input_bytes, runs=runs, jitted=pipe.jitted,
                plan=pipe.plan)
            pred = predicted_tpu_time(pipe, rf)
            mbps_tpu = cfg.input_bytes / (pred["t_pred_s"] * 1e6)
            lines.append(
                f"table2/{cfg.name}/{variant.value},"
                f"{cpu.t_avg_s * 1e6:.1f},"
                f"cpu_mbps={cpu.mbps:.2f};tpu_pred_mbps={mbps_tpu:.1f};"
                f"gather_elems={pred['gather_elems']:.3g};"
                f"tpu_pred_fps={1.0 / pred['t_pred_s']:.1f}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
