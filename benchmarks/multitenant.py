"""Multi-tenant serving benchmark: clients x batch policy sweep.

Beyond the single-probe streaming rows: N open-loop tenants (alternating
B-mode / Color-Doppler configs at staggered frame rates — the mixed
traffic a real scanner fleet produces) contend for one device through
the dynamic-batching scheduler (`repro.launch.scheduler`), and each
(clients, policy) cell reports aggregate sustained MB/s / FPS plus the
distributions throughput claims hide: per-stream completion latency
p50/p95/p99, queue delay, batch occupancy / fill rate, and the
per-stream deadline-miss rate.

The policy axis is the Jouppi trade: ``max_batch=1`` is
dispatch-on-arrival (best latency, no amortization), larger
``max_batch`` with a ``max_queue_delay_ms`` bound buys occupancy with
bounded waiting. ``--in-flight`` adds the pipelining axis: depth 1 is
the synchronous launch-block-retire loop, depth >= 2 overlaps host
coalescing with device execution (the record's ``device_busy_frac`` /
``overlap_frac`` columns show where the win comes from). Determinism
is not on any axis — the scheduler oracle test pins every cell's
outputs to the per-frame monolithic reference bit-for-bit at every
depth.

All cells share one `repro.core.aot.WarmPool`: each distinct
(config-group, max_batch) program AOT-compiles once for the whole
sweep, warm cost lands in the first cell that needs it (``warmup_s``)
and later cells stamp ``warm_source="pool"``.

``--drain`` adds the host-transfer axis: ``async`` (default) retires
batches through `copy_to_host_async` staging-ring drains so D2H rides
off the admit loop's critical path; ``block`` is the legacy
detect-block-harvest retirement. Both are bit-identical — the drain
mode only moves *when* host copies happen — so a block/async cell pair
on the same geometry isolates the transfer-overlap win
(``transfer_frac`` / ``acq_per_s``) with no confound.

``--profile`` adds the load axis (repro.data.traces): ``steady`` is
the historical uniform open-loop schedule (reproduced bit-identically —
same arrivals, same trace_sha256), ``burst`` / ``diurnal_ramp`` /
``churn`` / ``adversarial`` generate seeded arrival traces and replay
them through `make_trace_streams`; ``--trace PATH`` replays a recorded
repro-trace-v1 file instead. Every record stamps ``load_profile`` and
``trace_sha256`` — the profile is part of the gate's cell identity, so
a burst row never gates against a steady baseline.

NDJSON rows are ``{"kind": "multitenant", ...}`` — schema enforced by
`repro.bench.schema` (CI validates the smoke artifact with exactly that
module):

  PYTHONPATH=src python -m benchmarks.multitenant --fast \
      --profile steady,burst --ndjson MT.ndjson
  PYTHONPATH=src python -m repro.bench.schema MT.ndjson \
      --require-kind multitenant
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence, Tuple

# (max_batch, max_queue_delay_ms) cells: dispatch-on-arrival baseline,
# then two coalescing depths at a realistic wait bound.
DEFAULT_POLICIES = ((1, 0.0), (4, 5.0), (8, 10.0))
DEFAULT_CLIENTS = (1, 2, 4)


def run(client_counts: Sequence[int] = DEFAULT_CLIENTS,
        policies: Sequence[Tuple[int, float]] = DEFAULT_POLICIES, *,
        in_flights: Sequence[int] = (2,), fast: bool = False,
        repeats: int = 1, drains: Sequence[str] = ("async",),
        deadline_ms: Optional[float] = 100.0, base_fps: float = 120.0,
        plan_policy: Optional[str] = None, cfg_bmode=None,
        cfg_doppler=None, variant=None,
        profiles: Sequence[str] = ("steady",),
        trace_path: Optional[str] = None
        ) -> Tuple[List[str], List[dict]]:
    """Returns (csv lines, NDJSON-ready records), one per sweep cell.

    ``cfg_bmode`` / ``cfg_doppler`` override the tenant geometries
    (tests and the CI smoke pass tiny configs); the default is the
    streaming benchmark geometry with the Doppler head swapped in for
    odd tenants. ``in_flights`` sweeps the dispatch-pipelining depth.
    ``base_fps`` sets the fastest tenant's open-loop arrival rate —
    raise it far above the service rate to measure the device-bound
    throughput ceiling (where the in-flight overlap win is visible in
    ``acq_per_s`` rather than only in ``device_busy_frac``).

    ``repeats`` serves each cell's window that many times (the shared
    `WarmPool` means only the first window anywhere pays AOT cost) and
    replaces the record's degenerate ``acq_per_s_ci`` /
    ``device_busy_frac_ci`` / ``overlap_frac_ci`` with two-level
    bootstrap CIs over the per-window values — the intervals the
    statistical regression gate compares. The point metrics then
    report the across-window means; the distribution blocks (latency,
    occupancy) stay those of the last window.

    ``drains`` sweeps the host-transfer retirement mode
    (``async`` / ``block``, part of the record name and the gate's
    cell identity); outputs are bit-identical across the axis.

    ``profiles`` sweeps load scenarios (`repro.data.traces.PROFILES`):
    ``steady`` drives the historical `make_mixed_streams` uniform
    schedule directly (bit-identical arrivals and trace_sha256 to the
    pre-profile benchmark); other profiles generate a seeded trace per
    (profile, client count) and replay it through `make_trace_streams`.
    ``trace_path`` replays one recorded repro-trace-v1 file instead —
    the trace then fixes the tenant count and ``client_counts`` is
    ignored.
    """
    from benchmarks.common import stream_config
    from repro.bench.stats import bootstrap_ci
    from repro.core import Modality, Variant
    from repro.core.aot import WarmPool
    from repro.data.traces import PROFILES, generate_trace, load_trace
    from repro.launch.scheduler import (BatchPolicy, make_mixed_streams,
                                        make_trace_streams,
                                        serve_multitenant)

    assert repeats >= 1, repeats
    for d in drains:
        if d not in ("async", "block"):
            raise ValueError(f"unknown drain mode {d!r} "
                             f"(expected 'async' or 'block')")
    for p in profiles:
        if p not in PROFILES:
            raise ValueError(f"unknown profile {p!r} "
                             f"(expected one of {PROFILES})")

    v = variant if variant is not None else Variant.DYNAMIC
    if cfg_bmode is None:
        cfg_bmode = stream_config(False).with_(variant=v)
    if cfg_doppler is None:
        cfg_doppler = cfg_bmode.with_(modality=Modality.DOPPLER)
    n_frames = 8 if fast else 24

    replay = None
    if trace_path is not None:
        replay = load_trace(trace_path)
        client_counts = (len(replay.streams),)
        profiles = (replay.profile or "trace",)

    pool = WarmPool()
    lines, records = [], []
    for n in client_counts:
        for profile in profiles:
            if replay is not None:
                streams = make_trace_streams(
                    replay, cfg_bmode, cfg_doppler,
                    deadline_ms=deadline_ms)
            elif profile == "steady":
                # The historical uniform path, untouched: steady cells
                # must reproduce the pre-profile benchmark exactly.
                streams = make_mixed_streams(n, cfg_bmode, cfg_doppler,
                                             base_fps=base_fps,
                                             n_frames=n_frames,
                                             deadline_ms=deadline_ms)
            else:
                trace = generate_trace(profile, n_streams=n,
                                       n_frames=n_frames,
                                       base_fps=base_fps, seed=0)
                streams = make_trace_streams(
                    trace, cfg_bmode, cfg_doppler,
                    deadline_ms=deadline_ms)
            for max_batch, delay_ms in policies:
                for in_flight in in_flights:
                    for drain in drains:
                        windows = [serve_multitenant(
                            streams,
                            policy=BatchPolicy(max_batch, delay_ms),
                            in_flight=in_flight, drain=drain,
                            plan_policy=plan_policy,
                            pool=pool, load_profile=profile)
                            for _ in range(repeats)]
                        stats = windows[-1]
                        if repeats > 1:
                            for metric in ("acq_per_s",
                                           "device_busy_frac",
                                           "overlap_frac"):
                                ci = bootstrap_ci(
                                    [w[metric] for w in windows])
                                stats[metric] = ci.mean
                                stats[metric + "_ci"] = ci.json_dict()
                        rec = {"kind": "multitenant", **stats}
                        records.append(rec)
                        lat, occ = stats["latency"], stats["occupancy"]
                        worst_p95 = max(
                            s["latency"]["p95_s"]
                            for s in stats["per_stream"].values()
                            if s["latency"] is not None)
                        lines.append(
                            f"{stats['name']},"
                            f"{1e6 / stats['acq_per_s']:.1f},"
                            f"clients={n};profile={profile};"
                            f"max_batch={max_batch};"
                            f"delay_ms={delay_ms:g};"
                            f"in_flight={in_flight};drain={drain};"
                            f"mbps={stats['sustained_mbps']:.2f};"
                            f"fps={stats['fps']:.2f};"
                            f"p50_ms={lat['p50_s'] * 1e3:.2f};"
                            f"worst_stream_p95_ms={worst_p95 * 1e3:.2f};"
                            f"fill={occ['mean_fill']:.2f};"
                            f"busy={stats['device_busy_frac']:.2f};"
                            f"overlap={stats['overlap_frac']:.2f};"
                            f"xfer={stats['transfer_frac']:.2f};"
                            f"dropped={stats['dropped']};"
                            f"miss_rate={stats['deadline_miss_rate']:.3f}")
    return lines, records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="fewer frames per tenant")
    ap.add_argument("--repeats", type=int, default=1,
                    help="serving windows per cell; > 1 replaces the "
                         "degenerate acq_per_s_ci with a bootstrap CI "
                         "over the per-window acq/s (the statistical "
                         "gate's interval; use >= 3 for baselines)")
    ap.add_argument("--tiny", action="store_true",
                    help="tiny test geometry (CI smoke)")
    ap.add_argument("--clients", default=None,
                    help="comma-separated tenant counts "
                         f"(default {','.join(map(str, DEFAULT_CLIENTS))})")
    ap.add_argument("--max-batch", type=int, default=None,
                    help="single policy cell: coalescing ceiling")
    ap.add_argument("--queue-delay-ms", type=float, default=5.0,
                    help="single policy cell: max queue delay "
                         "(with --max-batch)")
    ap.add_argument("--in-flight", default="2",
                    help="comma-separated dispatch-pipelining depths to "
                         "sweep (1 = synchronous; default 2)")
    ap.add_argument("--drain", default="async",
                    help="comma-separated host-transfer retirement "
                         "modes to sweep (async = staging-ring "
                         "copy_to_host_async drain, block = legacy "
                         "blocking harvest; default async)")
    ap.add_argument("--deadline-ms", type=float, default=100.0,
                    help="per-frame completion budget (miss-rate metric)")
    ap.add_argument("--base-fps", type=float, default=120.0,
                    help="fastest tenant's open-loop arrival rate; far "
                         "above the service rate = device-bound cells "
                         "(overlap win shows in acq_per_s)")
    ap.add_argument("--profile", default="steady",
                    help="comma-separated load profiles to sweep "
                         "(steady, burst, diurnal_ramp, churn, "
                         "adversarial — repro.data.traces; steady is "
                         "the historical uniform schedule)")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="replay a recorded repro-trace-v1 file instead "
                         "of generating profiles (the trace fixes the "
                         "tenant count; overrides --profile/--clients)")
    ap.add_argument("--ndjson", metavar="PATH", default=None,
                    help="write one multitenant record per line")
    ap.add_argument("--merge-into", metavar="PATH", default=None,
                    help="merge the sweep's records into an existing "
                         "benchmarks.run --json artifact under its "
                         "'multitenant' key (regenerates the "
                         "benchmarks/gate.py baseline; the command is "
                         "appended to the file's provenance note)")
    ap.add_argument("--plan", default=None,
                    choices=["fixed", "heuristic", "autotune"],
                    help="variant-resolution policy (repro.core.plan)")
    ap.add_argument("--variant", default=None,
                    choices=["dynamic", "cnn", "sparse", "auto"],
                    help="operator variant (auto = planner picks via "
                         "--plan; default: dynamic)")
    args = ap.parse_args()

    # Fail on an unwritable telemetry path now, not after the sweep.
    if args.ndjson:
        open(args.ndjson, "a").close()
    if args.merge_into:
        open(args.merge_into).close()   # must already exist (run --json)

    from repro.core import Modality, Variant, tiny_config
    variant = Variant(args.variant) if args.variant else None
    if variant == Variant.AUTO and args.plan == "fixed":
        ap.error("--variant auto needs --plan heuristic or autotune")

    cfg_bmode = cfg_doppler = None
    if args.tiny:
        v = variant if variant is not None else Variant.DYNAMIC
        cfg_bmode = tiny_config(variant=v)
        cfg_doppler = cfg_bmode.with_(modality=Modality.DOPPLER)

    client_counts = ([int(x) for x in args.clients.split(",")]
                     if args.clients else DEFAULT_CLIENTS)
    policies = ([(args.max_batch, args.queue_delay_ms)]
                if args.max_batch is not None else DEFAULT_POLICIES)
    in_flights = [int(x) for x in args.in_flight.split(",")]

    lines, records = run(client_counts, policies, in_flights=in_flights,
                         fast=args.fast, repeats=args.repeats,
                         drains=tuple(args.drain.split(",")),
                         deadline_ms=args.deadline_ms,
                         base_fps=args.base_fps, plan_policy=args.plan,
                         cfg_bmode=cfg_bmode, cfg_doppler=cfg_doppler,
                         variant=variant,
                         profiles=tuple(args.profile.split(",")),
                         trace_path=args.trace)
    print("name,us_per_acq,derived")
    for line in lines:
        print(line)
        sys.stdout.flush()

    if args.ndjson:
        with open(args.ndjson, "w") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")

    if args.merge_into:
        with open(args.merge_into) as f:
            doc = json.load(f)
        doc["multitenant"] = records
        doc.setdefault("provenance", []).append(
            "python -m benchmarks.multitenant " + " ".join(sys.argv[1:]))
        with open(args.merge_into, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
