"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
records in benchmarks/results/dryrun.json.

Per (arch x shape x mesh): the three terms in seconds, the dominant term,
MODEL_FLOPS, the useful-compute ratio, per-device memory, and a one-line
"what would move the dominant term" note (from the knowledge base below).
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List

RESULTS = os.path.join(os.path.dirname(__file__), "results",
                       "dryrun_optimized.json")

# what would move the dominant term down, per (dominant, kind)
ADVICE = {
    ("t_collective", "train"): ("sequence-parallel reduce-scatter instead "
                                "of TP all-reduce; overlap grads with bwd; "
                                "int8 grad compression on the DCN axis"),
    ("t_collective", "prefill"): ("shard KV heads instead of gathering; "
                                  "fuse TP collectives into matmuls"),
    ("t_collective", "decode"): ("keep logits sharded (argmax locally, "
                                 "psum the winner) — avoid the vocab "
                                 "all-gather; batch decode steps"),
    ("t_memory", "train"): ("save-dots remat policy (skip recompute of "
                            "cheap elementwise); bf16 activations; bigger "
                            "microbatch per device"),
    ("t_memory", "prefill"): ("flash attention keeps scores in VMEM; "
                              "fused block softmax"),
    ("t_memory", "decode"): ("bf16/int8 KV cache; grouped-query heads "
                             "amortize cache reads"),
    ("t_compute", "train"): ("already compute-bound — raise MFU via larger "
                             "per-chip batch or reduced remat"),
    ("t_compute", "prefill"): ("compute-bound prefill is the goal state"),
    ("t_compute", "decode"): ("compute-bound decode: batch is large "
                              "enough; consider speculative decoding"),
}


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill"}.get(
        shape, "decode")


def load(path: str = RESULTS) -> List[Dict]:
    with open(path) as f:
        return json.load(f)


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:8.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:7.2f}ms"
    return f"{x * 1e6:7.1f}us"


def render(records: List[Dict], mesh: str = "single") -> str:
    rows = []
    header = (f"| arch | shape | t_compute | t_memory | t_collective | "
              f"dominant | MFU-bound | useful | note |")
    sep = "|" + "---|" * 9
    rows.append(header)
    rows.append(sep)
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped | — | — | {r['reason'][:60]} |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR | — | — | {r['error'][:60]} |")
            continue
        t = r["roofline"]
        total = max(t["t_compute"], t["t_memory"], t["t_collective"])
        mfu_bound = t["t_compute"] / total if total else 0.0
        note = ADVICE.get((r["dominant"], kind_of(r["shape"])), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute'])} | "
            f"{fmt_s(t['t_memory'])} | {fmt_s(t['t_collective'])} | "
            f"{r['dominant'][2:]} | {mfu_bound:.3f} | "
            f"{r['useful_ratio']:.2f} | {note[:70]} |")
    return "\n".join(rows)


def memory_table(records: List[Dict], mesh: str = "single") -> str:
    rows = ["| arch | shape | args GB/dev | temps GB/dev | fits v5e 16GB? |",
            "|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r["status"] != "ok":
            continue
        m = r["memory"]
        args_gb = m["argument_bytes"] / 1e9
        temp_gb = m["temp_bytes"] / 1e9
        fits = "yes" if (args_gb + temp_gb) < 16 else "NO"
        rows.append(f"| {r['arch']} | {r['shape']} | {args_gb:.2f} | "
                    f"{temp_gb:.2f} | {fits} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi"])
    ap.add_argument("--memory", action="store_true")
    args = ap.parse_args()
    recs = load()
    print(render(recs, args.mesh))
    if args.memory:
        print()
        print(memory_table(recs, args.mesh))


if __name__ == "__main__":
    main()
