"""Stage-graph roofline: measured per-stage time vs the analytic floor.

For every schedulable unit of the DSP stage graph (demod / beamform /
head — `repro.core.stages.stage_fns`), the stage's compiled HLO is
costed with `repro.launch.hlo_cost.analyze` (loop-aware FLOPs, fusion
boundary bytes, perfectly-fused ``bytes_min``) and compared against
*calibrated* machine peaks — a timed large matmul for attainable
FLOP/s, a timed large copy for attainable bytes/s, both measured on
this process's actual backend rather than quoted from a datasheet. The
roofline floor for a stage is

    t_roof = max(flops / peak_flops, bytes_min / peak_bytes)

and ``pct_roofline = t_roof / t_measured`` is the fraction of
attainable the measured stage actually achieves (1.0 = on the roof;
the dominant term names the stage ``bound``). `attach_roofline` stamps
this per-stage dict onto a `BenchResult` (schema:
`repro.bench.schema.ROOFLINE_STAGE_KEYS`), so every *gated* benchmark
row carries its "% of attainable" context — a regression verdict can
distinguish "we left the roof" from "the roof moved".

  PYTHONPATH=src python -m benchmarks.roofline_report [--paper] [--json]
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Dict, Optional

# What would move a stage's dominant term down, per (bound, stage kind).
# Stage names are graph-dependent (fusion collapses spans into
# 'demod+beamform+head'), so advice is keyed by the bound alone with a
# gather-specific override — the beamform DAS gather is the documented
# TPU-hostile access pattern.
ADVICE = {
    "compute": ("on-roof compute: only an algorithmic change (sparser "
                "apodization, lower-rank delay model) buys more"),
    "memory": ("memory-bound: fuse across the stage boundary (the "
               "megakernel path) or drop the precision tier to halve "
               "the traffic"),
    "memory+gather": ("gather-dominated traffic: the dynamic DAS gather "
                      "is the portability cliff — the CNN variant "
                      "trades it for dense MACs"),
}


@dataclasses.dataclass(frozen=True)
class MachinePeaks:
    """Attainable (not datasheet) peaks, measured on this backend."""

    flops_per_s: float
    bytes_per_s: float
    backend: str

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


_PEAKS_CACHE: Dict[str, MachinePeaks] = {}


def calibrate_peaks(backend: Optional[str] = None,
                    n: int = 1024, copy_mb: int = 64,
                    reps: int = 3) -> MachinePeaks:
    """Measure attainable FLOP/s (large f32 matmul) and bytes/s (large
    copy) once per backend; memoized for the process lifetime.

    Calibrating instead of quoting a datasheet keeps pct_roofline
    meaningful across the heterogeneous CI runners the gate runs on:
    the peak moves with the machine, so the ratio compares like with
    like.
    """
    import jax
    import jax.numpy as jnp

    backend = backend or jax.default_backend()
    cached = _PEAKS_CACHE.get(backend)
    if cached is not None:
        return cached

    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (n, n), dtype=jnp.float32)
    b = jax.random.normal(key, (n, n), dtype=jnp.float32)
    mm = jax.jit(lambda x, y: x @ y)
    jax.block_until_ready(mm(a, b))          # compile outside the clock
    best_mm = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(mm(a, b))
        best_mm = min(best_mm, time.perf_counter() - t0)
    flops_per_s = 2.0 * n ** 3 / best_mm

    elems = copy_mb * (1 << 20) // 4
    big = jnp.zeros((elems,), dtype=jnp.float32)
    cp = jax.jit(lambda x: x + 1.0)          # read + write one pass each
    jax.block_until_ready(cp(big))
    best_cp = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(cp(big))
        best_cp = min(best_cp, time.perf_counter() - t0)
    bytes_per_s = 2.0 * elems * 4 / best_cp

    peaks = MachinePeaks(flops_per_s=flops_per_s,
                         bytes_per_s=bytes_per_s, backend=backend)
    _PEAKS_CACHE[backend] = peaks
    return peaks


def stage_costs(cfg) -> Dict[str, "object"]:
    """Per-stage `hlo_cost.Cost` from each stage's *compiled* module.

    Each stage is lowered on the real intermediate tensors (each
    consumes its predecessor's output, exactly like `bench_stages`), so
    the analytic bytes/FLOPs describe the program the timings measured,
    not an idealization of it.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import stages as stages_lib
    from repro.core.pipeline import init_pipeline
    from repro.data import synth_rf
    from repro.launch import hlo_cost

    consts = jax.tree.map(jnp.asarray, init_pipeline(cfg))
    x = jnp.asarray(synth_rf(cfg, seed=0))
    costs: Dict[str, hlo_cost.Cost] = {}
    for name, fn in stages_lib.stage_fns(cfg).items():
        fn_j = jax.jit(fn)
        compiled = fn_j.lower(consts, x).compile()
        costs[name] = hlo_cost.analyze(compiled.as_text())
        x = fn_j(consts, x)
    return costs


def stage_roofline(cfg, measured_s: Dict[str, float], *,
                   peaks: Optional[MachinePeaks] = None) -> Dict[str, dict]:
    """Per-stage roofline rows (schema: ROOFLINE_STAGE_KEYS + extras).

    ``measured_s`` maps stage name -> measured seconds (mean of the
    `bench_stages` breakdown). Stages without a measurement are
    skipped — the stamp only ever annotates numbers that exist.
    """
    peaks = peaks or calibrate_peaks()
    out: Dict[str, dict] = {}
    for name, cost in stage_costs(cfg).items():
        t_meas = measured_s.get(name)
        if t_meas is None or t_meas <= 0.0:
            continue
        t_compute = cost.flops / peaks.flops_per_s
        t_memory = cost.bytes_min / peaks.bytes_per_s
        t_roof = max(t_compute, t_memory)
        bound = "compute" if t_compute >= t_memory else "memory"
        if bound == "memory" and cost.gather_elems > 0.0:
            bound = "memory+gather"
        out[name] = {
            "flops": float(cost.flops),
            "bytes": float(cost.bytes),
            "bytes_min": float(cost.bytes_min),
            "t_measured_s": float(t_meas),
            "t_roof_s": float(t_roof),
            "pct_roofline": float(t_roof / t_meas),
            "bound": bound,
            "peaks": peaks.json_dict(),
        }
    return out


def attach_roofline(res, cfg, *,
                    peaks: Optional[MachinePeaks] = None) -> None:
    """Stamp the per-stage roofline onto a BenchResult in place.

    Uses the result's own `stage_breakdown` means as the measured
    times; a result without a breakdown gets no stamp (the schema
    treats `roofline` as optional, never empty).
    """
    if not res.stage_breakdown:
        return
    measured = {name: st.mean_s
                for name, st in res.stage_breakdown.items()}
    roof = stage_roofline(cfg, measured, peaks=peaks)
    res.roofline = roof or None


def fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:8.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:7.2f}ms"
    return f"{x * 1e6:7.1f}us"


def render(roofline: Dict[str, dict], title: str = "") -> str:
    """Markdown table of one row's per-stage roofline stamp."""
    rows = []
    if title:
        rows.append(f"### {title}")
    rows.append("| stage | GFLOP | MB (min) | t_measured | t_roof | "
                "% roof | bound | note |")
    rows.append("|" + "---|" * 8)
    for name, r in roofline.items():
        note = ADVICE.get(r["bound"], "")
        rows.append(
            f"| {name} | {r['flops'] / 1e9:.3f} | "
            f"{r.get('bytes_min', r['bytes']) / 1e6:.2f} | "
            f"{fmt_s(r['t_measured_s'])} | {fmt_s(r['t_roof_s'])} | "
            f"{100.0 * r['pct_roofline']:5.1f}% | {r['bound']} | "
            f"{note[:70]} |")
    return "\n".join(rows)


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Measure the stage graph and report each stage "
                    "against the calibrated machine roofline.")
    ap.add_argument("--paper", action="store_true",
                    help="exact paper geometry (slow on CPU)")
    ap.add_argument("--variant", default="dynamic",
                    choices=["dynamic", "cnn", "sparse"])
    ap.add_argument("--runs", type=int, default=3,
                    help="timed runs per stage")
    ap.add_argument("--json", action="store_true",
                    help="print the raw per-stage dict instead of the "
                         "markdown table")
    args = ap.parse_args()

    import jax.numpy as jnp

    from benchmarks.common import bench_config
    from repro.bench import bench_stages
    from repro.core import Variant
    from repro.data import synth_rf

    cfg = bench_config(args.paper).with_(variant=Variant(args.variant))
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    breakdown = bench_stages(cfg, rf, runs=args.runs)
    measured = {name: st.mean_s for name, st in breakdown.items()}
    peaks = calibrate_peaks()
    roof = stage_roofline(cfg, measured, peaks=peaks)
    if args.json:
        print(json.dumps(roof, indent=2, sort_keys=True))
    else:
        print(f"peaks ({peaks.backend}): "
              f"{peaks.flops_per_s / 1e9:.1f} GFLOP/s, "
              f"{peaks.bytes_per_s / 1e9:.1f} GB/s")
        print(render(roof, title=f"{cfg.name}/{args.variant}"))


if __name__ == "__main__":
    main()
