"""Scaling benchmark: devices x batch -> FPS, MB/s, peak-mem, J/frame.

Sweeps the two scale axes the streaming engine exposes — device count
(`ShardedExecutor` data-parallel mesh) and per-device batch — and emits
one row per cell with sustained throughput, measured peak memory,
measured incremental energy (None off-NVML — the J/frame column the
paper reports "where available"), and scale efficiency against the
single-device baseline at the same per-device batch.

On hosts with one physical device the benchmark forces a 2-device CPU
host mesh via ``XLA_FLAGS=--xla_force_host_platform_device_count=2``
(set *before* JAX initializes; pre-set XLA_FLAGS or
``--force-host-devices 0`` override this), so the scale axis is
exercised anywhere — CI runs exactly that smoke row.

  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  PYTHONPATH=src python -m benchmarks.scaling --fast --ndjson SCALING.ndjson

NDJSON rows are ``{"kind": "scaling", "plan": {...}, "devices": N,
"batch_per_device": B, "fps": ..., "sustained_mbps": ...,
"peak_memory_bytes": ..., "energy_joules": ..., "joules_per_frame": ...,
"speedup_vs_single": ..., "scale_efficiency": ..., ...}`` — schema in
docs/benchmarking-methodology.md.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def _ensure_multidevice_host() -> None:
    """Force >=2 host devices when nothing else configured the count.

    Must run before any jax import; a no-op when XLA_FLAGS already
    forces a count (e.g. CI's explicit env) or on accelerator hosts
    (forcing the *host* platform count never hides GPUs/TPUs).
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count=2 {flags}".strip()


def _device_counts(n_local: int) -> list:
    counts, c = {1, n_local}, 2
    while c < n_local:
        counts.add(c)
        c *= 2
    return sorted(counts)


def run(device_counts=None, batch_sizes=(1, 4), *, fast: bool = False,
        deadline_ms: float = 100.0, policy=None, variant=None, cfg=None):
    """Returns (csv lines, NDJSON-ready records), one per (devices, batch).

    ``device_counts=None`` sweeps 1, powers of two, and all local
    devices. Single-device rows run through `serve_ultrasound_stream`
    and seed the scale-efficiency baselines for the sharded rows.
    ``cfg`` overrides the streaming geometry (tests pass tiny configs
    to exercise the emitter cheaply).
    """
    import jax

    from benchmarks.common import stream_config
    from repro.core import Variant
    from repro.launch.serve import (serve_ultrasound_sharded,
                                    serve_ultrasound_stream)

    local = jax.local_devices()
    if device_counts is None:
        device_counts = _device_counts(len(local))
    bad = [d for d in device_counts if d > len(local)]
    if bad:
        raise ValueError(
            f"device counts {bad} exceed {len(local)} local devices "
            "(CPU hosts: XLA_FLAGS=--xla_force_host_platform_device_count=N)")

    if cfg is None:
        cfg = stream_config(False).with_(variant=Variant.DYNAMIC)
    if variant is not None:
        cfg = cfg.with_(variant=variant)   # explicit ask beats cfg's own
    n_batches = 8 if fast else 24
    deadline_s = deadline_ms / 1e3

    lines, records = [], []
    baselines = {}                     # batch_per_device -> single-device fps
    for d in device_counts:
        for b in batch_sizes:
            if d == 1:
                stats = serve_ultrasound_stream(
                    cfg, batch=b, n_batches=n_batches, depth=2,
                    deadline_s=deadline_s, policy=policy)
                stats.update(devices=1, batch_per_device=b, baseline_fps=None,
                             speedup_vs_single=1.0, scale_efficiency=1.0)
                baselines[b] = stats["fps"]
            else:
                stats = serve_ultrasound_sharded(
                    cfg, batch_per_device=b, n_batches=n_batches, depth=2,
                    deadline_s=deadline_s, policy=policy,
                    devices=local[:d], baseline_fps=baselines.get(b))
                if stats["baseline_fps"] is not None:
                    # a sweep without a devices=1 row measures its own
                    # baseline once — reuse it for later device counts
                    baselines.setdefault(b, stats["baseline_fps"])
            res = stats["resources"]
            joules = res["energy_joules"]
            rec = {
                "kind": "scaling",
                "name": stats["name"],
                "plan": stats["plan"],
                "devices": stats["devices"],
                "batch_per_device": b,
                "batch": stats["batch"],
                "n_batches": stats["n_batches"],
                "wall_s": stats["wall_s"],
                "fps": stats["fps"],
                "sustained_mbps": stats["sustained_mbps"],
                "peak_memory_bytes": res["peak_memory_bytes"],
                "memory_source": res["memory_source"],
                "energy_joules": joules,
                "joules_per_frame": (joules / stats["frames"]
                                     if joules is not None else None),
                "speedup_vs_single": stats["speedup_vs_single"],
                "scale_efficiency": stats["scale_efficiency"],
                "latency": stats["latency"].json_dict(),
            }
            records.append(rec)
            peak = res["peak_memory_bytes"]
            jpf = rec["joules_per_frame"]
            peak_mb = f"{peak / 1e6:.1f}" if peak is not None else "n/a"
            j_frame = f"{jpf:.5f}" if jpf is not None else "n/a"
            lines.append(
                f"scaling/{stats['name']},"
                f"{1e6 / stats['acq_per_s']:.1f},"
                f"devices={rec['devices']};batch={b};"
                f"fps={rec['fps']:.2f};mbps={rec['sustained_mbps']:.2f};"
                f"peak_mem_mb={peak_mb};J_per_frame={j_frame};"
                f"scale_eff={rec['scale_efficiency']:.2f}")
    return lines, records


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="fewer batches")
    ap.add_argument("--ndjson", metavar="PATH", default=None,
                    help="write one scaling record per line")
    ap.add_argument("--devices", default=None,
                    help="comma-separated device counts (default: 1, "
                         "powers of 2, all local)")
    ap.add_argument("--batch", default="1,4",
                    help="comma-separated per-device batch sizes")
    ap.add_argument("--deadline-ms", type=float, default=100.0)
    ap.add_argument("--plan", default=None,
                    choices=["fixed", "heuristic", "autotune"],
                    help="variant-resolution policy (repro.core.plan)")
    ap.add_argument("--variant", default=None,
                    choices=["dynamic", "cnn", "sparse", "auto"],
                    help="operator variant (auto = planner picks via "
                         "--plan; default: dynamic)")
    ap.add_argument("--force-host-devices", type=int, default=None,
                    help="force N CPU host devices (default: 2 when "
                         "XLA_FLAGS doesn't already force a count; "
                         "0 disables)")
    args = ap.parse_args()

    # Before the first jax import — the host device count locks at init.
    if args.force_host_devices:
        # Appended, not prepended: XLA honors the LAST occurrence, so an
        # explicit CLI request must beat a pre-set env flag.
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count="
              f"{args.force_host_devices}").strip()
    elif args.force_host_devices is None:
        _ensure_multidevice_host()

    device_counts = ([int(x) for x in args.devices.split(",")]
                     if args.devices else None)
    batch_sizes = tuple(int(x) for x in args.batch.split(","))

    # Fail on an unwritable telemetry path now, not after the sweep.
    if args.ndjson:
        open(args.ndjson, "a").close()

    # Imported only after the XLA flags are settled (jax init locks them).
    from repro.core import Variant
    variant = Variant(args.variant) if args.variant else None
    if variant == Variant.AUTO and args.plan == "fixed":
        ap.error("--variant auto needs --plan heuristic or autotune")

    lines, records = run(device_counts, batch_sizes, fast=args.fast,
                         deadline_ms=args.deadline_ms, policy=args.plan,
                         variant=variant)
    print("name,us_per_acq,derived")
    for line in lines:
        print(line)
        sys.stdout.flush()

    if args.ndjson:
        with open(args.ndjson, "w") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")


if __name__ == "__main__":
    main()
