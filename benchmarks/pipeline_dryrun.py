"""Beyond-paper: the ultrasound pipeline at pod scale.

The paper runs one chip. Here the full-CNN B-mode pipeline (exact
published geometry, 5.472 MB per acquisition) is sharded over the
production mesh — acquisitions (a leading batch of independent RF frames
sets) over the data axis, image pixels of the interpolation operator over
the model axis — and lowered/compiled like any LM dry-run cell, with the
same roofline terms. This is the "large-array / high-frame-rate" regime
the paper's §VII motivates (their compressor module targets it).

  PYTHONPATH=src python -m benchmarks.pipeline_dryrun
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import json

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import Variant, paper_config
from repro.core.pipeline import init_pipeline, pipeline_fn
from repro.launch import hlo_cost
from repro.launch import hlo_analysis as hlo
from repro.launch.dryrun import append_result
from repro.launch.mesh import make_production_mesh

OUT = os.path.join(os.path.dirname(__file__), "results",
                   "dryrun_optimized.json")

N_ACQ = 256  # simultaneous acquisitions (a probe-array farm / batch job)


def main():
    cfg = paper_config(variant=Variant.CNN)
    mesh = make_production_mesh()
    consts_np = init_pipeline(cfg)
    consts_abs = jax.tree.map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), consts_np)
    rf_abs = jax.ShapeDtypeStruct((N_ACQ,) + cfg.rf_shape, jnp.int16)

    fn = pipeline_fn(cfg)
    batched = jax.vmap(fn, in_axes=(None, 0))

    const_shardings = jax.tree.map(
        lambda a: NamedSharding(mesh, P()), consts_abs)
    # the big interpolation operator shards its pixel dim over model
    const_shardings["interp_matrix"] = NamedSharding(
        mesh, P(None, "model", None, None))
    rf_sharding = NamedSharding(mesh, P("data"))

    with jax.set_mesh(mesh):
        lowered = jax.jit(
            batched,
            in_shardings=(const_shardings, rf_sharding)).lower(
                consts_abs, rf_abs)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = hlo_cost.analyze(compiled.as_text())
    n_chips = mesh.devices.size
    terms = hlo.roofline_terms(cost.flops, cost.bytes_min,
                               int(cost.coll_bytes), n_chips)
    total = max(terms.values())
    in_bytes = N_ACQ * cfg.input_bytes
    print(json.dumps({
        "cell": "ultrasound-bmode-cnn x 256 acquisitions",
        "mesh": "single(16x16)",
        "roofline": terms,
        "dominant": hlo.dominant_term(terms),
        "per_device_temp_gb": mem.temp_size_in_bytes / 1e9,
        "predicted_throughput_GBps": in_bytes / total / 1e9,
        "predicted_fps_per_pass": 1.0 / total,
        "images_per_second": N_ACQ * cfg.n_f / total,
    }, indent=2))

    record = {
        "arch": "ultrasound-bmode-cnn-batch256", "shape": "paper_5.472MB",
        "mesh": "single", "n_chips": int(n_chips), "status": "ok",
        "roofline": terms, "dominant": hlo.dominant_term(terms),
        "flops_per_device": cost.flops, "bytes_per_device": cost.bytes_min,
        "bytes_per_device_max": cost.bytes, "collective_total":
        int(cost.coll_bytes),
        "collective_bytes": {k: int(v) for k, v in cost.coll.items()},
        "memory": {"argument_bytes": int(mem.argument_size_in_bytes),
                   "output_bytes": int(mem.output_size_in_bytes),
                   "temp_bytes": int(mem.temp_size_in_bytes),
                   "generated_code_bytes": 0},
        "model_flops_global": 0, "model_flops_per_device": 0,
        "useful_ratio": 0, "params_total": 0, "params_active": 0,
        "unknown_trip_loops": cost.unknown_loops,
        "compile_s": 0,
    }
    append_result(record, OUT)


if __name__ == "__main__":
    main()
