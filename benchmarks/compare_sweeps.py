"""Before/after roofline comparison: baseline vs optimized dry-run sweeps.

  PYTHONPATH=src python -m benchmarks.compare_sweeps [--mesh single]
"""

from __future__ import annotations

import argparse
import json
import os

RES = os.path.join(os.path.dirname(__file__), "results")


def load(name):
    with open(os.path.join(RES, name)) as f:
        return {(r["arch"], r["shape"], r["mesh"]): r for r in json.load(f)}


def bound(r):
    t = r["roofline"]
    return max(t["t_compute"], t["t_memory"], t["t_collective"])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--baseline", default="dryrun_baseline.json")
    ap.add_argument("--optimized", default="dryrun_optimized.json")
    args = ap.parse_args()

    base = load(args.baseline)
    opt = load(args.optimized)
    print("| arch | shape | bound before | bound after | speedup | "
          "dom before -> after |")
    print("|---|---|---|---|---|---|")
    total_b = total_o = 0.0
    for key in sorted(base):
        if key[2] != args.mesh:
            continue
        rb, ro = base[key], opt.get(key)
        if rb["status"] != "ok" or not ro or ro["status"] != "ok":
            continue
        tb, to = bound(rb), bound(ro)
        total_b += tb
        total_o += to
        print(f"| {key[0]} | {key[1]} | {tb:9.3f}s | {to:9.3f}s | "
              f"{tb / to:6.1f}x | {rb['dominant'][2:]} -> "
              f"{ro['dominant'][2:]} |")
    print(f"\nsum-of-bounds: {total_b:.1f}s -> {total_o:.1f}s "
          f"({total_b / total_o:.2f}x)")


if __name__ == "__main__":
    main()
