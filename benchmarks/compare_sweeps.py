"""Before/after comparison of two benchmark sweep artifacts.

Compares the table1 rows of two ``benchmarks.run --json`` artifacts by
row name, reporting each matched cell's mean with its bootstrap CI, the
speedup as a *ratio CI* (`repro.bench.stats.ci_ratio` over the rows'
committed ``run_means`` — a speedup whose interval straddles 1.0 is
labelled noise, not a win), and the worst-stage % -of-roofline when the
rows carry a stamp — so "2x faster" and "2x closer to the roof" are
distinguishable claims.

  PYTHONPATH=src python -m benchmarks.compare_sweeps \
      --baseline BENCH_before.json --current BENCH_after.json
"""

from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional, Tuple

from repro.bench.stats import ci_ratio


def load_rows(path: str) -> Dict[str, dict]:
    with open(path) as f:
        return {r["name"]: r for r in json.load(f)["results"]}


def row_runs(row: dict) -> List[float]:
    """A row's level-one run means (falls back to the single mean)."""
    ci = row.get("ci") or {}
    means = ci.get("run_means")
    if isinstance(means, list) and means:
        return [float(m) for m in means]
    return [float(row["t_avg_s"])]


def ci_str(row: dict) -> str:
    ci = row.get("ci") or {}
    if "ci_lo" in ci and "ci_hi" in ci and ci.get("n_runs", 1) > 1:
        return (f"{row['t_avg_s'] * 1e3:.2f}ms "
                f"[{ci['ci_lo'] * 1e3:.2f}, {ci['ci_hi'] * 1e3:.2f}]")
    return f"{row['t_avg_s'] * 1e3:.2f}ms"


def worst_roofline(row: dict) -> Optional[Tuple[str, float]]:
    """(stage, pct) of the stage furthest below its roofline floor."""
    roof = row.get("roofline")
    if not roof:
        return None
    stage = min(roof, key=lambda s: roof[s]["pct_roofline"])
    return stage, roof[stage]["pct_roofline"]


def compare(baseline: Dict[str, dict],
            current: Dict[str, dict]) -> List[str]:
    lines = ["| cell | before (CI) | after (CI) | speedup (CI) | "
             "verdict | worst-stage roof |",
             "|" + "---|" * 6]
    for name in sorted(baseline):
        base = baseline[name]
        cur = current.get(name)
        if cur is None:
            lines.append(f"| {name} | {ci_str(base)} | — | — | "
                         f"missing | — |")
            continue
        # speedup = t_before / t_after: resample the ratio with the
        # sides swapped so > 1 means faster.
        r = ci_ratio(row_runs(cur), row_runs(base))
        if r.ci_lo > 1.0:
            verdict = "faster"
        elif r.ci_hi < 1.0:
            verdict = "SLOWER"
        else:
            verdict = "noise"
        roof = worst_roofline(cur) or worst_roofline(base)
        roof_txt = (f"{roof[0]} {100.0 * roof[1]:.0f}%" if roof else "—")
        lines.append(
            f"| {name} | {ci_str(base)} | {ci_str(cur)} | "
            f"{r.ratio:.2f}x [{r.ci_lo:.2f}, {r.ci_hi:.2f}] | "
            f"{verdict} | {roof_txt} |")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Compare two benchmarks.run --json artifacts cell "
                    "by cell with ratio CIs.")
    ap.add_argument("--baseline", required=True,
                    help="'before' benchmarks.run --json artifact")
    ap.add_argument("--current", required=True,
                    help="'after' benchmarks.run --json artifact")
    args = ap.parse_args()

    for line in compare(load_rows(args.baseline),
                        load_rows(args.current)):
        print(line)


if __name__ == "__main__":
    main()
