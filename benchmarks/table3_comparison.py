"""Paper Table III: throughput context vs prior deterministic pipelines.

Reference rows are the paper's own citations (fixed literature values);
our rows come from Table I (measured CPU stand-in) and Table II's modeled
TPU prediction, normalized to GB/s of RF input.
"""

from __future__ import annotations

from typing import List

REFERENCES = [
    ("paper/RTX5090-doppler-dynamic", 7.2),       # GB/s, Table III
    ("paper/TPUv5e-doppler-fullcnn", 0.53),
    ("yiu2018/dual-GTX480-planewave", 1.5),       # 1-2 GB/s midpoint
    ("rossi2023/jetson-xavier-vector-doppler", 7.5),
    ("liu2023/rtx4090-3d-rowcol (compressed)", 2.3),
]


def run(our_results=None) -> List[str]:
    lines = []
    for name, gbps in REFERENCES:
        lines.append(f"table3/{name},0.0,ref_gbps={gbps}")
    if our_results:
        for r in our_results:
            lines.append(
                f"table3/this-work/{r.name.split('/', 1)[1]},"
                f"{r.t_avg_s * 1e6:.1f},gbps={r.mbps / 1000.0:.4f}")
    return lines


if __name__ == "__main__":
    for line in run():
        print(line)
