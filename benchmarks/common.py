"""Shared benchmark geometry.

The paper's exact acquisition (5.472 MB int16 RF per forward pass) is kept
for the throughput normalization; the image grid / channel count / frame
count are reduced so the full-CNN variant's dense operator fits a 1-core
CPU stand-in (the paper ran an RTX 5090 / TPU v5e). `--paper` restores the
exact published geometry (slow on CPU). Methodology is identical either
way — same code, same metrics, same execution model.
"""

from __future__ import annotations

from repro.core import UltrasoundConfig


def bench_config(paper_scale: bool = False) -> UltrasoundConfig:
    if paper_scale:
        from repro.core import paper_config
        return paper_config()
    return UltrasoundConfig(
        n_l=1336, n_c=32, n_f=8,
        nz=48, nx=48,
        sparse_block_p=32, sparse_block_s=32,
    )


def stream_config(paper_scale: bool = False) -> UltrasoundConfig:
    """Geometry for the streaming (sustained-throughput) benchmark.

    Real-time imaging runs small ensembles at high rate, so the streaming
    section uses a lighter grid than the Table I offline geometry: per
    acquisition compute drops to the point where dispatch and host->device
    overhead — exactly what batching amortizes — is a visible fraction of
    the budget. Full axial depth (n_l) is kept so B_in stays realistic.
    """
    if paper_scale:
        from repro.core import paper_config
        return paper_config()
    return UltrasoundConfig(
        n_l=1336, n_c=16, n_f=8,
        nz=32, nx=32,
        sparse_block_p=32, sparse_block_s=32,
    )
