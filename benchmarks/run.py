"""Benchmark entry point — one function per paper table.

Prints ``name,us_per_call,derived`` CSV lines:
  table1_* : Table I  — variants x modalities, end-to-end (CPU stand-in)
  table2_* : Table II — portability (CPU measured + TPU predicted)
  table3_* : Table III — throughput context vs prior work
  stream_* : sustained streaming throughput (batched stage-graph engine)
  lm_*     : zoo throughput smoke (tokens/s on reduced configs)

``--json PATH`` writes a BENCH_*.json-compatible results file (name,
t_avg, fps, mbps, percentiles); ``--ndjson PATH`` writes the full
distribution telemetry (summary / per-sample / per-stage records; schema
in EXPERIMENTS.md). ``--deadline-ms`` sets the per-forward-pass frame
budget used for the deadline-miss rate.

``--plan {fixed,heuristic,autotune}`` selects the variant-resolution
policy and ``--variant auto`` hands the choice to the planner
(repro.core.plan); the resolved plan is stamped into every telemetry
record. ``--lowering {xla,pallas}`` pins the beamform stage's operator
lowering (repro.core.lowering) for the table1/stream sections — pallas
sweeps only the variants that register a Pallas kernel, so the
variant x lowering matrix is benchmarkable end to end (interpret mode
off-TPU). ``--fusion {none,fused,both}`` routes the demod+beamform+head
span through the fused Pallas megakernel and ``--precision
{f32,bf16,f16}`` selects the mixed-precision contract tier; cells a
requested sweep cannot run (no fused registration, f32-only xla under
reduced precision, missing lowering) emit explicit
``<cell>,skipped,reason=...`` lines so coverage is auditable. ``--only``
restricts the run to one section (the CI autotune smoke uses
``--only table1 --variant auto --plan autotune``; the CI lowering smoke
uses ``--only table1 --lowering pallas``; the CI fused smoke uses
``--only table1 --fusion both --precision bf16``).

``python -m benchmarks.run [--paper] [--fast] [--json PATH] [--ndjson PATH]``
"""

from __future__ import annotations

import argparse
import sys
import time


def _lm_smoke_bench(runs: int = 3):
    """Reduced-config train-step timing for three representative archs."""
    import jax
    import jax.numpy as jnp

    from repro.configs import TrainConfig, get_smoke
    from repro.data.tokens import TokenDataset
    from repro.models import get_model
    from repro.train import steps as steps_lib

    lines = []
    for arch in ["qwen3-8b", "granite-moe-3b-a800m", "mamba2-130m"]:
        cfg = get_smoke(arch)
        model = get_model(cfg)
        tcfg = TrainConfig()
        state = steps_lib.init_train_state(model, jax.random.PRNGKey(0))
        step = jax.jit(steps_lib.make_train_step(model, tcfg))
        data = TokenDataset(cfg, 4, 128)
        batch = jax.tree.map(jnp.asarray, data.batch_for_step(0))
        state, _ = step(state, batch)  # warmup/compile
        jax.block_until_ready(state)
        t0 = time.perf_counter()
        for _ in range(runs):
            state, metrics = step(state, batch)
            jax.block_until_ready(metrics["loss"])
        t = (time.perf_counter() - t0) / runs
        tok_s = 4 * 128 / t
        lines.append(f"lm_train/{arch},{t * 1e6:.1f},tok_per_s={tok_s:.0f}")
    return lines


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--paper", action="store_true",
                    help="exact paper geometry (slow on CPU)")
    ap.add_argument("--fast", action="store_true",
                    help="fewer timed runs")
    ap.add_argument("--repeats", type=int, default=1,
                    help="repeat each table1 cell's timed window this "
                         "many times; > 1 makes the summary's `ci` "
                         "block a real bootstrap interval over the "
                         "per-repeat means (use >= 3 for gate "
                         "baselines; 1 = degenerate zero-width CI)")
    ap.add_argument("--roofline", action="store_true",
                    help="stamp per-stage roofline context (bytes/FLOPs "
                         "from compiled HLO vs calibrated machine "
                         "peaks) into the table1 summary rows")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write BENCH_*.json-compatible results")
    ap.add_argument("--merge-into", metavar="PATH", default=None,
                    help="merge this run's table1 rows into an existing "
                         "benchmarks.run --json artifact (rows with the "
                         "same name are replaced; the command is "
                         "appended to the file's provenance note) — "
                         "how the committed baseline accumulates its "
                         "pallas/fused cells")
    ap.add_argument("--ndjson", metavar="PATH", default=None,
                    help="write per-sample / per-stage NDJSON telemetry")
    ap.add_argument("--deadline-ms", type=float, default=100.0,
                    help="frame budget per forward pass (miss-rate metric)")
    ap.add_argument("--plan", default="fixed",
                    choices=["fixed", "heuristic", "autotune"],
                    help="variant-resolution policy for the table1/stream "
                         "sections (repro.core.plan)")
    ap.add_argument("--variant", default=None,
                    choices=["dynamic", "cnn", "sparse", "auto"],
                    help="single variant for the table1/stream sections "
                         "(auto = planner picks); default: sweep all "
                         "three. table2's dynamic-vs-cnn comparison is "
                         "fixed by construction")
    ap.add_argument("--lowering", default=None,
                    choices=["xla", "pallas"],
                    help="pin the beamform stage's operator lowering for "
                         "the table1/stream sections (pallas: only the "
                         "variants registering a kernel run; interpret "
                         "mode off-TPU); default: planner-resolved")
    ap.add_argument("--fusion", default="none",
                    choices=["none", "fused", "both"],
                    help="route the demod+beamform+head span through the "
                         "fused Pallas megakernel for the table1/stream "
                         "sections ('both' sweeps unfused and fused per "
                         "cell; cells with no fused registration emit "
                         "explicit skipped lines)")
    ap.add_argument("--precision", default="f32",
                    choices=["f32", "bf16", "f16"],
                    help="kernel compute-precision tier (matmul operands; "
                         "accumulation stays f32). Reduced precision "
                         "needs --fusion fused/both — the xla references "
                         "are f32-only and their cells are skipped")
    ap.add_argument("--only", default="all",
                    choices=["all", "table1", "table2", "table3",
                             "stream", "lm"],
                    help="run a single benchmark section")
    args = ap.parse_args()
    runs = 2 if args.fast else 5
    deadline_s = args.deadline_ms / 1e3
    if args.repeats < 1:
        ap.error("--repeats must be >= 1")

    from repro.core import Variant
    variant = Variant(args.variant) if args.variant else None
    if variant == Variant.AUTO and args.plan == "fixed":
        ap.error("--variant auto needs --plan heuristic or autotune")
    if args.lowering == "pallas" and args.variant == "cnn":
        ap.error("no pallas lowering is registered for the cnn beamform "
                 "(the dense matmul IS the MXU formulation)")
    if args.lowering == "xla" and args.fusion in ("fused", "both"):
        ap.error("--lowering xla contradicts --fusion fused: the fused "
                 "span claims the beamform stage with its pallas "
                 "megakernel")
    if args.fusion in ("fused", "both") and args.variant in ("cnn",
                                                             "sparse"):
        ap.error("fused lowerings are registered for the dynamic variant "
                 "only (the megakernel's DAS gather IS the dynamic "
                 "formulation)")

    def on(section):
        return args.only in ("all", section)

    # Fail on unwritable telemetry paths now, not after minutes of timing.
    for path in (args.json, args.ndjson):
        if path:
            open(path, "a").close()
    if args.merge_into:
        open(args.merge_into).close()   # must already exist (run --json)

    from benchmarks import stream_throughput, table1_variants, \
        table2_portability, table3_comparison

    print("name,us_per_call,derived")
    t1 = []
    if on("table1") or on("table3"):   # table3 derives from table1 rows
        t1, t1_skipped = table1_variants.run(
            paper_scale=args.paper, runs=runs, repeats=args.repeats,
            deadline_s=deadline_s, stage_breakdown=True,
            roofline=args.roofline,
            policy=args.plan, variant=variant,
            lowering=args.lowering, fusion=args.fusion,
            precision=args.precision)
        if on("table1"):
            for r in t1:
                print(r.csv())
                sys.stdout.flush()
            # Sweep coverage is auditable from the output alone: every
            # requested cell that did not run says so, with the reason.
            for cell, reason in t1_skipped:
                print(f"{cell},skipped,reason={reason}")
                sys.stdout.flush()
    if on("table2"):
        for line in table2_portability.run(paper_scale=args.paper,
                                           runs=max(runs - 2, 2)):
            print(line)
            sys.stdout.flush()
    if on("table3"):
        for line in table3_comparison.run(t1):
            print(line)
    stream_records = []
    if on("stream"):
        stream_lines, stream_records = stream_throughput.run(
            paper_scale=args.paper, fast=args.fast,
            deadline_ms=args.deadline_ms,
            policy=args.plan, variant=variant,
            lowering=args.lowering,
            # "both" streams the fused program — the new cell; the
            # unfused stream is the long-standing default row.
            fusion="fused" if args.fusion != "none" else "none",
            precision=args.precision)
        for line in stream_lines:
            print(line)
            sys.stdout.flush()
    if on("lm"):
        for line in _lm_smoke_bench():
            print(line)
            sys.stdout.flush()

    if args.json or args.ndjson:
        from repro.bench import write_json, write_ndjson
        if args.json:
            write_json(args.json, t1,
                       extra={"stream": stream_records,
                              "deadline_ms": args.deadline_ms,
                              "plan_policy": args.plan,
                              "provenance": ["python -m benchmarks.run "
                                             + " ".join(sys.argv[1:])]})
        if args.ndjson:
            write_ndjson(args.ndjson, t1, extra_records=stream_records)

    if args.merge_into:
        import json
        with open(args.merge_into) as f:
            doc = json.load(f)
        fresh = {r.name: r.json_dict() for r in t1}
        doc["results"] = ([row for row in doc.get("results", [])
                           if row.get("name") not in fresh]
                          + list(fresh.values()))
        doc.setdefault("provenance", []).append(
            "python -m benchmarks.run " + " ".join(sys.argv[1:]))
        with open(args.merge_into, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


if __name__ == "__main__":
    main()
