"""Perf lab: per-instruction cost attribution for a dry-run cell.

The hillclimb loop's "profiler": compiles one (arch x shape x mesh) cell,
runs the loop-aware cost model, and prints the top instructions by
collective bytes / HBM bytes / FLOPs — each with its JAX-level op_name
metadata so the line of Python responsible is identifiable.

  PYTHONPATH=src python -m benchmarks.perf_lab --arch qwen3-8b \
      --shape decode_32k --top 15 --by collective
"""

import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import re

from repro.launch import cells as cells_lib
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh

_METADATA_RE = re.compile(r'op_name="([^"]+)"')


def attribute(text: str):
    comps = hlo_cost.parse_module(text)
    entry = next(c for c in comps.values() if c.is_entry)

    # effective execution multiplier per computation
    mult = {entry.name: 1.0}
    order = [entry.name]
    seen = {entry.name}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for inst in comp.insts:
            scale = float(inst.trip) if inst.op == "while" else 1.0
            for child in inst.called:
                mult[child] = mult.get(child, 0.0) + mult[cname] * scale
                if child not in seen:
                    seen.add(child)
                    order.append(child)

    fusion_names = {c.name for c in comps.values()
                    if "fused" in c.name or "wrapped" in c.name}
    rows = []
    for cname, m in mult.items():
        comp = comps.get(cname)
        if comp is None:
            continue
        in_fusion = cname in fusion_names
        for inst in comp.insts:
            c = hlo_cost._local_cost(inst, comp, in_fusion)
            meta = _METADATA_RE.search(inst.rest)
            rows.append({
                "coll": c.coll_bytes * m,
                "bytes": c.bytes_min * m,
                "flops": c.flops * m,
                "op": inst.op,
                "comp": cname,
                "name": inst.name,
                "where": meta.group(1) if meta else "",
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--by", default="collective",
                    choices=["collective", "bytes", "flops"])
    ap.add_argument("--top", type=int, default=15)
    ap.add_argument("--dump", default=None, help="save HLO text here")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
    cell = cells_lib.build_cell(args.arch, args.shape, mesh)
    compiled = cells_lib.lower_cell(cell, mesh).compile()
    text = compiled.as_text()
    if args.dump:
        with open(args.dump, "w") as f:
            f.write(text)

    total = hlo_cost.analyze(text)
    print(f"totals/device: flops={total.flops:.3e} "
          f"bytes_min={total.bytes_min:.3e} coll={total.coll_bytes:.3e}")
    print(f"collective breakdown: "
          + " ".join(f"{k}={v:.3e}" for k, v in total.coll.items()
                     if v))
    key = {"collective": "coll", "bytes": "bytes", "flops": "flops"}[
        args.by]
    rows = sorted(attribute(text), key=lambda r: -r[key])[: args.top]
    print(f"\ntop {args.top} by {args.by}:")
    for r in rows:
        if r[key] <= 0:
            break
        print(f"  {r[key]:.3e}  {r['op']:22s} {r['name'][:36]:36s} "
              f"{r['where'][:90]}")


if __name__ == "__main__":
    main()
