"""Step builders: train (grad-accum, clip, AdamW), prefill, serve.

These are the functions the launcher jits/lowers — one per (arch x shape)
dry-run cell:
  train_4k     -> make_train_step
  prefill_32k  -> make_prefill_step
  decode_32k / long_500k -> make_serve_step
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import TrainConfig
from repro.models.api import Model
from repro.optim.adamw import adamw_init, adamw_update, global_norm_clip


def init_train_state(model: Model, key) -> Dict:
    params = model.init_params(key)
    return {"params": params, "opt": adamw_init(params)}


def make_train_step(model: Model, tcfg: TrainConfig) -> Callable:
    def loss_of(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def train_step(state: Dict, batch: Dict) -> Tuple[Dict, Dict]:
        params = state["params"]

        if tcfg.microbatches > 1:
            m = tcfg.microbatches

            def split(x):
                return x.reshape((m, x.shape[0] // m) + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def acc_body(carry, mb_i):
                g_acc, l_acc = carry
                (loss, metrics), grads = grad_fn(params, mb_i)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / m, g_acc, grads)
                return (g_acc, l_acc + loss / m), metrics

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_stack = lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mb)
            metrics = jax.tree.map(lambda x: x.mean(), metrics_stack)
        else:
            (loss, metrics), grads = grad_fn(params, batch)

        grads, gnorm = global_norm_clip(grads, tcfg.grad_clip)
        new_params, new_opt, opt_metrics = adamw_update(
            tcfg, params, grads, state["opt"])
        out_metrics = {"loss": loss, "grad_norm": gnorm, **metrics,
                       **opt_metrics}
        return {"params": new_params, "opt": new_opt}, out_metrics

    return train_step


def make_prefill_step(model: Model) -> Callable:
    def prefill_step(params: Dict, batch: Dict):
        logits, cache = model.prefill(params, batch)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache

    return prefill_step


def make_serve_step(model: Model) -> Callable:
    """One decode iteration: write KV, attend, next token (greedy —
    deterministic, per the paper's execution model)."""

    def serve_step(params: Dict, tokens: jnp.ndarray, cache: Dict,
                   lengths: jnp.ndarray):
        logits, new_cache = model.decode_step(params, tokens, cache, lengths)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache, lengths + 1

    return serve_step
