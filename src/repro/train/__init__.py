from repro.train.steps import (  # noqa: F401
    init_train_state, make_prefill_step, make_serve_step, make_train_step)
