"""Mixture-of-Experts with the paper's three dispatch variants.

Token -> expert dispatch is the LM-scale instance of the paper's taxonomy
(DESIGN.md §5): the same routing decision can be executed as

  V1 DYNAMIC — scatter/gather: each (token, k) assignment computes a flat
      destination slot (expert * capacity + rank) and tokens are moved with
      dynamic scatter; results come back with a gather. Lean but irregular —
      exactly the access pattern the paper shows collapsing on TPU.
  V2 CNN     — GShard-style one-hot dispatch/combine einsums: routing is
      materialized as a {0,1} (groups, tokens, experts, capacity) tensor and
      token movement *is* a matmul. Fully static and MXU-native; costs
      O(T_g) extra FLOPs per token — the paper's portability-for-overhead
      trade. Group size bounds the overhead (see `group_size`).
  V3 SPARSE  — block-structured: tokens are slotted as in V1, but expert
      weights are gathered at *block* granularity and applied with dense
      per-block matmuls (MegaBlocks-on-TPU structure; block-level
      irregularity only, like the BSR beamformer).

All three are numerically identical given the same capacity (tested).
Routing itself (softmax, top-k, capacity ranking via cumsum) is shared.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.config import Variant
from repro.models import common
from repro.models.common import KeyGen, dense_init
from repro.runtime.sharding import shard


def moe_params(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.n_experts_eff      # incl. dead padding (never routed)
    p = {
        "router": dense_init(kg(), (d, cfg.n_experts), jnp.float32),
        "wi_gate": dense_init(kg(), (e, d, f), dtype),
        "wi_up": dense_init(kg(), (e, d, f), dtype),
        "wo": dense_init(kg(), (e, f, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = common.mlp_params(
            kg, d, cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


# ---------------------------------------------------------------------------
# Routing (shared by all variants)
# ---------------------------------------------------------------------------


def route(cfg: ModelConfig, router_w, x_flat: jnp.ndarray):
    """x_flat (T, d) -> (weights (T, k), idx (T, k), aux_losses dict)."""
    logits = x_flat.astype(jnp.float32) @ router_w          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = lax.top_k(probs, cfg.n_experts_per_tok)        # (T, k)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)

    # Aux: load-balance (Switch) + router z-loss.
    e = cfg.n_experts
    onehot_any = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(axis=1)
    frac_tokens = onehot_any.mean(axis=0)                   # (E,)
    frac_probs = probs.mean(axis=0)
    lb_loss = e * jnp.sum(frac_tokens * frac_probs)
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    aux = {"moe_lb_loss": lb_loss,
           "moe_z_loss": cfg.router_z_loss * z_loss}
    return w, idx, aux


def capacity_and_rank(cfg: ModelConfig, idx: jnp.ndarray, n_tokens: int,
                      ) -> Tuple[int, jnp.ndarray, jnp.ndarray]:
    """Deterministic capacity ranking.

    Returns (capacity, rank (T, k), keep (T, k) {0,1}). Assignments are
    prioritized k-major (all primary choices before secondary), then by
    token order — fixed, data-independent priority (paper §II-C).
    """
    e, k = cfg.n_experts, cfg.n_experts_per_tok
    cap = int(max(8, ((n_tokens * k * cfg.capacity_factor / e) // 8 + 1) * 8))

    ranks, keeps = [], []
    count = jnp.zeros((e,), dtype=jnp.int32)
    for kk in range(k):
        oh = jax.nn.one_hot(idx[:, kk], e, dtype=jnp.int32)  # (T, E)
        r = jnp.cumsum(oh, axis=0) - oh + count[None, :]
        rank_k = (r * oh).sum(axis=-1)                       # (T,)
        keep_k = rank_k < cap
        ranks.append(rank_k)
        keeps.append(keep_k)
        count = count + (oh * keep_k[:, None].astype(jnp.int32)).sum(axis=0)
    rank = jnp.stack(ranks, axis=1)
    keep = jnp.stack(keeps, axis=1)
    return cap, rank, keep


# ---------------------------------------------------------------------------
# Expert FFN (shared)
# ---------------------------------------------------------------------------


def _expert_ffn(params: Dict, xe: jnp.ndarray) -> jnp.ndarray:
    """xe (E, C, d) -> (E, C, d), per-expert SwiGLU via batched einsum."""
    gate = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["wi_gate"]))
    up = jnp.einsum("ecd,edf->ecf", xe, params["wi_up"])
    return jnp.einsum("ecf,efd->ecd", gate * up, params["wo"])


# ---------------------------------------------------------------------------
# V1 — dynamic scatter/gather
# ---------------------------------------------------------------------------


def _dispatch_dynamic(cfg, params, x_flat, w, idx, cap, rank, keep):
    t, d = x_flat.shape
    e, k = cfg.n_experts_eff, cfg.n_experts_per_tok
    dump = e * cap                                   # overflow slot
    dest = jnp.where(keep, idx * cap + rank, dump)   # (T, k)

    buf = jnp.zeros((e * cap + 1, d), dtype=x_flat.dtype)
    # Distinct (expert, rank) per kept assignment => .set is race-free.
    buf = buf.at[dest.reshape(-1)].set(
        jnp.repeat(x_flat, k, axis=0), mode="drop")
    xe = buf[:-1].reshape(e, cap, d)
    xe = shard(xe, "expert", None, None)

    ye = _expert_ffn(params, xe).reshape(e * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    gathered = ye[dest.reshape(-1)].reshape(t, k, d)  # dynamic gather
    return (gathered * w[..., None].astype(gathered.dtype)).sum(axis=1)


# ---------------------------------------------------------------------------
# V2 — one-hot einsum dispatch (GShard / full-CNN)
# ---------------------------------------------------------------------------


def group_size(cfg: ModelConfig, n_tokens: int) -> int:
    """Dispatch groups bound the O(T_g * E * C) one-hot overhead."""
    g = 256
    while n_tokens % g:
        g //= 2
    return max(g, 1)


def _dispatch_onehot(cfg, params, x_flat, w, idx, cap, rank, keep):
    t, d = x_flat.shape
    e, k = cfg.n_experts_eff, cfg.n_experts_per_tok
    tg = group_size(cfg, t)
    g = t // tg

    # Per-group capacity ranking was computed globally; recompute rank within
    # groups so capacity is per-group (standard GShard semantics).
    # Capacity is per *real* expert (dead padding gets empty slots).
    cap_g = int(max(8, ((tg * k * cfg.capacity_factor / cfg.n_experts)
                        // 8 + 1) * 8))
    idx_g = idx.reshape(g, tg, k)
    w_g = w.reshape(g, tg, k)

    def per_group(idx_1):
        ranks, keeps = [], []
        count = jnp.zeros((e,), dtype=jnp.int32)
        for kk in range(k):
            oh = jax.nn.one_hot(idx_1[:, kk], e, dtype=jnp.int32)
            r = jnp.cumsum(oh, axis=0) - oh + count[None, :]
            rank_k = (r * oh).sum(axis=-1)
            keep_k = rank_k < cap_g
            ranks.append(rank_k)
            keeps.append(keep_k)
            count = count + (oh * keep_k[:, None].astype(jnp.int32)
                             ).sum(axis=0)
        return jnp.stack(ranks, 1), jnp.stack(keeps, 1)

    rank_g, keep_g = jax.vmap(per_group)(idx_g)       # (G, Tg, k)

    # dispatch one-hot: (G, Tg, E, C) = [expert matches] x [slot matches].
    # Every intermediate is explicitly sharded (groups -> data axis,
    # experts -> model axis): without the constraints the partitioner
    # replicates the (G,E,C,d) dispatched activations and their gradients
    # across the mesh — measured at ~3 TB of all-gather per device per
    # step on granite-moe train_4k (§Perf iteration 1).
    oh_e = jax.nn.one_hot(idx_g, e, dtype=x_flat.dtype)          # (G,Tg,k,E)
    oh_c = jax.nn.one_hot(rank_g, cap_g, dtype=x_flat.dtype)     # (G,Tg,k,C)
    oh_c = oh_c * keep_g[..., None].astype(x_flat.dtype)
    disp = jnp.einsum("gtke,gtkc->gtec", oh_e, oh_c)             # 0/1
    disp = shard(disp, "batch", None, "expert", None)
    # combine = disp * (per-token expert weight). Factored so the
    # *differentiable* router-weight path stays (G,Tg,E)-sized — a fused
    # 3-operand einsum drags a (G,Tg,E,C) contraction through the
    # backward pass (§Perf: 77 GB/device of gathers on this cell).
    wsum = jnp.einsum("gtke,gtk->gte", oh_e, w_g.astype(x_flat.dtype))
    comb = disp * wsum[..., None]
    comb = shard(comb, "batch", None, "expert", None)

    xg = x_flat.reshape(g, tg, d)
    xg = shard(xg, "batch", None, None)
    xe = jnp.einsum("gtec,gtd->gecd", disp, xg)                  # (G,E,C,d)
    xe = shard(xe, "batch", "expert", None, None)
    ye = jax.vmap(lambda xe_1: _expert_ffn(params, xe_1))(xe)
    ye = shard(ye, "batch", "expert", None, None)
    yg = jnp.einsum("gtec,gecd->gtd", comb, ye)
    yg = shard(yg, "batch", None, None)
    return yg.reshape(t, d)


# ---------------------------------------------------------------------------
# V3 — block-structured sparse
# ---------------------------------------------------------------------------


def _dispatch_blocked(cfg, params, x_flat, w, idx, cap, rank, keep,
                      block: int = 8):
    t, d = x_flat.shape
    e, k = cfg.n_experts_eff, cfg.n_experts_per_tok
    dump = e * cap
    dest = jnp.where(keep, idx * cap + rank, dump)

    buf = jnp.zeros((e * cap + 1, d), dtype=x_flat.dtype)
    buf = buf.at[dest.reshape(-1)].set(
        jnp.repeat(x_flat, k, axis=0), mode="drop")
    xb = buf[:-1].reshape(e * cap // block, block, d)  # (NB, bs, d)
    # Block-level weight gather: every block belongs to exactly one expert.
    block_expert = jnp.repeat(jnp.arange(e, dtype=jnp.int32), cap // block)
    wg = jnp.take(params["wi_gate"], block_expert, axis=0)  # (NB, d, f)
    wu = jnp.take(params["wi_up"], block_expert, axis=0)
    wo = jnp.take(params["wo"], block_expert, axis=0)

    gate = jax.nn.silu(jnp.einsum("bcd,bdf->bcf", xb, wg))
    up = jnp.einsum("bcd,bdf->bcf", xb, wu)
    yb = jnp.einsum("bcf,bfd->bcd", gate * up, wo)

    ye = yb.reshape(e * cap, d)
    ye = jnp.concatenate([ye, jnp.zeros((1, d), ye.dtype)], axis=0)
    gathered = ye[dest.reshape(-1)].reshape(t, k, d)
    return (gathered * w[..., None].astype(gathered.dtype)).sum(axis=1)


# ---------------------------------------------------------------------------


_DISPATCH = {
    Variant.DYNAMIC: _dispatch_dynamic,
    Variant.CNN: _dispatch_onehot,
    Variant.SPARSE: _dispatch_blocked,
}


def moe_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
              ) -> Tuple[jnp.ndarray, Dict]:
    """x (B, S, d) -> (B, S, d), aux losses. Variant from cfg.moe_variant."""
    if cfg.moe_variant not in _DISPATCH:
        raise ValueError(
            f"moe_variant must be concrete (got {cfg.moe_variant!r}); "
            "Variant.AUTO is resolved by the ultrasound planner only")
    b, s, d = x.shape
    x_flat = x.reshape(b * s, d)
    w, idx, aux = route(cfg, params["router"], x_flat)

    if cfg.moe_variant == Variant.CNN:
        y = _dispatch_onehot(cfg, params, x_flat, w, idx, None, None, None)
    else:
        cap, rank, keep = capacity_and_rank(cfg, idx, b * s)
        y = _DISPATCH[cfg.moe_variant](cfg, params, x_flat, w, idx,
                                       cap, rank, keep)

    if cfg.n_shared_experts:
        y = y + common.mlp_apply(params["shared"], x_flat)
    return y.reshape(b, s, d), aux
