"""seamless-m4t-large-v2 backbone: encoder-decoder transformer.

The audio frontend is a stub per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d_model) from input_specs(). The
decoder is a causal transformer with cross-attention; decode caches both
its self-attention KV and the (static after encode) cross-attention KV.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention, common
from repro.models.common import KeyGen, dtype_of
from repro.runtime.sharding import shard


def _enc_layer(key, cfg: ModelConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "ln1": common.rmsnorm_params(cfg.d_model, dtype),
        "attn": attention.attn_params(kg, cfg, dtype),
        "ln2": common.rmsnorm_params(cfg.d_model, dtype),
        "mlp": common.mlp_params(kg, cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_layer(key, cfg: ModelConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "ln1": common.rmsnorm_params(cfg.d_model, dtype),
        "self_attn": attention.attn_params(kg, cfg, dtype),
        "ln_x": common.rmsnorm_params(cfg.d_model, dtype),
        "cross_attn": attention.attn_params(kg, cfg, dtype),
        "ln2": common.rmsnorm_params(cfg.d_model, dtype),
        "mlp": common.mlp_params(kg, cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    kg = KeyGen(key)
    enc_keys = jax.random.split(kg(), cfg.n_enc_layers)
    dec_keys = jax.random.split(kg(), cfg.n_layers)
    return {
        "embed": common.embed_params(kg, cfg, dtype),
        "enc_layers": jax.vmap(lambda k: _enc_layer(k, cfg, dtype))(enc_keys),
        "enc_norm": common.rmsnorm_params(cfg.d_model, dtype),
        "dec_layers": jax.vmap(lambda k: _dec_layer(k, cfg, dtype))(dec_keys),
        "final_norm": common.rmsnorm_params(cfg.d_model, dtype),
    }


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------


def encode(params: Dict, cfg: ModelConfig, enc_embeds: jnp.ndarray,
           ) -> jnp.ndarray:
    """(B, S_enc, D) precomputed frame embeddings -> encoder states."""
    h = shard(enc_embeds, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(hcur, lp):
        a = attention.gqa_attention(
            lp["attn"], cfg, common.rmsnorm(lp["ln1"], hcur), positions,
            causal=False)
        hcur = hcur + a
        hcur = hcur + common.mlp_apply(
            lp["mlp"], common.rmsnorm(lp["ln2"], hcur))
        return hcur, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=common.remat_policy_of(cfg))
    h, _ = lax.scan(body, h, params["enc_layers"])
    return common.rmsnorm(params["enc_norm"], h)


def cross_kv(params: Dict, cfg: ModelConfig, enc_out: jnp.ndarray):
    """Per-decoder-layer cross K/V from encoder states (computed once)."""
    b, s, _ = enc_out.shape
    hkv, dh = cfg.n_kv_heads, cfg.head_dim

    def per_layer(lp):
        k = (enc_out @ lp["cross_attn"]["wk"]).reshape(b, s, hkv, dh)
        v = (enc_out @ lp["cross_attn"]["wv"]).reshape(b, s, hkv, dh)
        return k, v

    return jax.vmap(per_layer)(params["dec_layers"])  # (L,B,S,hkv,dh) x2


# ---------------------------------------------------------------------------
# Decoder (teacher-forced training / prefill)
# ---------------------------------------------------------------------------


def _decoder(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
             xk: jnp.ndarray, xv: jnp.ndarray) -> jnp.ndarray:
    h = common.embed_tokens(params["embed"], tokens)
    h = shard(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(hcur, xs):
        lp, xk_l, xv_l = xs
        a = attention.gqa_attention(
            lp["self_attn"], cfg, common.rmsnorm(lp["ln1"], hcur), positions)
        hcur = hcur + a
        c = attention.gqa_attention(
            lp["cross_attn"], cfg, common.rmsnorm(lp["ln_x"], hcur),
            positions, cross_kv=(xk_l, xv_l))
        hcur = hcur + c
        hcur = hcur + common.mlp_apply(
            lp["mlp"], common.rmsnorm(lp["ln2"], hcur))
        return hcur, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=common.remat_policy_of(cfg))
    h, _ = lax.scan(body, h, (params["dec_layers"], xk, xv))
    return common.rmsnorm(params["final_norm"], h)


def forward(params: Dict, cfg: ModelConfig, batch: Dict,
            ) -> Tuple[jnp.ndarray, Dict]:
    enc_out = encode(params, cfg, batch["enc_embeds"])
    xk, xv = cross_kv(params, cfg, enc_out)
    h = _decoder(params, cfg, batch["tokens"], xk, xv)
    return h, {}


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict):
    h, _ = forward(params, cfg, batch)
    logits = common.logits_from_hidden(params["embed"], cfg, h)
    xent = common.softmax_xent(logits, batch["labels"],
                               batch.get("loss_mask"))
    return xent, {"xent": xent}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int) -> Dict:
    dtype = dtype_of(cfg.compute_dtype)
    L, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, max_len, hkv, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, hkv, dh), dtype),
        "xk": jnp.zeros((L, batch, enc_len, hkv, dh), dtype),
        "xv": jnp.zeros((L, batch, enc_len, hkv, dh), dtype),
    }


def cache_specs(cfg: ModelConfig, *, seq_sharded: bool = False):
    seq_ax = "seq" if seq_sharded else None
    return {
        "k": (None, "batch", seq_ax, "kv_heads", None),
        "v": (None, "batch", seq_ax, "kv_heads", None),
        "xk": (None, "batch", seq_ax, "kv_heads", None),
        "xv": (None, "batch", seq_ax, "kv_heads", None),
    }


def prefill(params: Dict, cfg: ModelConfig, batch: Dict):
    """Encode + cross-KV: the enc-dec analogue of prompt prefill."""
    enc_out = encode(params, cfg, batch["enc_embeds"])
    xk, xv = cross_kv(params, cfg, enc_out)
    b = enc_out.shape[0]
    max_len = batch.get("dec_len", 256)
    dtype = dtype_of(cfg.compute_dtype)
    L, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    cache = {
        "k": jnp.zeros((L, b, max_len, hkv, dh), dtype),
        "v": jnp.zeros((L, b, max_len, hkv, dh), dtype),
        "xk": xk.astype(dtype), "xv": xv.astype(dtype),
    }
    bos = jnp.zeros((b, 1), dtype=jnp.int32)
    logits, cache = decode_step(params, cfg, bos, cache,
                                jnp.zeros((b,), jnp.int32))
    return logits, cache


def decode_step(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Dict, lengths: jnp.ndarray):
    h = common.embed_tokens(params["embed"], tokens)
    b = h.shape[0]
    enc_len = cache["xk"].shape[2]
    enc_lengths = jnp.full((b,), enc_len - 1, dtype=jnp.int32)

    def body(hcur, xs):
        lp, k_l, v_l, xk_l, xv_l = xs
        a_in = common.rmsnorm(lp["ln1"], hcur)
        a_out, new_kv = attention.gqa_decode(
            lp["self_attn"], cfg, a_in, {"k": k_l, "v": v_l}, lengths)
        hcur = hcur + a_out
        # cross attention: single query vs static encoder KV
        x_in = common.rmsnorm(lp["ln_x"], hcur)
        q, _, _ = attention.gqa_project_qkv(
            lp["cross_attn"], cfg, x_in, lengths[:, None])
        c = attention.decode_attention(q, xk_l, xv_l, enc_lengths)
        hcur = hcur + c.reshape(b, 1, -1) @ lp["cross_attn"]["wo"]
        hcur = hcur + common.mlp_apply(
            lp["mlp"], common.rmsnorm(lp["ln2"], hcur))
        return hcur, (new_kv["k"], new_kv["v"])

    h, (new_k, new_v) = lax.scan(
        body, h, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    h = common.rmsnorm(params["final_norm"], h)
    logits = common.logits_from_hidden(params["embed"], cfg, h)
    new_cache = dict(cache, k=new_k, v=new_v)
    return logits, new_cache
