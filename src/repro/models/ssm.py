"""Mamba2 (SSD — state-space duality) block.

The selective-state-space recurrence

    h_t = exp(A dt_t) h_{t-1} + dt_t B_t x_t^T ;  y_t = C_t^T h_t + D x_t

is executed with the chunked SSD decomposition: intra-chunk masked matmuls
(the "duality" with attention) + a compact inter-chunk state scan. This is
itself an instance of the paper's philosophy — an irregular per-step
recurrence recast as a static graph of matmuls/convs/reductions (DESIGN.md
§5). Train/prefill use the chunked form (XLA path here; the Pallas
`ssd_scan` kernel is the opt-in fused version); decode is the O(1)-state
single-step update (pure pointwise — no dynamic indexing at all, which is
why SSMs run the long_500k cell).

Layout: d_inner = ssm_expand * d_model, heads = d_inner / ssm_head_dim.
B and C are shared across heads within a single group (n_groups = 1).
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common
from repro.models.common import KeyGen, dense_init


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    return d_inner, n_heads, cfg.ssm_head_dim, cfg.ssm_state


def ssm_params(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d = cfg.d_model
    d_inner, nh, hd, ns = _dims(cfg)
    conv_dim = d_inner + 2 * ns  # conv over x, B, C jointly (mamba2 layout)
    return {
        # order: [z (gate), x, B, C, dt]
        "in_proj": dense_init(
            kg(), (d, 2 * d_inner + 2 * ns + nh), dtype),
        "conv_w": dense_init(kg(), (cfg.ssm_conv, conv_dim), dtype,
                             scale=cfg.ssm_conv ** -0.5),
        "conv_b": jnp.zeros((conv_dim,), dtype=dtype),
        "a_log": jnp.zeros((nh,), dtype=jnp.float32),   # A = -exp(a_log)
        "dt_bias": jnp.full((nh,), -2.0, dtype=jnp.float32),
        "d_skip": jnp.ones((nh,), dtype=jnp.float32),
        "norm": common.rmsnorm_params(d_inner, dtype),
        "out_proj": dense_init(kg(), (d_inner, d), dtype),
    }


def _split_proj(cfg: ModelConfig, proj: jnp.ndarray):
    d_inner, nh, hd, ns = _dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * ns], axis=-1)
    return z, xbc, dt


def _causal_conv(w, b, xbc, state=None):
    """Depthwise causal conv along time. xbc (B, S, C); w (K, C).

    Returns (out (B, S, C), new_state (B, K-1, C)) — state carries the last
    K-1 inputs for streaming decode.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state
    full = jnp.concatenate([pad, xbc], axis=1)          # (B, S+K-1, C)
    # sum_k w[k] * full[:, t+k] — static unrolled taps (K is tiny)
    out = sum(w[i][None, None, :] * full[:, i:i + xbc.shape[1]]
              for i in range(k))
    new_state = full[:, -(k - 1):] if k > 1 else None
    return out + b[None, None, :], new_state


def _ssd_chunked(log_a, x, bmat, cmat, chunk: int):
    """Chunked SSD, pure jnp (the XLA path; mirrors kernels/ssd_scan).

    log_a (B,S,H); x (B,S,H,P); bmat/cmat (B,S,N) group-shared.
    Returns y (B,S,H,P).
    """
    bsz, s, h = log_a.shape
    p = x.shape[-1]
    n = bmat.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    nc = log_a.shape[1] // q

    la = log_a.reshape(bsz, nc, q, h).astype(jnp.float32)
    xc = x.reshape(bsz, nc, q, h, p).astype(jnp.float32)
    bc = bmat.reshape(bsz, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(bsz, nc, q, n).astype(jnp.float32)

    lac = jnp.cumsum(la, axis=2)                        # inclusive, per chunk
    # --- intra-chunk (masked attention-like matmul) ---
    sqq = jnp.einsum("bcin,bcjn->bcij", cc, bc)         # (B,NC,Q,Q)
    # clamp BEFORE exp: for future positions (i < j) the log-decay is
    # positive and exp overflows; the mask kills the value but not the
    # inf in the gradient (0 * inf = NaN in the cotangent).
    dlog = jnp.minimum(
        lac[:, :, :, None, :] - lac[:, :, None, :, :], 0.0)
    decay = jnp.exp(dlog)
    mask = jnp.tril(jnp.ones((q, q), dtype=bool))
    m = jnp.where(mask[None, None, :, :, None], sqq[..., None] * decay, 0.0)
    y = jnp.einsum("bcijh,bcjhp->bcihp", m, xc)

    # --- inter-chunk state scan ---
    ea_last = jnp.exp(lac[:, :, -1, :])                 # (B,NC,H)
    wdec = jnp.exp(lac[:, :, -1:, :] - lac)             # (B,NC,Q,H)
    chunk_state = jnp.einsum("bcqn,bcqh,bcqhp->bchnp", bc, wdec, xc)

    def scan_step(h_prev, inp):
        ea_1, cs_1 = inp                                # (B,H), (B,H,N,P)
        h_new = ea_1[..., None, None] * h_prev + cs_1
        return h_new, h_prev

    h0 = jnp.zeros((bsz, la.shape[-1], n, p), jnp.float32)
    h_last, h_before = lax.scan(
        scan_step,
        h0,
        (ea_last.transpose(1, 0, 2), chunk_state.transpose(1, 0, 2, 3, 4)))
    h_before = h_before.transpose(1, 0, 2, 3, 4)        # (B,NC,H,N,P)

    y = y + jnp.einsum("bcqn,bcqh,bchnp->bcqhp",
                       cc, jnp.exp(lac), h_before)
    y = y.reshape(bsz, nc * q, h, p)
    return y[:, :s], h_last


def ssm_apply(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
              return_state: bool = False):
    """Train/prefill. x (B, S, d_model) -> (B, S, d_model).

    With return_state=True also returns the streaming cache (final SSM
    state + conv tail) so a prefill can hand off to decode.
    """
    d_inner, nh, hd, ns = _dims(cfg)
    bsz, s, _ = x.shape

    proj = x @ params["in_proj"]
    z, xbc_raw, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(params["conv_w"], params["conv_b"],
                                   xbc_raw)
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + ns], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])   # (B,S,H)
    a = -jnp.exp(params["a_log"])[None, None, :]             # (1,1,H)
    log_a = a * dt                                           # <= 0
    xh = xs.reshape(bsz, s, nh, hd)
    # fold dt into x (equivalent to dt * B x^T)
    xh_dt = xh.astype(jnp.float32) * dt[..., None]

    h_last = None
    if cfg.use_ssd_kernel and not return_state:
        from repro.kernels.ssd_scan import ssd_scan
        bh = jnp.broadcast_to(bmat[:, :, None, :], (bsz, s, nh, ns))
        ch = jnp.broadcast_to(cmat[:, :, None, :], (bsz, s, nh, ns))
        y = ssd_scan(log_a, xh_dt, bh, ch, chunk=cfg.ssm_chunk)
    else:
        y, h_last = _ssd_chunked(log_a, xh_dt, bmat, cmat, cfg.ssm_chunk)

    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["out_proj"]
    if return_state:
        # h_last indexed (B, H, N, P); decode cache uses (B, H, N, P) too.
        return out, {"conv": conv_state, "ssm": h_last}
    return out


# ---------------------------------------------------------------------------
# Streaming decode (O(1) state per layer)
# ---------------------------------------------------------------------------


def ssm_init_cache(cfg: ModelConfig, batch: int, dtype) -> Dict:
    d_inner, nh, hd, ns = _dims(cfg)
    conv_dim = d_inner + 2 * ns
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype=dtype),
        "ssm": jnp.zeros((batch, nh, ns, hd), dtype=jnp.float32),
    }


def ssm_decode(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
               cache: Dict) -> Tuple[jnp.ndarray, Dict]:
    """One-step decode. x (B, 1, d_model). No dynamic indexing anywhere."""
    d_inner, nh, hd, ns = _dims(cfg)
    bsz = x.shape[0]

    proj = x @ params["in_proj"]
    z, xbc, dt = _split_proj(cfg, proj)
    xbc, conv_state = _causal_conv(params["conv_w"], params["conv_b"],
                                   xbc, state=cache["conv"])
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + ns], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"][None, None, :])   # (B,1,H)
    a = -jnp.exp(params["a_log"])[None, None, :]
    ea = jnp.exp(a * dt)[:, 0]                               # (B,H)

    xh = xs.reshape(bsz, nh, hd).astype(jnp.float32)         # (B,H,P)
    xh_dt = xh * dt[:, 0, :, None]
    b1 = bmat[:, 0].astype(jnp.float32)                      # (B,N)
    c1 = cmat[:, 0].astype(jnp.float32)

    h_new = (ea[..., None, None] * cache["ssm"] +
             jnp.einsum("bn,bhp->bhnp", b1, xh_dt))
    y = jnp.einsum("bn,bhnp->bhp", c1, h_new)
    y = y + params["d_skip"][None, :, None] * xh
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = common.rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"], {"conv": conv_state, "ssm": h_new}
