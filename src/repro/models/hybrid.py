"""zamba2-1.2b: Mamba2 trunk + a single *shared* attention block.

Zamba2's signature trick: one set of attention weights, invoked after every
`shared_attn_every` Mamba2 layers (6 invocations over a 38-layer trunk
here). Each invocation has its own KV cache slot; the weights are shared.
The Mamba2 trunk runs as segmented lax.scans over stacked params so HLO
stays compact while the shared block sits between segments.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention, common, ssm
from repro.models.common import KeyGen, dtype_of
from repro.runtime.sharding import shard


def _segments(cfg: ModelConfig) -> List[Tuple[int, int]]:
    """[(start, end)) mamba-layer segments; shared attn runs between them."""
    step = cfg.shared_attn_every or cfg.n_layers
    bounds = list(range(0, cfg.n_layers, step)) + [cfg.n_layers]
    return list(zip(bounds[:-1], bounds[1:]))


def n_attn_invocations(cfg: ModelConfig) -> int:
    return len(_segments(cfg)) - 1


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    kg = KeyGen(key)
    layer_keys = jax.random.split(kg(), cfg.n_layers)

    def one_layer(k):
        kg_l = KeyGen(k)
        return {"ln": common.rmsnorm_params(cfg.d_model, dtype),
                "ssm": ssm.ssm_params(kg_l, cfg, dtype)}

    layers = jax.vmap(one_layer)(layer_keys)
    shared_kg = KeyGen(kg())
    shared = {
        "ln1": common.rmsnorm_params(cfg.d_model, dtype),
        "attn": attention.attn_params(shared_kg, cfg, dtype),
        "ln2": common.rmsnorm_params(cfg.d_model, dtype),
        "mlp": common.mlp_params(shared_kg, cfg.d_model, cfg.d_ff, dtype),
    }
    return {
        "embed": common.embed_params(kg, cfg, dtype),
        "layers": layers,
        "shared_attn": shared,
        "final_norm": common.rmsnorm_params(cfg.d_model, dtype),
    }


def _slice_layers(layers: Dict, start: int, end: int) -> Dict:
    return jax.tree.map(lambda a: a[start:end], layers)


def _mamba_segment(cfg: ModelConfig, layers_seg: Dict, h: jnp.ndarray,
                   collect_state: bool = False):
    def body(hcur, lp):
        if collect_state:
            out, st = ssm.ssm_apply(
                lp["ssm"], cfg, common.rmsnorm(lp["ln"], hcur),
                return_state=True)
            return hcur + out, st
        out = ssm.ssm_apply(lp["ssm"], cfg, common.rmsnorm(lp["ln"], hcur))
        return hcur + out, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=common.remat_policy_of(cfg))
    return lax.scan(body, h, layers_seg)


def _shared_attn_block(cfg: ModelConfig, shared: Dict, h, positions,
                       return_kv: bool = False):
    a_in = common.rmsnorm(shared["ln1"], h)
    res = attention.gqa_attention(shared["attn"], cfg, a_in, positions,
                                  return_kv=return_kv)
    if return_kv:
        a_out, kv = res
    else:
        a_out, kv = res, None
    h = h + a_out
    h = h + common.mlp_apply(shared["mlp"],
                             common.rmsnorm(shared["ln2"], h))
    return (h, kv) if return_kv else h


def forward(params: Dict, cfg: ModelConfig, batch: Dict,
            ) -> Tuple[jnp.ndarray, Dict]:
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = shard(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    segs = _segments(cfg)
    for i, (st, en) in enumerate(segs):
        h, _ = _mamba_segment(cfg, _slice_layers(params["layers"], st, en), h)
        if i < len(segs) - 1:
            h = _shared_attn_block(cfg, params["shared_attn"], h, positions)
    h = common.rmsnorm(params["final_norm"], h)
    return h, {}


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict):
    h, _ = forward(params, cfg, batch)
    logits = common.logits_from_hidden(params["embed"], cfg, h)
    xent = common.softmax_xent(logits, batch["labels"],
                               batch.get("loss_mask"))
    return xent, {"xent": xent}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    dtype = dtype_of(cfg.compute_dtype)
    single = ssm.ssm_init_cache(cfg, batch, dtype)
    mamba = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
        single)
    n_inv = n_attn_invocations(cfg)
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "mamba": mamba,
        "attn_k": jnp.zeros((n_inv, batch, max_len, hkv, dh), dtype),
        "attn_v": jnp.zeros((n_inv, batch, max_len, hkv, dh), dtype),
    }


def cache_specs(cfg: ModelConfig, *, seq_sharded: bool = False):
    seq_ax = "seq" if seq_sharded else None
    return {
        "mamba": {"conv": (None, "batch", None, "model"),
                  "ssm": (None, "batch", "model", None, None)},
        "attn_k": (None, "batch", seq_ax, "kv_heads", None),
        "attn_v": (None, "batch", seq_ax, "kv_heads", None),
    }


def prefill(params: Dict, cfg: ModelConfig, batch: Dict):
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = shard(h, "batch", None, None)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    segs = _segments(cfg)
    mamba_states, attn_ks, attn_vs = [], [], []
    for i, (st, en) in enumerate(segs):
        h, states = _mamba_segment(
            cfg, _slice_layers(params["layers"], st, en), h,
            collect_state=True)
        mamba_states.append(states)
        if i < len(segs) - 1:
            h, (k, v) = _shared_attn_block(cfg, params["shared_attn"], h,
                                           positions, return_kv=True)
            attn_ks.append(k)
            attn_vs.append(v)
    h = common.rmsnorm(params["final_norm"], h)
    logits = common.logits_from_hidden(params["embed"], cfg, h[:, -1:])
    cache = {
        "mamba": jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *mamba_states),
        "attn_k": jnp.stack(attn_ks, axis=0),
        "attn_v": jnp.stack(attn_vs, axis=0),
    }
    return logits, cache


def decode_step(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Dict, lengths: jnp.ndarray):
    h = common.embed_tokens(params["embed"], tokens)
    segs = _segments(cfg)

    new_mamba_states, new_ks, new_vs = [], [], []
    for i, (st, en) in enumerate(segs):
        seg_layers = _slice_layers(params["layers"], st, en)
        seg_cache = jax.tree.map(lambda a: a[st:en], cache["mamba"])

        def body(hcur, xs):
            lp, cache_l = xs
            out, new_c = ssm.ssm_decode(
                lp["ssm"], cfg, common.rmsnorm(lp["ln"], hcur), cache_l)
            return hcur + out, new_c

        h, seg_new = lax.scan(body, h, (seg_layers, seg_cache))
        new_mamba_states.append(seg_new)

        if i < len(segs) - 1:
            shared = params["shared_attn"]
            a_in = common.rmsnorm(shared["ln1"], h)
            a_out, kv = attention.gqa_decode(
                shared["attn"], cfg, a_in,
                {"k": cache["attn_k"][i], "v": cache["attn_v"][i]}, lengths)
            h = h + a_out
            h = h + common.mlp_apply(shared["mlp"],
                                     common.rmsnorm(shared["ln2"], h))
            new_ks.append(kv["k"])
            new_vs.append(kv["v"])

    h = common.rmsnorm(params["final_norm"], h)
    logits = common.logits_from_hidden(params["embed"], cfg, h)
    new_cache = {
        "mamba": jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba_states),
        "attn_k": jnp.stack(new_ks, axis=0),
        "attn_v": jnp.stack(new_vs, axis=0),
    }
    return logits, new_cache
