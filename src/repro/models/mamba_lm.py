"""mamba2-130m: attention-free SSM language model.

Per DESIGN.md §5 the paper's dynamic-indexing technique is N/A here — there
is no gather anywhere in this model; the SSD formulation is already a fully
static graph. The arch is implemented without the technique, as assigned.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import common, ssm
from repro.models.common import KeyGen, dtype_of
from repro.runtime.sharding import shard


def _layer_params(key, cfg: ModelConfig, dtype) -> Dict:
    kg = KeyGen(key)
    return {
        "ln": common.rmsnorm_params(cfg.d_model, dtype),
        "ssm": ssm.ssm_params(kg, cfg, dtype),
    }


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    kg = KeyGen(key)
    layer_keys = jax.random.split(kg(), cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_params(k, cfg, dtype))(layer_keys)
    return {
        "embed": common.embed_params(kg, cfg, dtype),
        "layers": layers,
        "final_norm": common.rmsnorm_params(cfg.d_model, dtype),
    }


def forward(params: Dict, cfg: ModelConfig, batch: Dict,
            ) -> Tuple[jnp.ndarray, Dict]:
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = shard(h, "batch", None, None)

    def body(hcur, lp):
        out = ssm.ssm_apply(lp["ssm"], cfg, common.rmsnorm(lp["ln"], hcur))
        return hcur + out, None

    if cfg.remat:
        body = jax.checkpoint(body, policy=common.remat_policy_of(cfg))
    h, _ = lax.scan(body, h, params["layers"])
    h = common.rmsnorm(params["final_norm"], h)
    return h, {}


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict):
    h, _ = forward(params, cfg, batch)
    logits = common.logits_from_hidden(params["embed"], cfg, h)
    xent = common.softmax_xent(logits, batch["labels"],
                               batch.get("loss_mask"))
    return xent, {"xent": xent}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    dtype = dtype_of(cfg.compute_dtype)
    single = ssm.ssm_init_cache(cfg, batch, dtype)
    return jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape),
        single)


def cache_specs(cfg: ModelConfig, *, seq_sharded: bool = False):
    return {
        "conv": (None, "batch", None, "model"),
        "ssm": (None, "batch", "model", None, None),
    }


def prefill(params: Dict, cfg: ModelConfig, batch: Dict):
    """-> (last logits, streaming cache). State emitted per scanned layer."""
    h = common.embed_tokens(params["embed"], batch["tokens"])
    h = shard(h, "batch", None, None)

    def body(hcur, lp):
        out, state = ssm.ssm_apply(
            lp["ssm"], cfg, common.rmsnorm(lp["ln"], hcur),
            return_state=True)
        return hcur + out, state

    if cfg.remat:
        body = jax.checkpoint(body, policy=common.remat_policy_of(cfg))
    h, cache = lax.scan(body, h, params["layers"])
    h = common.rmsnorm(params["final_norm"], h)
    logits = common.logits_from_hidden(params["embed"], cfg, h[:, -1:])
    return logits, cache


def decode_step(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Dict, lengths: jnp.ndarray):
    """lengths is unused (SSM state is positionless) but kept for API parity."""
    del lengths
    h = common.embed_tokens(params["embed"], tokens)

    def body(hcur, xs):
        lp, cache_l = xs
        out, new_cache = ssm.ssm_decode(
            lp["ssm"], cfg, common.rmsnorm(lp["ln"], hcur), cache_l)
        return hcur + out, new_cache

    h, new_cache = lax.scan(body, h, (params["layers"], cache))
    h = common.rmsnorm(params["final_norm"], h)
    logits = common.logits_from_hidden(params["embed"], cfg, h)
    return logits, new_cache
