"""Decoder-only transformer LM: dense, MoE (incl. MLA), VLM backbones.

Covers qwen3-8b, granite-3-8b, llama3-405b, gemma3-1b (5:1 local:global),
qwen2-vl-2b (M-RoPE + vision-embed stub), granite-moe-3b-a800m,
deepseek-v2-236b (MLA + 160-expert MoE).

Layers are stored stacked (leading layer axis) and executed with lax.scan,
so lowered HLO size and compile time are depth-independent — llama3's 126
layers compile as one scanned block. Heterogeneous stacks (gemma3) share
one scanned body; the per-layer kind is a traced input (window / rope base
selected arithmetically, never with python control flow — the paper's
static-graph discipline).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import attention, common, moe
from repro.models.common import KeyGen, dtype_of
from repro.runtime.sharding import shard


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def _layer_params(key, cfg: ModelConfig, dtype) -> Dict:
    kg = KeyGen(key)
    p = {"ln1": common.rmsnorm_params(cfg.d_model, dtype),
         "ln2": common.rmsnorm_params(cfg.d_model, dtype)}
    if cfg.use_mla:
        p["attn"] = attention.mla_params(kg, cfg, dtype)
    else:
        p["attn"] = attention.attn_params(kg, cfg, dtype)
    if cfg.n_experts:
        p["moe"] = moe.moe_params(kg, cfg, dtype)
    else:
        p["mlp"] = common.mlp_params(kg, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Dict:
    dtype = dtype_of(cfg.param_dtype)
    kg = KeyGen(key)
    layer_keys = jax.random.split(kg(), cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_params(k, cfg, dtype))(layer_keys)
    return {
        "embed": common.embed_params(kg, cfg, dtype),
        "layers": layers,
        "final_norm": common.rmsnorm_params(cfg.d_model, dtype),
    }


def layer_kinds(cfg: ModelConfig) -> np.ndarray:
    """Per-layer is_local flags (gemma3 N:1 pattern; all-global else)."""
    if cfg.local_global_pattern > 0:
        period = cfg.local_global_pattern + 1
        return (np.arange(cfg.n_layers) % period
                != cfg.local_global_pattern).astype(np.int32)
    return np.zeros((cfg.n_layers,), dtype=np.int32)


# ---------------------------------------------------------------------------
# Embedding (token + optional modality-stub override)
# ---------------------------------------------------------------------------


def embed_inputs(params: Dict, cfg: ModelConfig, batch: Dict) -> jnp.ndarray:
    h = common.embed_tokens(params["embed"], batch["tokens"])
    if cfg.frontend != "none" and "embeds" in batch:
        # Precomputed patch/frame embeddings (frontend is a stub per the
        # assignment): override token embeddings where embed_mask == 1.
        m = batch["embed_mask"][..., None].astype(h.dtype)
        h = h * (1.0 - m) + batch["embeds"].astype(h.dtype) * m
    if cfg.family == "dense" and cfg.vocab_size > 200_000:
        # gemma-style sqrt(d) embedding scale (large-vocab stability)
        h = h * jnp.asarray(np.sqrt(cfg.d_model), dtype=h.dtype)
    return h


def _positions(cfg: ModelConfig, batch: Dict, s: int) -> jnp.ndarray:
    if "positions" in batch:
        return batch["positions"]
    b = batch["tokens"].shape[0]
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(pos[:, None, :], (b, 3, s))
    return pos


# ---------------------------------------------------------------------------
# Forward (train / prefill): scan over stacked layers
# ---------------------------------------------------------------------------


def forward(params: Dict, cfg: ModelConfig, batch: Dict,
            ) -> Tuple[jnp.ndarray, Dict]:
    """-> (hidden (B,S,D), aux losses)."""
    h = embed_inputs(params, cfg, batch)
    h = shard(h, "batch", None, None)
    b, s, _ = h.shape
    positions = _positions(cfg, batch, s)
    kinds = jnp.asarray(layer_kinds(cfg))

    def body(carry, xs):
        hcur, aux_lb, aux_z = carry
        lp, is_local = xs
        window = jnp.where(is_local > 0, cfg.sliding_window, 0)
        a_in = common.rmsnorm(lp["ln1"], hcur)
        if cfg.use_mla:
            a_out = attention.mla_attention(lp["attn"], cfg, a_in, positions)
        else:
            a_out = attention.gqa_attention(
                lp["attn"], cfg, a_in, positions, window=window,
                is_local=(is_local > 0))
        hcur = hcur + a_out
        f_in = common.rmsnorm(lp["ln2"], hcur)
        if cfg.n_experts:
            f_out, aux = moe.moe_apply(lp["moe"], cfg, f_in)
            aux_lb = aux_lb + aux["moe_lb_loss"]
            aux_z = aux_z + aux["moe_z_loss"]
        else:
            f_out = common.mlp_apply(lp["mlp"], f_in)
        hcur = hcur + f_out
        hcur = shard(hcur, "batch", None, None)
        return (hcur, aux_lb, aux_z), None

    if cfg.remat:
        body = jax.checkpoint(body, policy=common.remat_policy_of(cfg))

    (h, aux_lb, aux_z), _ = lax.scan(
        body, (h, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (params["layers"], kinds))

    h = common.rmsnorm(params["final_norm"], h)
    denom = max(cfg.n_layers, 1)
    return h, {"moe_lb_loss": aux_lb / denom, "moe_z_loss": aux_z / denom}


def loss_fn(params: Dict, cfg: ModelConfig, batch: Dict,
            ) -> Tuple[jnp.ndarray, Dict]:
    h, aux = forward(params, cfg, batch)
    logits = common.logits_from_hidden(params["embed"], cfg, h)
    mask = batch.get("loss_mask")
    xent = common.softmax_xent(logits, batch["labels"], mask)
    loss = xent + 0.01 * aux["moe_lb_loss"] + aux["moe_z_loss"]
    return loss, {"xent": xent, **aux}


# ---------------------------------------------------------------------------
# Prefill: forward pass that also emits the KV cache (scan ys)
# ---------------------------------------------------------------------------


def prefill(params: Dict, cfg: ModelConfig, batch: Dict,
            ) -> Tuple[jnp.ndarray, Dict]:
    """Fill the cache from a full prompt. -> (last-position logits, cache)."""
    h = embed_inputs(params, cfg, batch)
    h = shard(h, "batch", None, None)
    b, s, _ = h.shape
    positions = _positions(cfg, batch, s)
    kinds = jnp.asarray(layer_kinds(cfg))

    def body(hcur, xs):
        lp, is_local = xs
        window = jnp.where(is_local > 0, cfg.sliding_window, 0)
        a_in = common.rmsnorm(lp["ln1"], hcur)
        if cfg.use_mla:
            a_out, kv = attention.mla_attention(
                lp["attn"], cfg, a_in, positions, return_kv=True)
        else:
            a_out, kv = attention.gqa_attention(
                lp["attn"], cfg, a_in, positions, window=window,
                is_local=(is_local > 0), return_kv=True)
        hcur = hcur + a_out
        f_in = common.rmsnorm(lp["ln2"], hcur)
        if cfg.n_experts:
            f_out, _ = moe.moe_apply(lp["moe"], cfg, f_in)
        else:
            f_out = common.mlp_apply(lp["mlp"], f_in)
        return hcur + f_out, kv

    if cfg.remat:
        body = jax.checkpoint(body, policy=common.remat_policy_of(cfg))
    h1, kvs = lax.scan(body, h, (params["layers"], kinds))
    h1 = common.rmsnorm(params["final_norm"], h1)
    logits = common.logits_from_hidden(params["embed"], cfg, h1[:, -1:])
    if cfg.use_mla:
        cache = {"c_kv": kvs[0], "k_rope": kvs[1]}
    else:
        cache = {"k": kvs[0], "v": kvs[1]}
    return logits, cache


# ---------------------------------------------------------------------------
# KV cache + decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict:
    dtype = dtype_of(cfg.compute_dtype)
    L = cfg.n_layers
    if cfg.use_mla:
        return {
            "c_kv": jnp.zeros((L, batch, max_len, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((L, batch, max_len, 1,
                                 cfg.qk_rope_head_dim), dtype),
        }
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((L, batch, max_len, hkv, dh), dtype),
        "v": jnp.zeros((L, batch, max_len, hkv, dh), dtype),
    }


def cache_specs(cfg: ModelConfig, *, seq_sharded: bool = False):
    """Logical axes for the cache pytree (for launch-time shardings)."""
    seq_ax = "seq" if seq_sharded else None
    if cfg.use_mla:
        return {
            "c_kv": (None, "batch", seq_ax, None),
            "k_rope": (None, "batch", seq_ax, None, None),
        }
    return {
        "k": (None, "batch", seq_ax, "kv_heads", None),
        "v": (None, "batch", seq_ax, "kv_heads", None),
    }


def decode_step(params: Dict, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: Dict, lengths: jnp.ndarray,
                ) -> Tuple[jnp.ndarray, Dict]:
    """One decode step. tokens (B, 1); lengths (B,) write positions.

    The layer-stacked cache rides the scan CARRY and is updated with
    token-granular windows (stacked_cache_update) — per step each layer
    costs one cache-slice read plus a one-token write, instead of the
    full-layer rewrite a scan-ys cache implies (§Perf iteration 2).

    Returns (logits (B, 1, V), updated cache).
    """
    h = common.embed_tokens(params["embed"], tokens)
    if cfg.family == "dense" and cfg.vocab_size > 200_000:
        h = h * jnp.asarray(np.sqrt(cfg.d_model), dtype=h.dtype)
    h = shard(h, "batch", None, None)
    kinds = jnp.asarray(layer_kinds(cfg))
    layer_ids = jnp.arange(cfg.n_layers, dtype=jnp.int32)

    def body(carry, xs):
        hcur, cache_full = carry
        lp, is_local, i = xs
        window = jnp.where(is_local > 0, cfg.sliding_window, 0)
        a_in = common.rmsnorm(lp["ln1"], hcur)
        if cfg.use_mla:
            a_out, cache_full = attention.mla_decode(
                lp["attn"], cfg, a_in, cache_full, lengths, layer_idx=i)
        else:
            a_out, cache_full = attention.gqa_decode_stacked(
                lp["attn"], cfg, a_in, cache_full, lengths, i,
                window=window, is_local=(is_local > 0))
        hcur = hcur + a_out
        f_in = common.rmsnorm(lp["ln2"], hcur)
        if cfg.n_experts:
            f_out, _ = moe.moe_apply(lp["moe"], cfg, f_in)
        else:
            f_out = common.mlp_apply(lp["mlp"], f_in)
        return (hcur + f_out, cache_full), None

    (h1, new_cache), _ = lax.scan(
        body, (h, cache), (params["layers"], kinds, layer_ids))
    h1 = common.rmsnorm(params["final_norm"], h1)
    logits = common.logits_from_hidden(params["embed"], cfg, h1)
    return logits, new_cache
