"""Shared building blocks: init helpers, norms, RoPE (incl. M-RoPE), MLP.

Parameters are plain nested dicts of jnp arrays (pytrees). Layer stacks are
stored with a leading layer axis and executed with lax.scan so the lowered
HLO is depth-independent (critical for 126-layer dry-runs).
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.runtime.sharding import shard_pin


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


class KeyGen:
    """Sequential PRNG splitter for readable init code."""

    def __init__(self, key):
        self._key = key

    def __call__(self):
        self._key, sub = jax.random.split(self._key)
        return sub


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_params(d: int, dtype) -> Dict:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(params: Dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
               mrope_sections: Sequence[int] = ()) -> jnp.ndarray:
    """Rotary embedding, computed on the fly (no precomputed tables).

    x: (B, S, H, D); positions: (B, S) int32, or (B, 3, S) for M-RoPE
    (temporal/height/width position triplets, qwen2-vl style). With M-RoPE,
    `mrope_sections` gives the per-axis split of D/2 frequency slots.
    """
    b, s, h, d = x.shape
    half = d // 2
    inv = jnp.asarray(rope_freqs(d, theta), dtype=jnp.float32)  # (half,)

    if mrope_sections:
        assert positions.ndim == 3, "M-RoPE needs (B, 3, S) positions"
        assert sum(mrope_sections) == half, (mrope_sections, half)
        # Each frequency slot takes its position from one of the 3 axes.
        sect = np.repeat(np.arange(len(mrope_sections)),
                         mrope_sections)                      # (half,)
        sect = jnp.asarray(sect)
        pos = positions.astype(jnp.float32)                   # (B, 3, S)
        pos_per_slot = jnp.take_along_axis(
            pos, jnp.broadcast_to(sect[None, :, None], (b, half, s)).astype(
                jnp.int32), axis=1)                           # (B, half, S)
        ang = pos_per_slot.transpose(0, 2, 1) * inv[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, half)

    cos = jnp.cos(ang)[:, :, None, :]                         # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    # NOTE: callers constrain the rotated output (attention.py
    # _post_rope_shard) — positions/cos/sin are replicated and would
    # otherwise propagate "replicated" onto q/k (measured: full-tensor
    # f32 all-gathers in every layer).
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_params(kg: KeyGen, d: int, ff: int, dtype) -> Dict:
    return {
        "wi_gate": dense_init(kg(), (d, ff), dtype),
        "wi_up": dense_init(kg(), (d, ff), dtype),
        "wo": dense_init(kg(), (ff, d), dtype),
    }


def mlp_apply(params: Dict, x: jnp.ndarray) -> jnp.ndarray:
    gate = jax.nn.silu(x @ params["wi_gate"])
    up = x @ params["wi_up"]
    return (gate * up) @ params["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_params(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    p = {"embedding": dense_init(kg(), (cfg.vocab_size, cfg.d_model), dtype,
                                 scale=0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(kg(), (cfg.d_model, cfg.vocab_size), dtype)
    return p


def embed_tokens(params: Dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["embedding"], tokens, axis=0)


def logits_from_hidden(params: Dict, cfg: ModelConfig,
                       h: jnp.ndarray) -> jnp.ndarray:
    if cfg.tie_embeddings:
        w = params["embedding"].T
    else:
        w = params["lm_head"]
    # f32 logits for a stable softmax/loss.
    return jnp.einsum("...d,dv->...v", h.astype(jnp.float32),
                      w.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token cross entropy; logits (..., V) f32, labels (...)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def remat_policy_of(cfg):
    """jax.checkpoint policy from ModelConfig.remat_policy."""
    import jax
    if cfg.remat_policy == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint_policies.nothing_saveable
