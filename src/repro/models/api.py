"""Uniform model API: one entry point for all 10 architectures.

`get_model(cfg)` returns a `Model` whose methods close over the config:
  init_params(key)                  -> params pytree
  loss_fn(params, batch)            -> (scalar loss, metrics)
  forward(params, batch)            -> (hidden, aux)
  prefill(params, batch)            -> (logits, cache)
  init_cache(batch, max_len, ...)   -> cache pytree
  decode_step(params, tokens, cache, lengths) -> (logits, cache)
  cache_specs(seq_sharded=...)      -> logical sharding axes for the cache
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, mamba_lm, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable
    loss_fn: Callable
    forward: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable
    cache_specs: Callable


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "ssm": mamba_lm,
    "hybrid": hybrid,
    "audio": encdec,
}


def get_model(cfg: ModelConfig) -> Model:
    mod = _FAMILY_MODULES[cfg.family]
    return Model(
        cfg=cfg,
        init_params=functools.partial(_flip(mod.init_params), cfg=cfg),
        loss_fn=functools.partial(_with_cfg(mod.loss_fn), cfg),
        forward=functools.partial(_with_cfg(mod.forward), cfg),
        prefill=functools.partial(_with_cfg(mod.prefill), cfg),
        init_cache=functools.partial(mod.init_cache, cfg),
        decode_step=functools.partial(_with_cfg(mod.decode_step), cfg),
        cache_specs=functools.partial(mod.cache_specs, cfg),
    )


def _flip(fn):
    def wrapped(key, *, cfg):
        return fn(cfg, key)
    return wrapped


def _with_cfg(fn):
    def wrapped(cfg, params, *args, **kwargs):
        return fn(params, cfg, *args, **kwargs)
    return wrapped
