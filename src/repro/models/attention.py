"""Attention: GQA (+qk-norm, sliding window), MLA, KV-cache variants.

Three execution paths, all one codebase (the paper's portability contract):
  * train/prefill — `chunked_attention`: lax.scan over query blocks, scores
    never materialized at (S x S); safe to lower at 32k and beyond.
  * decode — single-query attention over the cache; per-slot lengths
    (continuous-batching style). The cache *update* ships in the paper's
    V1 (dynamic_update_slice) and V2 (one-hot blend — pure CNN ops)
    variants, selectable per config (`kv_variant`).
  * optional Pallas flash kernel for prefill (config.use_flash_kernel).

Long-context decode (long_500k) relies on the cache being sharded along the
sequence axis; reductions over that axis (softmax max/sum, weighted sum)
are handled by the SPMD partitioner as cross-shard collectives.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.core.config import Variant
from repro.models import common
from repro.models.common import KeyGen, dense_init
from repro.runtime import sharding as shlib
from repro.runtime.sharding import shard


def _heads_shardable(cfg: ModelConfig) -> bool:
    binding = shlib.current_binding()
    if binding is None:
        return True
    ext = binding.extent(binding.rules.get("model", ()))
    return ext <= 1 or cfg.n_heads % ext == 0


def _attn_fallback_shard(x):
    """Hard batch-over-whole-mesh constraint iff the batch dim divides."""
    binding = shlib.current_binding()
    if binding is None:
        return x
    ext = binding.extent(binding.rules.get("attn_batch", ()))
    if ext > 1 and x.shape[0] % ext == 0:
        return shard(x, "attn_batch", *([None] * (x.ndim - 1)))
    return x


def _post_rope_shard(cfg: ModelConfig, t):
    """Constraint on rotated q/k (rope's replicated cos/sin otherwise
    propagate replication onto them — full-tensor f32 gathers per layer).

    Head-sharded archs: pin only batch (UNCONSTRAINED heads keep TP).
    attn-batch-fallback archs: hard batch pin (replicated elsewhere) —
    the soft variant let the partitioner choose layouts that regressed
    train cells 5x (§Perf log). Replicated-attention archs (heads don't
    divide, fallback off — gemma3/qwen2-vl): NO pin; their attention is
    replicated anyway, and any pin inserts per-layer reshards (measured
    2x on gemma3 prefill).
    """
    if _heads_shardable(cfg):
        return shlib.shard_pin(t, d0="batch")
    if cfg.attn_batch_fallback:
        return shard(t, "batch", *([None] * (t.ndim - 1)))
    return t

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def attn_params(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(kg(), (d, h * dh), dtype),
        "wk": dense_init(kg(), (d, hkv * dh), dtype),
        "wv": dense_init(kg(), (d, hkv * dh), dtype),
        "wo": dense_init(kg(), (h * dh, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = common.rmsnorm_params(dh, dtype)
        p["k_norm"] = common.rmsnorm_params(dh, dtype)
    return p


def mla_params(kg: KeyGen, cfg: ModelConfig, dtype) -> Dict:
    d, h = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    p = {
        "wq_a": dense_init(kg(), (d, cfg.q_lora_rank), dtype),
        "q_norm": common.rmsnorm_params(cfg.q_lora_rank, dtype),
        "wq_b": dense_init(kg(), (cfg.q_lora_rank, h * (dn + dr)), dtype),
        "wkv_a": dense_init(kg(), (d, cfg.kv_lora_rank + dr), dtype),
        "kv_norm": common.rmsnorm_params(cfg.kv_lora_rank, dtype),
        "wk_b": dense_init(kg(), (cfg.kv_lora_rank, h * dn), dtype),
        "wv_b": dense_init(kg(), (cfg.kv_lora_rank, h * dv), dtype),
        "wo": dense_init(kg(), (h * dv, d), dtype),
    }
    return p


# ---------------------------------------------------------------------------
# Masks (additive bias, built per query chunk — never (S x S) at once)
# ---------------------------------------------------------------------------


def _chunk_bias(q_start, bq: int, kv_len: int, *, causal: bool,
                window, q_offset) -> jnp.ndarray:
    """(bq, kv_len) additive bias for queries [q_start, q_start+bq).

    `window` may be a *traced* scalar (gemma3's local/global layers share one
    scanned block body); window <= 0 means unbounded.
    """
    rows = q_offset + q_start + lax.broadcasted_iota(
        jnp.int32, (bq, kv_len), 0)
    cols = lax.broadcasted_iota(jnp.int32, (bq, kv_len), 1)
    ok = jnp.ones((bq, kv_len), dtype=bool)
    if causal:
        ok &= cols <= rows
    w = jnp.asarray(window, dtype=jnp.int32)
    weff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
    ok &= cols > rows - weff
    return jnp.where(ok, 0.0, NEG_INF)


# ---------------------------------------------------------------------------
# Chunked attention (train / prefill)
# ---------------------------------------------------------------------------


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      causal: bool = True, window=0,
                      q_offset: int | jnp.ndarray = 0,
                      chunk: int = 512,
                      softcap: float = 0.0,
                      pin_batch_only: bool = False) -> jnp.ndarray:
    """(B,S,H,dh) x (B,Sk,Hkv,dh)^2 -> (B,S,H,dh); scores blockwise only.

    GQA is expressed by reshaping q heads into (Hkv, rep) groups so no kv
    duplication is materialized.

    pin_batch_only: hard-pin operands batch-sharded/replicated-elsewhere.
    Used by replicated-attention archs (heads don't divide the model
    axis): without it the partitioner shards the d_head *contraction*
    dim and all-reduces the (bq x Sk) scores of every chunk — measured
    at 223 GB/device on gemma3 prefill_32k.
    """
    b, s, h, dh = q.shape
    _, sk, hkv, _ = k.shape
    rep = h // hkv
    scale = dh ** -0.5

    bq = min(chunk, s)
    pad = (-s) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // bq
    qc = q.reshape(b, nq, bq, hkv, rep, dh).transpose(1, 0, 3, 4, 2, 5)
    # qc: (nq, B, Hkv, rep, bq, dh)

    # storage-dtype operands + f32 accumulation (no f32 copies of K/V)
    kt = k.transpose(0, 2, 1, 3)                       # (B, Hkv, Sk, dh)
    vt = v.transpose(0, 2, 1, 3)
    if pin_batch_only:
        qc = shard(qc, None, "batch", None, None, None, None)
        kt = shard(kt, "batch", None, None, None)
        vt = shard(vt, "batch", None, None, None)

    def one_chunk(ci, q_blk):
        # q_blk: (B, Hkv, rep, bq, dh)
        s_blk = jnp.einsum("bgrqd,bgkd->bgrqk", q_blk, kt,
                           preferred_element_type=jnp.float32) * scale
        if softcap > 0.0:
            s_blk = jnp.tanh(s_blk / softcap) * softcap
        bias = _chunk_bias(ci * bq, bq, sk, causal=causal, window=window,
                           q_offset=q_offset)
        s_blk = s_blk + bias[None, None, None]
        p = jax.nn.softmax(s_blk, axis=-1)
        return jnp.einsum("bgrqk,bgkd->bgrqd", p.astype(vt.dtype), vt,
                          preferred_element_type=jnp.float32)

    out = lax.map(lambda args: one_chunk(*args),
                  (jnp.arange(nq), qc))                # (nq,B,Hkv,rep,bq,dh)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(b, nq * bq, h, dh)
    return out[:, :s].astype(q.dtype)


# ---------------------------------------------------------------------------
# KV-cache update: the paper's V1 (dynamic) vs V2 (one-hot CNN) variants
# ---------------------------------------------------------------------------


def cache_update(cache: jnp.ndarray, new: jnp.ndarray,
                 lengths: jnp.ndarray, variant: Variant) -> jnp.ndarray:
    """Write `new` (B, 1, H, dh) into cache (B, S, H, dh) at per-slot index.

    V1 DYNAMIC: per-batch dynamic_update_slice (gather/scatter addressing).
    V2 CNN:     one-hot blend — cache*(1-m) + new*m with m built from iota;
                pure pointwise arithmetic, the paper's portable formulation.
    """
    b, s = cache.shape[0], cache.shape[1]
    if variant == Variant.DYNAMIC:
        def upd(c1, n1, p1):
            return lax.dynamic_update_slice_in_dim(c1, n1, p1, axis=0)
        return jax.vmap(upd)(cache, new, lengths)
    # CNN variant (also used for SPARSE at this op: no blocked structure to
    # exploit for a single-position write).
    iota = lax.broadcasted_iota(jnp.int32, (b, s), 1)
    m = (iota == lengths[:, None]).astype(cache.dtype)[..., None, None]
    return cache * (1.0 - m) + new.astype(cache.dtype) * m


def stacked_cache_update(cache: jnp.ndarray, new: jnp.ndarray,
                         lengths: jnp.ndarray, layer_idx,
                         variant: Variant) -> jnp.ndarray:
    """Write `new` (B,1,H,dh) into a layer-stacked cache (L,B,S,H,dh) at
    (layer_idx, :, lengths[b]) — token-granular, so a scan-carried cache
    costs one window write per layer instead of a full-layer rewrite
    (§Perf iteration 2: 3.4x decode HBM-bytes reduction).

    V1 DYNAMIC: per-batch DUS window (1,1,H,dh).
    V2 CNN:     (L,S) one-hot blend — touches the whole buffer by
                construction (the paper's portability-for-traffic trade,
                now visible at cache scale).
    """
    l, b, s = cache.shape[0], cache.shape[1], cache.shape[2]
    if variant == Variant.DYNAMIC:
        # One scatter with B token-windows; expressible in-place, so the
        # scan carry aliases (a vmap-of-DUS here defeats aliasing and
        # copies the whole cache every layer — measured, not theoretical).
        rows = jnp.broadcast_to(jnp.asarray(layer_idx, jnp.int32), (b,))
        return cache.at[rows, jnp.arange(b, dtype=jnp.int32),
                        lengths].set(new[:, 0].astype(cache.dtype),
                                     mode="drop")
    iota_l = lax.broadcasted_iota(jnp.int32, (l, b, s), 0)
    iota_s = lax.broadcasted_iota(jnp.int32, (l, b, s), 2)
    m = ((iota_l == layer_idx) &
         (iota_s == lengths[None, :, None])).astype(cache.dtype)
    m = m[..., None, None]
    return cache * (1.0 - m) + new[None].astype(cache.dtype) * m


# ---------------------------------------------------------------------------
# Decode attention (single query vs cache, per-slot lengths)
# ---------------------------------------------------------------------------


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, lengths: jnp.ndarray, *,
                     window=0,
                     softcap: float = 0.0) -> jnp.ndarray:
    """q (B,1,H,dh); caches (B,S,Hkv,dh); lengths (B,) current position.

    Attends to cols <= lengths[b] (the new token was just written there).
    Softmax reductions run over the cache's sequence axis; if that axis is
    sharded, the partitioner inserts the cross-shard collectives
    (flash-decode-style partial softmax, derived automatically).
    """
    b, _, h, dh = q.shape
    s = k_cache.shape[1]
    hkv = k_cache.shape[2]
    rep = h // hkv
    scale = dh ** -0.5

    # Cache operands stay in their storage dtype (bf16); accumulation is
    # f32 via preferred_element_type. Casting the cache would materialize
    # (and re-shard) a 2x-size copy — measured as the dominant collective
    # AND memory cost of the decode cells (EXPERIMENTS.md §Perf).
    qg = q.reshape(b, hkv, rep, dh)
    scores = jnp.einsum("bgrd,bsgd->bgrs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    if softcap > 0.0:
        scores = jnp.tanh(scores / softcap) * softcap

    cols = lax.broadcasted_iota(jnp.int32, (b, s), 1)
    ok = cols <= lengths[:, None]
    w = jnp.asarray(window, dtype=jnp.int32)
    weff = jnp.where(w > 0, w, jnp.int32(2 ** 30))
    ok &= cols > (lengths[:, None] - weff)
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :]
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full GQA attention block (projections + rope + attention + out-proj)
# ---------------------------------------------------------------------------


def gqa_project_qkv(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray, is_local=None,
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(b, s, h, dh)
    k = (x @ params["wk"]).reshape(b, s, hkv, dh)
    v = (x @ params["wv"]).reshape(b, s, hkv, dh)
    if cfg.qk_norm:
        q = common.rmsnorm(params["q_norm"], q)
        k = common.rmsnorm(params["k_norm"], k)

    def rope(t):
        out = common.apply_rope(t, positions, cfg.rope_theta,
                                cfg.mrope_sections)
        if cfg.rope_local_theta and is_local is not None:
            # gemma3: local layers use a different rope base; is_local is a
            # traced scalar (one scanned body serves both layer kinds).
            loc = common.apply_rope(t, positions, cfg.rope_local_theta,
                                    cfg.mrope_sections)
            out = jnp.where(is_local, loc, out)
        return _post_rope_shard(cfg, out)

    return rope(q), rope(k), v


def gqa_attention(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, *, window=0, is_local=None,
                  cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                  causal: bool = True, return_kv: bool = False):
    """Train/prefill self- (or cross-) attention over full sequences."""
    q, k, v = gqa_project_qkv(params, cfg, x, positions, is_local)
    if cross_kv is not None:
        k, v = cross_kv
        causal = False
    if _heads_shardable(cfg):
        q = shard(q, "batch", None, "model", None)
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
    elif cfg.attn_batch_fallback:
        # Heads don't divide the model axis: fold the model axis into the
        # batch dim so attention runs once across the full mesh instead
        # of replicated 16x. Hard constraint (soft variants measurably
        # regress); opt-in per config — see attn_batch_fallback.
        q = _attn_fallback_shard(q)
        k = _attn_fallback_shard(k)
        v = _attn_fallback_shard(v)
    static_window = isinstance(window, int) and window == 0
    if (cfg.use_flash_kernel and causal and static_window
            and cross_kv is None):
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(q, k, v, causal=True)
    else:
        out = chunked_attention(
            q, k, v, causal=causal, window=window, chunk=cfg.attn_chunk,
            softcap=cfg.attn_logit_softcap,
            pin_batch_only=(not _heads_shardable(cfg)
                            and not cfg.attn_batch_fallback))
    b, s = x.shape[:2]
    y = out.reshape(b, s, -1) @ params["wo"]
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode_stacked(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                       cache: Dict, lengths: jnp.ndarray, layer_idx, *,
                       window=0, is_local=None) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode against a layer-stacked carried cache.

    cache: {"k","v"} of (L,B,S,hkv,dh). Writes one token window at
    (layer_idx, :, lengths[b]), then attends against the layer's slice.
    """
    b = x.shape[0]
    positions = lengths[:, None]
    if cfg.mrope_sections:
        positions = jnp.broadcast_to(lengths[:, None, None], (b, 3, 1))
    q, k, v = gqa_project_qkv(params, cfg, x, positions, is_local)
    k_full = stacked_cache_update(cache["k"], k, lengths, layer_idx,
                                  cfg.kv_variant)
    v_full = stacked_cache_update(cache["v"], v, lengths, layer_idx,
                                  cfg.kv_variant)
    k_l = lax.dynamic_index_in_dim(k_full, layer_idx, 0, keepdims=False)
    v_l = lax.dynamic_index_in_dim(v_full, layer_idx, 0, keepdims=False)
    out = decode_attention(q, k_l, v_l, lengths, window=window,
                           softcap=cfg.attn_logit_softcap)
    y = out.reshape(b, 1, -1) @ params["wo"]
    return y, {"k": k_full, "v": v_full}


def gqa_decode(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
               cache: Dict, lengths: jnp.ndarray, *, window=0,
               is_local=None) -> Tuple[jnp.ndarray, Dict]:
    """One-token decode with cache update. x: (B, 1, D)."""
    b = x.shape[0]
    positions = lengths[:, None]  # (B, 1)
    if cfg.mrope_sections:
        # text continuation: all three M-RoPE axes advance with the token
        positions = jnp.broadcast_to(lengths[:, None, None], (b, 3, 1))
    q, k, v = gqa_project_qkv(params, cfg, x, positions, is_local)
    k_cache = cache_update(cache["k"], k, lengths, cfg.kv_variant)
    v_cache = cache_update(cache["v"], v, lengths, cfg.kv_variant)
    out = decode_attention(q, k_cache, v_cache, lengths, window=window,
                           softcap=cfg.attn_logit_softcap)
    y = out.reshape(b, 1, -1) @ params["wo"]
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (deepseek-v2): low-rank compressed KV, absorbed decode
# ---------------------------------------------------------------------------


def _mla_qkv_expand(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                    positions: jnp.ndarray):
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    ql = common.rmsnorm(params["q_norm"], x @ params["wq_a"])
    q = (ql @ params["wq_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = _post_rope_shard(
        cfg, common.apply_rope(q_rope, positions, cfg.rope_theta))

    kv = x @ params["wkv_a"]                       # (B,S, rank+dr)
    c_kv = common.rmsnorm(params["kv_norm"], kv[..., :cfg.kv_lora_rank])
    k_rope = _post_rope_shard(
        cfg, common.apply_rope(kv[..., None, cfg.kv_lora_rank:], positions,
                               cfg.rope_theta))    # (B,S,1,dr) shared head
    return q_nope, q_rope, c_kv, k_rope


def mla_attention(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
                  positions: jnp.ndarray, *, return_kv: bool = False):
    """Train/prefill MLA with expanded keys/values (chunk-safe einsums)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim

    q_nope, q_rope, c_kv, k_rope = _mla_qkv_expand(params, cfg, x, positions)
    k_nope = (c_kv @ params["wk_b"]).reshape(b, s, h, dn)
    v = (c_kv @ params["wv_b"]).reshape(b, s, h, dv)
    # Pack rope/nope into one head dim so chunked_attention applies.
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope, (b, s, h, dr))], axis=-1)
    # v has a different head dim; pad to match for the shared kernel, then
    # slice (cheap, fused by XLA).
    dh = dn + dr
    v_pad = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dh - dv)))
    out = chunked_attention(q, k, v_pad, causal=True, chunk=cfg.attn_chunk)
    out = out[..., :dv]
    y = out.reshape(b, s, -1) @ params["wo"]
    if return_kv:
        return y, (c_kv, k_rope)
    return y


def mla_decode(params: Dict, cfg: ModelConfig, x: jnp.ndarray,
               cache: Dict, lengths: jnp.ndarray, layer_idx=None,
               ) -> Tuple[jnp.ndarray, Dict]:
    """Absorbed-weight MLA decode: attention runs in the compressed space.

    Cache holds only (c_kv, k_rope) — the MLA memory saving (the reason
    deepseek-v2 fits a 128-slot 32k cache in ~100 MB/device). With
    layer_idx given, the cache is the layer-stacked carry and updates are
    token-granular (see stacked_cache_update).
    """
    b = x.shape[0]
    h = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    rank = cfg.kv_lora_rank
    positions = lengths[:, None]

    q_nope, q_rope, c_kv, k_rope = _mla_qkv_expand(params, cfg, x, positions)
    if layer_idx is not None:
        ckv_full = stacked_cache_update(
            cache["c_kv"][..., None, :], c_kv[..., None, :], lengths,
            layer_idx, cfg.kv_variant)[..., 0, :]
        rope_full = stacked_cache_update(cache["k_rope"], k_rope, lengths,
                                         layer_idx, cfg.kv_variant)
        ckv_cache = lax.dynamic_index_in_dim(ckv_full, layer_idx, 0,
                                             keepdims=False)
        rope_cache = lax.dynamic_index_in_dim(rope_full, layer_idx, 0,
                                              keepdims=False)
    else:
        ckv_cache = cache_update(
            cache["c_kv"][..., None, :], c_kv[..., None, :],
            lengths, cfg.kv_variant)[..., 0, :]
        rope_cache = cache_update(cache["k_rope"], k_rope, lengths,
                                  cfg.kv_variant)

    # Absorb wk_b into the query: q_eff (B,1,H,rank). Cache operands stay
    # bf16; accumulate f32 (no f32 cache copies — see decode_attention).
    wk_b = params["wk_b"].reshape(rank, h, dn)
    q_eff = jnp.einsum("bohd,rhd->bohr", q_nope, wk_b,
                       preferred_element_type=jnp.float32)
    scale = (dn + dr) ** -0.5
    s_nope = jnp.einsum("bohr,bsr->bhs", q_eff.astype(ckv_cache.dtype),
                        ckv_cache, preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bohd,bsod->bhs", q_rope, rope_cache,
                        preferred_element_type=jnp.float32)
    scores = (s_nope + s_rope) * scale

    slen = ckv_cache.shape[1]
    cols = lax.broadcasted_iota(jnp.int32, (b, slen), 1)
    ok = cols <= lengths[:, None]
    scores = scores + jnp.where(ok, 0.0, NEG_INF)[:, None, :]
    p = jax.nn.softmax(scores, axis=-1)

    ctx = jnp.einsum("bhs,bsr->bhr", p.astype(ckv_cache.dtype), ckv_cache,
                     preferred_element_type=jnp.float32)
    wv_b = params["wv_b"].reshape(rank, h, dv)
    out = jnp.einsum("bhr,rhv->bhv", ctx.astype(wv_b.dtype), wv_b,
                     preferred_element_type=jnp.float32)
    y = out.reshape(b, 1, h * dv).astype(x.dtype) @ params["wo"]
    if layer_idx is not None:
        new_cache = {"c_kv": ckv_full, "k_rope": rope_full}
    else:
        new_cache = {"c_kv": ckv_cache, "k_rope": rope_cache}
    return y, new_cache
