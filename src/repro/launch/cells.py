"""Dry-run cell construction: (arch x shape x mesh) -> lowerable step.

`input_specs(cfg, shape)` produces ShapeDtypeStruct stand-ins for every
runtime input (weak-type-correct, shardable, zero allocation); `build_cell`
adds the step function and in/out shardings. The dry-run lowers and
compiles each cell; nothing is ever materialized.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.base import (
    ModelConfig, ParallelConfig, SHAPES, ShapeConfig, TrainConfig)
from repro.data import batches
from repro.models import get_model
from repro.models.api import Model
from repro.runtime import param_sharding as psh
from repro.runtime import sharding as shlib
from repro.train import steps as steps_lib

# Archs that must shard params over data too (too big otherwise).
FSDP_ARCHS = {"llama3-405b", "deepseek-v2-236b"}


def parallel_for(cfg: ModelConfig, shape: ShapeConfig) -> ParallelConfig:
    """Per-cell distribution choices.

    Decode cells shard the KV cache along the *sequence* axis: KV heads
    rarely divide the 16-way model axis (gemma3 has 1), and replicating a
    multi-GB cache makes decode collective-bound (§Perf iteration 1).
    The softmax/contraction reductions over the sharded axis lower to
    small psums (flash-decode, derived by the SPMD partitioner). batch=1
    long-context additionally folds the idle data axis into "seq".
    """
    seq_axes: tuple = ("model",)
    if shape.kind == "decode" and shape.global_batch == 1:
        seq_axes = ("data", "model")
    return ParallelConfig(
        fsdp=cfg.name in FSDP_ARCHS,
        seq_shard_decode=(shape.kind == "decode"),
        seq_axes=seq_axes,
    )


def cell_supported(cfg: ModelConfig, shape: ShapeConfig
                   ) -> Tuple[bool, str]:
    """The assignment's skip rules (recorded, not silently dropped)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("pure full-attention arch: long_500k needs "
                       "sub-quadratic attention (DESIGN.md §5)")
    return True, ""


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------


def _abstract_state(model: Model) -> Any:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(
        lambda k: steps_lib.init_train_state(model, k), key)


def _abstract_params(model: Model) -> Any:
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(model.init_params, key)


def _abstract_cache(model: Model, cfg: ModelConfig, batch: int,
                    seq: int) -> Any:
    if cfg.family == "audio":
        return jax.eval_shape(
            lambda: model.init_cache(batch, 256, seq))
    return jax.eval_shape(lambda: model.init_cache(batch, seq))


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                model: Optional[Model] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every input of the cell's step fn."""
    model = model or get_model(cfg)
    if shape.kind == "train":
        return {
            "state": _abstract_state(model),
            "batch": batches.train_batch_spec(
                cfg, shape.global_batch, shape.seq_len),
        }
    if shape.kind == "prefill":
        return {
            "params": _abstract_params(model),
            "batch": batches.train_batch_spec(
                cfg, shape.global_batch, shape.seq_len),
        }
    # decode
    dec = batches.decode_inputs_spec(cfg, shape.global_batch)
    return {
        "params": _abstract_params(model),
        "tokens": dec["tokens"],
        "cache": _abstract_cache(model, cfg, shape.global_batch,
                                 shape.seq_len),
        "lengths": dec["lengths"],
    }


# ---------------------------------------------------------------------------
# shardings
# ---------------------------------------------------------------------------


def _batch_shardings(mesh, batch_spec):
    def leaf(s):
        ax = ("batch",) + (None,) * (len(s.shape) - 1)
        return NamedSharding(mesh, shlib.resolve(s.shape, *ax))
    return jax.tree.map(leaf, batch_spec)


def _param_shardings(mesh, params_abs):
    return psh.shardings_for(mesh, psh.param_pspecs(params_abs))


def _state_shardings(mesh, state_abs, zero1: bool = True):
    params_abs = state_abs["params"]
    logical = psh.logical_param_axes(params_abs)
    p_specs = psh.specs_from_logical(logical, params_abs)
    if zero1:
        m_logical = psh.zero1_moment_axes(logical, params_abs)
        m_specs = psh.specs_from_logical(m_logical, params_abs,
                                         keep_fsdp=True)
    else:
        m_specs = p_specs
    return {
        "params": psh.shardings_for(mesh, p_specs),
        "opt": {
            "m": psh.shardings_for(mesh, m_specs),
            "v": psh.shardings_for(mesh, m_specs),
            "step": NamedSharding(mesh, P()),
        },
    }


def _cache_shardings(mesh, model: Model, cache_abs, seq_sharded: bool):
    logical = model.cache_specs(seq_sharded=seq_sharded)
    return jax.tree.map(
        lambda ax, leaf: NamedSharding(
            mesh, shlib.resolve(leaf.shape, *ax)),
        logical, cache_abs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, (str, tuple)) for a in x))


def _replicated(mesh, tree_abs):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree_abs)


# ---------------------------------------------------------------------------
# cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Cell:
    arch: str
    shape: ShapeConfig
    cfg: ModelConfig
    step: Callable
    specs: Dict[str, Any]
    in_shardings: Any
    out_shardings: Any
    donate: Tuple[int, ...] = ()


def build_cell(arch: str, shape_name: str, mesh,
               overrides: Optional[Dict] = None,
               tcfg: Optional[TrainConfig] = None) -> Cell:
    shape = SHAPES[shape_name]
    cfg = get_config(arch, **(overrides or {}))
    parallel = parallel_for(cfg, shape)
    model = get_model(cfg)
    tcfg = tcfg or TrainConfig()

    binding = _mesh_binding(mesh, parallel)
    with jax.set_mesh(mesh), shlib.use_binding(binding):
        specs = input_specs(cfg, shape, model)

        if shape.kind == "train":
            step = steps_lib.make_train_step(model, tcfg)
            st_sh = _state_shardings(mesh, specs["state"], tcfg.zero1)
            in_sh = (st_sh, _batch_shardings(mesh, specs["batch"]))
            metrics_abs = jax.eval_shape(step, specs["state"],
                                         specs["batch"])[1]
            out_sh = (st_sh, _replicated(mesh, metrics_abs))
        elif shape.kind == "prefill":
            step = steps_lib.make_prefill_step(model)
            p_sh = _param_shardings(mesh, specs["params"])
            in_sh = (p_sh, _batch_shardings(mesh, specs["batch"]))
            tok_abs, cache_abs = jax.eval_shape(
                step, specs["params"], specs["batch"])
            out_sh = (
                NamedSharding(mesh, shlib.resolve(tok_abs.shape, "batch")),
                _cache_shardings(mesh, model, cache_abs, False))
        else:  # decode
            step = steps_lib.make_serve_step(model)
            p_sh = _param_shardings(mesh, specs["params"])
            c_sh = _cache_shardings(mesh, model, specs["cache"],
                                    parallel.seq_shard_decode)
            tok_sh = NamedSharding(
                mesh, shlib.resolve(specs["tokens"].shape, "batch", None))
            len_sh = NamedSharding(
                mesh, shlib.resolve(specs["lengths"].shape, "batch"))
            in_sh = (p_sh, tok_sh, c_sh, len_sh)
            out_sh = (tok_sh, c_sh, len_sh)

    # Buffer donation: train state and decode caches are updated in place
    # (XLA aliases the buffers; without this every step round-trips a full
    # copy of the optimizer state / KV cache through HBM — §Perf iter 2).
    donate = {"train": (0,), "prefill": (), "decode": (2,)}[shape.kind]
    return Cell(arch=arch, shape=shape, cfg=cfg, step=step, specs=specs,
                in_shardings=in_sh, out_shardings=out_sh, donate=donate)


def _mesh_binding(mesh, parallel: ParallelConfig) -> shlib.Binding:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = dict(shlib.MULTI_POD_RULES if "pod" in mesh.axis_names
                 else shlib.SINGLE_POD_RULES)
    rules["seq"] = tuple(a for a in parallel.seq_axes
                         if a in axis_sizes)
    return shlib.Binding(rules, axis_sizes, fsdp=parallel.fsdp)


def lower_cell(cell: Cell, mesh):
    """jit -> lower under the mesh + binding. Returns the Lowered object."""
    parallel = parallel_for(cell.cfg, cell.shape)
    binding = _mesh_binding(mesh, parallel)
    order = list(cell.specs.keys())
    args = [cell.specs[k] for k in order]
    with jax.set_mesh(mesh), shlib.use_binding(binding):
        jitted = jax.jit(cell.step, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=cell.donate)
        return jitted.lower(*args)


# ---------------------------------------------------------------------------
# model-level FLOP accounting (roofline's "useful compute")
# ---------------------------------------------------------------------------


def count_params(cfg: ModelConfig) -> Tuple[int, int]:
    """(total, active) parameter counts from the abstract tree."""
    model = get_model(cfg)
    abs_params = _abstract_params(model)
    total = 0
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(abs_params)[0]:
        names = [str(getattr(k, "key", k)) for k in path]
        n = int(np.prod(leaf.shape))
        total += n
        if "moe" in names and names[-1] in ("wi_gate", "wi_up", "wo"):
            expert += n
    if cfg.n_experts:
        active = total - expert + expert * (
            cfg.n_experts_per_tok / cfg.n_experts)
    else:
        active = total
    return int(total), int(active)


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6*N_active*D for train; 2*N_active*D for inference."""
    _, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # one token per slot
