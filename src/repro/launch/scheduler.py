"""Multi-tenant dynamic-batching serve scheduler.

`serve_ultrasound_stream` measures ONE synthetic probe feeding the
engine as fast as it can. Real deployments (the ROADMAP north star)
look different: N independent probes — mixed modalities, mixed
geometries, mixed frame rates — each producing acquisitions on its own
clock, all contending for the same accelerator. Accelerator serving
throughput is won or lost in the batching-under-latency-bound policy
(Jouppi et al.: datacenter inference batches aggressively but bounds
queue delay), and the determinism contract (TINA lineage, paper §II-C)
requires that none of that batching changes a single output bit.

This module is that frontend:

  * `StreamSpec` — one client: an `UltrasoundConfig`, an arrival rate
    (``fps`` acquisitions per second; open-loop arrivals, frame k of a
    stream arrives at k/fps on the window clock whether or not the
    device is keeping up), a frame count, a seed, and an optional
    per-frame completion deadline.
  * `BatchPolicy` — the two knobs of dynamic batching: ``max_batch``
    (coalescing ceiling = the padded dispatch shape) and
    ``max_queue_delay_ms`` (the longest any frame may wait for
    companions; 0 = greedy dispatch-on-arrival).
  * `serve_multitenant` — per-config queues: frames are grouped by the
    full canonical config hash (only identical pipelines may share a
    compiled program), coalesced into batches under the policy, and
    dispatched through `BatchedExecutor.call_padded` (or
    `ShardedExecutor.call_padded` when ``devices`` spans a mesh) at ONE
    fixed compiled shape per group — occupancy varies, the program
    never recompiles. Among queues eligible to flush (full, or oldest
    frame past the delay bound) the oldest head dispatches first, so a
    saturated tenant never starves a sparse one (FIFO fairness; frames
    of one stream never reorder).

Telemetry per window (stamped into the established NDJSON records by
`benchmarks/multitenant.py`): per-frame queue delay (dispatch − arrival)
and completion latency (done − arrival) distributions, aggregate and
per-stream (LatencyStats: p50/p95/p99, jitter, deadline-miss rate
against each stream's own budget), per-dispatch batch occupancy
(`OccupancyStats`: mean fill, full-batch rate), per-group resolved
`PipelinePlan` stamps, and the `ResourceStats` of the window.

Invariants (asserted in tests/test_scheduler.py):

  * determinism oracle — every frame served through the coalescing
    scheduler is bit-identical (`np.array_equal`) to the same frame run
    alone through `monolithic_pipeline_fn`, across all three variants
    and both modalities: batching composition, padding, and arrival
    order leave no trace in the pixels;
  * a lone frame flushes once its queue delay reaches the policy bound
    — it never waits for companions that are not coming;
  * occupancy never exceeds ``max_batch``; warm-up compilation happens
    before the window opens and never counts toward any metric.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.config import UltrasoundConfig

__all__ = ["BatchPolicy", "StreamSpec", "make_mixed_streams",
           "serve_multitenant"]


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Dynamic-batching policy: coalescing ceiling + queue-delay bound.

    ``max_batch`` is both the coalescing limit and the padded dispatch
    shape (one compiled program per config group). ``max_queue_delay_ms``
    bounds how long the OLDEST queued frame may wait for companions
    before the batch is flushed partial; 0 means dispatch whatever is
    queued the moment the device is free (greedy, lowest latency,
    worst occupancy).
    """

    max_batch: int = 4
    max_queue_delay_ms: float = 5.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 "
                             f"(got {self.max_batch})")
        if self.max_queue_delay_ms < 0:
            raise ValueError(f"max_queue_delay_ms must be >= 0 "
                             f"(got {self.max_queue_delay_ms})")

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One tenant: a probe configuration plus its arrival process.

    ``fps`` is the open-loop arrival rate in acquisitions per second
    (frame k arrives at ``k / fps`` on the window clock); ``phase_s``
    offsets the whole stream (staggering tenants de-synchronizes their
    bursts). ``pool`` pre-generated acquisitions cycle like
    `SyntheticAcquisitionSource` so host-side synthesis stays out of
    the serving window; frame k carries RF
    ``synth_rf(cfg, seed=seed + (k % pool))``.
    """

    stream_id: str
    cfg: UltrasoundConfig
    fps: float = 100.0
    n_frames: int = 16
    seed: int = 0
    pool: int = 4
    phase_s: float = 0.0
    deadline_ms: Optional[float] = None   # per-frame completion budget

    def __post_init__(self):
        if self.fps <= 0:
            raise ValueError(f"fps must be > 0 (got {self.fps})")
        if self.n_frames < 1:
            raise ValueError(f"n_frames must be >= 1 "
                             f"(got {self.n_frames})")
        if self.pool < 1:
            raise ValueError(f"pool must be >= 1 (got {self.pool})")

    def arrival_s(self, k: int) -> float:
        return self.phase_s + k / self.fps


def make_mixed_streams(n_clients: int, cfg_bmode: UltrasoundConfig,
                       cfg_doppler: UltrasoundConfig, *,
                       base_fps: float = 120.0, n_frames: int = 24,
                       deadline_ms: Optional[float] = 100.0
                       ) -> List[StreamSpec]:
    """Mixed-tenant traffic: alternating modalities, staggered rates.

    Client i runs B-mode (even) or Color Doppler (odd) at
    ``base_fps / (1 + i/2)`` — tenants never share a clock, so the
    scheduler's coalescing has to earn its occupancy from genuinely
    unaligned arrivals. Phases stagger by 1/4 of the fastest period.
    Used by ``--multitenant`` serving and `benchmarks/multitenant.py`.
    """
    if n_clients < 1:
        raise ValueError(f"n_clients must be >= 1 (got {n_clients})")
    return [
        StreamSpec(
            stream_id=f"probe{i}",
            cfg=cfg_bmode if i % 2 == 0 else cfg_doppler,
            fps=base_fps / (1 + i / 2), n_frames=n_frames,
            seed=17 * i, phase_s=i * 0.25 / base_fps,
            deadline_ms=deadline_ms)
        for i in range(n_clients)]


@dataclasses.dataclass
class _Frame:
    """One enqueued acquisition, tracked from arrival to completion."""

    stream: int            # index into the spec list
    seq: int               # per-stream sequence number
    rf: np.ndarray
    t_arrival: float       # window clock (s)
    t_dispatch: float = -1.0
    t_done: float = -1.0


class _Group:
    """All streams sharing one canonical config hash -> one executor."""

    def __init__(self, key: str, cfg: UltrasoundConfig, engine):
        self.key = key
        self.cfg = cfg
        self.engine = engine
        self.queue: collections.deque = collections.deque()
        self.stream_ids: List[str] = []
        self.occupancies: List[int] = []


def _build_groups(specs: Sequence[StreamSpec], policy: BatchPolicy, *,
                  devices, plan_policy) -> Tuple[List["_Group"],
                                                 List["_Group"]]:
    """Group specs by full config hash and build one executor each.

    Returns (groups, group_of_stream). Grouping uses the PLAN-RESOLVED
    config's canonical hash: two tenants may share a compiled program
    only when every config field agrees — same geometry, same modality,
    same resolved variant, same exec_map. `Variant.AUTO` tenants
    resolve through ``plan_policy`` first, so an AUTO B-mode probe and
    an explicit one land in the same group when the planner agrees.
    """
    from repro.core.executor import BatchedExecutor, ShardedExecutor
    from repro.core.pipeline import _resolve_plan

    sharded = devices is not None and len(devices) > 1
    if sharded and policy.max_batch % len(devices):
        raise ValueError(
            f"max_batch={policy.max_batch} must be a multiple of "
            f"n_devices={len(devices)} for sharded dispatch")

    groups: Dict[str, _Group] = {}
    group_of_stream: List[_Group] = []
    for spec in specs:
        # Resolve the plan (cheap, cached) BEFORE building anything —
        # duplicate configs must share the group's one executor, not
        # construct a throwaway each.
        plan = _resolve_plan(spec.cfg, None, plan_policy)
        key = plan.concretize(spec.cfg).canonical_hash()
        if key not in groups:
            engine = (ShardedExecutor(spec.cfg, devices=devices, plan=plan)
                      if sharded
                      else BatchedExecutor(spec.cfg, plan=plan))
            groups[key] = _Group(key, engine.cfg, engine)
        groups[key].stream_ids.append(spec.stream_id)
        group_of_stream.append(groups[key])
    return list(groups.values()), group_of_stream


def _make_frames(specs: Sequence[StreamSpec]) -> List[_Frame]:
    """Pre-generate every frame (arrival-sorted); synthesis is untimed."""
    from repro.data import synth_rf

    pools = []
    for spec in specs:
        n = min(spec.pool, spec.n_frames)
        pools.append([synth_rf(spec.cfg, seed=spec.seed + i)
                      for i in range(n)])
    frames = [
        _Frame(stream=si, seq=k, rf=pools[si][k % len(pools[si])],
               t_arrival=spec.arrival_s(k))
        for si, spec in enumerate(specs)
        for k in range(spec.n_frames)]
    frames.sort(key=lambda f: (f.t_arrival, f.stream, f.seq))
    return frames


def _pick_group(groups: List[_Group], now: float,
                policy: BatchPolicy) -> Optional[_Group]:
    """The group to flush now, or None if every queue may keep waiting.

    A queue becomes *eligible* when it is full (occupancy is free
    throughput) or when its oldest frame has waited max_queue_delay.
    Among eligible queues the OLDEST head wins — bounded queue delay
    beats occupancy, so a saturated tenant whose queue is always full
    can never starve a sparse tenant's expired frame past the bound by
    more than the in-service dispatch ahead of it.
    """
    delay_s = policy.max_queue_delay_ms / 1e3
    best, best_head = None, None
    for g in groups:
        if not g.queue:
            continue
        head = g.queue[0].t_arrival
        if len(g.queue) >= policy.max_batch or now - head >= delay_s:
            if best is None or head < best_head:
                best, best_head = g, head
    return best


def serve_multitenant(streams: Sequence[StreamSpec], *,
                      policy: BatchPolicy = BatchPolicy(),
                      devices=None, plan_policy: Optional[str] = None,
                      collect_outputs: bool = False) -> dict:
    """Serve N open-loop tenants through coalescing dynamic batching.

    Runs one serving window: every frame of every stream is admitted at
    its scheduled arrival time, queued per config group, coalesced
    under ``policy``, executed at the group's fixed padded shape, and
    timed from arrival to completion. Dispatch is synchronous (one
    batch in flight — queue delay and occupancy are the axes under
    test; in-flight depth composes the same way `serve_ultrasound_stream`
    demonstrates).

    ``devices``: a sequence of >= 2 local devices routes dispatch
    through `ShardedExecutor.call_padded` (``max_batch`` must divide
    evenly). ``plan_policy`` resolves `Variant.AUTO` tenants
    (repro.core.plan). ``collect_outputs=True`` additionally returns
    every served image (``outputs[stream_id][seq]``, numpy) — the hook
    the determinism-oracle tests compare against the per-frame
    monolithic reference.

    Returns a stats dict (schema: `repro.bench.schema`, kind
    "multitenant" once the benchmark stamps it): aggregate + per-stream
    latency and queue-delay LatencyStats, OccupancyStats, per-group
    plan stamps, ResourceStats, sustained MB/s / FPS / acq/s.
    """
    from repro.bench.harness import latency_stats, occupancy_stats
    from repro.bench.resources import ResourceMeter

    if not streams:
        raise ValueError("serve_multitenant needs at least one stream")
    ids = [s.stream_id for s in streams]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate stream_id in {ids}")

    specs = list(streams)
    groups, group_of_stream = _build_groups(
        specs, policy, devices=devices, plan_policy=plan_policy)
    frames = _make_frames(specs)

    # Meter before warm-up: the NVML idle baseline must see the board
    # cold; one meter spans every group's devices.
    meter = ResourceMeter()

    # Warm-up: compile each group's ONE padded program (occupancy 1 and
    # max_batch hit the same shape) — excluded from the window.
    for g in groups:
        rf0 = np.zeros((1,) + g.cfg.rf_shape,
                       dtype=np.dtype(g.cfg.rf_dtype))
        jax.block_until_ready(
            g.engine.call_padded(jnp.asarray(rf0), policy.max_batch))

    outputs: Dict[str, dict] = {s.stream_id: {} for s in specs}
    delay_s = policy.max_queue_delay_ms / 1e3

    meter.start()
    t0 = time.perf_counter()
    ai, done = 0, 0
    while done < len(frames):
        now = time.perf_counter() - t0
        while ai < len(frames) and frames[ai].t_arrival <= now:
            f = frames[ai]
            ai += 1
            group_of_stream[f.stream].queue.append(f)
        g = _pick_group(groups, now, policy)
        if g is None:
            # Nothing must flush yet: sleep to the next arrival or the
            # earliest queue-delay expiry, whichever comes first.
            horizon = []
            if ai < len(frames):
                horizon.append(frames[ai].t_arrival)
            horizon.extend(g2.queue[0].t_arrival + delay_s
                           for g2 in groups if g2.queue)
            dt = min(horizon) - (time.perf_counter() - t0)
            if dt > 0:
                time.sleep(min(dt, 0.05))
            continue

        batch = [g.queue.popleft()
                 for _ in range(min(len(g.queue), policy.max_batch))]
        t_dispatch = time.perf_counter() - t0
        out = g.engine.call_padded(
            jnp.asarray(np.stack([f.rf for f in batch])),
            policy.max_batch)
        out = np.asarray(jax.block_until_ready(out))
        t_done = time.perf_counter() - t0
        meter.sample()
        g.occupancies.append(len(batch))
        for i, f in enumerate(batch):
            f.t_dispatch, f.t_done = t_dispatch, t_done
            if collect_outputs:
                outputs[specs[f.stream].stream_id][f.seq] = out[i]
        done += len(batch)
    wall = time.perf_counter() - t0
    resources = meter.stop()

    # ---- telemetry ----------------------------------------------------
    def budget(spec):
        return (spec.deadline_ms / 1e3
                if spec.deadline_ms is not None else None)

    per_stream = {}
    misses, with_budget = 0, 0
    for si, spec in enumerate(specs):
        fs = [f for f in frames if f.stream == si]
        lat = latency_stats([f.t_done - f.t_arrival for f in fs],
                            budget_s=budget(spec))
        qd = latency_stats([f.t_dispatch - f.t_arrival for f in fs])
        if budget(spec) is not None:
            misses += int(round(lat.miss_rate * lat.n))
            with_budget += lat.n
        per_stream[spec.stream_id] = {
            "pipeline": spec.cfg.name,
            "variant": group_of_stream[si].cfg.variant.value,
            "arrival_fps": spec.fps,
            "acquisitions": spec.n_frames,
            "frames": spec.n_frames * spec.cfg.n_f,
            "deadline_ms": spec.deadline_ms,
            "latency": lat.json_dict(),
            "queue_delay": qd.json_dict(),
            "deadline_miss_rate": lat.miss_rate,
        }

    acqs = len(frames)
    total_frames = sum(s.n_frames * s.cfg.n_f for s in specs)
    total_bytes = sum(s.n_frames * s.cfg.input_bytes for s in specs)
    all_occ = [n for g in groups for n in g.occupancies]
    stats = {
        "name": (f"multitenant/{len(specs)}streams/{len(groups)}groups"
                 f"/b{policy.max_batch}q{policy.max_queue_delay_ms:g}"),
        "clients": len(specs),
        "policy": policy.json_dict(),
        "wall_s": wall,
        "acquisitions": acqs,
        "frames": total_frames,
        "sustained_mbps": total_bytes / (wall * 1e6),
        "fps": total_frames / wall,
        "acq_per_s": acqs / wall,
        "deadline_miss_rate": (misses / with_budget if with_budget
                               else 0.0),
        "latency": latency_stats(
            [f.t_done - f.t_arrival for f in frames]).json_dict(),
        "queue_delay": latency_stats(
            [f.t_dispatch - f.t_arrival for f in frames]).json_dict(),
        "occupancy": occupancy_stats(all_occ,
                                     policy.max_batch).json_dict(),
        "per_stream": per_stream,
        "groups": {
            g.key: {
                "plan": g.engine.plan.json_dict(),
                "streams": list(g.stream_ids),
                "batches": len(g.occupancies),
                "occupancy": occupancy_stats(
                    g.occupancies, policy.max_batch).json_dict(),
            } for g in groups},
        "resources": resources.json_dict(),
    }
    if collect_outputs:
        stats["outputs"] = {
            sid: [seqs[k] for k in sorted(seqs)]
            for sid, seqs in outputs.items()}
    return stats
