"""Multi-tenant dynamic-batching serve scheduler.

`serve_ultrasound_stream` measures ONE synthetic probe feeding the
engine as fast as it can. Real deployments (the ROADMAP north star)
look different: N independent probes — mixed modalities, mixed
geometries, mixed frame rates — each producing acquisitions on its own
clock, all contending for the same accelerator. Accelerator serving
throughput is won or lost in the batching-under-latency-bound policy
(Jouppi et al.: datacenter inference batches aggressively but bounds
queue delay), and the determinism contract (TINA lineage, paper §II-C)
requires that none of that batching changes a single output bit.

This module is that frontend:

  * `StreamSpec` — one client: an `UltrasoundConfig`, an arrival
    process (`repro.data.traces.ArrivalProcess` — uniform open-loop
    ``phase_s + k / fps`` by default, or a `TraceArrival` replaying
    recorded timestamps bit-identically), a frame count, a seed, an
    optional connect/disconnect window (``start_s`` / ``stop_s`` —
    churn: frames whose arrival falls outside the window are dropped
    deterministically at admission), and an optional per-frame
    completion deadline.
  * `BatchPolicy` — the two knobs of dynamic batching: ``max_batch``
    (coalescing ceiling = the padded dispatch shape) and
    ``max_queue_delay_ms`` (the longest any frame may wait for
    companions; 0 = greedy dispatch-on-arrival).
  * `serve_multitenant` — per-config queues: frames are grouped by the
    full canonical config hash (only identical pipelines may share a
    compiled program), coalesced into batches under the policy, and
    dispatched through the executors' async ``dispatch_padded`` (over a
    mesh when ``devices`` spans one) at ONE fixed compiled shape per
    group — occupancy varies, the program never recompiles. Among
    queues eligible to flush (full, or oldest frame past the delay
    bound) the oldest head dispatches first — ties on identical head
    arrival times resolve to the first group in construction order —
    so a saturated tenant never starves a sparse one (FIFO fairness;
    frames of one stream never reorder).

Dispatch is PIPELINED: up to ``in_flight`` launched batches ride a
bounded ring as pending completions while the host keeps admitting
arrivals, coalescing queues, and launching the next eligible batch —
the `serve_ultrasound_stream` depth-N pattern lifted into the
coalescing scheduler, so the device no longer idles during host-side
bookkeeping and vice versa. Completions drain via non-blocking
readiness checks, oldest-first *per group* (a later batch of a group
never retires before an earlier one, so a stream's frames can never
reorder no matter which ring slot settles first); outputs are keyed by
(stream, seq), so even a cross-group out-of-order drain leaves no trace
in the pixels — the determinism oracle holds bit-for-bit at every
depth. Every group's padded program is compiled AHEAD of the window
(`repro.core.aot`: `jax.jit(...).lower().compile()` + the persistent
compilation cache), and the cost is measured and stamped
(``warmup_s``), never silently excluded.

The HOST TRANSFER path is zero-copy and (by default) asynchronous:
each group coalesces admitted frames straight into a preallocated
`repro.core.staging.StagingRing` slot (no stack, no pad concatenate —
the pad region was zeroed once at construction), the slot is committed
H2D through the executor's timed ``place`` and launched with
``dispatch_staged`` (optionally donating the device buffer —
``donate``), and retirements start their D2H with
``copy_to_host_async()`` the moment compute is detected settled, so
the admit loop never blocks on a transfer (``drain="async"``;
``drain="block"`` keeps the synchronous control path the benchmarks
gate against). The costs are stamped per window: ``stage_copy_s``,
``h2d_s``, ``d2h_s``, ``transfer_frac``.

Telemetry per window (stamped into the established NDJSON records by
`benchmarks/multitenant.py`): per-frame queue delay (dispatch − arrival)
and completion latency (done − arrival) distributions, aggregate and
per-stream (LatencyStats: p50/p95/p99, jitter, deadline-miss rate
against each stream's own budget), per-dispatch batch occupancy
(`OccupancyStats`: mean fill, full-batch rate), device-overlap columns
(``device_busy_frac``, ``overlap_frac``, `InFlightStats` of the ring),
per-group resolved `PipelinePlan` stamps (serving context included:
warm_start, in_flight), warm-up seconds total and per group, and the
`ResourceStats` of the window (sampled at drain time, so peak-memory
telemetry sees overlapped batches live together).

Invariants (asserted in tests/test_scheduler.py):

  * determinism oracle — every frame served through the coalescing
    scheduler is bit-identical (`np.array_equal`) to the same frame run
    alone through `monolithic_pipeline_fn`, across all three variants
    and both modalities, at in-flight depth 1 and >= 2, and under
    adversarially out-of-order completion drains: batching composition,
    padding, arrival order, and drain order leave no trace in the
    pixels;
  * a lone frame flushes once its queue delay reaches the policy bound
    — it never waits for companions that are not coming;
  * occupancy never exceeds ``max_batch``; the ring never exceeds
    ``in_flight``; warm-up compilation happens before the window opens
    and is *stamped* (``warmup_s``) rather than silently excluded;
  * the idle path never busy-spins: a non-positive sleep horizon always
    means an arrival or a flush is already due.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax

from repro.core.config import UltrasoundConfig
from repro.core.staging import StagingRing
from repro.data.traces import (ArrivalProcess, StreamTrace, Trace,
                               TraceArrival, mixed_phase, mixed_rate,
                               seed_space)

__all__ = ["BatchPolicy", "StreamSpec", "make_mixed_streams",
           "make_trace_streams", "trace_of_streams",
           "serve_multitenant"]


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Dynamic-batching policy: coalescing ceiling + queue-delay bound.

    ``max_batch`` is both the coalescing limit and the padded dispatch
    shape (one compiled program per config group). ``max_queue_delay_ms``
    bounds how long the OLDEST queued frame may wait for companions
    before the batch is flushed partial; 0 means dispatch whatever is
    queued the moment the device is free (greedy, lowest latency,
    worst occupancy).
    """

    max_batch: int = 4
    max_queue_delay_ms: float = 5.0

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 "
                             f"(got {self.max_batch})")
        if self.max_queue_delay_ms < 0:
            raise ValueError(f"max_queue_delay_ms must be >= 0 "
                             f"(got {self.max_queue_delay_ms})")

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class StreamSpec:
    """One tenant: a probe configuration plus its arrival process.

    Arrivals: with the default ``arrival=None`` the stream is uniform
    open-loop — frame k arrives at ``phase_s + k / fps`` on the window
    clock (``phase_s`` staggers tenants so their bursts de-synchronize).
    Any `repro.data.traces.ArrivalProcess` plugs in instead: a
    `TraceArrival` replays recorded timestamps bit-identically.

    Connect window (churn): frames whose arrival timestamp falls before
    ``start_s`` or at/after ``stop_s`` are DROPPED deterministically at
    admission — the probe is not connected — and counted in the
    ``dropped`` telemetry. The decision uses only arrival timestamps,
    never wall clock, so a replay drops the same frames.

    RF content: ``pool`` pre-generated acquisitions cycle like
    `SyntheticAcquisitionSource` so host-side synthesis stays out of
    the serving window. The cycle period is ``min(pool, n_frames)``
    (never more pools than frames are synthesized); frame k carries RF
    ``synth_rf(cfg, seed=self.frame_seed(k))``, where `frame_seed`
    derives a per-(stream_id, seed) disjoint seed space via
    `repro.data.traces.seed_space` — two tenants never share a
    byte-identical frame just because their base seeds sit close.
    """

    stream_id: str
    cfg: UltrasoundConfig
    fps: float = 100.0
    n_frames: int = 16
    seed: int = 0
    pool: int = 4
    phase_s: float = 0.0
    deadline_ms: Optional[float] = None   # per-frame completion budget
    arrival: Optional[ArrivalProcess] = None
    start_s: float = 0.0                  # connect instant
    stop_s: Optional[float] = None        # disconnect instant (churn)

    def __post_init__(self):
        if self.fps <= 0:
            raise ValueError(f"fps must be > 0 (got {self.fps})")
        if self.n_frames < 1:
            raise ValueError(f"n_frames must be >= 1 "
                             f"(got {self.n_frames})")
        if self.pool < 1:
            raise ValueError(f"pool must be >= 1 (got {self.pool})")
        if self.start_s < 0:
            raise ValueError(f"start_s must be >= 0 "
                             f"(got {self.start_s})")
        if self.stop_s is not None and self.stop_s <= self.start_s:
            raise ValueError(f"stop_s={self.stop_s} must be > "
                             f"start_s={self.start_s}")
        if self.arrival is not None:
            try:
                n = len(self.arrival)          # type: ignore[arg-type]
            except TypeError:
                n = None
            if n is not None and self.n_frames > n:
                raise ValueError(
                    f"n_frames={self.n_frames} exceeds the arrival "
                    f"process's {n} recorded timestamps")

    def arrival_s(self, k: int) -> float:
        if self.arrival is not None:
            return self.arrival.arrival_s(k)
        return self.phase_s + k / self.fps

    def frame_seed(self, k: int) -> int:
        """The `synth_rf` seed of frame k: the pool cycles with period
        ``min(pool, n_frames)``, each slot in a seed space disjoint
        per (seed, stream_id)."""
        return seed_space("stream", self.seed, self.stream_id,
                          k % min(self.pool, self.n_frames))

    def in_window(self, t: float) -> bool:
        """Is the probe connected at window-clock time t?"""
        return t >= self.start_s and (self.stop_s is None
                                      or t < self.stop_s)


def make_mixed_streams(n_clients: int, cfg_bmode: UltrasoundConfig,
                       cfg_doppler: UltrasoundConfig, *,
                       base_fps: float = 120.0, n_frames: int = 24,
                       deadline_ms: Optional[float] = 100.0
                       ) -> List[StreamSpec]:
    """Mixed-tenant traffic: alternating modalities, staggered rates.

    Client i runs B-mode (even) or Color Doppler (odd) at
    ``base_fps / (1 + i/2)`` — tenants never share a clock, so the
    scheduler's coalescing has to earn its occupancy from genuinely
    unaligned arrivals. Phases stagger by 1/4 of the fastest period.
    Rates/phases come from `repro.data.traces.mixed_rate` /
    `mixed_phase` — the SAME helpers the ``steady`` trace generator
    uses, so a generated steady trace replays this schedule
    bit-identically (equal floats, equal trace_sha256).
    Used by ``--multitenant`` serving and `benchmarks/multitenant.py`.
    """
    if n_clients < 1:
        raise ValueError(f"n_clients must be >= 1 (got {n_clients})")
    return [
        StreamSpec(
            stream_id=f"probe{i}",
            cfg=cfg_bmode if i % 2 == 0 else cfg_doppler,
            fps=mixed_rate(i, base_fps), n_frames=n_frames,
            seed=17 * i, phase_s=mixed_phase(i, base_fps),
            deadline_ms=deadline_ms)
        for i in range(n_clients)]


def make_trace_streams(trace: Trace, cfg_bmode: UltrasoundConfig,
                       cfg_doppler: UltrasoundConfig, *,
                       deadline_ms: Optional[float] = 100.0,
                       pool: int = 4) -> List[StreamSpec]:
    """Bind a recorded/generated `Trace` to the mixed-tenant configs.

    Stream i of the trace gets the same modality assignment (B-mode
    even, Doppler odd) and the same RF seed (``17 * i``) as
    `make_mixed_streams` client i, but its arrivals come from a
    `TraceArrival` — replayed bit-identically — and its connect window
    from the trace's ``start_s`` / ``stop_s``. Replaying a ``steady``
    trace therefore serves the exact frames `make_mixed_streams` would.
    """
    return [
        StreamSpec(
            stream_id=st.stream_id,
            cfg=cfg_bmode if i % 2 == 0 else cfg_doppler,
            fps=st.fps, n_frames=len(st.arrivals),
            seed=17 * i, pool=pool, deadline_ms=deadline_ms,
            arrival=TraceArrival(st.arrivals),
            start_s=st.start_s, stop_s=st.stop_s)
        for i, st in enumerate(trace.streams)]


def trace_of_streams(specs: Sequence[StreamSpec], *,
                     profile: Optional[str] = None,
                     seed: Optional[int] = None) -> Trace:
    """The `Trace` a set of specs will replay — uniform or recorded.

    Materializes every spec's arrival process into timestamps, so the
    uniform open-loop default and a `TraceArrival` replay of its saved
    copy produce the same trace — and therefore the same ``sha256``
    provenance stamp in the telemetry.
    """
    return Trace(
        streams=tuple(StreamTrace(
            stream_id=s.stream_id,
            arrivals=tuple(s.arrival_s(k) for k in range(s.n_frames)),
            fps=s.fps, start_s=s.start_s, stop_s=s.stop_s)
            for s in specs),
        profile=profile, seed=seed)


@dataclasses.dataclass
class _Frame:
    """One enqueued acquisition, tracked from arrival to completion."""

    stream: int            # index into the spec list
    seq: int               # per-stream sequence number
    rf: np.ndarray
    t_arrival: float       # window clock (s)
    t_dispatch: float = -1.0
    t_done: float = -1.0


class _Group:
    """All streams sharing one canonical config hash -> one executor."""

    def __init__(self, key: str, cfg: UltrasoundConfig, engine):
        self.key = key
        self.cfg = cfg
        self.engine = engine
        self.queue: collections.deque = collections.deque()
        self.stream_ids: List[str] = []
        self.occupancies: List[int] = []
        self.depths: List[int] = []       # ring depth at each launch
        self.n_pending = 0                # this group's batches in flight
        self.warm_source = "aot"          # "aot" | "pool"
        self.warmup_s = 0.0               # warm cost paid by THIS window
        self.ring: Optional[StagingRing] = None   # built per window


@dataclasses.dataclass
class _Pending:
    """One launched batch riding the in-flight ring until it settles."""

    group: _Group
    batch: List[_Frame]
    out: object                # device array, possibly still computing
    t_dispatch: float


def _ready(out) -> bool:
    """Non-blocking: has this dispatched batch's device buffer settled?

    Module-level so the out-of-order-drain determinism test can
    monkeypatch it with a seeded gate that delays arbitrary pendings.
    """
    try:
        return bool(out.is_ready())
    except AttributeError:     # plain numpy (already settled)
        return True


def _build_groups(specs: Sequence[StreamSpec], policy: BatchPolicy, *,
                  devices, plan_policy, pool=None, donate=None
                  ) -> Tuple[List["_Group"], List["_Group"]]:
    """Group specs by full config hash and build one executor each.

    Returns (groups, group_of_stream). Grouping uses the PLAN-RESOLVED
    config's canonical hash: two tenants may share a compiled program
    only when every config field agrees — same geometry, same modality,
    same resolved variant, same exec_map. `Variant.AUTO` tenants
    resolve through ``plan_policy`` first, so an AUTO B-mode probe and
    an explicit one land in the same group when the planner agrees.

    A `repro.core.aot.WarmPool` supplies already-warm executors: a pool
    hit (same hash, same padded shape, same device count, same resolved
    donation signature) reuses the pooled engine — AOT program
    installed, compilation already paid — and the group is marked
    ``warm_source="pool"`` with zero warm cost charged to this window.
    ``donate`` resolves exactly as the executor constructors resolve it
    (arg > plan > backend default), so a lookup and the engine it would
    otherwise build can never disagree on the donation signature.
    """
    from repro.core.executor import (BatchedExecutor, ShardedExecutor,
                                     _resolve_donate)
    from repro.core.pipeline import _resolve_plan

    sharded = devices is not None and len(devices) > 1
    n_devices = len(devices) if sharded else 1
    if sharded and policy.max_batch % n_devices:
        raise ValueError(
            f"max_batch={policy.max_batch} must be a multiple of "
            f"n_devices={n_devices} for sharded dispatch")

    groups: Dict[str, _Group] = {}
    group_of_stream: List[_Group] = []
    for spec in specs:
        # Resolve the plan (cheap, cached) BEFORE building anything —
        # duplicate configs must share the group's one executor, not
        # construct a throwaway each.
        plan = _resolve_plan(spec.cfg, None, plan_policy)
        key = plan.concretize(spec.cfg).canonical_hash()
        if key not in groups:
            entry = (pool.get((key, policy.max_batch, n_devices,
                               _resolve_donate(donate, plan)))
                     if pool is not None else None)
            if entry is not None:
                g = _Group(key, entry.engine.cfg, entry.engine)
                g.warm_source = "pool"
            else:
                engine = (ShardedExecutor(spec.cfg, devices=devices,
                                          plan=plan, donate=donate)
                          if sharded
                          else BatchedExecutor(spec.cfg, plan=plan,
                                               donate=donate))
                g = _Group(key, engine.cfg, engine)
            groups[key] = g
        groups[key].stream_ids.append(spec.stream_id)
        group_of_stream.append(groups[key])
    return list(groups.values()), group_of_stream


def _make_frames(specs: Sequence[StreamSpec]
                 ) -> Tuple[List[_Frame], List[int]]:
    """Pre-generate every in-window frame (arrival-sorted) + drops.

    Synthesis is untimed. Frames whose arrival falls outside the
    stream's connect window are dropped HERE — the admit/retire
    decision depends only on trace timestamps, never on wall clock, so
    replays drop identically. Returns (frames, dropped-per-stream).
    The sort key ``(t_arrival, stream, seq)`` makes simultaneous
    arrivals (equal timestamps — bursts, trace replays) admit in
    deterministic spec order.
    """
    from repro.data import synth_rf

    pools = []
    for spec in specs:
        n = min(spec.pool, spec.n_frames)
        pools.append([synth_rf(spec.cfg, seed=spec.frame_seed(i))
                      for i in range(n)])
    frames: List[_Frame] = []
    dropped = [0] * len(specs)
    for si, spec in enumerate(specs):
        for k in range(spec.n_frames):
            t = spec.arrival_s(k)
            if not spec.in_window(t):
                dropped[si] += 1
                continue
            frames.append(_Frame(stream=si, seq=k,
                                 rf=pools[si][k % len(pools[si])],
                                 t_arrival=t))
    frames.sort(key=lambda f: (f.t_arrival, f.stream, f.seq))
    return frames, dropped


def _pick_group(groups: List[_Group], now: float,
                policy: BatchPolicy) -> Optional[_Group]:
    """The group to flush now, or None if every queue may keep waiting.

    A queue becomes *eligible* when it is full (occupancy is free
    throughput) or when its oldest frame has waited max_queue_delay.
    Among eligible queues the OLDEST head wins — bounded queue delay
    beats occupancy, so a saturated tenant whose queue is always full
    can never starve a sparse tenant's expired frame past the bound by
    more than the in-service dispatch ahead of it.
    """
    delay_s = policy.max_queue_delay_ms / 1e3
    best, best_head = None, None
    for g in groups:
        if not g.queue:
            continue
        head = g.queue[0].t_arrival
        if len(g.queue) >= policy.max_batch or now - head >= delay_s:
            # Strict < keeps ties deterministic: equal heads resolve to
            # the FIRST group in construction (= spec) order, so a rerun
            # with identical arrivals replays identical dispatch order.
            if best is None or head < best_head:
                best, best_head = g, head
    return best


_POLL_S = 2e-4       # base readiness-poll grain (REPRO_POLL_S overrides)
_POLL_CAP_S = 5e-3   # adaptive-grain ceiling: completion-detection bound


def _poll_base() -> float:
    """The busy-poll base grain: ``REPRO_POLL_S`` env override or the
    built-in default. Invalid / non-positive values fall back rather
    than crash a serving window."""
    env = os.environ.get("REPRO_POLL_S")
    if env:
        try:
            v = float(env)
            if v > 0:
                return v
        except ValueError:
            pass
    return _POLL_S


def _poll_grain(now: float, horizon: Optional[float], *,
                base: float, cap: float = _POLL_CAP_S) -> float:
    """Adaptive busy-poll sleep while dispatches are in flight.

    A fixed fine grain spins the host core the staging ring now shares
    even when the next scheduling decision is provably far away (a
    low-rate stream whose next arrival is milliseconds out). Instead
    the grain stretches toward the `_idle_horizon` — there is nothing
    to admit or flush before it — but never past ``cap``, which bounds
    how late a completion can be detected, and never below ``base``,
    the spin floor when work is imminent (horizon past due, or no
    horizon at all while the final batches settle).
    """
    if horizon is None:
        return base
    return min(max(base, horizon - now), cap)


def _idle_horizon(frames: List[_Frame], ai: int, groups: List[_Group],
                  delay_s: float) -> Optional[float]:
    """Next window-clock instant at which the idle loop can act.

    Either the next un-admitted arrival or the earliest queue-delay
    expiry (head arrival + delay bound), whichever is sooner; None when
    neither exists. No-busy-spin invariant (tested directly): whenever
    the horizon is <= now, progress is already available — an arrival
    is due for admission, or some queue head has waited past the delay
    bound and `_pick_group` returns it. The serving loop therefore only
    sleeps on a strictly positive horizon gap, and a non-positive gap
    always precedes an admission or a launch, never a spin.
    """
    horizon = []
    if ai < len(frames):
        horizon.append(frames[ai].t_arrival)
    horizon.extend(g.queue[0].t_arrival + delay_s
                   for g in groups if g.queue)
    return min(horizon) if horizon else None


def serve_multitenant(streams: Sequence[StreamSpec], *,
                      policy: BatchPolicy = BatchPolicy(),
                      in_flight: int = 2,
                      devices=None, plan_policy: Optional[str] = None,
                      collect_outputs: bool = False,
                      pool=None, load_profile: str = "steady",
                      drain: str = "async",
                      donate: Optional[bool] = None) -> dict:
    """Serve N open-loop tenants through coalescing dynamic batching.

    Runs one serving window: every frame of every stream is admitted at
    its scheduled arrival time (uniform or trace-replayed — see
    `StreamSpec.arrival`), queued per config group, coalesced
    under ``policy``, executed at the group's fixed padded shape, and
    timed from arrival to completion. Frames arriving outside a
    stream's connect window are dropped deterministically at admission
    (churn); frames admitted before a disconnect always drain.
    Dispatch is PIPELINED to depth
    ``in_flight``: launched batches ride a bounded ring as pending
    completions while the host keeps admitting, coalescing, and
    launching; completions drain via non-blocking readiness checks,
    oldest-first per group, so frames of one stream never reorder.
    ``in_flight=1`` recovers the synchronous launch-block-retire loop
    exactly (the ring holds one slot).

    Every group's padded program is AOT-compiled before the window
    opens (`repro.core.aot.aot_warm`, persistent compilation cache
    included) and the cost is stamped into the stats (``warmup_s``).
    Pass a `repro.core.aot.WarmPool` (built by
    `repro.core.aot.warm_pool`) to start warm: pool hits reuse the
    pooled executor and charge zero warm cost to this window.

    HOST TRANSFER PATH (docs/serving.md#host-transfer-path): each
    group coalesces straight into a preallocated `StagingRing` slot
    (zero extra host copies — no stack, no pad concatenate), the slot
    is committed H2D by the executor's ``place`` (timed: ``h2d_s``)
    and launched via ``dispatch_staged``. Retirement is governed by
    ``drain``:

      * ``"async"`` (default) — when a batch's compute is detected
        settled it leaves the in-flight ring immediately (the next
        launch may proceed) and ``copy_to_host_async()`` starts its
        D2H in the background; the images are harvested on a LATER
        drain pass, so only the residual transfer tail is ever waited
        on (``d2h_s``). Group-FIFO retirement order is preserved:
        detection scans in launch order and skips a group whose older
        batch is still pending, and harvests happen in detection
        order.
      * ``"block"`` — the pre-staging behavior: detection immediately
        blocks on the compute and performs a synchronous D2H before
        the loop continues. Kept as the control cell the benchmarks
        gate the async win against.

    ``donate`` opts the compiled programs into consuming their device
    input buffer (donate_argnums; None = plan / backend default —
    False on CPU where XLA cannot alias). Safe with the staging ring:
    ``place`` always produces a fresh device array, the reused host
    slot is never donated.

    ``devices``: a sequence of >= 2 local devices routes dispatch
    through `ShardedExecutor.dispatch_padded` (``max_batch`` must
    divide evenly). ``plan_policy`` resolves `Variant.AUTO` tenants
    (repro.core.plan). ``collect_outputs=True`` additionally returns
    every served image (``outputs[stream_id][seq]``, numpy) — the hook
    the determinism-oracle tests compare against the per-frame
    monolithic reference.

    Returns a stats dict (schema: `repro.bench.schema`, kind
    "multitenant" once the benchmark stamps it): aggregate + per-stream
    latency and queue-delay LatencyStats, OccupancyStats,
    device-overlap columns (``device_busy_frac``, ``overlap_frac``,
    ``in_flight_occupancy``), warm-up seconds, per-group plan stamps,
    ResourceStats, sustained MB/s / FPS / acq/s. Load provenance is
    stamped on every window: ``load_profile`` (the scenario name —
    part of the gate's cell identity), ``trace_sha256`` (the
    `trace_of_streams` hash of the exact arrival schedule served),
    ``dropped`` (out-of-window frames, aggregate and per stream), and
    ``dispatch_order`` (the launched batches as ``[stream_id, seq]``
    lists, in launch order — what the trace-replay determinism oracle
    compares across reruns).
    """
    from repro.bench.harness import (in_flight_stats, latency_stats,
                                     occupancy_stats)
    from repro.bench.resources import ResourceMeter
    from repro.bench.stats import bootstrap_ci
    from repro.core.aot import WarmEntry, aot_warm

    if not streams:
        raise ValueError("serve_multitenant needs at least one stream")
    if in_flight < 1:
        raise ValueError(f"in_flight must be >= 1 (got {in_flight})")
    if drain not in ("async", "block"):
        raise ValueError(f"drain must be 'async' or 'block' "
                         f"(got {drain!r})")
    ids = [s.stream_id for s in streams]
    if len(set(ids)) != len(ids):
        raise ValueError(f"duplicate stream_id in {ids}")

    specs = list(streams)
    groups, group_of_stream = _build_groups(
        specs, policy, devices=devices, plan_policy=plan_policy,
        pool=pool, donate=donate)
    frames, dropped_per_stream = _make_frames(specs)
    if not frames:
        raise ValueError(
            "every frame falls outside its stream's connect window — "
            "nothing to serve (check the trace's start_s/stop_s)")
    trace_sha256 = trace_of_streams(specs).sha256()

    # Meter before warm-up: the NVML idle baseline must see the board
    # cold; one meter spans every group's devices.
    meter = ResourceMeter()

    # Warm-up: AOT-compile each group's ONE padded program (occupancy 1
    # and max_batch hit the same shape) ahead of the window — measured
    # and stamped, not silently excluded. Pool hits already paid;
    # misses are warmed here and published back so the next window
    # (the next sweep cell) starts from "pool".
    n_devices = len(devices) if devices is not None and len(devices) > 1 else 1
    for g in groups:
        if g.warm_source == "pool":
            continue
        prog = aot_warm(g.engine, policy.max_batch)
        g.warmup_s = prog.warmup_s
        if pool is not None:
            pool.put((g.key, policy.max_batch, n_devices,
                      g.engine.donate),
                     WarmEntry(engine=g.engine, program=prog))
    warmup_s = sum(g.warmup_s for g in groups)

    # Staging rings: per group, in_flight+1 preallocated padded host
    # buffers (the minimum that can never alias a slot the device is
    # still reading — see repro.core.staging). Built per window so ring
    # depth tracks this window's in_flight; pooled engines are shared,
    # rings are not.
    for g in groups:
        g.ring = StagingRing(policy.max_batch, g.cfg.rf_shape,
                             g.cfg.rf_dtype, depth=in_flight)

    outputs: Dict[str, dict] = {s.stream_id: {} for s in specs}
    delay_s = policy.max_queue_delay_ms / 1e3

    # In-flight ring + host-observed device-busy accounting. The busy
    # clock runs whenever >= 1 dispatch is pending; sleeps taken while
    # it runs are subtracted to get the fraction of the wall the host
    # spent doing USEFUL work (admit/coalesce/launch/drain) concurrent
    # with device execution. ``landing`` holds batches whose COMPUTE is
    # known settled (they no longer occupy the in-flight ring) but
    # whose images are still crossing D2H (async drain) — transfer
    # time, not device-busy time.
    pending: collections.deque = collections.deque()
    landing: collections.deque = collections.deque()
    dispatch_order: List[List[List[object]]] = []   # [[stream_id, seq]]
    depth_samples: List[int] = []
    busy_since: Optional[float] = None
    device_busy_s = 0.0
    sleep_while_busy_s = 0.0
    h2d_s = 0.0               # timed `place` (host buffer -> device)
    d2h_s = 0.0               # residual wait for images to land on host
    poll_base = _poll_base()

    meter.start()
    t0 = time.perf_counter()

    def clk() -> float:
        return time.perf_counter() - t0

    def harvest(p: _Pending) -> int:
        """Copy a settled batch's images to host; retire its frames.

        Under the async drain the D2H was started at detection time,
        so the ``np.asarray`` here pays only the residual transfer
        tail — that residual is what ``d2h_s`` measures. ``t_done`` is
        stamped once the images are ON THE HOST: completion latency
        includes the transfer, exactly as the blocking drain counts it.
        """
        nonlocal d2h_s
        t = time.perf_counter()
        out = np.asarray(p.out)
        d2h_s += time.perf_counter() - t
        t_done = clk()
        p.group.n_pending -= 1
        p.group.occupancies.append(len(p.batch))
        for i, f in enumerate(p.batch):
            f.t_dispatch, f.t_done = p.t_dispatch, t_done
            if collect_outputs:
                outputs[specs[f.stream].stream_id][f.seq] = out[i]
        return len(p.batch)

    def drain_pending(block: bool) -> int:
        """Retire settled pendings, oldest-first per group.

        Scanning the ring in launch order and skipping any group whose
        older batch is still pending guarantees a later batch of a
        group never retires before an earlier one — out-of-order
        settlement across groups is harvested, within a group it is
        serialized (outputs are keyed by (stream, seq) regardless, so
        this is a latency-accounting discipline, not a correctness
        crutch). With ``block`` the oldest pending of each group is
        waited on (final flush).

        Async mode splits retirement in two: detection frees the
        in-flight slot and starts the D2H in the background; the
        harvest (above) runs at the START of the next drain pass, so
        the admit/launch work in between is the transfer's head start.
        Harvests run in detection order — group-FIFO is preserved
        end to end.
        """
        nonlocal busy_since, device_busy_s
        retired = 0
        while landing:
            retired += harvest(landing.popleft())
        seen: set = set()
        for p in list(pending):
            if id(p.group) in seen:
                continue
            seen.add(id(p.group))
            if not (block or _ready(p.out)):
                continue
            if block:
                jax.block_until_ready(p.out)
            meter.sample()     # detection: overlapped batches are live
            pending.remove(p)
            if drain == "block":
                retired += harvest(p)
            else:
                try:
                    p.out.copy_to_host_async()
                except AttributeError:   # backend without async D2H
                    pass
                landing.append(p)
        if not pending and busy_since is not None:
            device_busy_s += clk() - busy_since
            busy_since = None
        return retired

    ai, done = 0, 0
    while done < len(frames):
        now = clk()
        while ai < len(frames) and frames[ai].t_arrival <= now:
            f = frames[ai]
            ai += 1
            group_of_stream[f.stream].queue.append(f)

        done += drain_pending(block=False)

        if len(pending) < in_flight:
            g = _pick_group(groups, clk(), policy)
            if g is not None:
                batch = [g.queue.popleft()
                         for _ in range(min(len(g.queue),
                                            policy.max_batch))]
                # Zero-copy launch: coalesce straight into the group's
                # staging-ring slot (pad rows pre-zeroed; ring depth
                # covers the in-flight bound so the slot cannot alias a
                # batch the device still reads), timed H2D commit, then
                # launch-only dispatch.
                t_dispatch = clk()
                buf, _ = g.ring.stage([f.rf for f in batch])
                t = time.perf_counter()
                dev = g.engine.place(buf)
                h2d_s += time.perf_counter() - t
                out = g.engine.dispatch_staged(dev, policy.max_batch)
                if busy_since is None:
                    busy_since = t_dispatch
                pending.append(_Pending(group=g, batch=batch, out=out,
                                        t_dispatch=t_dispatch))
                dispatch_order.append(
                    [[specs[f.stream].stream_id, f.seq] for f in batch])
                g.n_pending += 1
                g.depths.append(len(pending))
                depth_samples.append(len(pending))
                continue          # keep launching while the ring has room

        if landing:
            # Nothing to admit or launch right now, but images are in
            # flight D2H: finish them instead of sleeping on top of
            # them, so frames retire no later than the blocking drain
            # would have retired them.
            done += drain_pending(block=False)
            continue

        if pending:
            # Device busy: poll readiness. The grain adapts — fine
            # (``poll_base``) while the next scheduling decision is
            # imminent, stretching toward the idle horizon (capped)
            # when it is not, so low-rate streams stop spinning the
            # core the staging ring shares. These sleeps happen UNDER
            # the busy clock and are charged against the overlap
            # fraction — host idle while device works.
            dt = _poll_grain(clk(),
                             _idle_horizon(frames, ai, groups, delay_s),
                             base=poll_base)
            time.sleep(dt)
            sleep_while_busy_s += dt
            continue

        # Fully idle: sleep to the next arrival or the earliest
        # queue-delay expiry, whichever comes first. A non-positive gap
        # means progress is already due (see `_idle_horizon`) — loop.
        hz = _idle_horizon(frames, ai, groups, delay_s)
        if hz is not None:
            dt = hz - clk()
            if dt > 0:
                time.sleep(min(dt, 0.05))

    wall = clk()
    resources = meter.stop()
    stage_copy_s = sum(g.ring.stage_copy_s for g in groups)

    # ---- telemetry ----------------------------------------------------
    def budget(spec):
        return (spec.deadline_ms / 1e3
                if spec.deadline_ms is not None else None)

    per_stream = {}
    misses, with_budget = 0, 0
    for si, spec in enumerate(specs):
        fs = [f for f in frames if f.stream == si]
        # A fully-dropped stream (disconnected before its first
        # arrival) has no latency distribution — the blocks are None
        # (nullable in the schema), never empty stats.
        lat = (latency_stats([f.t_done - f.t_arrival for f in fs],
                             budget_s=budget(spec)) if fs else None)
        qd = (latency_stats([f.t_dispatch - f.t_arrival for f in fs])
              if fs else None)
        if budget(spec) is not None:
            # Count misses directly from the per-frame completion
            # latencies — re-deriving the count from the rounded
            # miss_rate float loses frames once n is large enough that
            # rate*n straddles a .5 boundary.
            misses += sum(1 for f in fs
                          if f.t_done - f.t_arrival > budget(spec))
            with_budget += len(fs)
        per_stream[spec.stream_id] = {
            "pipeline": spec.cfg.name,
            "variant": group_of_stream[si].cfg.variant.value,
            "arrival_fps": spec.fps,
            "acquisitions": len(fs),           # served (admitted) frames
            "frames": len(fs) * spec.cfg.n_f,
            "dropped": dropped_per_stream[si],  # out-of-window arrivals
            "deadline_ms": spec.deadline_ms,
            "latency": lat.json_dict() if lat else None,
            "queue_delay": qd.json_dict() if qd else None,
            "deadline_miss_rate": lat.miss_rate if lat else 0.0,
        }

    # Throughput counts what was SERVED: dropped (disconnected) frames
    # never reached the device and must not inflate MB/s or acq/s.
    acqs = len(frames)
    total_frames = sum(per_stream[s.stream_id]["frames"] for s in specs)
    total_bytes = sum(
        per_stream[s.stream_id]["acquisitions"] * s.cfg.input_bytes
        for s in specs)
    all_occ = [n for g in groups for n in g.occupancies]
    stats = {
        "name": (f"multitenant/{len(specs)}streams/{len(groups)}groups"
                 f"/b{policy.max_batch}q{policy.max_queue_delay_ms:g}"
                 f"if{in_flight}/{drain}/{load_profile}"),
        "clients": len(specs),
        "policy": policy.json_dict(),
        "in_flight": in_flight,
        "drain": drain,
        "load_profile": load_profile,
        "trace_sha256": trace_sha256,
        "dropped": sum(dropped_per_stream),
        "dispatch_order": dispatch_order,
        "wall_s": wall,
        "warmup_s": warmup_s,
        "acquisitions": acqs,
        "frames": total_frames,
        "sustained_mbps": total_bytes / (wall * 1e6),
        "fps": total_frames / wall,
        "acq_per_s": acqs / wall,
        # One serving window = one run: a degenerate (zero-width)
        # interval. benchmarks/multitenant.py --repeats replaces this
        # with the bootstrap CI over repeated windows; the schema
        # requires the stamp either way so the gate always has one.
        "acq_per_s_ci": bootstrap_ci([acqs / wall]).json_dict(),
        "deadline_miss_rate": (misses / with_budget if with_budget
                               else 0.0),
        "device_busy_s": device_busy_s,
        "device_busy_frac": device_busy_s / wall,
        "overlap_frac": max(0.0, (device_busy_s - sleep_while_busy_s)
                            / wall),
        # Degenerate one-window intervals, like acq_per_s_ci: the
        # benchmark's --repeats replaces them with real bootstraps so
        # the gate can apply CI-exclusion to the overlap columns too.
        "device_busy_frac_ci": bootstrap_ci(
            [device_busy_s / wall]).json_dict(),
        "overlap_frac_ci": bootstrap_ci(
            [max(0.0, (device_busy_s - sleep_while_busy_s) / wall)]
        ).json_dict(),
        # Host transfer telemetry: all three are host-thread-sequential
        # slices of the wall, so the fraction is well-defined in [0,1].
        # Under the async drain d2h_s is only the residual tail the
        # harvest still had to wait on — the overlap win shows up as
        # this number shrinking, not as transfers disappearing.
        "stage_copy_s": stage_copy_s,
        "h2d_s": h2d_s,
        "d2h_s": d2h_s,
        "transfer_frac": min(1.0, (stage_copy_s + h2d_s + d2h_s)
                             / wall) if wall > 0 else 0.0,
        "latency": latency_stats(
            [f.t_done - f.t_arrival for f in frames]).json_dict(),
        "queue_delay": latency_stats(
            [f.t_dispatch - f.t_arrival for f in frames]).json_dict(),
        "occupancy": occupancy_stats(all_occ,
                                     policy.max_batch).json_dict(),
        "in_flight_occupancy": in_flight_stats(
            depth_samples, in_flight).json_dict(),
        "per_stream": per_stream,
        "groups": {
            g.key: {
                "plan": g.engine.plan.with_serving(
                    warm_start=g.warm_source,
                    in_flight=in_flight).json_dict(),
                "streams": list(g.stream_ids),
                "batches": len(g.occupancies),
                "warmup_s": g.warmup_s,
                "warm_source": g.warm_source,
                # A group whose every stream was fully dropped launches
                # zero batches — no distributions to report.
                "occupancy": (occupancy_stats(
                    g.occupancies, policy.max_batch).json_dict()
                    if g.occupancies else None),
                "in_flight": (in_flight_stats(
                    g.depths, in_flight).json_dict()
                    if g.depths else None),
            } for g in groups},
        "resources": resources.json_dict(),
    }
    if collect_outputs:
        stats["outputs"] = {
            sid: [seqs[k] for k in sorted(seqs)]
            for sid, seqs in outputs.items()}
    return stats
