"""Loop-aware cost analysis of optimized (post-SPMD) HLO text.

XLA's built-in cost_analysis counts every computation ONCE — a lax.scan
over 126 layers reports one layer of FLOPs (verified empirically; see
EXPERIMENTS.md §Dry-run methodology). Since the whole model zoo is
scan-based, we re-derive per-device costs from the compiled module text:

  cost(entry) = sum over instructions of
      local_cost(inst) + trip_count(inst) * cost(called_computation)

Trip counts come from the `backend_config={"known_trip_count":{"n":...}}`
annotation XLA attaches to canonicalized while loops (always present for
lax.scan/fori_loop with static bounds). Conditionals take the max branch.

Local costs follow XLA's HloCostAnalysis conventions:
  * dot: 2 * prod(result_dims) * prod(contracting_dims) FLOPs
  * elementwise / reduce: result (resp. operand) element count
  * bytes: operands + result, except {dynamic-}slice/gather-style ops,
    which touch only the sliced window, and fusions, whose internal ops
    contribute FLOPs but not bytes (XLA's fusion-boundary convention)
  * collectives: result bytes, tallied by kind (this is the wire-bytes
    proxy used by the roofline's collective term)
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1,
    "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]\w*?)\[([\d,]*)\](?:\{[^}]*\})?")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*"
    r"([a-z][\w\-]*)\((.*)$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|body|to_apply)=%?([\w.\-]+)")
_COND_BRANCHES_RE = re.compile(
    r"(?:branch_computations|true_computation|false_computation)="
    r"\{?%?([\w.\-,% ]+)\}?")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

_SLICE_LIKE = {"dynamic-slice", "slice", "gather", "dynamic-update-slice",
               "scatter"}
_FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple",
             "bitcast", "after-all", "partition-id", "replica-id",
             "iota", "rng-bit-generator", "custom-call", "reshape"}


@dataclasses.dataclass
class Shape:
    nbytes: int
    elems: int
    dims_list: List[List[int]]  # per tuple component


def _parse_shape(text: str) -> Shape:
    nbytes = 0
    elems = 0
    dims_list = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        d = [int(x) for x in dims.split(",")] if dims else []
        n = 1
        for x in d:
            n *= x
        nbytes += n * _DTYPE_BYTES[dtype]
        elems += n
        dims_list.append(d)
    return Shape(nbytes, elems, dims_list)


@dataclasses.dataclass
class Inst:
    name: str
    shape: Shape
    op: str
    rest: str           # everything after the opening paren
    operands: List[str]
    called: List[str]
    trip: int
    is_cond: bool


@dataclasses.dataclass
class Computation:
    name: str
    insts: List[Inst]
    symbols: Dict[str, Shape]
    is_entry: bool


def _parse_operands(rest: str) -> List[str]:
    # operand list = up to the matching close paren of the op
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return _OPERAND_RE.findall(rest[:i])
    return _OPERAND_RE.findall(rest)


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->.*\{",
                          stripped)
        if header and not stripped.startswith("//"):
            cur = Computation(name=header.group(2), insts=[], symbols={},
                              is_entry=bool(header.group(1)))
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if stripped.startswith("}"):
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, shape_txt, op, rest = m.groups()
        shape = _parse_shape(shape_txt)
        called = _CALL_ATTR_RE.findall(rest)
        branches = _COND_BRANCHES_RE.findall(rest)
        if branches:
            called += [b.strip().lstrip("%") for b in branches[0].split(",")]
        trip_m = _TRIP_RE.search(rest)
        inst = Inst(name=name, shape=shape, op=op, rest=rest,
                    operands=_parse_operands(rest), called=called,
                    trip=int(trip_m.group(1)) if trip_m else 1,
                    is_cond=(op == "conditional"))
        cur.symbols[name] = shape
        cur.insts.append(inst)
    return comps


@dataclasses.dataclass
class Cost:
    """bytes = fusion-boundary traffic of the *CPU-optimized* module (an
    upper bound for TPU, whose fusion is more aggressive); bytes_min =
    dot/reduce/collective/copy/slice traffic only, i.e. a perfectly-fused
    lower bound. TPU reality sits between; the roofline reports both."""

    flops: float = 0.0
    bytes: float = 0.0
    bytes_min: float = 0.0
    gather_elems: float = 0.0   # elements moved by gather ops (TPU-hostile)
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    unknown_loops: int = 0

    def add(self, other: "Cost", scale: float = 1.0):
        self.flops += other.flops * scale
        self.bytes += other.bytes * scale
        self.bytes_min += other.bytes_min * scale
        self.gather_elems += other.gather_elems * scale
        for k in _COLLECTIVES:
            self.coll[k] += other.coll[k] * scale
        self.unknown_loops += other.unknown_loops

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def _dot_flops(inst: Inst, comp: Computation) -> float:
    contract = 1
    m = _CONTRACT_RE.search(inst.rest)
    if m and inst.operands:
        lhs = comp.symbols.get(inst.operands[0])
        if lhs and lhs.dims_list:
            dims = lhs.dims_list[0]
            for i_str in (m.group(1).split(",") if m.group(1) else []):
                i = int(i_str)
                if i < len(dims):
                    contract *= dims[i]
    return 2.0 * inst.shape.elems * contract


def _operand_bytes(inst: Inst, comp: Computation) -> float:
    total = 0
    for o in inst.operands:
        s = comp.symbols.get(o)
        if s:
            total += s.nbytes
    return float(total)


def _local_cost(inst: Inst, comp: Computation, in_fusion: bool) -> Cost:
    c = Cost()
    op = inst.op
    if op in _FREE_OPS:
        return c
    if op == "dot":
        c.flops = _dot_flops(inst, comp)
        c.bytes_min = _operand_bytes(inst, comp) + inst.shape.nbytes
        if not in_fusion:
            c.bytes = c.bytes_min
        return c
    if op == "convolution":
        # 2 * result elems * kernel elems / out_features (approx; convs
        # appear only in the DSP pipeline cells)
        kern = comp.symbols.get(inst.operands[1]) if len(
            inst.operands) > 1 else None
        k_elems = kern.elems if kern else 1
        out_feat = inst.shape.dims_list[0][1] if (
            inst.shape.dims_list and len(inst.shape.dims_list[0]) > 1) else 1
        c.flops = 2.0 * inst.shape.elems * max(k_elems // max(out_feat, 1),
                                               1)
        c.bytes_min = _operand_bytes(inst, comp) + inst.shape.nbytes
        if not in_fusion:
            c.bytes = c.bytes_min
        return c
    for kind in _COLLECTIVES:
        if op == kind or op == f"{kind}-start":
            c.coll[kind] = float(inst.shape.nbytes)
            if op.endswith("-start"):
                c.coll[kind] /= 2.0  # start tuple ~ (input, output)
            c.bytes = 0.0 if in_fusion else float(inst.shape.nbytes)
            c.bytes_min = c.coll[kind]
            return c
        if op == f"{kind}-done":
            return c
    if op in _SLICE_LIKE:
        # Traffic is the *window*, not the full buffer. For update-style
        # ops the result shape IS the full buffer, so use the update
        # operand's size (DUS: operand 1; scatter: operand 2).
        if op == "dynamic-update-slice":
            upd = (comp.symbols.get(inst.operands[1])
                   if len(inst.operands) > 1 else None)
            window = upd.nbytes if upd else inst.shape.nbytes
            c.flops = float(upd.elems) if upd else inst.shape.elems
        elif op == "scatter":
            upd = (comp.symbols.get(inst.operands[2])
                   if len(inst.operands) > 2 else None)
            window = upd.nbytes if upd else inst.shape.nbytes
            c.flops = float(upd.elems) if upd else inst.shape.elems
        else:
            window = inst.shape.nbytes
            c.flops = inst.shape.elems
            if op == "gather":
                c.gather_elems = float(inst.shape.elems)
        c.bytes_min = 2.0 * window
        if not in_fusion:
            c.bytes = c.bytes_min
        return c
    if op in ("while", "conditional", "fusion", "call", "reduce",
              "sort", "map"):
        # flops/bytes come from the called computation(s); at the call
        # site only the data movement counts.
        if not in_fusion and op in ("fusion", "reduce", "sort", "map"):
            c.bytes = _operand_bytes(inst, comp) + inst.shape.nbytes
        if op == "reduce":
            op0 = (comp.symbols.get(inst.operands[0])
                   if inst.operands else None)
            c.flops = float(op0.elems) if op0 else 0.0
            c.bytes_min = (float(op0.nbytes) if op0 else 0.0) + \
                inst.shape.nbytes
        return c
    # generic elementwise / copy / compare / select / convert ...
    c.flops = float(inst.shape.elems)
    if op == "copy":
        c.bytes_min = 2.0 * inst.shape.nbytes
    if not in_fusion:
        c.bytes = _operand_bytes(inst, comp) + inst.shape.nbytes
    return c


def analyze(text: str) -> Cost:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return Cost()
    memo: Dict[Tuple[str, bool], Cost] = {}

    def comp_cost(name: str, in_fusion: bool) -> Cost:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        total = Cost()
        memo[key] = total  # guards (benign) recursion
        if comp is None:
            return total
        for inst in comp.insts:
            total.add(_local_cost(inst, comp, in_fusion))
            if not inst.called:
                continue
            child_fusion = in_fusion or inst.op == "fusion"
            if inst.is_cond:
                branches = [comp_cost(b, child_fusion)
                            for b in inst.called]
                if branches:
                    worst = max(branches, key=lambda b: b.flops + b.bytes)
                    total.add(worst)
            else:
                scale = float(inst.trip) if inst.op == "while" else 1.0
                if inst.op == "while" and "known_trip_count" not in \
                        inst.rest:
                    total.unknown_loops += 1
                for child in inst.called:
                    total.add(comp_cost(child, child_fusion), scale)
        memo[key] = total
        return total

    return comp_cost(entry.name, False)


def analyze_compiled(compiled) -> Cost:
    return analyze(compiled.as_text())


if __name__ == "__main__":
    import sys
    with open(sys.argv[1]) as f:
        cost = analyze(f.read())
    print(json.dumps({"flops": cost.flops, "bytes": cost.bytes,
                      "bytes_min": cost.bytes_min,
                      "gather_elems": cost.gather_elems,
                      "collectives": cost.coll,
                      "unknown_loops": cost.unknown_loops}, indent=2))
