"""Fault-tolerant training driver.

End-to-end loop: deterministic data pipeline -> jitted train_step ->
async checkpointing -> preemption/hang handling -> restart-from-checkpoint.
Works unchanged from 1 CPU device (smoke configs) to the production mesh
(full configs; pass --mesh single|multi under the dry-run device count or
on real hardware).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt_lib
from repro.configs import SHAPES, TrainConfig, get_config, get_smoke
from repro.data.tokens import TokenDataset
from repro.models import get_model
from repro.runtime import sharding as shlib
from repro.runtime.fault_tolerance import (
    HangWatchdog, PreemptionHandler, TransientError)
from repro.train import steps as steps_lib


def train_loop(cfg, tcfg: TrainConfig, *, batch: int, seq: int,
               steps: int, ckpt_dir: Optional[str] = None,
               preemption: Optional[PreemptionHandler] = None,
               watchdog: Optional[HangWatchdog] = None,
               fail_at_step: Optional[int] = None,
               log_every: int = 10,
               metrics_out: Optional[list] = None) -> int:
    """Run (or resume) training. Returns the last completed step."""
    model = get_model(cfg)
    data = TokenDataset(cfg, batch, seq, seed=tcfg.seed)
    train_step = jax.jit(steps_lib.make_train_step(model, tcfg))

    start_step = 0
    state = None
    if ckpt_dir:
        latest = ckpt_lib.latest_step(ckpt_dir)
        if latest is not None:
            template = jax.eval_shape(
                lambda k: steps_lib.init_train_state(model, k),
                jax.random.PRNGKey(tcfg.seed))
            state = ckpt_lib.restore(ckpt_dir, latest, template)
            state = jax.tree.map(jnp.asarray, state)
            start_step = latest
    if state is None:
        state = steps_lib.init_train_state(
            model, jax.random.PRNGKey(tcfg.seed))

    saver = ckpt_lib.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    step = start_step
    t_last = time.time()
    for step in range(start_step + 1, steps + 1):
        batch_np = data.batch_for_step(step)
        state, metrics = train_step(state, jax.tree.map(jnp.asarray,
                                                        batch_np))
        if fail_at_step is not None and step == fail_at_step:
            raise TransientError(f"injected failure at step {step}")
        if watchdog is not None:
            watchdog.heartbeat()
        if metrics_out is not None:
            metrics_out.append(
                {k: float(v) for k, v in metrics.items()})
        if step % log_every == 0 or step == steps:
            dt = time.time() - t_last
            t_last = time.time()
            tok_s = batch * seq * log_every / max(dt, 1e-9)
            print(f"step {step:6d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"tok/s={tok_s:,.0f}", flush=True)
        if saver and (step % tcfg.checkpoint_every == 0 or step == steps):
            saver.save(step, state)
        if preemption is not None and preemption.preempted:
            if saver:
                saver.save(step, state)
                saver.wait()
            print(f"preempted: checkpointed at step {step}", flush=True)
            return step
    if saver:
        saver.wait()
    return step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--hang-timeout", type=float, default=600.0)
    args = ap.parse_args()

    cfg = (get_smoke(args.arch) if args.smoke else get_config(args.arch))
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 20, 1),
                       microbatches=args.microbatches,
                       checkpoint_every=args.ckpt_every)

    watchdog = HangWatchdog(args.hang_timeout).start()
    with PreemptionHandler() as pre:
        train_loop(cfg, tcfg, batch=args.batch, seq=args.seq,
                   steps=args.steps, ckpt_dir=args.ckpt_dir,
                   preemption=pre, watchdog=watchdog)
    watchdog.stop()


if __name__ == "__main__":
    main()
