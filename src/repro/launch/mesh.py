"""Production meshes and logical-axis bindings.

Single pod:  (data=16, model=16)        — 256 chips (TPU v5e pod)
Multi-pod:   (pod=2, data=16, model=16) — 512 chips

`make_production_mesh` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; only the dry-run / launchers
call it, after setting XLA_FLAGS for placeholder devices where needed.

The `pod` axis composes with data parallelism by default (gradient
all-reduce crosses the DCN once per step); ParallelConfig.pod_axis_role
can repurpose it.
"""

from __future__ import annotations

from typing import Optional

import jax

from repro.configs.base import ParallelConfig
from repro.runtime import sharding as shlib


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for in-process sharding tests (host device count
    permitting)."""
    return jax.make_mesh(shape, axes)


def binding_for(mesh, parallel: Optional[ParallelConfig] = None,
                ) -> shlib.Binding:
    parallel = parallel or ParallelConfig()
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    rules = (shlib.MULTI_POD_RULES if "pod" in mesh.axis_names
             else shlib.SINGLE_POD_RULES)
    return shlib.Binding(rules, axis_sizes, fsdp=parallel.fsdp)
