import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The ONLY entry point that forces 512 placeholder devices (set above,
before any jax import — jax locks device count at first init). Proves the
distribution config is coherent: sharding mismatches, compile-time OOMs
and unsupported collectives all surface here as failures.

Per cell it records:
  * memory_analysis(): per-device argument/output/temp/peak bytes,
  * cost_analysis(): HLO FLOPs + bytes accessed,
  * collective result bytes parsed from the optimized HLO,
  * derived roofline terms (launch/hlo_analysis.py),
  * MODEL_FLOPS = 6|2 * N_active * D and the useful-compute ratio.

Results append to benchmarks/results/dryrun.json (one record per cell).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import time
import traceback
from typing import Dict

import jax

from repro.configs import ARCHS, SHAPES, get_config
from repro.launch import cells as cells_lib
from repro.launch import hlo_analysis as hlo
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh

ARCH_IDS = [
    "granite-moe-3b-a800m", "deepseek-v2-236b", "zamba2-1.2b",
    "qwen2-vl-2b", "qwen3-8b", "gemma3-1b", "granite-3-8b",
    "llama3-405b", "mamba2-130m", "seamless-m4t-large-v2",
]

RESULTS_PATH = os.path.join(
    os.path.dirname(__file__), "..", "..", "..",
    "benchmarks", "results", "dryrun.json")


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> Dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    record: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "n_chips": int(n_chips)}

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cells_lib.cell_supported(cfg, shape)
    if not ok:
        record.update(status="skipped", reason=why)
        return record

    try:
        cell = cells_lib.build_cell(arch, shape_name, mesh)
        lowered = cells_lib.lower_cell(cell, mesh)
        compiled = lowered.compile()

        mem = compiled.memory_analysis()
        xla_cost = compiled.cost_analysis()
        if isinstance(xla_cost, list):  # jax<0.5 returns [dict]
            xla_cost = xla_cost[0] if xla_cost else {}
        # Loop-aware per-device cost (XLA's cost_analysis counts scan
        # bodies once — useless for 126-layer models; see hlo_cost.py).
        cost = hlo_cost.analyze(compiled.as_text())

        flops = float(cost.flops)
        bytes_acc = float(cost.bytes_min)   # fused-ideal (TPU-like) bound
        bytes_max = float(cost.bytes)       # CPU-fusion-boundary bound
        coll = {k: int(v) for k, v in cost.coll.items()}
        coll_total = int(cost.coll_bytes)
        terms = hlo.roofline_terms(flops, bytes_acc, coll_total, n_chips)
        terms["t_memory_max"] = bytes_max / hlo.HBM_BW
        mflops = cells_lib.model_flops(cfg, shape)
        total_p, active_p = cells_lib.count_params(cfg)

        record.update(
            status="ok",
            compile_s=round(time.time() - t0, 1),
            memory=dict(
                argument_bytes=int(getattr(mem, "argument_size_in_bytes",
                                           0)),
                output_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
                temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
                generated_code_bytes=int(getattr(
                    mem, "generated_code_size_in_bytes", 0)),
            ),
            flops_per_device=flops,
            bytes_per_device=bytes_acc,
            bytes_per_device_max=bytes_max,
            collective_bytes=coll,
            collective_total=coll_total,
            unknown_trip_loops=int(cost.unknown_loops),
            xla_flops_body_once=float(xla_cost.get("flops", 0.0)),
            roofline=terms,
            dominant=hlo.dominant_term(terms),
            model_flops_global=mflops,
            model_flops_per_device=mflops / n_chips,
            useful_ratio=(mflops / n_chips) / flops if flops else 0.0,
            params_total=total_p,
            params_active=active_p,
        )
    except Exception as e:  # noqa: BLE001 — a failed cell is a bug report
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    return record


def append_result(record: Dict, path: str = RESULTS_PATH) -> None:
    path = os.path.abspath(path)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    data = []
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    # replace any previous record for the same cell
    key = (record["arch"], record["shape"], record["mesh"])
    data = [r for r in data
            if (r["arch"], r["shape"], r["mesh"]) != key]
    data.append(record)
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) cell")
    ap.add_argument("--out", default=RESULTS_PATH)
    args = ap.parse_args()

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh_kind)
                append_result(rec, args.out)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f" dom={rec['dominant']}"
                             f" t={rec['roofline']}"
                             f" compile={rec['compile_s']}s")
                elif status == "error":
                    n_fail += 1
                    extra = " " + rec["error"][:200]
                print(f"[{mesh_kind}] {arch} x {shape_name}: "
                      f"{status}{extra}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
