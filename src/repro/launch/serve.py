"""Serving drivers for both system halves.

LM half — slot-based batching: B fixed slots, each request prefills into
its slot, then all slots decode in lockstep (static shapes — one compiled
program for the whole serving session, the paper's §II-E execution model).
Works on CPU with smoke configs; the production mesh shards slots over
data and heads/experts over model exactly like the dry-run decode cells.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 16 --batch 4 --prompt-len 32 --max-new 32

Ultrasound half — two streaming drivers over the stage-graph executors
(repro.core.executor), both fed by a synthetic acquisition source and
both reporting *sustained* MB/s / FPS under queue pressure plus the
completion-latency distribution (p50/p95/p99, jitter, deadline misses —
semantics in EXPERIMENTS.md and docs/benchmarking-methodology.md):

  * `serve_ultrasound_stream` — single-device `BatchedExecutor` loop;
    up to `depth` batches stay in flight against the async dispatch
    queue.
  * `serve_ultrasound_sharded` — multi-device `ShardedExecutor` loop:
    every dispatch splits its batch across the mesh, each device gets
    its own in-flight queue of output shards (per-device completion
    intervals -> per-device latency stats), and the stats report
    aggregated throughput plus scale efficiency against a single-device
    baseline (speedup_vs_single = sharded FPS / single-device FPS;
    scale_efficiency = speedup / n_devices).

Both stamp the resolved `PipelinePlan` (with device topology) and the
measured `ResourceStats` (peak memory; energy where NVML exists, else
None) into their stats dict, so streaming telemetry carries the same
attribution and resource columns as the offline tables.

Invariants: warm-up round trips never count toward the timed window;
throughput is computed over wall clock of the whole window (sustained,
not best-case); the sharded loop only dispatches device-aligned batches
(batch_per_device * n_devices), so no host-side remainder slicing ever
re-synchronizes the stream.

Multi-tenant serving (`--multitenant`) runs N open-loop probe clients
— alternating B-mode / Doppler configs at staggered frame rates —
through the dynamic-batching scheduler (`repro.launch.scheduler`):
per-config queues, same-config-hash coalescing under a
max_batch / max_queue_delay_ms policy, fixed padded dispatch shapes,
AOT warm-start compilation (repro.core.aot), pipelined dispatch to
``--in-flight`` depth, zero-copy staging rings with a ``--drain``
retirement mode (async copy_to_host_async vs legacy blocking harvest),
per-stream latency + queue-delay + occupancy + device-overlap +
host-transfer telemetry. Design and knobs: docs/serving.md.

  PYTHONPATH=src python -m repro.launch.serve --ultrasound \
      --batch 4 --batches 32 --depth 2 --deadline-ms 50

  # multi-device (CPU hosts: force a 2-device mesh first)
  XLA_FLAGS=--xla_force_host_platform_device_count=2 \
  PYTHONPATH=src python -m repro.launch.serve --ultrasound \
      --devices 2 --batch 4 --batches 32 --depth 2

  # 4 mixed-modality tenants through the dynamic-batching scheduler
  PYTHONPATH=src python -m repro.launch.serve --ultrasound \
      --multitenant --clients 4 --max-batch 4 --queue-delay-ms 5 \
      --deadline-ms 100
"""

from __future__ import annotations

import argparse
import collections
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.batches import synth_train_batch
from repro.models import get_model
from repro.train import steps as steps_lib


def serve_session(cfg, *, requests: int, batch: int, prompt_len: int,
                  max_new: int, seed: int = 0):
    """Process `requests` prompts in slot batches of `batch`.

    Returns (generated tokens array, stats dict).
    """
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    prefill_step = jax.jit(steps_lib.make_prefill_step(model))
    serve_step = jax.jit(steps_lib.make_serve_step(model))

    outs = []
    n_steps = 0
    # perf_counter, like every other timing window in this module: an
    # NTP step mid-session would skew (or negate) a time.time() wall.
    t0 = time.perf_counter()
    max_len = prompt_len + max_new + 1
    for r0 in range(0, requests, batch):
        bsz = min(batch, requests - r0)
        prompt = synth_train_batch(cfg, bsz, prompt_len, seed=seed + r0)
        tok_next, cache = prefill_step(params, prompt)
        tok = tok_next[:, None]
        if cfg.family == "audio":
            # enc-dec prefill returns a decode-ready cache (BOS consumed)
            lengths = jnp.ones((bsz,), jnp.int32)
        else:
            # decoder-only: extend the prefilled cache to serving length
            cache = _grow_cache(model, cache, max_len)
            lengths = jnp.full((bsz,), prompt_len, jnp.int32)

        gen = [np.asarray(tok)]
        for _ in range(max_new):
            tok, cache, lengths = serve_step(params, tok, cache, lengths)
            gen.append(np.asarray(tok))
            n_steps += 1
        outs.append(np.concatenate(gen, axis=1))

    wall = time.perf_counter() - t0
    toks = sum(o.size for o in outs)
    stats = {"wall_s": wall, "tokens": toks,
             "tok_per_s": toks / max(wall, 1e-9),
             "decode_steps": n_steps}
    return np.concatenate(outs, axis=0)[:requests], stats


def _grow_cache(model, cache, max_len: int):
    """Pad every leaf's sequence axis to max_len.

    The sequence axis is identified structurally via cache_specs
    (seq_sharded=True labels it "seq"); leaves without one (SSM/conv
    states) pass through untouched.
    """
    specs = model.cache_specs(seq_sharded=True)

    def grow(ax, a):
        if "seq" not in ax:
            return a
        i = ax.index("seq")
        if a.shape[i] >= max_len:
            return a
        pad = [(0, 0)] * a.ndim
        pad[i] = (0, max_len - a.shape[i])
        return jnp.pad(a, pad)

    return jax.tree.map(
        grow, specs, cache,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, (str, tuple)) for a in x))


class SyntheticAcquisitionSource:
    """Host-side RF batch source (stand-in for a probe front end).

    Pre-generates a pool of distinct (batch, n_l, n_c, n_f) acquisitions
    (a probe sweep) and cycles it — generation cost stays out of the
    streaming window, while every dispatch still uploads a fresh
    host->device buffer like a real acquisition stream would.

    Frame seeds come from `repro.data.traces.seed_space`, so sources
    with different base seeds occupy disjoint seed spaces: the old
    additive ``seed + b * batch + i`` scheme made two sources whose
    base seeds differed by less than ``pool * batch`` stream
    byte-identical RF.
    """

    def __init__(self, cfg, batch: int, *, pool: int = 4, seed: int = 0):
        from repro.data import seed_space, synth_rf
        self.cfg = cfg
        self.batch = batch
        self._pool = [
            np.stack([synth_rf(
                cfg, seed=seed_space("source", seed, b * batch + i))
                for i in range(batch)])
            for b in range(pool)]
        self._i = 0

    def next(self) -> np.ndarray:
        rf = self._pool[self._i % len(self._pool)]
        self._i += 1
        return rf


def serve_ultrasound_stream(cfg, *, batch: int = 4, n_batches: int = 32,
                            depth: int = 2, pool: int = 4, seed: int = 0,
                            deadline_s=None, source=None,
                            plan=None, policy=None) -> dict:
    """Stream RF batches through the stage-graph engine, `depth` in flight.

    Dispatches are asynchronous; the loop only blocks on the *oldest*
    in-flight batch once `depth` are queued, so host-side source work and
    device compute overlap. Completion-to-completion intervals form the
    latency samples; the per-batch deadline budget is
    ``batch * deadline_s`` (deadline_s is the per-acquisition frame
    budget — see EXPERIMENTS.md).

    `plan` / `policy` resolve the executor's variant and exec_map
    (repro.core.plan); the resolved plan is stamped into the stats so
    streaming telemetry stays attributable. ``Variant.AUTO`` configs
    resolve heuristically when neither is given.

    Returns a stats dict with sustained throughput and a LatencyStats.
    """
    from repro.bench.harness import latency_stats
    from repro.bench.resources import ResourceMeter, devices_of
    from repro.core.executor import BatchedExecutor

    if batch < 1 or n_batches < 1 or depth < 1:
        raise ValueError(
            f"batch, n_batches, depth must be >= 1 "
            f"(got {batch}, {n_batches}, {depth})")

    engine = BatchedExecutor(cfg, plan=plan, policy=policy)
    cfg = engine.cfg                 # plan-resolved (concrete variant)
    if source is None:
        source = SyntheticAcquisitionSource(cfg, batch, pool=pool, seed=seed)

    # Meter built BEFORE warm-up so the NVML idle baseline sees the
    # board cold; scoped to the engine's device — a sharded neighbor's
    # buffers on other devices must not pollute this single-device stamp.
    meter = ResourceMeter(devices=devices_of(engine.consts))

    # warm-up: compile + one full round trip, excluded from timing
    jax.block_until_ready(engine(jnp.asarray(source.next())))
    meter.start()
    in_flight: collections.deque = collections.deque()
    intervals = []
    t0 = time.perf_counter()
    last = t0
    for _ in range(n_batches):
        in_flight.append(engine(jnp.asarray(source.next())))
        while len(in_flight) >= depth:
            jax.block_until_ready(in_flight.popleft())
            now = time.perf_counter()
            intervals.append(now - last)
            last = now
            meter.sample()
    while in_flight:
        jax.block_until_ready(in_flight.popleft())
        now = time.perf_counter()
        intervals.append(now - last)
        last = now
        meter.sample()
    wall = time.perf_counter() - t0

    acqs = n_batches * batch
    budget = batch * deadline_s if deadline_s is not None else None
    return {
        "name": f"stream/{cfg.name}/{cfg.variant.value}/b{batch}",
        "batch": batch, "n_batches": n_batches, "depth": depth,
        "plan": engine.plan.json_dict(),
        "wall_s": wall,
        "acquisitions": acqs,
        "frames": acqs * cfg.n_f,
        "sustained_mbps": acqs * cfg.input_bytes / (wall * 1e6),
        "fps": acqs * cfg.n_f / wall,
        "acq_per_s": acqs / wall,
        "latency": latency_stats(intervals, budget_s=budget),
        "resources": meter.stop().json_dict(),
    }


def serve_ultrasound_sharded(cfg, *, batch_per_device: int = 4,
                             n_batches: int = 32, depth: int = 2,
                             pool: int = 4, seed: int = 0,
                             deadline_s=None, devices=None, source=None,
                             plan=None, policy=None,
                             baseline_fps=None,
                             measure_baseline: bool = True) -> dict:
    """Stream RF through the `ShardedExecutor`, per-device in-flight queues.

    Every dispatch carries ``batch_per_device * n_devices`` acquisitions,
    split across the mesh by the executor's batch sharding. The output
    stays sharded; each device's output shard goes onto that device's
    own in-flight queue, and once ``depth`` dispatches are queued the
    loop blocks on the *oldest shard of each device* — so per-device
    completion intervals (and stragglers) are observable individually
    while dispatch stays global and asynchronous.

    Scale efficiency: ``baseline_fps`` is the single-device sustained
    FPS at the same per-device batch (measured via
    `serve_ultrasound_stream` when not supplied and
    ``measure_baseline``); the stats report
    ``speedup_vs_single = fps / baseline_fps`` and
    ``scale_efficiency = speedup_vs_single / n_devices`` (1.0 = perfect
    linear scaling). Both are None when no baseline is available.

    Returns a stats dict shaped like `serve_ultrasound_stream`'s plus
    ``devices``, ``per_device_latency``, ``speedup_vs_single``,
    ``scale_efficiency``; ``plan`` carries the mesh topology and
    ``resources`` the measured peak memory / energy.
    """
    from repro.bench.harness import latency_stats
    from repro.bench.resources import ResourceMeter
    from repro.core.executor import ShardedExecutor

    if batch_per_device < 1 or n_batches < 1 or depth < 1:
        raise ValueError(
            f"batch_per_device, n_batches, depth must be >= 1 "
            f"(got {batch_per_device}, {n_batches}, {depth})")

    engine = ShardedExecutor(cfg, devices=devices, plan=plan, policy=policy)
    cfg = engine.cfg                 # plan-resolved (concrete variant)
    n_dev = engine.n_devices
    batch = batch_per_device * n_dev

    # Meter built first — before the single-device baseline stream and
    # the warm-up run heat the boards — so the NVML idle baseline
    # actually sees them cold.
    meter = ResourceMeter(devices=engine.devices)

    if baseline_fps is None and measure_baseline:
        # Same resolved decisions, single-device topology stamp: the
        # baseline's telemetry must not claim the mesh it didn't use.
        baseline_plan = dataclasses.replace(
            engine.plan, devices=1, mesh_shape=None)
        baseline_fps = serve_ultrasound_stream(
            cfg, batch=batch_per_device, n_batches=n_batches, depth=depth,
            pool=pool, seed=seed, deadline_s=deadline_s,
            plan=baseline_plan, policy=None)["fps"]

    if source is None:
        source = SyntheticAcquisitionSource(cfg, batch, pool=pool, seed=seed)

    # warm-up: compile + one full sharded round trip, excluded from timing
    jax.block_until_ready(engine.dispatch(jnp.asarray(source.next())))

    dev_index = {d: i for i, d in enumerate(engine.devices)}
    queues = [collections.deque() for _ in engine.devices]
    dev_intervals = [[] for _ in engine.devices]
    intervals = []                     # global: all devices of a dispatch

    meter.start()
    t0 = time.perf_counter()
    last_dev = [t0] * n_dev
    last_global = t0

    def drain_one():
        """Retire the oldest in-flight shard of every device.

        Completion times are observed by polling shard readiness, not
        by blocking in device order — a straggling device must not
        inflate the recorded completion time of devices that already
        finished (its stall shows up in *its own* interval only).
        """
        nonlocal last_global
        pending = {i: q.popleft() for i, q in enumerate(queues)}
        while pending:
            for i in list(pending):
                sh = pending[i]
                ready = sh.is_ready() if hasattr(sh, "is_ready") else True
                if ready:
                    jax.block_until_ready(sh)     # settled: returns at once
                    now = time.perf_counter()
                    dev_intervals[i].append(now - last_dev[i])
                    last_dev[i] = now
                    del pending[i]
            if pending:
                time.sleep(1e-4)
        now = time.perf_counter()
        intervals.append(now - last_global)
        last_global = now
        meter.sample()

    for _ in range(n_batches):
        out = engine.dispatch(jnp.asarray(source.next()))
        for sh in out.addressable_shards:
            queues[dev_index[sh.device]].append(sh.data)
        while len(queues[0]) >= depth:
            drain_one()
    while queues[0]:
        drain_one()
    wall = time.perf_counter() - t0

    acqs = n_batches * batch
    fps = acqs * cfg.n_f / wall
    budget = batch * deadline_s if deadline_s is not None else None
    speedup = fps / baseline_fps if baseline_fps else None
    return {
        "name": (f"stream/{cfg.name}/{cfg.variant.value}"
                 f"/b{batch_per_device}xd{n_dev}"),
        "devices": n_dev,
        "batch_per_device": batch_per_device,
        "batch": batch, "n_batches": n_batches, "depth": depth,
        "plan": engine.plan.json_dict(),
        "wall_s": wall,
        "acquisitions": acqs,
        "frames": acqs * cfg.n_f,
        "sustained_mbps": acqs * cfg.input_bytes / (wall * 1e6),
        "fps": fps,
        "acq_per_s": acqs / wall,
        "latency": latency_stats(intervals, budget_s=budget),
        "per_device_latency": {
            str(d): latency_stats(dev_intervals[i]).json_dict()
            for i, d in enumerate(engine.devices)},
        "baseline_fps": baseline_fps,
        "speedup_vs_single": speedup,
        "scale_efficiency": (speedup / n_dev
                             if speedup is not None else None),
        "resources": meter.stop().json_dict(),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ultrasound", action="store_true",
                    help="stream RF through the batched stage-graph engine")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batches", type=int, default=32,
                    help="ultrasound: RF batches to stream")
    ap.add_argument("--depth", type=int, default=2,
                    help="ultrasound: max batches in flight")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="ultrasound: per-acquisition frame budget")
    ap.add_argument("--devices", type=int, default=None,
                    help="ultrasound: shard each batch across N local "
                         "devices (--batch becomes per-device; with "
                         "--multitenant, --max-batch must divide by N; "
                         "CPU hosts need XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--plan", default=None,
                    choices=["fixed", "heuristic", "autotune"],
                    help="ultrasound: variant-resolution policy")
    ap.add_argument("--variant", default=None,
                    choices=["dynamic", "cnn", "sparse", "auto"],
                    help="ultrasound: operator variant (auto = planner)")
    ap.add_argument("--multitenant", action="store_true",
                    help="ultrasound: N mixed-modality clients through "
                         "the dynamic-batching scheduler "
                         "(repro.launch.scheduler; docs/serving.md)")
    ap.add_argument("--clients", type=int, default=4,
                    help="multitenant: number of probe clients")
    ap.add_argument("--max-batch", type=int, default=4,
                    help="multitenant: coalescing ceiling (padded "
                         "dispatch shape)")
    ap.add_argument("--queue-delay-ms", type=float, default=5.0,
                    help="multitenant: max wait of the oldest queued "
                         "frame before a partial batch flushes")
    ap.add_argument("--frames", type=int, default=24,
                    help="multitenant: acquisitions per client")
    ap.add_argument("--in-flight", type=int, default=2,
                    help="multitenant: dispatch-pipelining depth (1 = "
                         "synchronous launch-block-retire)")
    ap.add_argument("--drain", default="async",
                    choices=["async", "block"],
                    help="multitenant: host-transfer retirement mode "
                         "(async = copy_to_host_async off the admit "
                         "loop's critical path, block = legacy "
                         "blocking harvest; bit-identical outputs)")
    args = ap.parse_args()

    if args.variant == "auto" and args.plan == "fixed":
        ap.error("--variant auto needs --plan heuristic or autotune")

    def cli_devices():
        """Validated --devices -> prefix of local devices (None = unset).

        Shared by the sharded-stream and multitenant paths so the range
        checks (and the XLA_FLAGS hint) cannot drift between them.
        """
        if args.devices is None:
            return None
        local = jax.local_devices()
        if args.devices < 1:
            ap.error(f"--devices must be >= 1 (got {args.devices})")
        if args.devices > len(local):
            ap.error(f"--devices {args.devices} > {len(local)} local "
                     "devices (CPU hosts: set XLA_FLAGS="
                     "--xla_force_host_platform_device_count="
                     f"{args.devices})")
        return local[:args.devices]

    if args.multitenant:                 # implies --ultrasound
        from repro.core import Modality, Variant, tiny_config
        from repro.launch.scheduler import (BatchPolicy,
                                            make_mixed_streams,
                                            serve_multitenant)
        if args.clients < 1:
            ap.error(f"--clients must be >= 1 (got {args.clients})")
        variant = (Variant(args.variant) if args.variant
                   else Variant.DYNAMIC)
        cfg = tiny_config(nz=32, nx=32, n_f=8, n_c=16, variant=variant)
        streams = make_mixed_streams(
            args.clients, cfg, cfg.with_(modality=Modality.DOPPLER),
            n_frames=args.frames, deadline_ms=args.deadline_ms)
        stats = serve_multitenant(
            streams,
            policy=BatchPolicy(args.max_batch, args.queue_delay_ms),
            in_flight=args.in_flight, drain=args.drain,
            devices=cli_devices(), plan_policy=args.plan)
        lat, qd = stats["latency"], stats["queue_delay"]
        occ = stats["occupancy"]
        print(f"{stats['name']}: {stats['acquisitions']} acquisitions "
              f"({stats['frames']} frames) from {stats['clients']} "
              f"clients in {stats['wall_s']:.2f}s = "
              f"{stats['sustained_mbps']:.2f} MB/s, "
              f"{stats['fps']:.1f} FPS "
              f"(warm-up {stats['warmup_s']:.2f}s ahead of window)")
        ifo = stats["in_flight_occupancy"]
        print(f"overlap: in_flight={stats['in_flight']} "
              f"mean_depth={ifo['mean_depth']:.2f} "
              f"device_busy={stats['device_busy_frac']:.2f} "
              f"overlap_frac={stats['overlap_frac']:.2f}")
        print(f"transfer: drain={stats['drain']} "
              f"stage_copy={stats['stage_copy_s'] * 1e3:.2f}ms "
              f"h2d={stats['h2d_s'] * 1e3:.2f}ms "
              f"d2h={stats['d2h_s'] * 1e3:.2f}ms "
              f"transfer_frac={stats['transfer_frac']:.3f}")
        print(f"latency: p50={lat['p50_s'] * 1e3:.2f}ms "
              f"p95={lat['p95_s'] * 1e3:.2f}ms "
              f"p99={lat['p99_s'] * 1e3:.2f}ms; queue delay "
              f"p50={qd['p50_s'] * 1e3:.2f}ms "
              f"p95={qd['p95_s'] * 1e3:.2f}ms; "
              f"occupancy={occ['mean_occupancy']:.2f}/"
              f"{occ['max_batch']} (fill={occ['mean_fill']:.2f}, "
              f"full_rate={occ['full_rate']:.2f}); "
              f"miss_rate={stats['deadline_miss_rate']:.3f}")
        for sid, s in stats["per_stream"].items():
            sl = s["latency"]
            print(f"  {sid} [{s['pipeline']}/{s['variant']}"
                  f"@{s['arrival_fps']:.0f}fps]: "
                  f"p50={sl['p50_s'] * 1e3:.2f}ms "
                  f"p95={sl['p95_s'] * 1e3:.2f}ms "
                  f"p99={sl['p99_s'] * 1e3:.2f}ms "
                  f"miss_rate={s['deadline_miss_rate']:.3f}")
        for key, g in stats["groups"].items():
            plan = g["plan"]
            print(f"  group {key}: streams={g['streams']} "
                  f"variant={plan['variant']} "
                  f"backend={plan['backend']} "
                  f"batches={g['batches']} "
                  f"fill={g['occupancy']['mean_fill']:.2f}")
        return

    if args.ultrasound:
        from repro.core import Variant, tiny_config
        cfg = tiny_config(nz=32, nx=32, n_f=8, n_c=16)
        if args.variant is not None:
            cfg = cfg.with_(variant=Variant(args.variant))
        deadline_s = (args.deadline_ms / 1e3
                      if args.deadline_ms is not None else None)
        devices = cli_devices()
        if devices is not None:
            stats = serve_ultrasound_sharded(
                cfg, batch_per_device=args.batch, n_batches=args.batches,
                depth=args.depth, policy=args.plan,
                devices=devices, deadline_s=deadline_s)
        else:
            stats = serve_ultrasound_stream(
                cfg, batch=args.batch, n_batches=args.batches,
                depth=args.depth, policy=args.plan, deadline_s=deadline_s)
        lat = stats["latency"]
        plan = stats["plan"]
        print(f"plan: policy={plan['policy']} backend={plan['backend']} "
              f"variant={plan['variant']} exec_map={plan['exec_map']} "
              f"devices={plan['devices']} ({plan['provenance']})")
        print(f"{stats['name']}: {stats['acquisitions']} acquisitions "
              f"({stats['frames']} frames) in {stats['wall_s']:.2f}s = "
              f"{stats['sustained_mbps']:.2f} MB/s, {stats['fps']:.1f} FPS; "
              f"p50={lat.p50_s * 1e3:.2f}ms p95={lat.p95_s * 1e3:.2f}ms "
              f"p99={lat.p99_s * 1e3:.2f}ms jitter={lat.jitter_s * 1e3:.2f}ms "
              f"miss_rate={lat.miss_rate:.3f}")
        res = stats.get("resources") or {}
        peak = res.get("peak_memory_bytes")
        joules = res.get("energy_joules")
        print("resources: "
              f"peak_mem={peak / 1e6:.1f}MB ({res.get('memory_source')}) "
              if peak is not None else "resources: peak_mem=n/a ",
              end="")
        print(f"energy={joules:.2f}J" if joules is not None
              else "energy=n/a (no NVML)")
        if stats.get("speedup_vs_single") is not None:
            print(f"scaling: {stats['devices']} devices, "
                  f"baseline_fps={stats['baseline_fps']:.1f}, "
                  f"speedup={stats['speedup_vs_single']:.2f}x, "
                  f"scale_efficiency={stats['scale_efficiency']:.2f}")
        return

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    out, stats = serve_session(
        cfg, requests=args.requests, batch=args.batch,
        prompt_len=args.prompt_len, max_new=args.max_new)
    print(f"served {args.requests} requests: {stats['tokens']} tokens in "
          f"{stats['wall_s']:.2f}s = {stats['tok_per_s']:,.0f} tok/s")


if __name__ == "__main__":
    main()
