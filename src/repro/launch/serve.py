"""Batched serving driver: prefill + greedy decode over request batches.

Slot-based batching: B fixed slots, each request prefills into its slot,
then all slots decode in lockstep (static shapes — one compiled program
for the whole serving session, the paper's §II-E execution model). Works
on CPU with smoke configs; the production mesh shards slots over data and
heads/experts over model exactly like the dry-run decode cells.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 16 --batch 4 --prompt-len 32 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.batches import synth_train_batch
from repro.models import get_model
from repro.train import steps as steps_lib


def serve_session(cfg, *, requests: int, batch: int, prompt_len: int,
                  max_new: int, seed: int = 0):
    """Process `requests` prompts in slot batches of `batch`.

    Returns (generated tokens array, stats dict).
    """
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    prefill_step = jax.jit(steps_lib.make_prefill_step(model))
    serve_step = jax.jit(steps_lib.make_serve_step(model))

    outs = []
    n_steps = 0
    t0 = time.time()
    max_len = prompt_len + max_new + 1
    for r0 in range(0, requests, batch):
        bsz = min(batch, requests - r0)
        prompt = synth_train_batch(cfg, bsz, prompt_len, seed=seed + r0)
        tok_next, cache = prefill_step(params, prompt)
        tok = tok_next[:, None]
        if cfg.family == "audio":
            # enc-dec prefill returns a decode-ready cache (BOS consumed)
            lengths = jnp.ones((bsz,), jnp.int32)
        else:
            # decoder-only: extend the prefilled cache to serving length
            cache = _grow_cache(model, cache, max_len)
            lengths = jnp.full((bsz,), prompt_len, jnp.int32)

        gen = [np.asarray(tok)]
        for _ in range(max_new):
            tok, cache, lengths = serve_step(params, tok, cache, lengths)
            gen.append(np.asarray(tok))
            n_steps += 1
        outs.append(np.concatenate(gen, axis=1))

    wall = time.time() - t0
    toks = sum(o.size for o in outs)
    stats = {"wall_s": wall, "tokens": toks,
             "tok_per_s": toks / max(wall, 1e-9),
             "decode_steps": n_steps}
    return np.concatenate(outs, axis=0)[:requests], stats


def _grow_cache(model, cache, max_len: int):
    """Pad every leaf's sequence axis to max_len.

    The sequence axis is identified structurally via cache_specs
    (seq_sharded=True labels it "seq"); leaves without one (SSM/conv
    states) pass through untouched.
    """
    specs = model.cache_specs(seq_sharded=True)

    def grow(ax, a):
        if "seq" not in ax:
            return a
        i = ax.index("seq")
        if a.shape[i] >= max_len:
            return a
        pad = [(0, 0)] * a.ndim
        pad[i] = (0, max_len - a.shape[i])
        return jnp.pad(a, pad)

    return jax.tree.map(
        grow, specs, cache,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, (str, tuple)) for a in x))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    out, stats = serve_session(
        cfg, requests=args.requests, batch=args.batch,
        prompt_len=args.prompt_len, max_new=args.max_new)
    print(f"served {args.requests} requests: {stats['tokens']} tokens in "
          f"{stats['wall_s']:.2f}s = {stats['tok_per_s']:,.0f} tok/s")


if __name__ == "__main__":
    main()
