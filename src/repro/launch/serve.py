"""Serving drivers for both system halves.

LM half — slot-based batching: B fixed slots, each request prefills into
its slot, then all slots decode in lockstep (static shapes — one compiled
program for the whole serving session, the paper's §II-E execution model).
Works on CPU with smoke configs; the production mesh shards slots over
data and heads/experts over model exactly like the dry-run decode cells.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --requests 16 --batch 4 --prompt-len 32 --max-new 32

Ultrasound half — `serve_ultrasound_stream`: a streaming loop over the
batched stage-graph engine (repro.core.executor). A synthetic acquisition
source feeds RF batches; up to `depth` batches stay in flight against the
async dispatch queue, and the loop reports *sustained* MB/s / FPS under
queue pressure plus the batch-completion latency distribution
(p50/p95/p99, jitter, deadline misses — semantics in EXPERIMENTS.md).

  PYTHONPATH=src python -m repro.launch.serve --ultrasound \
      --batch 4 --batches 32 --depth 2 --deadline-ms 50
"""

from __future__ import annotations

import argparse
import collections
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data.batches import synth_train_batch
from repro.models import get_model
from repro.train import steps as steps_lib


def serve_session(cfg, *, requests: int, batch: int, prompt_len: int,
                  max_new: int, seed: int = 0):
    """Process `requests` prompts in slot batches of `batch`.

    Returns (generated tokens array, stats dict).
    """
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(seed))
    prefill_step = jax.jit(steps_lib.make_prefill_step(model))
    serve_step = jax.jit(steps_lib.make_serve_step(model))

    outs = []
    n_steps = 0
    t0 = time.time()
    max_len = prompt_len + max_new + 1
    for r0 in range(0, requests, batch):
        bsz = min(batch, requests - r0)
        prompt = synth_train_batch(cfg, bsz, prompt_len, seed=seed + r0)
        tok_next, cache = prefill_step(params, prompt)
        tok = tok_next[:, None]
        if cfg.family == "audio":
            # enc-dec prefill returns a decode-ready cache (BOS consumed)
            lengths = jnp.ones((bsz,), jnp.int32)
        else:
            # decoder-only: extend the prefilled cache to serving length
            cache = _grow_cache(model, cache, max_len)
            lengths = jnp.full((bsz,), prompt_len, jnp.int32)

        gen = [np.asarray(tok)]
        for _ in range(max_new):
            tok, cache, lengths = serve_step(params, tok, cache, lengths)
            gen.append(np.asarray(tok))
            n_steps += 1
        outs.append(np.concatenate(gen, axis=1))

    wall = time.time() - t0
    toks = sum(o.size for o in outs)
    stats = {"wall_s": wall, "tokens": toks,
             "tok_per_s": toks / max(wall, 1e-9),
             "decode_steps": n_steps}
    return np.concatenate(outs, axis=0)[:requests], stats


def _grow_cache(model, cache, max_len: int):
    """Pad every leaf's sequence axis to max_len.

    The sequence axis is identified structurally via cache_specs
    (seq_sharded=True labels it "seq"); leaves without one (SSM/conv
    states) pass through untouched.
    """
    specs = model.cache_specs(seq_sharded=True)

    def grow(ax, a):
        if "seq" not in ax:
            return a
        i = ax.index("seq")
        if a.shape[i] >= max_len:
            return a
        pad = [(0, 0)] * a.ndim
        pad[i] = (0, max_len - a.shape[i])
        return jnp.pad(a, pad)

    return jax.tree.map(
        grow, specs, cache,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            a is None or isinstance(a, (str, tuple)) for a in x))


class SyntheticAcquisitionSource:
    """Host-side RF batch source (stand-in for a probe front end).

    Pre-generates a pool of distinct (batch, n_l, n_c, n_f) acquisitions
    (a probe sweep) and cycles it — generation cost stays out of the
    streaming window, while every dispatch still uploads a fresh
    host->device buffer like a real acquisition stream would.
    """

    def __init__(self, cfg, batch: int, *, pool: int = 4, seed: int = 0):
        from repro.data import synth_rf
        self.cfg = cfg
        self.batch = batch
        self._pool = [
            np.stack([synth_rf(cfg, seed=seed + b * batch + i)
                      for i in range(batch)])
            for b in range(pool)]
        self._i = 0

    def next(self) -> np.ndarray:
        rf = self._pool[self._i % len(self._pool)]
        self._i += 1
        return rf


def serve_ultrasound_stream(cfg, *, batch: int = 4, n_batches: int = 32,
                            depth: int = 2, pool: int = 4, seed: int = 0,
                            deadline_s=None, source=None,
                            plan=None, policy=None) -> dict:
    """Stream RF batches through the stage-graph engine, `depth` in flight.

    Dispatches are asynchronous; the loop only blocks on the *oldest*
    in-flight batch once `depth` are queued, so host-side source work and
    device compute overlap. Completion-to-completion intervals form the
    latency samples; the per-batch deadline budget is
    ``batch * deadline_s`` (deadline_s is the per-acquisition frame
    budget — see EXPERIMENTS.md).

    `plan` / `policy` resolve the executor's variant and exec_map
    (repro.core.plan); the resolved plan is stamped into the stats so
    streaming telemetry stays attributable. ``Variant.AUTO`` configs
    resolve heuristically when neither is given.

    Returns a stats dict with sustained throughput and a LatencyStats.
    """
    from repro.bench.harness import latency_stats
    from repro.core.executor import BatchedExecutor

    if batch < 1 or n_batches < 1 or depth < 1:
        raise ValueError(
            f"batch, n_batches, depth must be >= 1 "
            f"(got {batch}, {n_batches}, {depth})")

    engine = BatchedExecutor(cfg, plan=plan, policy=policy)
    cfg = engine.cfg                 # plan-resolved (concrete variant)
    if source is None:
        source = SyntheticAcquisitionSource(cfg, batch, pool=pool, seed=seed)

    # warm-up: compile + one full round trip, excluded from timing
    jax.block_until_ready(engine(jnp.asarray(source.next())))

    in_flight: collections.deque = collections.deque()
    intervals = []
    t0 = time.perf_counter()
    last = t0
    for _ in range(n_batches):
        in_flight.append(engine(jnp.asarray(source.next())))
        while len(in_flight) >= depth:
            jax.block_until_ready(in_flight.popleft())
            now = time.perf_counter()
            intervals.append(now - last)
            last = now
    while in_flight:
        jax.block_until_ready(in_flight.popleft())
        now = time.perf_counter()
        intervals.append(now - last)
        last = now
    wall = time.perf_counter() - t0

    acqs = n_batches * batch
    budget = batch * deadline_s if deadline_s is not None else None
    return {
        "name": f"stream/{cfg.name}/{cfg.variant.value}/b{batch}",
        "batch": batch, "n_batches": n_batches, "depth": depth,
        "plan": engine.plan.json_dict(),
        "wall_s": wall,
        "acquisitions": acqs,
        "frames": acqs * cfg.n_f,
        "sustained_mbps": acqs * cfg.input_bytes / (wall * 1e6),
        "fps": acqs * cfg.n_f / wall,
        "acq_per_s": acqs / wall,
        "latency": latency_stats(intervals, budget_s=budget),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ultrasound", action="store_true",
                    help="stream RF through the batched stage-graph engine")
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--batches", type=int, default=32,
                    help="ultrasound: RF batches to stream")
    ap.add_argument("--depth", type=int, default=2,
                    help="ultrasound: max batches in flight")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="ultrasound: per-acquisition frame budget")
    ap.add_argument("--plan", default=None,
                    choices=["fixed", "heuristic", "autotune"],
                    help="ultrasound: variant-resolution policy")
    ap.add_argument("--variant", default=None,
                    choices=["dynamic", "cnn", "sparse", "auto"],
                    help="ultrasound: operator variant (auto = planner)")
    args = ap.parse_args()

    if args.ultrasound:
        from repro.core import Variant, tiny_config
        if args.variant == "auto" and args.plan == "fixed":
            ap.error("--variant auto needs --plan heuristic or autotune")
        cfg = tiny_config(nz=32, nx=32, n_f=8, n_c=16)
        if args.variant is not None:
            cfg = cfg.with_(variant=Variant(args.variant))
        stats = serve_ultrasound_stream(
            cfg, batch=args.batch, n_batches=args.batches,
            depth=args.depth, policy=args.plan,
            deadline_s=(args.deadline_ms / 1e3
                        if args.deadline_ms is not None else None))
        lat = stats["latency"]
        plan = stats["plan"]
        print(f"plan: policy={plan['policy']} backend={plan['backend']} "
              f"variant={plan['variant']} exec_map={plan['exec_map']} "
              f"({plan['provenance']})")
        print(f"{stats['name']}: {stats['acquisitions']} acquisitions "
              f"({stats['frames']} frames) in {stats['wall_s']:.2f}s = "
              f"{stats['sustained_mbps']:.2f} MB/s, {stats['fps']:.1f} FPS; "
              f"p50={lat.p50_s * 1e3:.2f}ms p95={lat.p95_s * 1e3:.2f}ms "
              f"p99={lat.p99_s * 1e3:.2f}ms jitter={lat.jitter_s * 1e3:.2f}ms "
              f"miss_rate={lat.miss_rate:.3f}")
        return

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    out, stats = serve_session(
        cfg, requests=args.requests, batch=args.batch,
        prompt_len=args.prompt_len, max_new=args.max_new)
    print(f"served {args.requests} requests: {stats['tokens']} tokens in "
          f"{stats['wall_s']:.2f}s = {stats['tok_per_s']:,.0f} tok/s")


if __name__ == "__main__":
    main()
