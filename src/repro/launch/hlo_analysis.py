"""Roofline terms from compiled dry-run artifacts.

cost_analysis() provides HLO FLOPs / bytes accessed; collective traffic is
not in cost_analysis, so we parse the (post-SPMD-partitioning, per-device)
optimized HLO text and sum the *result* bytes of every collective op —
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Result bytes are the standard proxy for wire bytes per device (all-gather
output == gathered bytes received; all-reduce moves ~2x in a ring, which we
note rather than model).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# matches e.g. f32[16,128]{1,0} or bf16[8,4096,128]
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_OP_RE = re.compile(
    r"=\s*(?P<result>[^=]*?)\s"
    r"(?P<kind>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<async>-start|-done)?\(")


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes, from one device's optimized HLO.

    Sync ops are counted at the op; async pairs are counted at the -done
    (whose result is the actual communicated tensor; the -start result is
    a buffer tuple that would double count).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group("async") == "-start":
            continue
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(m.group("result")))
        out[m.group("kind")] += nbytes
    return out


def roofline_terms(flops: float, bytes_accessed: float,
                   coll_bytes: int, n_chips: int) -> Dict[str, float]:
    """Three roofline terms in seconds. Inputs are per-device values from
    the SPMD module (cost_analysis of the partitioned program)."""
    return {
        "t_compute": flops / PEAK_FLOPS,
        "t_memory": bytes_accessed / HBM_BW,
        "t_collective": coll_bytes / ICI_BW,
    }


def dominant_term(terms: Dict[str, float]) -> str:
    return max(("t_compute", "t_memory", "t_collective"),
               key=lambda k: terms[k])
