"""Architecture registry: one module per assigned architecture.

`get_config(name)` returns the full published config; `get_smoke(name)` a
reduced same-family config for CPU tests.
"""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ModelConfig, ParallelConfig, ShapeConfig, SHAPES, TrainConfig)

ARCHS: List[str] = [
    "granite_moe_3b_a800m",
    "deepseek_v2_236b",
    "zamba2_1p2b",
    "qwen2_vl_2b",
    "qwen3_8b",
    "gemma3_1b",
    "granite_3_8b",
    "llama3_405b",
    "mamba2_130m",
    "seamless_m4t_large_v2",
]

# CLI ids (dashes) -> module names
_ALIASES: Dict[str, str] = {
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "zamba2-1.2b": "zamba2_1p2b",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "qwen3-8b": "qwen3_8b",
    "gemma3-1b": "gemma3_1b",
    "granite-3-8b": "granite_3_8b",
    "llama3-405b": "llama3_405b",
    "mamba2-130m": "mamba2_130m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}


def _module(name: str):
    mod_name = _ALIASES.get(name, name)
    return importlib.import_module(f"repro.configs.{mod_name}")


def get_config(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).config()
    return cfg.with_(**overrides) if overrides else cfg


def get_smoke(name: str, **overrides) -> ModelConfig:
    cfg = _module(name).smoke()
    return cfg.with_(**overrides) if overrides else cfg
