"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000,
ssm_state=64; Mamba2 trunk + shared attention block. [arXiv:2411.15242; hf]

The single shared attention block (weights reused) runs after every 6th
Mamba2 layer; each invocation keeps its own KV cache slot.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        n_layers=38,           # mamba2 layers
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,             # shared attention block's MLP
        vocab_size=32000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        shared_attn_every=6,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=5, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        ssm_state=16, ssm_head_dim=16, ssm_chunk=16, shared_attn_every=2,
        vocab_size=256, param_dtype="float32", compute_dtype="float32",
        remat=False)
