"""qwen2-vl-2b [vlm]: 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936; M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

The vision frontend is a stub per the assignment: input_specs() provides
precomputed patch embeddings + an embed mask + (B, 3, S) M-RoPE position
triplets (temporal / height / width).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_head=128,
        d_ff=8960,
        vocab_size=151936,
        mrope_sections=(16, 24, 24),   # t/h/w splits of d_head/2 = 64
        rope_theta=1e6,
        frontend="vision",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, mrope_sections=(2, 3, 3),
        param_dtype="float32", compute_dtype="float32", remat=False)
