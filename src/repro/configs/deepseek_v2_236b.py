"""deepseek-v2-236b [moe]: 60L d_model=5120 128H d_ff=1536 vocab=102400,
MLA kv_lora=512, 2 shared + 160 routed experts top-6. [arXiv:2405.04434; hf]

MLA: low-rank compressed KV (c_kv rank 512 + decoupled 64-dim rope key);
decode runs with absorbed weights directly in the compressed space — the
cache stays (S, 512+64) per layer regardless of the 128 heads.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,      # MHA semantics; MLA compresses the cache
        d_ff=1536,
        vocab_size=102400,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        n_experts=160,
        n_experts_per_tok=6,
        n_shared_experts=2,
        moe_d_ff=1536,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, q_lora_rank=32, kv_lora_rank=16,
        qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
        d_ff=64, moe_d_ff=64, n_experts=8, n_experts_per_tok=2,
        n_shared_experts=1, vocab_size=256,
        param_dtype="float32", compute_dtype="float32", remat=False)
