"""granite-3-8b [dense]: 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155. [hf:ibm-granite family; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        n_layers=40,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12800,
        vocab_size=49155,
        rope_theta=1e4,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, param_dtype="float32",
        compute_dtype="float32", remat=False)
