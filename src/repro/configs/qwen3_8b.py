"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936; qk_norm. [hf:Qwen/Qwen3-8B; hf]
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        n_layers=36,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_head=128,
        d_ff=12288,
        vocab_size=151936,
        qk_norm=True,
        rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=256, param_dtype="float32",
        compute_dtype="float32", remat=False)
