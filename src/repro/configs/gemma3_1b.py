"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144; 5:1 local:global attention, 128k. [hf:google/gemma-3-1b-pt]

Local layers use a 512-token sliding window with rope base 10k; global
layers use full attention with rope base 1M. The 5:1 pattern makes this the
only *dense* assigned arch that runs the long_500k cell (global-layer KV is
tiny: 1 kv head x 256 dim).
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-1b",
        family="dense",
        n_layers=26,
        d_model=1152,
        n_heads=4,
        n_kv_heads=1,
        d_head=256,
        d_ff=6912,
        vocab_size=262144,
        qk_norm=True,
        local_global_pattern=5,
        sliding_window=512,
        rope_theta=1e6,          # global layers
        rope_local_theta=1e4,    # local layers
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, vocab_size=512, sliding_window=8,
        param_dtype="float32", compute_dtype="float32", remat=False)
