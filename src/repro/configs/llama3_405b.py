"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256. [arXiv:2407.21783]

The scale outlier: parameters alone are ~810 GB in bf16. Training this cell
requires FSDP (params + optimizer state sharded over data x model); see
ParallelConfig.fsdp in the launcher and EXPERIMENTS.md for the memory
analysis at 256 / 512 chips.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3-405b",
        family="dense",
        n_layers=126,
        d_model=16384,
        n_heads=128,
        n_kv_heads=8,
        d_head=128,
        d_ff=53248,
        vocab_size=128256,
        rope_theta=5e5,
        # save matmul outputs in remat: -18% train FLOPs, -11% collectives
        # for ~1.8x live-activation memory (§Perf iteration log)
        remat_policy="dots",
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
        d_ff=192, vocab_size=256, param_dtype="float32",
        compute_dtype="float32", remat=False)
