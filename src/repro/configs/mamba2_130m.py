"""mamba2-130m [ssm]: 24L d_model=768, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060]

Paper-technique note (DESIGN.md §5): no dynamic indexing exists in this
arch; it is implemented without the variant taxonomy.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=0,
        n_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=64,
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        vocab_size=256, param_dtype="float32", compute_dtype="float32",
        remat=False)
