"""seamless-m4t-large-v2 [audio]: enc-dec, 24L d_model=1024 16H (kv=16)
d_ff=8192 vocab=256206. [arXiv:2308.11596; hf]

Interpretation: 24 encoder layers + 24 decoder layers (the published
speech-encoder/text-decoder split). The audio frontend is a stub: the
encoder consumes precomputed frame embeddings from input_specs().
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2",
        family="audio",
        n_layers=24,           # decoder layers
        n_enc_layers=24,       # encoder layers
        d_model=1024,
        n_heads=16,
        n_kv_heads=16,
        d_ff=8192,
        vocab_size=256206,
        is_encoder_decoder=True,
        frontend="audio",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab_size=256, param_dtype="float32",
        compute_dtype="float32", remat=False)
