"""Model / shape / parallelism configuration dataclasses.

One `ModelConfig` per assigned architecture lives in
src/repro/configs/<arch_id>.py with the exact published numbers; every
config also provides a reduced `smoke()` variant (same family, tiny dims)
for CPU tests. Shapes are the assignment's four input-shape cells.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.config import Variant


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # identity -----------------------------------------------------------
    name: str = "model"
    family: str = "dense"     # dense | moe | ssm | hybrid | vlm | audio

    # trunk ---------------------------------------------------------------
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv_heads: int = 4
    d_head: int = 0           # 0 => d_model // n_heads
    d_ff: int = 512
    vocab_size: int = 1024
    tie_embeddings: bool = False

    # attention -----------------------------------------------------------
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl M-RoPE (t, h, w) splits
    sliding_window: int = 0                # >0 enables windowed layers
    local_global_pattern: int = 0          # N => N local layers : 1 global
    rope_local_theta: float = 0.0          # gemma3: local layers' rope base
    attn_logit_softcap: float = 0.0

    # MLA (deepseek-v2) -----------------------------------------------------
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128

    # MoE -------------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_variant: Variant = Variant.CNN     # paper taxonomy: dispatch impl
    router_z_loss: float = 1e-3
    # Pad the expert dimension with never-routed dead experts so it
    # divides the model-axis extent (granite-moe: 40 -> 48). Costs
    # (pad-E)/pad compute on zero slots, buys full expert-parallelism.
    n_experts_padded: int = 0

    # SSM (mamba2) ------------------------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128

    # hybrid (zamba2) -----------------------------------------------------------
    shared_attn_every: int = 0             # insert shared attn after every N

    # enc-dec (seamless) ----------------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0

    # modality frontend stubs -------------------------------------------------
    frontend: str = "none"                 # none | vision | audio

    # numerics / execution ------------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # "nothing": recompute everything (min memory, +33% flops)
    # "dots":    save dot outputs without batch dims (matmul results kept,
    #            elementwise recomputed) — less recompute traffic/flops at
    #            higher live-activation memory
    remat_policy: str = "nothing"
    use_flash_kernel: bool = False         # Pallas flash attn (opt-in)
    use_ssd_kernel: bool = False           # Pallas SSD scan (opt-in)
    kv_variant: Variant = Variant.DYNAMIC  # KV-cache update impl (paper V1/V2)
    attn_chunk: int = 512                  # q-block for chunked attention
    # When heads don't divide the model axis: fold the model axis into the
    # batch dim for attention (compute sharded instead of replicated).
    # Wins when attention FLOPs outweigh the per-layer resharding (granite-
    # moe: 17x compute cut); loses for thin-attention archs (gemma3,
    # qwen2-vl: measured 5x collective regression) — hence per-config.
    attn_batch_fallback: bool = False

    # ---------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def n_experts_eff(self) -> int:
        """Expert-dim size incl. dead padding (weights / dispatch slots)."""
        return max(self.n_experts_padded, self.n_experts)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic (or bounded-KV) archs that run the long_500k cell."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.local_global_pattern > 0 and self.sliding_window > 0

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned (arch x shape) cell."""

    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1        # gradient accumulation
    zero1: bool = True           # shard optimizer state over data axis
    grad_compression: bool = False  # int8 all-reduce via shard_map
    checkpoint_every: int = 100
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    fsdp: bool = False           # shard params over data axis too (ZeRO-3)
    pod_axis_role: str = "data"  # data | pipeline
    seq_shard_decode: bool = False    # shard decode KV along sequence
    seq_axes: Tuple[str, ...] = ("model",)  # physical axes for "seq"
