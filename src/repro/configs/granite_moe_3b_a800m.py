"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite family; hf]

The MoE dispatch is the paper's taxonomy applied at LM scale: the default
variant here is V2 (one-hot einsum, TPU-portable); V1/V3 selectable.
"""

from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        n_layers=32,
        d_model=1536,
        n_heads=24,
        n_kv_heads=8,
        d_ff=512,            # per-expert FFN width
        vocab_size=49155,
        n_experts=40,
        n_experts_per_tok=8,
        moe_d_ff=512,
        n_experts_padded=48,   # 48 % 16 == 0: full EP on the 16-way axis
        attn_batch_fallback=True,  # 24 heads % 16 != 0: see ModelConfig
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return config().with_(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=64, moe_d_ff=64, n_experts=8, n_experts_per_tok=2,
        vocab_size=256, param_dtype="float32", compute_dtype="float32",
        remat=False)
