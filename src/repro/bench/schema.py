"""NDJSON telemetry schema validation (`repro-bench-v1`).

One schema, every emitter: `benchmarks/run.py` (summary / sample /
stage + stream records), `benchmarks/stream_throughput.py`,
`benchmarks/scaling.py`, and `benchmarks/multitenant.py` all funnel
through `validate_record`, and the CI smoke rows assert their artifact
files with the module CLI instead of ad-hoc inline asserts:

  PYTHONPATH=src python -m repro.bench.schema BENCH_ci.ndjson \
      SCALING_ci.ndjson --require-kind scaling --require-multidevice

Validation is structural — required keys and JSON types per record
``kind``, plus the nested `plan` (PipelinePlan.json_dict), `resources`
(ResourceStats.json_dict), `latency` (LatencyStats.json_dict),
`occupancy` (OccupancyStats.json_dict), `ci` / `acq_per_s_ci`
(CIStats.json_dict — required on summary and multitenant records so
the statistical gate always has an interval, degenerate when no
repeats were run), and `roofline` (per-stage % -of-attainable, when
stamped) blocks. ``None`` is legal
exactly where the producers document "not measurable on this backend"
(energy off-NVML, budget_s without a deadline) — a missing *key* is
always an error, so a producer that silently drops a column fails CI
loudly instead of drifting.

Tests apply the same helper to records generated in-process
(tests/test_ndjson_schema.py), so the schema cannot fork between what
CI checks and what the emitters write.
"""

from __future__ import annotations

import argparse
import json
import numbers
import sys
from typing import Dict, Iterable, Optional, Tuple

SCHEMA = "repro-bench-v1"

# Type tokens: "str" / "int" / "real" / "bool" / "dict" / "list".
# A "?" suffix additionally admits None (nullable column, never absent).
_CHECKS = {
    "str": lambda v: isinstance(v, str),
    "int": lambda v: isinstance(v, numbers.Integral)
    and not isinstance(v, bool),
    "real": lambda v: isinstance(v, numbers.Real)
    and not isinstance(v, bool),
    "bool": lambda v: isinstance(v, bool),
    "dict": lambda v: isinstance(v, dict),
    "list": lambda v: isinstance(v, (list, tuple)),
}

LATENCY_KEYS: Dict[str, str] = {
    "n": "int", "mean_s": "real", "std_s": "real", "p50_s": "real",
    "p95_s": "real", "p99_s": "real", "jitter_s": "real",
    "budget_s": "real?", "miss_rate": "real",
}

# CIStats.json_dict (repro.bench.stats): the two-level bootstrap
# confidence interval the statistical regression gate compares.
# `run_means` is the level-one data — committed baselines must carry it
# so a later gate can bootstrap a ratio CI against fresh runs.
CI_KEYS: Dict[str, str] = {
    "mean": "real", "ci_lo": "real", "ci_hi": "real", "n_runs": "int",
    "confidence": "real", "n_boot": "int", "seed": "int",
    "method": "str", "run_means": "list",
}

# Per-stage roofline stamp (benchmarks/roofline_report.py): analytic
# bytes/FLOPs from the compiled HLO vs calibrated machine peaks.
ROOFLINE_STAGE_KEYS: Dict[str, str] = {
    "flops": "real", "bytes": "real", "t_measured_s": "real",
    "t_roof_s": "real", "pct_roofline": "real", "bound": "str",
}

PLAN_KEYS: Dict[str, str] = {
    "policy": "str", "backend": "str", "variant": "str",
    "exec_map": "str", "donate": "bool?", "jit_stages": "dict",
    "stage_lowerings": "dict",
    # Fusion/precision contract stamp: both are required (never absent)
    # so a fused/bf16 row can never masquerade as an unfused/f32 one;
    # group/block are null exactly when fusion == "none".
    "fusion": "str", "precision": "str",
    "fusion_group": "str?", "fusion_block": "int?",
    "config_key": "str", "geometry_key": "str", "provenance": "str",
    "devices": "int", "mesh_shape": "list?",
    # Serving-context stamp: how the program reached the device. Null
    # outside a serving window; `warm_start` is "aot" (compiled this
    # process) or "pool" (reused a WarmPool entry), `in_flight` is the
    # scheduler's dispatch-ring bound the row ran under.
    "warm_start": "str?", "in_flight": "int?",
}

RESOURCE_KEYS: Dict[str, str] = {
    "peak_memory_bytes": "int?", "memory_source": "str?",
    "energy_joules": "real?", "energy_source": "str?",
    "devices": "int", "duration_s": "real?",
}

OCCUPANCY_KEYS: Dict[str, str] = {
    "batches": "int", "frames": "int", "max_batch": "int",
    "mean_occupancy": "real", "p50_occupancy": "real",
    "min_occupancy": "int", "max_occupancy": "int",
    "mean_fill": "real", "full_rate": "real",
}

# InFlightStats.json_dict — the dispatch ring's depth distribution.
INFLIGHT_KEYS: Dict[str, str] = {
    "dispatches": "int", "in_flight": "int", "mean_depth": "real",
    "p50_depth": "real", "max_depth": "int", "full_rate": "real",
}

# Host-transfer telemetry block (optional on summary records; the
# multitenant keys are required flat — see RECORD_KEYS). All three
# components are host-thread-sequential slices of the wall, so
# transfer_frac is a true fraction.
TRANSFER_KEYS: Dict[str, str] = {
    "stage_copy_s": "real", "h2d_s": "real", "d2h_s": "real",
    "transfer_frac": "real",
}

# VarianceDecomposition.json_dict (repro.bench.stats): within- vs
# between-run share of the run-mean variance — sizes --repeats.
VARIANCE_KEYS: Dict[str, str] = {
    "n_runs": "int", "mean_iters": "real", "within_var": "real",
    "between_var": "real", "within_share": "real",
    "between_share": "real",
}

# Per-stream block inside a multitenant record (one per client).
# `latency` / `queue_delay` are null exactly when the stream served
# zero frames (fully dropped by a churn disconnect); `dropped` counts
# the out-of-window arrivals that never reached the scheduler.
MT_STREAM_KEYS: Dict[str, str] = {
    "pipeline": "str", "variant": "str", "arrival_fps": "real",
    "frames": "int", "acquisitions": "int", "dropped": "int",
    "latency": "dict?", "queue_delay": "dict?",
    "deadline_miss_rate": "real",
}

# kind -> required top-level keys. Stamps (plan/resources/latency/
# occupancy) listed here are REQUIRED for that kind; extra keys are
# always permitted (schema grows forward-compatibly).
RECORD_KEYS: Dict[str, Dict[str, str]] = {
    "summary": {
        "name": "str", "t_avg_s": "real", "fps": "real", "mbps": "real",
        "joules_per_run_model": "real", "peak_mem_gb": "real",
        "runs": "int", "latency": "dict", "ci": "dict",
    },
    "sample": {"name": "str", "run": "int", "t_s": "real"},
    "stage": {"name": "str", "stage": "str", **LATENCY_KEYS},
    "stream": {
        "name": "str", "batch": "int", "n_batches": "int", "depth": "int",
        "plan": "dict", "wall_s": "real", "acquisitions": "int",
        "frames": "int", "sustained_mbps": "real", "fps": "real",
        "acq_per_s": "real", "latency": "dict", "resources": "dict",
    },
    "scaling": {
        "name": "str", "plan": "dict", "devices": "int",
        "batch_per_device": "int", "batch": "int", "n_batches": "int",
        "wall_s": "real", "fps": "real", "sustained_mbps": "real",
        "peak_memory_bytes": "int?", "memory_source": "str?",
        "energy_joules": "real?", "joules_per_frame": "real?",
        "speedup_vs_single": "real?", "scale_efficiency": "real?",
        "latency": "dict",
    },
    "multitenant": {
        "name": "str", "clients": "int", "policy": "dict",
        "in_flight": "int", "wall_s": "real", "warmup_s": "real",
        "acquisitions": "int", "frames": "int",
        "sustained_mbps": "real", "fps": "real", "acq_per_s": "real",
        "acq_per_s_ci": "dict", "deadline_miss_rate": "real",
        "device_busy_s": "real", "device_busy_frac": "real",
        "overlap_frac": "real",
        # Overlap-column intervals (degenerate without --repeats) so
        # the gate can apply CI-exclusion beyond acq_per_s.
        "device_busy_frac_ci": "dict", "overlap_frac_ci": "dict",
        # Host-transfer telemetry + the drain mode that produced it
        # ("async" = copy_to_host_async at retirement detection,
        # "block" = synchronous D2H — part of the gate cell identity).
        "drain": "str", **TRANSFER_KEYS,
        "latency": "dict",
        "queue_delay": "dict", "occupancy": "dict",
        "in_flight_occupancy": "dict",
        "per_stream": "dict", "groups": "dict", "resources": "dict",
        # Load provenance (repro.launch.scheduler): the scenario name
        # (gate cell identity) and the repro-trace-v1 hash of the exact
        # arrival schedule served; `dropped` counts out-of-window
        # (churn-disconnected) frames across all streams.
        "load_profile": "str", "trace_sha256": "str", "dropped": "int",
    },
}

MT_POLICY_KEYS: Dict[str, str] = {
    "max_batch": "int", "max_queue_delay_ms": "real",
}


class SchemaError(AssertionError):
    """A telemetry record violates the repro-bench-v1 schema."""


def _check(rec: dict, keys: Dict[str, str], path: str) -> None:
    for key, token in keys.items():
        if key not in rec:
            raise SchemaError(f"{path}: missing required key {key!r}")
        nullable = token.endswith("?")
        v = rec[key]
        if v is None:
            if not nullable:
                raise SchemaError(f"{path}.{key}: null not allowed")
            continue
        if not _CHECKS[token.rstrip("?")](v):
            raise SchemaError(
                f"{path}.{key}: expected {token}, got "
                f"{type(v).__name__} ({v!r})")


def _check_latency(lat: dict, path: str) -> None:
    _check(lat, LATENCY_KEYS, path)
    if not (lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"]):
        raise SchemaError(f"{path}: percentiles not monotone "
                          f"(p50={lat['p50_s']}, p95={lat['p95_s']}, "
                          f"p99={lat['p99_s']})")


def _check_ci(ci: dict, path: str) -> None:
    _check(ci, CI_KEYS, path)
    if not (ci["ci_lo"] <= ci["mean"] <= ci["ci_hi"]):
        raise SchemaError(
            f"{path}: interval does not contain its point estimate "
            f"(ci_lo={ci['ci_lo']}, mean={ci['mean']}, "
            f"ci_hi={ci['ci_hi']})")
    if ci["n_runs"] < 1:
        raise SchemaError(f"{path}.n_runs: expected >= 1, "
                          f"got {ci['n_runs']}")
    if len(ci["run_means"]) != ci["n_runs"]:
        raise SchemaError(
            f"{path}.run_means: {len(ci['run_means'])} entries but "
            f"n_runs={ci['n_runs']} — a baseline without its level-one "
            f"data cannot be re-bootstrapped")


def _check_roofline(roof: dict, path: str) -> None:
    if not roof:
        raise SchemaError(f"{path}: empty")
    for stage, row in roof.items():
        if not isinstance(row, dict):
            raise SchemaError(f"{path}[{stage}]: expected dict, got "
                              f"{type(row).__name__}")
        _check(row, ROOFLINE_STAGE_KEYS, f"{path}[{stage}]")
        if row["pct_roofline"] < 0.0:
            raise SchemaError(f"{path}[{stage}].pct_roofline: negative")


def validate_record(rec: dict, path: str = "record") -> str:
    """Validate one NDJSON record; returns its kind, raises SchemaError.

    The `plan` / `resources` stamps are validated structurally wherever
    they appear (and are *required* where RECORD_KEYS says so); latency
    blocks additionally assert percentile monotonicity.
    """
    if not isinstance(rec, dict):
        raise SchemaError(f"{path}: not a JSON object")
    kind = rec.get("kind")
    if kind not in RECORD_KEYS:
        raise SchemaError(
            f"{path}: unknown kind {kind!r} "
            f"(expected one of {sorted(RECORD_KEYS)})")
    _check(rec, RECORD_KEYS[kind], path)

    if "plan" in rec and rec["plan"] is not None:
        _check(rec["plan"], PLAN_KEYS, f"{path}.plan")
        for stage, name in rec["plan"]["stage_lowerings"].items():
            if not isinstance(name, str):
                raise SchemaError(
                    f"{path}.plan.stage_lowerings[{stage}]: expected a "
                    f"lowering name string, got {type(name).__name__} "
                    f"({name!r})")
    if "resources" in rec and rec["resources"] is not None:
        _check(rec["resources"], RESOURCE_KEYS, f"{path}.resources")
    if "ci" in rec and rec["ci"] is not None:
        _check_ci(rec["ci"], f"{path}.ci")
    if "roofline" in rec and rec["roofline"] is not None:
        _check_roofline(rec["roofline"], f"{path}.roofline")
    if "transfer" in rec and rec["transfer"] is not None:
        _check(rec["transfer"], TRANSFER_KEYS, f"{path}.transfer")
        tf = rec["transfer"]["transfer_frac"]
        if not 0.0 <= tf <= 1.0:
            raise SchemaError(
                f"{path}.transfer.transfer_frac: expected a fraction "
                f"in [0, 1], got {tf!r}")
    if "variance" in rec and rec["variance"] is not None:
        _check(rec["variance"], VARIANCE_KEYS, f"{path}.variance")
        for share in ("within_share", "between_share"):
            v = rec["variance"][share]
            if not 0.0 <= v <= 1.0:
                raise SchemaError(
                    f"{path}.variance.{share}: expected a fraction in "
                    f"[0, 1], got {v!r}")
    if kind == "stage":
        _check_latency(rec, path)
    elif "latency" in rec and rec["latency"] is not None:
        _check_latency(rec["latency"], f"{path}.latency")
    if "queue_delay" in rec and rec["queue_delay"] is not None:
        _check_latency(rec["queue_delay"], f"{path}.queue_delay")
    if "occupancy" in rec and rec["occupancy"] is not None:
        _check(rec["occupancy"], OCCUPANCY_KEYS, f"{path}.occupancy")

    if kind == "multitenant":
        _check(rec["policy"], MT_POLICY_KEYS, f"{path}.policy")
        _check_ci(rec["acq_per_s_ci"], f"{path}.acq_per_s_ci")
        _check_ci(rec["device_busy_frac_ci"],
                  f"{path}.device_busy_frac_ci")
        _check_ci(rec["overlap_frac_ci"], f"{path}.overlap_frac_ci")
        _check(rec["in_flight_occupancy"], INFLIGHT_KEYS,
               f"{path}.in_flight_occupancy")
        if rec["drain"] not in ("async", "block"):
            raise SchemaError(
                f"{path}.drain: expected 'async' or 'block', "
                f"got {rec['drain']!r}")
        sha = rec["trace_sha256"]
        if len(sha) != 64 or any(c not in "0123456789abcdef"
                                 for c in sha):
            raise SchemaError(
                f"{path}.trace_sha256: expected 64 lowercase hex chars "
                f"(a repro-trace-v1 provenance hash), got {sha!r}")
        for frac in ("device_busy_frac", "overlap_frac",
                     "transfer_frac"):
            if not 0.0 <= rec[frac] <= 1.0:
                raise SchemaError(
                    f"{path}.{frac}: expected a fraction in [0, 1], "
                    f"got {rec[frac]!r}")
        if not rec["per_stream"]:
            raise SchemaError(f"{path}.per_stream: empty")
        for sid, s in rec["per_stream"].items():
            spath = f"{path}.per_stream[{sid}]"
            _check(s, MT_STREAM_KEYS, spath)
            # Null latency blocks are legal only for a stream that
            # served nothing (every arrival dropped out-of-window).
            if s["latency"] is not None:
                _check_latency(s["latency"], f"{spath}.latency")
            if s["queue_delay"] is not None:
                _check_latency(s["queue_delay"], f"{spath}.queue_delay")
            if s["latency"] is None and s["acquisitions"] > 0:
                raise SchemaError(
                    f"{spath}.latency: null but the stream served "
                    f"{s['acquisitions']} acquisitions")
        if not rec["groups"]:
            raise SchemaError(f"{path}.groups: empty")
        for gid, g in rec["groups"].items():
            gpath = f"{path}.groups[{gid}]"
            _check(g, {"plan": "dict", "streams": "list",
                       "batches": "int", "occupancy": "dict?",
                       "warmup_s": "real", "warm_source": "str",
                       "in_flight": "dict?"}, gpath)
            _check(g["plan"], PLAN_KEYS, f"{gpath}.plan")
            # Null distributions are legal only for a group that
            # launched zero batches (all streams fully dropped).
            if g["occupancy"] is not None:
                _check(g["occupancy"], OCCUPANCY_KEYS,
                       f"{gpath}.occupancy")
            elif g["batches"] > 0:
                raise SchemaError(
                    f"{gpath}.occupancy: null but the group launched "
                    f"{g['batches']} batches")
            if g["in_flight"] is not None:
                _check(g["in_flight"], INFLIGHT_KEYS,
                       f"{gpath}.in_flight")
    return kind


def validate_lines(lines: Iterable[str], *,
                   source: str = "<ndjson>") -> Dict[str, int]:
    """Validate NDJSON lines; returns {kind: count}, raises SchemaError."""
    counts: Dict[str, int] = {}
    n = 0
    for i, line in enumerate(lines):
        if not line.strip():
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError as e:
            raise SchemaError(f"{source}:{i + 1}: invalid JSON: {e}")
        kind = validate_record(rec, f"{source}:{i + 1}")
        counts[kind] = counts.get(kind, 0) + 1
        n += 1
    if n == 0:
        raise SchemaError(f"{source}: no NDJSON records")
    return counts


def validate_ndjson(path: str) -> Dict[str, int]:
    """Validate a telemetry file; returns {kind: count}."""
    with open(path) as f:
        return validate_lines(f, source=path)


def main(argv: Optional[Tuple[str, ...]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Validate repro-bench-v1 NDJSON telemetry files.")
    ap.add_argument("paths", nargs="+", help="NDJSON files to validate")
    ap.add_argument("--require-kind", action="append", default=[],
                    metavar="KIND",
                    help="fail unless at least one record of KIND exists "
                         "across the given files (repeatable)")
    ap.add_argument("--require-multidevice", action="store_true",
                    help="fail unless some record ran on >= 2 devices")
    args = ap.parse_args(argv)

    totals: Dict[str, int] = {}
    multidevice = False
    try:
        for path in args.paths:
            counts = validate_ndjson(path)
            for k, v in counts.items():
                totals[k] = totals.get(k, 0) + v
            if args.require_multidevice and not multidevice:
                with open(path) as f:
                    multidevice = any(
                        json.loads(line).get("devices", 1) >= 2
                        for line in f if line.strip())
            print(f"{path}: " + ", ".join(
                f"{v} {k}" for k, v in sorted(counts.items())) + " ok")
        for kind in args.require_kind:
            if totals.get(kind, 0) == 0:
                raise SchemaError(f"no {kind!r} records in {args.paths}")
        if args.require_multidevice and not multidevice:
            raise SchemaError(
                f"no multi-device (devices >= 2) record in {args.paths}")
    except SchemaError as e:
        print(f"schema violation: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
