"""Statistics layer for the benchmark harness: repeated-run bootstrap
confidence intervals and the CI-exclusion regression-gate decision rule.

Why this module exists
----------------------
The paper's claims are sustained-throughput numbers, and Kalibera &
Jones (ISMM 2013; quoted in SNIPPETS.md) showed that comparing single
means — what `benchmarks/gate.py` did before this layer — invalidates
most published speedups: run-to-run variance on a shared machine can
manufacture or mask a 2x difference. The fix is their *two-level*
scheme: repeat the whole benchmark (runs), summarize each run by its
mean over iterations, and bootstrap over the run means. Iterations
within a run share warm caches / frequency state and are autocorrelated;
runs are the independent unit, so the run level is the only level that
is resampled.

Public API
----------
`bootstrap_ci`  — two-level bootstrap CI of a location statistic over
                  repeated runs. Input is either per-run means (flat) or
                  per-run sample lists (nested; each run is reduced to
                  its mean first). Deterministic: seeded PRNG, and run
                  means are SORTED before resampling so the interval is
                  invariant under run permutation.
`ci_ratio`      — baseline-vs-current ratio CI (independent resampling
                  of both sides; the speedup interval of K&J §5).
`gate_ratio`    — the gate decision rule: FAIL only when the ratio CI
                  *excludes* the allowed factor — a point estimate
                  beyond the factor whose interval still straddles it is
                  runner noise, not a regression; an interval entirely
                  beyond it is a regression no rerun will undo.
`variance_decomposition` — within-run vs between-run share of the
                  run-mean variance (one-way random effects), the
                  diagnostic that sizes ``--repeats`` per backend:
                  between-run noise only averages out with more RUNS,
                  within-run noise with more iterations.

Degenerate inputs are first-class: one run yields a zero-width interval
(`ci_lo == mean == ci_hi`), which makes `gate_ratio` collapse to the
legacy strict mean-factor comparison — no repeats, no noise estimate,
no false confidence. Intervals are clamped to contain their point
estimate, and a fixed seed at growing confidence levels yields nested
(monotonically widening) intervals because the percentiles are read off
the same bootstrap distribution.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Union

import numpy as np

DEFAULT_CONFIDENCE = 0.95
DEFAULT_N_BOOT = 2000
METHOD = "kalibera-jones-bootstrap"

Runs = Union[Sequence[float], Sequence[Sequence[float]]]

_STATISTICS = {"mean": np.mean, "median": np.median}


def run_means(runs: Runs) -> np.ndarray:
    """Reduce level-two samples to sorted per-run means (level one).

    Accepts a flat sequence of per-run means or a nested sequence of
    per-run iteration samples. Sorting makes every downstream interval
    invariant under run permutation (the resampling indices are drawn
    from a seeded PRNG, so without sorting a shuffle of the same data
    would change which values the indices hit).
    """
    if len(runs) == 0:
        raise ValueError("need at least one run")
    first = runs[0]
    if isinstance(first, (list, tuple, np.ndarray)):
        means = [float(np.mean(np.asarray(r, dtype=np.float64)))
                 for r in runs]
    else:
        means = [float(r) for r in runs]
    return np.sort(np.asarray(means, dtype=np.float64))


@dataclasses.dataclass
class CIStats:
    """A location estimate with its bootstrap confidence interval.

    ``run_means`` carries the level-one data the interval was computed
    from, so a *committed* baseline row contains everything a later
    gate needs to bootstrap a ratio CI against fresh measurements —
    endpoints alone cannot be resampled.
    """

    mean: float
    ci_lo: float
    ci_hi: float
    n_runs: int
    confidence: float = DEFAULT_CONFIDENCE
    n_boot: int = DEFAULT_N_BOOT
    seed: int = 0
    method: str = METHOD
    run_means: List[float] = dataclasses.field(default_factory=list)

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


def bootstrap_ci(runs: Runs, *, confidence: float = DEFAULT_CONFIDENCE,
                 n_boot: int = DEFAULT_N_BOOT, seed: int = 0,
                 statistic: str = "mean") -> CIStats:
    """Two-level bootstrap CI of ``statistic`` over repeated runs.

    Each run is reduced to its mean (level two -> one), then ``n_boot``
    resamples of the run means — with replacement, sized like the
    original — are summarized by ``statistic`` ("mean" or "median") and
    the interval is the equal-tailed percentile range at ``confidence``.
    The interval is clamped to contain the point estimate.
    """
    if not 0.0 < confidence < 1.0:
        raise ValueError(f"confidence must be in (0, 1): {confidence}")
    stat = _STATISTICS[statistic]
    means = run_means(runs)
    point = float(stat(means))
    n = means.size
    if n == 1:
        lo = hi = point
    else:
        rng = np.random.default_rng(seed)
        idx = rng.integers(0, n, size=(n_boot, n))
        boots = stat(means[idx], axis=1)
        tail = 100.0 * (1.0 - confidence) / 2.0
        lo, hi = np.percentile(boots, [tail, 100.0 - tail])
    return CIStats(mean=point, ci_lo=float(min(lo, point)),
                   ci_hi=float(max(hi, point)), n_runs=int(n),
                   confidence=confidence, n_boot=n_boot, seed=seed,
                   run_means=[float(m) for m in means])


@dataclasses.dataclass
class RatioCI:
    """current/baseline ratio with its bootstrap interval."""

    ratio: float
    ci_lo: float
    ci_hi: float
    n_runs_baseline: int
    n_runs_current: int
    confidence: float = DEFAULT_CONFIDENCE

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


def ci_ratio(baseline: Runs, current: Runs, *,
             confidence: float = DEFAULT_CONFIDENCE,
             n_boot: int = DEFAULT_N_BOOT, seed: int = 0,
             statistic: str = "mean") -> RatioCI:
    """Bootstrap CI of the current/baseline ratio of ``statistic``.

    Both sides are resampled independently (they were measured
    independently); each bootstrap replicate is the ratio of the two
    resampled statistics. With a single run on both sides the interval
    is the degenerate point ratio. Baseline values must be nonzero.
    """
    if not 0.0 < confidence < 1.0:
        raise ValueError(f"confidence must be in (0, 1): {confidence}")
    stat = _STATISTICS[statistic]
    base = run_means(baseline)
    cur = run_means(current)
    if np.any(base == 0.0):
        raise ValueError("baseline contains zero runs (ratio undefined)")
    point = float(stat(cur) / stat(base))
    if base.size == 1 and cur.size == 1:
        lo = hi = point
    else:
        rng = np.random.default_rng(seed)
        bi = rng.integers(0, base.size, size=(n_boot, base.size))
        ci_ = rng.integers(0, cur.size, size=(n_boot, cur.size))
        denom = stat(base[bi], axis=1)
        boots = stat(cur[ci_], axis=1) / denom
        tail = 100.0 * (1.0 - confidence) / 2.0
        lo, hi = np.percentile(boots, [tail, 100.0 - tail])
    return RatioCI(ratio=point, ci_lo=float(min(lo, point)),
                   ci_hi=float(max(hi, point)),
                   n_runs_baseline=int(base.size),
                   n_runs_current=int(cur.size), confidence=confidence)


@dataclasses.dataclass
class GateDecision:
    """One gate verdict: the ratio interval vs the allowed factor."""

    ok: bool
    ratio: RatioCI
    factor: float
    higher_is_better: bool
    reason: str


def gate_ratio(baseline: Runs, current: Runs, *, factor: float,
               higher_is_better: bool,
               confidence: float = DEFAULT_CONFIDENCE,
               n_boot: int = DEFAULT_N_BOOT, seed: int = 0) -> GateDecision:
    """The CI-exclusion regression rule for one (baseline, current) cell.

    ``factor`` is the allowed degradation (e.g. 2.0 = current may be up
    to 2x slower / half the throughput). With r = current/baseline:

      * time-like metrics (``higher_is_better=False``): FAIL iff the
        whole interval sits above the factor — ``ci_lo(r) > factor``.
      * throughput-like metrics (``higher_is_better=True``): FAIL iff
        the whole interval sits below the floor — ``ci_hi(r) < 1/factor``.

    An interval that *straddles* the bound passes: the data cannot
    distinguish the cell from an allowed one, and failing it would be
    exactly the runner-noise false alarm this module exists to kill.
    Degenerate single-run intervals reduce the rule to the legacy
    strict mean comparison.
    """
    if factor <= 0.0:
        raise ValueError(f"factor must be positive: {factor}")
    r = ci_ratio(baseline, current, confidence=confidence, n_boot=n_boot,
                 seed=seed)
    if higher_is_better:
        floor = 1.0 / factor
        ok = r.ci_hi >= floor
        reason = (f"ratio {r.ratio:.3f} CI [{r.ci_lo:.3f}, {r.ci_hi:.3f}]"
                  f" {'contains or exceeds' if ok else 'entirely below'}"
                  f" allowed floor {floor:.3f} (factor {factor:g})")
    else:
        ok = r.ci_lo <= factor
        reason = (f"ratio {r.ratio:.3f} CI [{r.ci_lo:.3f}, {r.ci_hi:.3f}]"
                  f" {'contains or undercuts' if ok else 'entirely above'}"
                  f" allowed factor {factor:g}")
    return GateDecision(ok=ok, ratio=r, factor=factor,
                        higher_is_better=higher_is_better, reason=reason)


@dataclasses.dataclass
class VarianceDecomposition:
    """Where the run-mean variance comes from: within or between runs.

    One-way random-effects decomposition over repeated benchmark runs
    (K&J §3: iterations within a run share warm caches and frequency
    state, runs are the independent unit). ``within_var`` is the mean
    per-run iteration variance (S² within); ``between_var`` is the
    method-of-moments estimate of the *true* run-to-run variance after
    the within-run sampling noise is subtracted (clamped at zero).
    ``between_share`` is the fraction of the observed run-mean variance
    that more iterations per run can never remove — when it dominates,
    size ``--repeats`` up; when ``within_share`` dominates, longer runs
    beat more runs.
    """

    n_runs: int
    mean_iters: float            # mean iterations per run
    within_var: float            # S²_within — mean per-run variance
    between_var: float           # σ²_between — excess run-to-run variance
    within_share: float          # share of run-mean variance
    between_share: float

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


def variance_decomposition(run_samples: Sequence[Sequence[float]]
                           ) -> VarianceDecomposition:
    """Decompose run-mean variance into within/between-run components.

    Input is the nested level-two data (per-run iteration samples, the
    same shape `bootstrap_ci` accepts nested). The observed variance of
    the run means is ``σ²_between + S²_within / n̄``; both shares are
    reported against that total. Degenerate inputs — one run, or
    single-iteration runs, or zero total variance — yield 0.0 shares
    rather than NaNs: no decomposition is claimable from them.
    """
    if len(run_samples) == 0:
        raise ValueError("need at least one run")
    runs = [np.asarray(r, dtype=np.float64) for r in run_samples]
    if any(r.ndim != 1 or r.size == 0 for r in runs):
        raise ValueError("each run must be a non-empty 1-D sample list")
    n_runs = len(runs)
    mean_iters = float(np.mean([r.size for r in runs]))
    within = float(np.mean([r.var(ddof=1) if r.size > 1 else 0.0
                            for r in runs]))
    means = np.asarray([r.mean() for r in runs])
    obs = float(means.var(ddof=1)) if n_runs > 1 else 0.0
    sampling = within / mean_iters if mean_iters > 0 else 0.0
    between = max(0.0, obs - sampling)
    total = between + sampling
    if n_runs < 2 or total <= 0.0:
        w_share = b_share = 0.0
    else:
        b_share = between / total
        w_share = sampling / total
    return VarianceDecomposition(
        n_runs=n_runs, mean_iters=mean_iters, within_var=within,
        between_var=between, within_share=w_share,
        between_share=b_share)


def ci_json(ci: Optional[CIStats]) -> Optional[dict]:
    """None-propagating json_dict (telemetry stamping convenience)."""
    return None if ci is None else ci.json_dict()
