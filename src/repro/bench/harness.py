"""The paper's benchmarking methodology (§II-E..I), as a harness.

Public API (full methodology reference: docs/benchmarking-methodology.md)
-------------------------------------------------------------------------
`latency_stats`  — per-run samples -> `LatencyStats` (p50/p95/p99,
                   jitter = p95-p50, deadline-miss rate). Also used for
                   queue-delay distributions (any per-event seconds
                   samples summarize the same way).
`occupancy_stats`— per-dispatch batch sizes -> `OccupancyStats` (mean /
                   p50 occupancy, fill fraction, full-batch rate) for
                   the dynamic-batching scheduler's coalescing window.
`bench_callable` — time a jitted callable per the paper's execution
                   model; returns a `BenchResult` carrying the full
                   sample distribution, the resolved `plan` stamp, and
                   measured `ResourceStats` (repro.bench.resources).
                   `repeats` > 1 repeats the whole timed window and the
                   result additionally carries a two-level bootstrap
                   confidence interval over the per-repeat means
                   (repro.bench.stats; Kalibera & Jones) — the `ci`
                   stamp the statistical regression gate compares.
`bench_stages`   — per-stage timing breakdown of the stage graph.
`BenchResult`    — one benchmark row; `csv()` (frozen legacy format),
                   `json_dict()`, `ndjson_lines()` (summary / sample /
                   stage records; every record carries the plan stamp,
                   and summary/sample additionally carry the resources
                   stamp for the metered window — stage timings run in
                   their own windows, so stamping the end-to-end
                   resources on them would misattribute).
`write_json` / `write_ndjson` — telemetry serialization.

Invariants: warm-up runs never count toward samples; every timed sample
is bracketed by `jax.block_until_ready`; metering (resources.py) is
exception-free and reports `None` — never zero — for metrics the
backend cannot measure; `csv()` output stays parseable by the frozen
paper-table readers.

Execution model reproduced exactly:
  * constants precomputed at init, excluded from timing (§II-C),
  * multiple warm-up iterations amortize compilation/graph setup (§II-E),
  * explicit device synchronization (block_until_ready) around the timed
    window (§II-E),
  * repeated inference-only forward passes on a fixed input tensor,
  * per-run samples are retained (not just the mean): every result carries
    the full latency distribution — p50/p95/p99, jitter (p95 − p50), and
    the deadline-miss rate against a configurable frame budget — because
    a mean alone cannot support a real-time throughput claim
    (Kalibera & Jones; CORTEX methodology);
      T_avg = mean(samples)
      FPS  = 1 / T_avg                      (eq. 1)
      MB/s = B_in / (T_avg * 1e6)           (eq. 2)
  * incremental energy per run E_run = (P_active - P_idle) * T_avg (eq. 3)
    — on this CPU stand-in there is no board telemetry (the paper hits the
    same wall on TPU), so E_run is reported from a documented MODEL:
    P_active - P_idle ≈ utilization * (TDP - idle), utilization from the
    roofline compute fraction. Flagged as modeled, never measured. Where
    NVML board power IS available, the *measured* incremental energy
    rides along in `ResourceStats.energy_joules` (None elsewhere).
  * peak memory from compiled.memory_analysis() (args + outputs + temps)
    — the static analogue of the paper's allocator peak — plus the
    *measured* high-water mark in `ResourceStats.peak_memory_bytes`
    (allocator stats on GPU/TPU, live-array sampling fallback on CPU).

Telemetry is serialized two ways: the legacy one-line CSV (paper tables,
unchanged) and NDJSON (one summary line + one line per sample + one line
per stage; schema in EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp

# Energy model constants (documented in EXPERIMENTS.md; eq. 3 shape).
CHIP_TDP_W = 200.0       # TPU v5e-class accelerator board power
CHIP_IDLE_W = 60.0


# ---------------------------------------------------------------------------
# Latency distributions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class LatencyStats:
    """Distribution summary of per-run wall-clock samples (seconds)."""

    n: int
    mean_s: float
    std_s: float
    p50_s: float
    p95_s: float
    p99_s: float
    jitter_s: float                       # p95 - p50
    budget_s: Optional[float] = None      # deadline per run, if configured
    miss_rate: float = 0.0                # fraction of samples > budget_s

    def json_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return d


def latency_stats(samples_s: List[float],
                  budget_s: Optional[float] = None) -> LatencyStats:
    """Summarize per-run samples into the distribution the tables report."""
    a = np.asarray(samples_s, dtype=np.float64)
    assert a.size > 0, "latency_stats needs at least one sample"
    p50, p95, p99 = np.percentile(a, [50.0, 95.0, 99.0])
    miss = float((a > budget_s).mean()) if budget_s is not None else 0.0
    return LatencyStats(
        n=int(a.size), mean_s=float(a.mean()), std_s=float(a.std()),
        p50_s=float(p50), p95_s=float(p95), p99_s=float(p99),
        jitter_s=float(p95 - p50), budget_s=budget_s, miss_rate=miss)


# ---------------------------------------------------------------------------
# Batch occupancy (dynamic-batching scheduler telemetry)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OccupancyStats:
    """Distribution of per-dispatch batch occupancy under coalescing.

    One sample per dispatched batch: how many *valid* frames it carried
    against the policy's ``max_batch`` padding target. ``mean_fill``
    (mean occupancy / max_batch) is the fraction of dispatched compute
    that served real frames — the padding waste is ``1 - mean_fill`` —
    and ``full_rate`` is the fraction of dispatches at exactly
    ``max_batch`` (coalescing filled the batch before the queue-delay
    bound forced a partial flush).
    """

    batches: int
    frames: int
    max_batch: int
    mean_occupancy: float
    p50_occupancy: float
    min_occupancy: int
    max_occupancy: int
    mean_fill: float                      # mean_occupancy / max_batch
    full_rate: float                      # fraction dispatched at max_batch

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


def occupancy_stats(batch_sizes: List[int],
                    max_batch: int) -> OccupancyStats:
    """Summarize per-dispatch occupancy samples (scheduler invariant:
    no sample may exceed ``max_batch`` — asserted here so a policy bug
    shows up in telemetry generation, not in silently wrong ratios)."""
    a = np.asarray(batch_sizes, dtype=np.int64)
    assert a.size > 0, "occupancy_stats needs at least one batch"
    assert max_batch >= 1, max_batch
    assert a.min() >= 1 and a.max() <= max_batch, (
        f"occupancy outside 1..{max_batch}: {a.min()}..{a.max()}")
    return OccupancyStats(
        batches=int(a.size), frames=int(a.sum()), max_batch=int(max_batch),
        mean_occupancy=float(a.mean()),
        p50_occupancy=float(np.percentile(a, 50.0)),
        min_occupancy=int(a.min()), max_occupancy=int(a.max()),
        mean_fill=float(a.mean() / max_batch),
        full_rate=float((a == max_batch).mean()))


@dataclasses.dataclass
class InFlightStats:
    """Distribution of in-flight dispatch depth at launch time.

    One sample per dispatched batch: how many dispatches (including the
    new one) were in flight the moment it launched, against the
    scheduler's ``in_flight`` ring bound. ``mean_depth`` near 1.0 means
    the window behaved synchronously (no overlap to win); ``full_rate``
    is the fraction of launches that filled the ring — sustained
    full-ring launches mean the device, not the host, is the
    bottleneck.
    """

    dispatches: int
    in_flight: int                        # the ring bound (the knob)
    mean_depth: float
    p50_depth: float
    max_depth: int
    full_rate: float                      # fraction launched at the bound

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


def in_flight_stats(depths: List[int], in_flight: int) -> InFlightStats:
    """Summarize per-launch in-flight depth samples (scheduler
    invariant: the bounded ring can never exceed ``in_flight`` —
    asserted here, like `occupancy_stats`, so a ring bug surfaces in
    telemetry generation)."""
    a = np.asarray(depths, dtype=np.int64)
    assert a.size > 0, "in_flight_stats needs at least one dispatch"
    assert in_flight >= 1, in_flight
    assert a.min() >= 1 and a.max() <= in_flight, (
        f"in-flight depth outside 1..{in_flight}: {a.min()}..{a.max()}")
    return InFlightStats(
        dispatches=int(a.size), in_flight=int(in_flight),
        mean_depth=float(a.mean()),
        p50_depth=float(np.percentile(a, 50.0)),
        max_depth=int(a.max()),
        full_rate=float((a == in_flight).mean()))


# ---------------------------------------------------------------------------
# Results
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BenchResult:
    name: str
    t_avg_s: float
    fps: float
    mbps: float
    joules_per_run_model: float
    peak_mem_gb: float
    runs: int
    samples_s: List[float] = dataclasses.field(default_factory=list)
    stats: Optional[LatencyStats] = None
    # Two-level bootstrap CI over per-repeat means (repro.bench.stats
    # CIStats.json_dict): {mean, ci_lo, ci_hi, n_runs, run_means, ...}.
    # n_runs == 1 (no --repeats) is the documented degenerate interval.
    ci: Optional[dict] = None
    # Per-repeat sample lists (level-two data behind `ci`); flattened
    # into samples_s for the legacy distribution columns.
    run_samples_s: List[List[float]] = dataclasses.field(
        default_factory=list)
    stage_breakdown: Dict[str, LatencyStats] = dataclasses.field(
        default_factory=dict)
    # Resolved execution plan (PipelinePlan.json_dict()): the exact
    # (backend, variant, exec_map, policy, devices) decision behind this
    # number.
    plan: Optional[dict] = None
    # Measured resource usage over the timed window
    # (ResourceStats.json_dict()): peak_memory_bytes + energy_joules,
    # None where the backend cannot measure them.
    resources: Optional[dict] = None
    # Stage-graph roofline stamp (benchmarks/roofline_report.py):
    # per-stage {flops, bytes, t_roof_s, pct_roofline, bound} against
    # calibrated machine peaks, so the gated number carries its
    # "% of attainable" context.
    roofline: Optional[dict] = None
    # Variance decomposition over the per-repeat sample lists
    # (repro.bench.stats VarianceDecomposition.json_dict): within- vs
    # between-run share of the run-mean variance — the diagnostic that
    # sizes --repeats per backend. Stamped when repeats > 1.
    variance: Optional[dict] = None
    # Host-transfer telemetry stamp ({stage_copy_s, h2d_s, d2h_s,
    # transfer_frac}; schema TRANSFER_KEYS) for rows whose producer
    # measured the host edge — serving rows carry the keys flat, a
    # summary producer may attach this block.
    transfer: Optional[dict] = None

    def csv(self) -> str:
        """Legacy one-line CSV — format frozen (paper-table parsers)."""
        return (f"{self.name},{self.t_avg_s * 1e6:.1f},"
                f"fps={self.fps:.2f};mbps={self.mbps:.2f};"
                f"J_run_model={self.joules_per_run_model:.4f};"
                f"peak_gb={self.peak_mem_gb:.3f}")

    def json_dict(self) -> dict:
        d = {
            "name": self.name,
            "t_avg_s": self.t_avg_s,
            "fps": self.fps,
            "mbps": self.mbps,
            "joules_per_run_model": self.joules_per_run_model,
            "peak_mem_gb": self.peak_mem_gb,
            "runs": self.runs,
        }
        if self.plan is not None:
            d["plan"] = self.plan
        if self.resources is not None:
            d["resources"] = self.resources
        if self.ci is not None:
            d["ci"] = self.ci
        if self.roofline is not None:
            d["roofline"] = self.roofline
        if self.variance is not None:
            d["variance"] = self.variance
        if self.transfer is not None:
            d["transfer"] = self.transfer
        if self.stats is not None:
            d["latency"] = self.stats.json_dict()
        if self.stage_breakdown:
            d["stages"] = {k: v.json_dict()
                           for k, v in self.stage_breakdown.items()}
        return d

    def ndjson_lines(self) -> List[str]:
        """Telemetry records: summary, per-sample, per-stage lines.

        Every record carries the resolved plan (when one was stamped) so
        each row is independently attributable to an exact
        (backend, variant, exec_map) decision.
        """
        lines = [json.dumps({"kind": "summary", **self.json_dict()})]
        budget = self.stats.budget_s if self.stats else None
        for i, t in enumerate(self.samples_s):
            rec = {"kind": "sample", "name": self.name, "run": i, "t_s": t}
            if budget is not None:
                rec["deadline_missed"] = bool(t > budget)
            if self.plan is not None:
                rec["plan"] = self.plan
            if self.resources is not None:
                rec["resources"] = self.resources
            lines.append(json.dumps(rec))
        for stage, st in self.stage_breakdown.items():
            rec = {"kind": "stage", "name": self.name, "stage": stage,
                   **st.json_dict()}
            if self.plan is not None:
                rec["plan"] = self.plan
            lines.append(json.dumps(rec))
        return lines


def write_ndjson(path: str, results: List["BenchResult"],
                 extra_records: Optional[List[dict]] = None) -> None:
    with open(path, "w") as f:
        for r in results:
            for line in r.ndjson_lines():
                f.write(line + "\n")
        for rec in (extra_records or []):
            f.write(json.dumps(rec) + "\n")


def write_json(path: str, results: List["BenchResult"],
               extra: Optional[dict] = None) -> None:
    doc = {"schema": "repro-bench-v1",
           "results": [r.json_dict() for r in results]}
    if extra:
        doc.update(extra)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


# ---------------------------------------------------------------------------
# Timing
# ---------------------------------------------------------------------------


def _timed_samples(fn_j: Callable, args: tuple, *, warmup: int,
                   runs: int, meter=None,
                   start_meter: bool = True) -> List[float]:
    """The paper's §II-E measurement protocol, shared by every bench:
    warm-up iterations excluded from timing, then per-run wall clock with
    device sync (block_until_ready) bracketing each sample. `meter` (a
    ResourceMeter) is started only after the warm-up loop — compilation
    energy/memory never count — and sampled after each run, outside the
    timed bracket, so metering overhead never pollutes the samples.
    `start_meter=False` keeps an already-open metering window running
    (repeat windows share one window; start() would reset its clock)."""
    for _ in range(warmup):
        jax.block_until_ready(fn_j(*args))
    if meter is not None and start_meter:
        meter.start()
    samples: List[float] = []
    for _ in range(runs):
        t0 = time.perf_counter()
        out = fn_j(*args)
        jax.block_until_ready(out)
        samples.append(time.perf_counter() - t0)
        if meter is not None:
            meter.sample()
    return samples


def bench_callable(name: str, fn: Callable, args: tuple, *,
                   input_bytes: int, warmup: int = 2, runs: int = 5,
                   repeats: int = 1, utilization: float = 0.5,
                   deadline_s: Optional[float] = None,
                   jitted: Optional[Callable] = None,
                   plan=None) -> BenchResult:
    """Time `fn(*args)` per the paper's execution model.

    Each steady-state run is timed individually (sync'd with
    block_until_ready) so the result carries the full latency
    distribution, not just T_avg. ``repeats`` repeats the whole timed
    window (warm-up is paid once): each repeat is one *run* in the
    Kalibera & Jones sense and the result's ``ci`` stamp is the
    two-level bootstrap confidence interval over the per-repeat means
    (degenerate zero-width at ``repeats=1`` — no noise estimate is
    ever invented). `plan` (a PipelinePlan or its json_dict) is
    stamped into the result and every telemetry record, as is the
    measured `ResourceStats` for the timed window (peak memory +
    incremental energy, None where unsupported).
    """
    from repro.bench.resources import ResourceMeter, devices_of
    from repro.bench.stats import bootstrap_ci, variance_decomposition

    assert repeats >= 1, repeats
    fn_j = jitted if jitted is not None else jax.jit(fn)
    if plan is not None and not isinstance(plan, dict):
        plan = plan.json_dict()

    # Scope the meter to the devices holding the inputs (host-resident
    # args: fall back to all local); started post-warmup by
    # _timed_samples. Later repeats skip the warm-up loop (the program
    # is warm by construction) and keep the same meter running.
    meter = ResourceMeter(devices=devices_of(args))
    run_samples = [_timed_samples(fn_j, args, warmup=warmup, runs=runs,
                                  meter=meter)]
    for _ in range(repeats - 1):
        run_samples.append(_timed_samples(fn_j, args, warmup=0,
                                          runs=runs, meter=meter,
                                          start_meter=False))
    resources = meter.stop()
    samples = [t for rs in run_samples for t in rs]
    t_avg = sum(samples) / len(samples)
    ci = bootstrap_ci(run_samples)

    # peak memory: static analysis of the compiled executable
    peak = 0.0
    try:
        mem = fn_j.lower(*args).compile().memory_analysis()
        peak = (getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)) / 1e9
    except Exception:   # noqa: BLE001 — memory analysis is best-effort
        pass

    e_run = (CHIP_TDP_W - CHIP_IDLE_W) * utilization * t_avg
    # Within/between-run noise split: only claimable from > 1 repeat
    # (a single window has no between-run axis to decompose).
    variance = (variance_decomposition(run_samples).json_dict()
                if repeats > 1 else None)
    return BenchResult(
        name=name, t_avg_s=t_avg, fps=1.0 / t_avg,
        mbps=input_bytes / (t_avg * 1e6),
        joules_per_run_model=e_run, peak_mem_gb=peak, runs=runs,
        samples_s=samples, stats=latency_stats(samples, deadline_s),
        ci=ci.json_dict(), run_samples_s=run_samples,
        plan=plan, resources=resources.json_dict(),
        variance=variance)


def bench_stages(cfg, rf, *, warmup: int = 1,
                 runs: int = 3) -> Dict[str, LatencyStats]:
    """Per-stage timing breakdown of the stage graph.

    Each stage is jitted and synchronized individually on the real
    intermediate tensors (each stage consumes its predecessor's output),
    so the breakdown attributes end-to-end time to demod / beamform /
    head. Individually-synced stage times need not sum to the fused
    end-to-end time — fusion across stage boundaries is exactly what the
    comparison quantifies.
    """
    from repro.core import stages as stages_lib
    from repro.core.pipeline import init_pipeline

    consts = jax.tree.map(jnp.asarray, init_pipeline(cfg))
    out: Dict[str, LatencyStats] = {}
    x = rf
    for name, fn in stages_lib.stage_fns(cfg).items():
        fn_j = jax.jit(fn)
        samples = _timed_samples(fn_j, (consts, x), warmup=warmup, runs=runs)
        out[name] = latency_stats(samples)
        x = fn_j(consts, x)
    return out
