"""The paper's benchmarking methodology (§II-E..I), as a harness.

Execution model reproduced exactly:
  * constants precomputed at init, excluded from timing (§II-C),
  * multiple warm-up iterations amortize compilation/graph setup (§II-E),
  * explicit device synchronization (block_until_ready) around the timed
    window (§II-E),
  * repeated inference-only forward passes on a fixed input tensor,
  * T_avg over the steady-state runs;
      FPS  = 1 / T_avg                      (eq. 1)
      MB/s = B_in / (T_avg * 1e6)           (eq. 2)
  * incremental energy per run E_run = (P_active - P_idle) * T_avg (eq. 3)
    — on this CPU stand-in there is no board telemetry (the paper hits the
    same wall on TPU), so E_run is reported from a documented MODEL:
    P_active - P_idle ≈ utilization * (TDP - idle), utilization from the
    roofline compute fraction. Flagged as modeled, never measured.
  * peak memory from compiled.memory_analysis() (args + outputs + temps)
    — the static analogue of the paper's allocator peak.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax

# Energy model constants (documented in EXPERIMENTS.md; eq. 3 shape).
CHIP_TDP_W = 200.0       # TPU v5e-class accelerator board power
CHIP_IDLE_W = 60.0


@dataclasses.dataclass
class BenchResult:
    name: str
    t_avg_s: float
    fps: float
    mbps: float
    joules_per_run_model: float
    peak_mem_gb: float
    runs: int

    def csv(self) -> str:
        return (f"{self.name},{self.t_avg_s * 1e6:.1f},"
                f"fps={self.fps:.2f};mbps={self.mbps:.2f};"
                f"J_run_model={self.joules_per_run_model:.4f};"
                f"peak_gb={self.peak_mem_gb:.3f}")


def bench_callable(name: str, fn: Callable, args: tuple, *,
                   input_bytes: int, warmup: int = 2, runs: int = 5,
                   utilization: float = 0.5,
                   jitted: Optional[Callable] = None) -> BenchResult:
    """Time `fn(*args)` per the paper's execution model."""
    fn_j = jitted if jitted is not None else jax.jit(fn)

    # warm-up (compilation, caching) — excluded from timing
    for _ in range(warmup):
        out = fn_j(*args)
        jax.block_until_ready(out)

    t0 = time.perf_counter()
    for _ in range(runs):
        out = fn_j(*args)
        jax.block_until_ready(out)
    t_avg = (time.perf_counter() - t0) / runs

    # peak memory: static analysis of the compiled executable
    peak = 0.0
    try:
        mem = fn_j.lower(*args).compile().memory_analysis()
        peak = (getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)) / 1e9
    except Exception:   # noqa: BLE001 — memory analysis is best-effort
        pass

    e_run = (CHIP_TDP_W - CHIP_IDLE_W) * utilization * t_avg
    return BenchResult(
        name=name, t_avg_s=t_avg, fps=1.0 / t_avg,
        mbps=input_bytes / (t_avg * 1e6),
        joules_per_run_model=e_run, peak_mem_gb=peak, runs=runs)
