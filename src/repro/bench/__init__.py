from repro.bench.harness import BenchResult, bench_callable  # noqa: F401
