from repro.bench.harness import (  # noqa: F401
    BenchResult,
    LatencyStats,
    bench_callable,
    bench_stages,
    latency_stats,
    write_json,
    write_ndjson,
)
