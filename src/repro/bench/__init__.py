from repro.bench.harness import (  # noqa: F401
    BenchResult,
    LatencyStats,
    bench_callable,
    bench_stages,
    latency_stats,
    write_json,
    write_ndjson,
)
from repro.bench.resources import (  # noqa: F401
    NvmlEnergyMeter,
    ResourceMeter,
    ResourceStats,
)

__all__ = [
    "BenchResult",
    "LatencyStats",
    "NvmlEnergyMeter",
    "ResourceMeter",
    "ResourceStats",
    "bench_callable",
    "bench_stages",
    "latency_stats",
    "write_json",
    "write_ndjson",
]
