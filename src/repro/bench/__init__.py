from repro.bench.harness import (  # noqa: F401
    BenchResult,
    InFlightStats,
    LatencyStats,
    OccupancyStats,
    bench_callable,
    bench_stages,
    in_flight_stats,
    latency_stats,
    occupancy_stats,
    write_json,
    write_ndjson,
)
from repro.bench.resources import (  # noqa: F401
    NvmlEnergyMeter,
    ResourceMeter,
    ResourceStats,
)
from repro.bench.stats import (  # noqa: F401
    CIStats,
    GateDecision,
    RatioCI,
    bootstrap_ci,
    ci_ratio,
    gate_ratio,
)
# NDJSON schema validation lives in repro.bench.schema — imported
# directly (not re-exported here) so `python -m repro.bench.schema`
# doesn't double-execute the module under runpy.

__all__ = [
    "BenchResult",
    "CIStats",
    "GateDecision",
    "InFlightStats",
    "LatencyStats",
    "NvmlEnergyMeter",
    "OccupancyStats",
    "RatioCI",
    "ResourceMeter",
    "ResourceStats",
    "bench_callable",
    "bench_stages",
    "bootstrap_ci",
    "ci_ratio",
    "gate_ratio",
    "in_flight_stats",
    "latency_stats",
    "occupancy_stats",
    "write_json",
    "write_ndjson",
]
