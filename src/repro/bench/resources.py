"""Resource metering: measured peak device memory + incremental energy.

The paper reports "incremental energy per run and peak memory usage,
where available" — the two columns the harness so far only *modeled*
(eq. 3 energy model, static `memory_analysis()` peak). This module adds
the measured counterparts, with the paper's "where available" contract
made literal:

  * **Peak memory** — where the backend exposes allocator statistics
    (GPU/TPU), the window peak is ``memory_stats()["peak_bytes_in_use"]``
    *when the allocator sets a new process high-water mark during the
    window* (source ``"device_memory_stats"``); otherwise — the process
    peak predates this window, so reporting it would attribute some
    earlier benchmark's allocation — the meter falls back to the max of
    ``bytes_in_use`` at the sample points (source
    ``"device_bytes_in_use"``, a window-scoped lower bound). The CPU
    stand-in has no allocator telemetry at all and samples
    `jax.live_arrays()` instead (source ``"live_arrays"``). The source
    is always recorded so a reader knows which of the three produced the
    number.
  * **Incremental energy** — NVML board power polled on a background
    thread and trapezoid-integrated over the metering window, minus the
    idle baseline sampled at meter *construction* — before warm-up or
    compilation has heated the board (the paper's eq. 3
    ``(P_active - P_idle) * T``, measured). `ResourceMeter` scopes the
    NVML handles to the GPU ordinals of the devices it meters, so a
    co-tenant ramping a *different* board never leaks into this run's
    joules (a bare ``NvmlEnergyMeter()`` sums every board — documented
    all-board scope). Where NVML is unavailable (no pynvml, no NVIDIA
    GPU — including this repo's CPU stand-in and the paper's TPU, which
    hits the same wall) the meter degrades to ``energy_joules=None``.
    It must never crash a benchmark.

Public API
----------
`ResourceStats`   — frozen record: ``peak_memory_bytes``,
                    ``memory_source``, ``energy_joules``,
                    ``energy_source``, ``devices``, ``duration_s``;
                    ``json_dict()`` for telemetry stamping.
`ResourceMeter`   — start() -> sample()* -> stop() -> ResourceStats.
                    ``sample()`` is cheap and safe to call once per
                    timed run; ``stop()`` always returns a stats object.
`NvmlEnergyMeter` — the NVML polling thread; ``available()`` is the
                    gate. Injectable into `ResourceMeter` for tests.

Invariants: meters never raise out of start/sample/stop (metering must
not take down the benchmark it observes); unavailable metrics are
``None``, never 0.0, so "not measured" is distinguishable from
"measured nothing".
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Optional, Sequence

import jax

__all__ = [
    "ResourceStats",
    "ResourceMeter",
    "NvmlEnergyMeter",
    "device_memory_stats_list",
    "device_peak_memory_bytes",
    "devices_of",
    "live_array_bytes",
    "nvml_indices_for_local_gpus",
]


@dataclasses.dataclass(frozen=True)
class ResourceStats:
    """Measured resource usage over one metering window.

    ``None`` fields mean "not measurable on this backend" (the paper's
    "where available"), never zero.
    """

    peak_memory_bytes: Optional[int] = None
    # "device_memory_stats" (allocator window peak) |
    # "device_bytes_in_use" (sampled allocator usage) | "live_arrays"
    memory_source: Optional[str] = None
    energy_joules: Optional[float] = None
    energy_source: Optional[str] = None   # "nvml"
    devices: int = 1
    duration_s: Optional[float] = None

    def json_dict(self) -> dict:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Peak memory
# ---------------------------------------------------------------------------


def device_memory_stats_list(devices) -> Optional[list]:
    """Per-device (peak_bytes_in_use, bytes_in_use) pairs, or None.

    GPU/TPU runtimes expose ``memory_stats()``; the CPU host backend
    returns nothing useful. Any device missing the counters makes the
    whole reading None (a partial reading would silently under-report).
    Note the peak is the allocator's *process-lifetime* high-water mark
    — `ResourceMeter` window-scopes it against the start() baseline,
    **per device** (summed lifetime peaks would let one device's old
    peak masquerade as another device's window).
    """
    out = []
    for d in devices:
        try:
            stats = d.memory_stats()
        except Exception:   # noqa: BLE001 — no allocator telemetry
            return None
        if (not stats or stats.get("peak_bytes_in_use") is None
                or stats.get("bytes_in_use") is None):
            return None
        out.append((int(stats["peak_bytes_in_use"]),
                    int(stats["bytes_in_use"])))
    return out


def device_peak_memory_bytes(devices) -> Optional[int]:
    """Sum of allocator process-lifetime peaks across `devices`, or None."""
    stats = device_memory_stats_list(devices)
    return sum(p for p, _ in stats) if stats is not None else None


def live_array_bytes(devices) -> int:
    """Bytes of live jax arrays resident on `devices` (snapshot).

    The CPU fallback proxy: sampling this at known points (after each
    timed run) gives a lower bound on the allocator peak — it sees
    arrays that are still referenced, not transient temporaries.
    """
    devset = set(devices)
    total = 0
    for a in jax.live_arrays():
        try:
            if devset & set(a.devices()):
                total += a.nbytes
        except Exception:   # noqa: BLE001 — deleted/donated buffers
            continue
    return total


# ---------------------------------------------------------------------------
# Energy
# ---------------------------------------------------------------------------


def devices_of(*trees) -> Optional[tuple]:
    """The distinct devices holding the jax arrays in `trees`, or None.

    Lets single-device producers (bench_callable, the single-device
    serve loop) scope their ResourceMeter to the devices actually in
    use instead of every local device — on a multi-device host the
    difference is whether a neighbor's buffers pollute the peak.
    """
    devs: dict = {}
    for t in trees:
        for leaf in jax.tree.leaves(t):
            get = getattr(leaf, "devices", None)
            if callable(get):
                try:
                    for d in get():
                        devs[d] = None
                except Exception:   # noqa: BLE001 — deleted buffers
                    continue
    return tuple(devs) if devs else None


_PYNVML_UNSET = object()
_pynvml_cache = _PYNVML_UNSET

# First idle-power reading per NVML handle set (coldest this process saw).
_IDLE_W_CACHE: dict = {}


def _load_pynvml():
    # Memoized: every meter construction would otherwise re-scan
    # sys.path / re-fail nvmlInit on NVML-less hosts (one per bench row).
    global _pynvml_cache
    if _pynvml_cache is not _PYNVML_UNSET:
        return _pynvml_cache
    try:
        import pynvml
        pynvml.nvmlInit()
        _pynvml_cache = pynvml
    except Exception:   # noqa: BLE001 — missing module, driver, or GPU
        _pynvml_cache = None
    return _pynvml_cache


def nvml_indices_for_local_gpus(local_ids, *,
                                visible=None) -> Optional[list]:
    """Map JAX local GPU ordinals to global NVML board indices.

    NVML numbers *all* boards on the host and ignores
    ``CUDA_VISIBLE_DEVICES``, while JAX's local ids are positions within
    the visible set — polling by local id on a pinned job would meter a
    co-tenant's boards. Returns None (caller should treat the scope as
    unknown and stay unavailable rather than guess) when the visible
    list uses UUID/MIG selectors that cannot be mapped numerically.
    """
    if visible is None:
        visible = os.environ.get("CUDA_VISIBLE_DEVICES")
    if visible is None:
        return list(local_ids)              # identity: all boards visible
    entries = [e.strip() for e in visible.split(",") if e.strip()]
    try:
        globals_ = [int(e) for e in entries]
    except ValueError:                      # UUID / MIG selectors
        return None
    try:
        return [globals_[i] for i in local_ids]
    except IndexError:
        return None


class NvmlEnergyMeter:
    """Incremental GPU board energy over a window, via NVML polling.

    A daemon thread samples board power every ``poll_s`` seconds and
    trapezoid-integrates it; ``stop()`` returns joules *above the idle
    baseline* sampled at construction (eq. 3, measured — construct the
    meter before warm-up so the baseline sees the board cold).
    ``device_indices`` selects the NVML board ordinals to integrate
    (None = every board on the host; an empty/fully-invalid selection
    makes the meter unavailable). Where NVML or a GPU is absent,
    ``available()`` is False and ``stop()`` returns None.
    """

    def __init__(self, poll_s: float = 0.05,
                 device_indices: Optional[Sequence[int]] = None):
        self.poll_s = poll_s
        self._nvml = _load_pynvml()
        self._handles = []
        self._board_key = ()
        if self._nvml is not None:
            try:
                count = self._nvml.nvmlDeviceGetCount()
                indices = list(range(count) if device_indices is None
                               else [i for i in device_indices
                                     if 0 <= i < count])
                self._handles = [
                    self._nvml.nvmlDeviceGetHandleByIndex(i)
                    for i in indices]
                self._board_key = tuple(sorted(indices))
            except Exception:   # noqa: BLE001
                self._handles = []
        self._thread: Optional[threading.Thread] = None
        self._stop_evt = threading.Event()
        self._joules = 0.0
        self._idle_w = 0.0
        self._integrated = False        # any valid interval accumulated?
        # Idle baseline (eq. 3 P_idle) sampled at construction: callers
        # build the meter BEFORE their warm-up/compile work, when the
        # board is as close to idle as this process can observe. A
        # post-warm-up reading would still be near active power (GPU
        # clocks decay over seconds) and bias incremental energy to ~0.
        # The FIRST reading per board set is cached process-wide: in a
        # back-to-back sweep (one meter per table row) row N's
        # construction-time reading is still hot from row N-1, so every
        # row reuses the coldest baseline this process ever saw.
        self._idle_w0 = None
        if self._handles:
            if self._board_key not in _IDLE_W_CACHE:
                idle = self._power_w()
                if idle is not None:
                    _IDLE_W_CACHE[self._board_key] = idle
            self._idle_w0 = _IDLE_W_CACHE.get(self._board_key)

    def available(self) -> bool:
        return bool(self._handles)

    def _power_w(self) -> Optional[float]:
        try:
            return sum(self._nvml.nvmlDeviceGetPowerUsage(h)
                       for h in self._handles) / 1e3   # mW -> W
        except Exception:   # noqa: BLE001
            return None

    def _poll(self) -> None:
        last_t = time.perf_counter()
        last_p = self._power_w()
        while True:
            # Integrate on the stop tick too: the tail between the last
            # poll and stop() (and the whole window, when it is shorter
            # than poll_s) must not be dropped.
            stopped = self._stop_evt.wait(self.poll_s)
            now, p = time.perf_counter(), self._power_w()
            if p is not None and last_p is not None:
                self._joules += (0.5 * (p + last_p) - self._idle_w) \
                    * (now - last_t)
                self._integrated = True
            last_t, last_p = now, p
            if stopped:
                return

    def start(self) -> None:
        if not self.available():
            return
        self._joules = 0.0
        self._integrated = False
        idle = self._idle_w0 if self._idle_w0 is not None \
            else self._power_w()
        if idle is None:
            # No idle baseline -> incremental energy is undefined; stay
            # unmeasured (None) rather than integrate absolute power.
            return
        self._idle_w = idle
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._poll, daemon=True)
        self._thread.start()

    def stop(self) -> Optional[float]:
        """Joules above idle since start(), or None if unmeasured.

        None whenever no valid power interval was integrated (meter
        unavailable, idle read failed, or every poll errored) — a
        measured 0.0 only ever means "ran at idle power".
        """
        if self._thread is None:
            return None
        self._stop_evt.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        if not self._integrated:
            return None
        return max(self._joules, 0.0)


# ---------------------------------------------------------------------------
# The meter
# ---------------------------------------------------------------------------


class ResourceMeter:
    """Meters one benchmark window: ``start() -> sample()* -> stop()``.

    ``sample()`` updates the peak-memory high-water mark; call it at
    points where interesting buffers are live (after each timed run /
    batch completion). ``stop()`` takes a final sample and returns the
    `ResourceStats`. All three are exception-free by contract.
    """

    def __init__(self, devices: Optional[Sequence] = None, *,
                 energy_meter=None):
        self.devices = tuple(devices) if devices is not None \
            else tuple(jax.local_devices())
        if energy_meter is not None:
            self._energy = energy_meter
        else:
            # Scope NVML to the boards we actually meter: local GPU ids
            # map through CUDA_VISIBLE_DEVICES to global NVML ordinals.
            # No GPUs in the set (cpu/tpu), or an unmappable visibility
            # selector (UUID/MIG), yields zero handles -> unavailable —
            # a co-resident board never fakes or pollutes a measurement.
            gpu_ids = [d.id for d in self.devices
                       if getattr(d, "platform", None) == "gpu"]
            nvml_ids = nvml_indices_for_local_gpus(gpu_ids)
            self._energy = NvmlEnergyMeter(
                device_indices=nvml_ids if nvml_ids is not None else [])
        self._peak: Optional[int] = None
        self._source: Optional[str] = None
        self._t0: Optional[float] = None
        self._baseline_alloc_peaks: Optional[list] = None

    def start(self) -> None:
        self._peak, self._source = None, None
        self._t0 = time.perf_counter()
        # Allocator peaks are process-lifetime marks; remember where each
        # device's high-water stood at window start so sample() can tell
        # a peak set *during* this window from one inherited from
        # earlier runs — per device, never on the sums.
        try:
            stats = device_memory_stats_list(self.devices)
            self._baseline_alloc_peaks = (
                [p for p, _ in stats] if stats is not None else None)
        except Exception:   # noqa: BLE001
            self._baseline_alloc_peaks = None
        try:
            self._energy.start()
        except Exception:   # noqa: BLE001 — a dying driver is not our crash
            pass
        self.sample()

    def sample(self) -> None:
        try:
            stats = device_memory_stats_list(self.devices)
            if stats is not None:
                base = self._baseline_alloc_peaks
                peak, all_alloc = 0, base is not None and len(base) == \
                    len(stats)
                for i, (alloc_peak, in_use) in enumerate(stats):
                    if (base is not None and i < len(base)
                            and alloc_peak > base[i]):
                        # this device set a new high-water mark inside
                        # the window — that IS its window peak,
                        # temporaries included
                        peak += alloc_peak
                    else:
                        # this device's lifetime peak predates the
                        # window: use its sampled current usage
                        # (window-scoped lower bound)
                        peak += in_use
                        all_alloc = False
                source = ("device_memory_stats" if all_alloc
                          else "device_bytes_in_use")
            else:
                peak, source = live_array_bytes(self.devices), "live_arrays"
            if self._peak is None or peak > self._peak:
                self._peak, self._source = peak, source
        except Exception:   # noqa: BLE001 — metering must never crash a run
            pass

    def stop(self) -> ResourceStats:
        self.sample()
        duration = (time.perf_counter() - self._t0
                    if self._t0 is not None else None)
        joules = None
        try:
            joules = self._energy.stop()
        except Exception:   # noqa: BLE001
            pass
        return ResourceStats(
            peak_memory_bytes=self._peak,
            memory_source=self._source if self._peak is not None else None,
            energy_joules=joules,
            energy_source="nvml" if joules is not None else None,
            devices=len(self.devices),
            duration_s=duration)
