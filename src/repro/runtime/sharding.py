"""Logical-axis sharding: models annotate with *logical* names; the launcher
binds them to physical mesh axes.

Models never mention physical axes. They call

    x = shard(x, "batch", None, "model")

and the active binding (a context set by launch/mesh.py) resolves logical
names to mesh axes — e.g. "batch" -> ("pod", "data") on the multi-pod mesh,
("data",) on a single pod, or nothing when no mesh is active (CPU tests:
shard() is then the identity). This is how one model definition serves
1-device smoke tests, the 256-chip pod and the 512-chip multi-pod without
code changes (the paper's single-source portability contract, applied to
distribution).

Resolution is divisibility-safe: a logical axis whose physical extent does
not divide the corresponding array dimension is dropped (e.g. gemma3's
single KV head cannot shard 16-way; the constraint silently degrades to
replication for that dim instead of erroring).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Logical = Union[str, None, Tuple[str, ...]]

_state = threading.local()

# Default logical -> physical bindings.
SINGLE_POD_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "seq": ("data",),      # long-context KV sharding (decode)
    "kv_heads": ("model",),
    "fsdp": ("data",),     # only consulted when ParallelConfig.fsdp
    # fallback batch sharding over the whole mesh — used by attention when
    # head counts don't divide the model axis (qwen2-vl: 12, granite-moe:
    # 24, gemma3: 4): compute once across the full mesh instead of
    # replicating it 16x over the model axis.
    "attn_batch": ("data", "model"),
}

MULTI_POD_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "model": ("model",),
    "expert": ("model",),
    "vocab": ("model",),
    "seq": ("data",),
    "kv_heads": ("model",),
    "fsdp": ("pod", "data"),
    "attn_batch": ("pod", "data", "model"),
}


class Binding:
    """Active logical->physical binding plus mesh axis sizes."""

    def __init__(self, rules: Dict[str, Tuple[str, ...]],
                 axis_sizes: Dict[str, int], fsdp: bool = False):
        self.rules = dict(rules)
        self.axis_sizes = dict(axis_sizes)
        # When False, "fsdp" axes are stripped from *parameter* specs
        # (ZeRO-1 moments still use them — see param_sharding.py).
        self.fsdp_params = fsdp

    def extent(self, phys: Tuple[str, ...]) -> int:
        n = 1
        for a in phys:
            n *= self.axis_sizes.get(a, 1)
        return n


def current_binding() -> Optional[Binding]:
    return getattr(_state, "binding", None)


@contextlib.contextmanager
def use_binding(binding: Optional[Binding]):
    prev = current_binding()
    _state.binding = binding
    try:
        yield
    finally:
        _state.binding = prev


def _phys_for(binding: Binding, ax: Logical) -> Tuple[str, ...]:
    if ax is None:
        return ()
    if isinstance(ax, tuple):
        return sum((binding.rules.get(a, ()) for a in ax), ())
    return binding.rules.get(ax, ())


def resolve(shape: Optional[Sequence[int]], *logical: Logical) -> P:
    """Logical axis names -> PartitionSpec under the active binding.

    If `shape` is given, axes that don't divide are dropped (replicated).
    A mesh axis already claimed by an earlier dim is dropped from later
    dims (lets rules say ("expert", None, "model"): EP takes the model
    axis when the expert count divides, TP over the ffn dim otherwise).
    """
    binding = current_binding()
    if binding is None:
        return P()
    spec = []
    used: set = set()
    for i, ax in enumerate(logical):
        phys = _phys_for(binding, ax)
        phys = tuple(a for a in phys if a not in used)
        if phys and shape is not None:
            if shape[i] % binding.extent(phys) != 0:
                phys = ()
        used.update(phys)
        if not phys:
            spec.append(None)
        elif len(phys) == 1:
            spec.append(phys[0])
        else:
            spec.append(phys)
    return P(*spec)


def shard(x, *logical: Logical):
    """with_sharding_constraint under the active binding (or identity)."""
    binding = current_binding()
    if binding is None:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    return jax.lax.with_sharding_constraint(
        x, resolve(x.shape, *logical))


def shard_pin(x, **dims: Logical):
    """Constrain only the given dims (by index); others UNCONSTRAINED.

    shard() with None dims *forces replication* on those dims — wrong when
    a tensor is legitimately sharded there by propagation (e.g. rope
    output heads). shard_pin(x, d0="batch") pins the batch dim and leaves
    the rest to the partitioner.
    """
    binding = current_binding()
    if binding is None:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    pinned = False
    for key, ax in dims.items():
        i = int(key[1:])
        phys = _phys_for(binding, ax)
        if phys and x.shape[i] % binding.extent(phys) == 0:
            spec[i] = phys if len(phys) > 1 else phys[0]
            pinned = True
        # indivisible: leave UNCONSTRAINED (never force replication)
    if not pinned:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))
