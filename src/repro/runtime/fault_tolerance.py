"""Fault tolerance: preemption handling, hang watchdog, restart loop.

Synchronous SPMD has no per-step straggler recourse — the mitigation stack
at 1000+ nodes is:
  1. static shapes everywhere (no recompile stalls — every step is the
     same program; this repo's configs guarantee it),
  2. async checkpointing (no save stalls on the critical path),
  3. preemption-aware exit: SIGTERM triggers checkpoint-and-exit at the
     next step boundary,
  4. hang watchdog: if no step completes within `hang_timeout_s` (dead
     host, wedged collective), the process aborts so the scheduler
     restarts it; restart resumes from the latest atomic checkpoint,
  5. elastic restart: the checkpoint is mesh-shape-agnostic (see
     checkpoint.py), so the job can resume on a resized slice; the data
     pipeline is step-addressable so no batches are lost or repeated.

`run_resilient` packages 3-5 for the train driver and is exercised
in-process by tests (simulated preemption/crash).
"""

from __future__ import annotations

import os
import signal
import threading
import time
from typing import Callable, Optional


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a graceful 'save and exit' flag."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            try:
                self._prev[s] = signal.signal(s, self._handle)
            except ValueError:  # non-main thread (tests)
                pass
        return self

    def _handle(self, signum, frame):
        self._flag.set()

    @property
    def preempted(self) -> bool:
        return self._flag.is_set()

    def trigger(self):  # test hook
        self._flag.set()

    def __exit__(self, *exc):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
        return False


class HangWatchdog:
    """Aborts (or calls on_hang) if heartbeat() isn't called in time."""

    def __init__(self, timeout_s: float, on_hang: Optional[Callable] = None,
                 poll_s: float = 1.0):
        self.timeout_s = timeout_s
        self.on_hang = on_hang or self._default_abort
        self._poll_s = poll_s
        self._last = time.monotonic()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)

    @staticmethod
    def _default_abort():
        os._exit(42)  # scheduler restarts us; checkpoint is atomic

    def start(self):
        self._thread.start()
        return self

    def heartbeat(self):
        self._last = time.monotonic()

    def _run(self):
        while not self._stop.wait(self._poll_s):
            if time.monotonic() - self._last > self.timeout_s:
                self.on_hang()
                return

    def stop(self):
        self._stop.set()


class TransientError(RuntimeError):
    """A step failure worth restarting from checkpoint (injected in tests)."""


def run_resilient(train_once: Callable[[], None], *, max_restarts: int = 3,
                  on_restart: Optional[Callable[[int], None]] = None) -> int:
    """Run train_once; on TransientError restart (from checkpoint) up to
    max_restarts times. Returns the number of restarts used."""
    restarts = 0
    while True:
        try:
            train_once()
            return restarts
        except TransientError:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts)
