"""Parameter PartitionSpecs from path-based rules.

Rules are expressed in *logical* axes (runtime/sharding.py) and resolved
divisibility-safely against the bound mesh. Stacked layer dims (leading
axis added by the per-layer vmap/scan layout) are auto-detected by rank
mismatch and get a leading None.

TP (model axis) follows the Megatron pattern: column-parallel in
(wq/wk/wv/wi_*), row-parallel out (wo/out_proj). EP shards the expert
axis. FSDP (ZeRO-3-ish) adds the data axis onto a free dim of every
matrix; ZeRO-1 applies the same to the Adam moments only.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.runtime import sharding as shlib

# leaf-name -> logical axes (by trailing dims; leading stack dims -> None)
_RULES: Dict[str, Tuple] = {
    # embeddings
    "embedding": ("vocab", None),
    "lm_head": (None, "vocab"),
    # attention / mlp matrices (column-parallel)
    "wq": ("fsdp", "model"),
    "wk": ("fsdp", "model"),
    "wv": ("fsdp", "model"),
    "wi_gate": ("fsdp", "model"),
    "wi_up": ("fsdp", "model"),
    # row-parallel
    "wo": ("model", "fsdp"),
    "out_proj": ("model", "fsdp"),
    # MLA
    "wq_a": ("fsdp", None),
    "wq_b": ("fsdp", "model"),
    "wkv_a": ("fsdp", None),
    "wk_b": ("fsdp", "model"),
    "wv_b": ("fsdp", "model"),
    # MoE (expert-parallel; note wi_*/wo 3-D variants below)
    "router": (None, None),
    # SSM
    "in_proj": ("fsdp", "model"),
    "conv_w": (None, "model"),
    "conv_b": ("model",),
    "a_log": (None,),
    "dt_bias": (None,),
    "d_skip": (None,),
    # norms
    "scale": (None,),
}

# EP takes the model axis when the (padded) expert count divides it; the
# trailing "model" falls back to TP over the ffn dim otherwise (resolve()
# drops duplicate mesh axes).
_MOE_RULES: Dict[str, Tuple] = {
    "wi_gate": ("expert", "fsdp", "model"),
    "wi_up": ("expert", "fsdp", "model"),
    "wo": ("expert", "model", "fsdp"),
}


def _leaf_rule(path, ndim: int) -> Tuple:
    names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    leaf = names[-1]
    in_moe = any(n == "moe" for n in names) and leaf in _MOE_RULES
    rule = _MOE_RULES[leaf] if in_moe else _RULES.get(leaf)
    if rule is None:
        rule = tuple(None for _ in range(ndim))
    # leading stacked-layer dims
    while len(rule) < ndim:
        rule = (None,) + rule
    assert len(rule) == ndim, (names, rule, ndim)
    return rule


def logical_param_axes(params_shape) -> Dict:
    """Pytree of logical-axis tuples matching the (abstract) param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_rule(path, len(leaf.shape)),
        params_shape)


def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(
        a is None or isinstance(a, (str, tuple)) for a in x)


def specs_from_logical(logical_tree, shapes_tree, *,
                       keep_fsdp: bool = None) -> Dict:
    """Resolve logical tuples to PartitionSpecs (divisibility-safe).

    "fsdp" axes are honored only when the binding has fsdp_params (params)
    or keep_fsdp=True is forced (ZeRO-1 moments).
    """
    binding = shlib.current_binding()
    fsdp_ok = keep_fsdp if keep_fsdp is not None else (
        binding.fsdp_params if binding else False)

    def resolve_leaf(ax, leaf):
        if not fsdp_ok:
            ax = tuple(None if a == "fsdp" else a for a in ax)
        return shlib.resolve(leaf.shape, *ax)

    return jax.tree.map(resolve_leaf, logical_tree, shapes_tree,
                        is_leaf=_is_axes)


def param_pspecs(params_shape) -> Dict:
    return specs_from_logical(logical_param_axes(params_shape),
                              params_shape)


def zero1_moment_axes(logical_tree, shapes_tree):
    """ZeRO-1: Adam moments get the fsdp (data) axis on a free dim."""
    def add_fsdp(ax, leaf):
        if "fsdp" in ax:
            return ax
        binding = shlib.current_binding()
        ext = binding.extent(binding.rules.get("fsdp", ())) if binding else 0
        out = list(ax)
        for i, a in enumerate(out):
            if a is None and ext and leaf.shape[i] % ext == 0:
                out[i] = "fsdp"
                break
        return tuple(out)

    return jax.tree.map(add_fsdp, logical_tree, shapes_tree,
                        is_leaf=_is_axes)


def shardings_for(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
