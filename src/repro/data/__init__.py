from repro.data.rf_data import synth_rf  # noqa: F401
from repro.data.traces import (ArrivalProcess, EmptyTraceError,  # noqa: F401
                               StreamTrace, Trace, TraceArrival,
                               TraceError, UniformArrival,
                               generate_trace, load_trace, seed_space)
