from repro.data.rf_data import synth_rf  # noqa: F401
