"""Deterministic synthetic LM data pipeline.

Stateless, step-addressable batches: batch(step) is a pure function of
(seed, step), so checkpoint restarts and elastic resizes resume *exactly*
(no data-loader state to save — the step number is the state). This is the
fault-tolerance property production pipelines get from deterministic
sharded readers, reproduced with a synthetic source.

The sequences follow an increment rule with rare random jumps
(x[t+1] = x[t] + stride, ~5% restarts), so next-token entropy is far below
uniform and a small model learns the rule within tens of steps — training
curves in examples/train_lm.py visibly descend while the jump floor keeps
the loss from collapsing to zero.
"""

from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

import jax.numpy as jnp

from repro.configs.base import ModelConfig


class TokenDataset:
    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.seed = seed

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        """Pure (seed, step) -> batch. int32 tokens/labels."""
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq
        rng = np.random.default_rng((self.seed * 1_000_003 + step) % 2**63)
        # increment-rule sequences: x[t+1] = x[t] + stride, with ~5%
        # random restarts (the irreducible loss floor)
        stride = rng.integers(1, 4, size=(b, 1))
        start = rng.integers(0, v, size=(b, 1))
        x = (start + stride * np.arange(s + 1)[None, :]) % v
        jumps = rng.random((b, s + 1)) < 0.05
        jump_to = rng.integers(0, v, size=(b, s + 1))
        offset = np.where(jumps, jump_to - x, 0).cumsum(axis=1)
        x = (x + offset) % v
        tokens = x[:, :s].astype(np.int32)
        labels = x[:, 1:s + 1].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.family == "vlm":
            d = self.cfg.d_model
            out["embeds"] = (0.02 * rng.standard_normal(
                (b, s, d))).astype(np.float32)
            mask = np.zeros((b, s), np.int32)
            mask[:, : s // 4] = 1
            out["embed_mask"] = mask
            pos = np.broadcast_to(np.arange(s, dtype=np.int32),
                                  (b, 3, s)).copy()
            out["positions"] = pos
        if self.cfg.family == "audio":
            d = self.cfg.d_model
            out["enc_embeds"] = (0.02 * rng.standard_normal(
                (b, s, d))).astype(np.float32)
        return out

    def iter_from(self, step: int) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_for_step(step)
            step += 1
