"""Synthetic RF data generator (stand-in for the paper's recorded data).

The paper loads recorded measurement data (§II-D); that data is proprietary,
so we synthesize physically-plausible RF: point scatterers insonified by a
0-degree plane wave, sampled with the same geometry the pipelines use, plus
slow-time motion so Doppler estimates are non-trivial. Deterministic given
the seed — every test/benchmark byte is reproducible.
"""

from __future__ import annotations

import numpy as np

from repro.core import geometry
from repro.core.config import UltrasoundConfig


def synth_rf(cfg: UltrasoundConfig, seed: int = 0, n_scatter: int = 24,
             flow_fraction: float = 0.5, flow_speed: float = 0.1,
             ) -> np.ndarray:
    """Return RF of shape (n_l, n_c, n_f), dtype cfg.rf_dtype.

    flow_speed is an axial displacement per frame in wavelengths; a fraction
    of scatterers move (blood), the rest are static (tissue/clutter), giving
    the wall filter something real to remove.
    """
    rng = np.random.default_rng(seed)
    xc = geometry.element_positions(cfg)                    # (n_c,)
    lam = cfg.c_sound / cfg.f0

    half_ap = (cfg.n_c - 1) / 2.0 * cfg.pitch
    zs = rng.uniform(cfg.z_min, cfg.z_max, n_scatter)
    xs = rng.uniform(-half_ap, half_ap, n_scatter)
    amp = rng.uniform(0.3, 1.0, n_scatter)
    moving = (np.arange(n_scatter) < int(flow_fraction * n_scatter))

    t = np.arange(cfg.n_l) / cfg.fs                         # (n_l,)
    # Gaussian-enveloped pulse, 2 cycles at f0.
    sigma = 1.0 / cfg.f0

    rf = np.zeros((cfg.n_l, cfg.n_c, cfg.n_f), dtype=np.float64)
    for f in range(cfg.n_f):
        dz = np.where(moving, flow_speed * lam * f, 0.0)
        z_f = zs + dz
        # time of flight: plane-wave transmit + per-element receive
        d_rx = np.sqrt(z_f[None, :] ** 2 +
                       (xs[None, :] - xc[:, None]) ** 2)    # (n_c, ns)
        tof = (z_f[None, :] + d_rx) / cfg.c_sound           # (n_c, ns)
        arg = t[:, None, None] - tof[None, :, :]            # (n_l, n_c, ns)
        pulse = np.exp(-0.5 * (arg / sigma) ** 2) * np.cos(
            2 * np.pi * cfg.f0 * arg)
        rf[:, :, f] = (pulse * amp[None, None, :]).sum(axis=-1)

    # additive noise floor, then quantize like an ADC
    rf += 1e-3 * rng.standard_normal(rf.shape)
    if cfg.rf_dtype == "int16":
        scale = 30000.0 / max(np.abs(rf).max(), 1e-9)
        return (rf * scale).astype(np.int16)
    return rf.astype(np.float32)
