"""Arrival-process layer + versioned trace format (`repro-trace-v1`).

Every serving benchmark before this module drove the multi-tenant
scheduler with perfectly uniform open-loop arrivals (frame k of a
stream at ``k / fps``) — a lab loop, not traffic. Accelerator serving
is judged by tail latency under bursty, mixed load (Jouppi et al.), and
the portability thesis requires those load scenarios to run unmodified
across backends. This module makes the arrival schedule a first-class,
replayable input:

  * `ArrivalProcess` — the pluggable clock of one tenant.
    `UniformArrival` is the historical default (``phase_s + k / fps``,
    bit-identical arithmetic); `TraceArrival` replays recorded
    timestamps verbatim — replaying a trace reproduces the exact
    arrival floats, so the scheduler's determinism oracle extends to
    the load itself.
  * `StreamTrace` / `Trace` — the versioned on-disk format: per-stream
    arrival timestamps, nominal rate, and a connect/disconnect window
    (``start_s`` / ``stop_s``) for churn. `Trace.sha256()` hashes the
    canonical JSON of the *load identity* (schema + streams, NOT the
    generator metadata), so a generated trace and its saved/replayed
    copy — or a uniform window and its recorded equivalent — share one
    provenance stamp. That hash lands in every ``kind=multitenant``
    record as ``trace_sha256``.
  * `generate_trace` — deterministic seeded generators for the load
    profiles the serving sweeps run: ``steady`` (the uniform schedule,
    reproduced bit-identically), ``burst`` (arrival clusters at ~10x
    rate separated by seeded quiet gaps), ``diurnal_ramp`` (rate swings
    through a slow-fast-slow cycle), ``churn`` (staggered probe
    connects, odd probes disconnect mid-stream), and ``adversarial``
    (one saturating tenant + many sparse ones).

Churn semantics (pinned by tests/test_traces.py): ``stop_s`` is the
disconnect instant. Frames whose *arrival timestamp* is at/after
``stop_s`` (or before ``start_s``) are DROPPED at admission — the probe
is gone — while frames that arrived before it always drain through the
scheduler. Both decisions depend only on trace timestamps, never on
wall-clock races, so a replay drops and drains the same frames.

Errors are named: `EmptyTraceError` for a trace with no streams or a
stream with no arrivals, `TraceError` for schema/monotonicity
violations — callers can catch the class instead of parsing messages.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
from typing import Optional, Sequence, Tuple

import numpy as np

TRACE_SCHEMA = "repro-trace-v1"

PROFILES = ("steady", "burst", "diurnal_ramp", "churn", "adversarial")

__all__ = ["TRACE_SCHEMA", "PROFILES", "TraceError", "EmptyTraceError",
           "ArrivalProcess", "UniformArrival", "TraceArrival",
           "StreamTrace", "Trace", "generate_trace", "load_trace",
           "mixed_phase", "mixed_rate", "seed_space"]


class TraceError(ValueError):
    """A trace violates the repro-trace-v1 contract."""


class EmptyTraceError(TraceError):
    """A trace with no streams, or a stream with no arrivals — there is
    nothing to replay, and silently serving zero frames would stamp a
    vacuous throughput record."""


def seed_space(*parts) -> int:
    """Disjoint deterministic seed spaces via SHA-256.

    Additive schemes like ``seed + b * batch + i`` collide whenever two
    sources' base seeds differ by less than their pool span — two
    "independent" tenants then stream byte-identical RF. Hashing the
    full identity tuple spreads every (namespace, base seed, index)
    into its own 63-bit region: collisions are cryptographically
    negligible, and the result is stable across processes and platforms
    (unlike ``hash()``, which Python salts per process).
    """
    text = "/".join(str(p) for p in parts)
    digest = hashlib.sha256(text.encode("utf-8")).digest()
    return int.from_bytes(digest[:8], "big") >> 1   # fit a non-neg int64


def mixed_rate(i: int, base_fps: float) -> float:
    """Nominal rate of mixed-traffic tenant i: ``base_fps / (1 + i/2)``.

    Shared by `repro.launch.scheduler.make_mixed_streams` and the
    ``steady`` generator so the uniform serving path and the steady
    trace replay compute the SAME floats — bit-identical arrivals, one
    trace_sha256.
    """
    return base_fps / (1 + i / 2)


def mixed_phase(i: int, base_fps: float) -> float:
    """Phase stagger of mixed-traffic tenant i (1/4 of the fastest
    period per tenant) — same sharing contract as `mixed_rate`."""
    return i * 0.25 / base_fps


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


class ArrivalProcess:
    """When does frame k of a stream arrive? (window-clock seconds)"""

    def arrival_s(self, k: int) -> float:
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class UniformArrival(ArrivalProcess):
    """The historical open-loop default: frame k at ``phase_s + k/fps``."""

    fps: float
    phase_s: float = 0.0

    def __post_init__(self):
        if self.fps <= 0:
            raise TraceError(f"fps must be > 0 (got {self.fps})")

    def arrival_s(self, k: int) -> float:
        return self.phase_s + k / self.fps


@dataclasses.dataclass(frozen=True)
class TraceArrival(ArrivalProcess):
    """Replays recorded timestamps bit-identically: frame k arrives at
    exactly ``arrivals[k]`` — no re-derivation, no float drift."""

    arrivals: Tuple[float, ...]

    def __post_init__(self):
        object.__setattr__(self, "arrivals", tuple(self.arrivals))
        if not self.arrivals:
            raise EmptyTraceError("TraceArrival needs >= 1 timestamp")

    def arrival_s(self, k: int) -> float:
        return self.arrivals[k]

    def __len__(self) -> int:
        return len(self.arrivals)


# ---------------------------------------------------------------------------
# Versioned trace format
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamTrace:
    """One tenant's recorded load: arrivals + connect/disconnect window.

    ``fps`` is the nominal offered rate (telemetry stamp — arrivals are
    authoritative). Arrivals outside ``[start_s, stop_s)`` are legal in
    the format and deterministically dropped at admission (churn: the
    probe disconnected while its clock kept producing).
    """

    stream_id: str
    arrivals: Tuple[float, ...]
    fps: float
    start_s: float = 0.0
    stop_s: Optional[float] = None

    def __post_init__(self):
        object.__setattr__(self, "arrivals", tuple(
            float(t) for t in self.arrivals))
        if not self.stream_id:
            raise TraceError("stream_id must be non-empty")
        if not self.arrivals:
            raise EmptyTraceError(
                f"stream {self.stream_id!r} has no arrivals")
        if self.fps <= 0:
            raise TraceError(f"stream {self.stream_id!r}: fps must be "
                             f"> 0 (got {self.fps})")
        a = np.asarray(self.arrivals)
        if a.min() < 0.0:
            raise TraceError(f"stream {self.stream_id!r}: negative "
                             f"arrival timestamp {a.min()}")
        if np.any(np.diff(a) < 0):
            raise TraceError(f"stream {self.stream_id!r}: arrivals are "
                             f"not non-decreasing")
        if self.start_s < 0.0:
            raise TraceError(f"stream {self.stream_id!r}: start_s must "
                             f"be >= 0 (got {self.start_s})")
        if self.stop_s is not None and self.stop_s <= self.start_s:
            raise TraceError(
                f"stream {self.stream_id!r}: stop_s={self.stop_s} must "
                f"be > start_s={self.start_s}")

    def json_dict(self) -> dict:
        return {"stream_id": self.stream_id, "fps": self.fps,
                "start_s": self.start_s, "stop_s": self.stop_s,
                "arrivals": list(self.arrivals)}


@dataclasses.dataclass(frozen=True)
class Trace:
    """A replayable multi-tenant load: N streams of arrival timestamps.

    ``profile`` / ``seed`` are generator metadata — they travel with a
    saved trace but are EXCLUDED from `sha256()`, so provenance
    identifies the load itself: a recorded trace and a generated one
    with identical timestamps hash the same.
    """

    streams: Tuple[StreamTrace, ...]
    profile: Optional[str] = None
    seed: Optional[int] = None

    def __post_init__(self):
        object.__setattr__(self, "streams", tuple(self.streams))
        if not self.streams:
            raise EmptyTraceError("trace has no streams")
        ids = [s.stream_id for s in self.streams]
        if len(set(ids)) != len(ids):
            raise TraceError(f"duplicate stream_id in {ids}")

    @property
    def n_frames(self) -> int:
        return sum(len(s.arrivals) for s in self.streams)

    def identity_dict(self) -> dict:
        """The hashed load identity: schema + streams, no metadata."""
        return {"schema": TRACE_SCHEMA,
                "streams": [s.json_dict() for s in self.streams]}

    def json_dict(self) -> dict:
        return {**self.identity_dict(), "profile": self.profile,
                "seed": self.seed}

    def sha256(self) -> str:
        """Provenance hash over the canonical load-identity JSON.

        `json.dumps` emits ``repr(float)`` which round-trips exactly,
        so save -> load -> sha256 is a fixed point: the stamp in a
        benchmark record names the byte-identical arrival schedule.
        """
        canonical = json.dumps(self.identity_dict(), sort_keys=True,
                               separators=(",", ":"))
        return hashlib.sha256(canonical.encode("utf-8")).hexdigest()

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.json_dict(), f, indent=1, sort_keys=True)
            f.write("\n")


def load_trace(path: str) -> Trace:
    """Load and validate a saved trace; raises the named errors."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, dict) or doc.get("schema") != TRACE_SCHEMA:
        raise TraceError(
            f"{path}: not a {TRACE_SCHEMA} trace "
            f"(schema={doc.get('schema') if isinstance(doc, dict) else None!r})")
    streams = doc.get("streams")
    if not isinstance(streams, list):
        raise TraceError(f"{path}: 'streams' must be a list")
    return Trace(
        streams=tuple(StreamTrace(
            stream_id=s["stream_id"], arrivals=tuple(s["arrivals"]),
            fps=s["fps"], start_s=s.get("start_s", 0.0),
            stop_s=s.get("stop_s")) for s in streams),
        profile=doc.get("profile"), seed=doc.get("seed"))


# ---------------------------------------------------------------------------
# Seeded profile generators
# ---------------------------------------------------------------------------


def _rng(seed: int, profile: str, i: int) -> np.random.Generator:
    return np.random.default_rng(seed_space("trace", seed, profile, i))


def _steady(i, n_frames, base_fps, rng):
    fps = mixed_rate(i, base_fps)
    phase = mixed_phase(i, base_fps)
    # Same expression tree as UniformArrival.arrival_s under
    # make_mixed_streams' parameters -> bit-identical floats.
    return [phase + k / fps for k in range(n_frames)], fps, 0.0, None


def _burst(i, n_frames, base_fps, rng):
    """Clusters of up to 4 arrivals at 10x rate, seeded quiet gaps."""
    fps = mixed_rate(i, base_fps)
    burst_len = max(1, min(4, n_frames))
    t = mixed_phase(i, base_fps)
    arrivals = []
    for k in range(n_frames):
        arrivals.append(t)
        if (k + 1) % burst_len == 0:
            t += (burst_len / fps) * (0.5 + float(rng.uniform()))
        else:
            t += 0.1 / fps
    return arrivals, fps, 0.0, None


def _diurnal_ramp(i, n_frames, base_fps, rng):
    """Rate swings 0.25x -> 1x -> 0.25x of nominal over the stream —
    the diurnal load curve compressed into one window."""
    fps = mixed_rate(i, base_fps)
    t = mixed_phase(i, base_fps)
    arrivals = []
    for k in range(n_frames):
        arrivals.append(t)
        mod = 0.25 + 0.75 * 0.5 * (1.0 - math.cos(
            2.0 * math.pi * (k + 1) / n_frames))
        t += 1.0 / (fps * mod)
    return arrivals, fps, 0.0, None


def _churn(i, n_frames, base_fps, rng):
    """Staggered connects; odd probes disconnect at 60% of their run —
    their tail arrivals land past ``stop_s`` and are dropped at
    admission, exercising the retire path deterministically."""
    fps = mixed_rate(i, base_fps)
    start = i * 0.25 * n_frames / base_fps
    arrivals = [start + k / fps for k in range(n_frames)]
    stop = None
    if i % 2 == 1:
        keep = max(1, int(math.ceil(0.6 * n_frames)))
        # Disconnect half a period after the last kept arrival: frames
        # 0..keep-1 are in the window, keep.. are dropped.
        stop = start + (keep - 0.5) / fps if keep < n_frames else None
    return arrivals, fps, start, stop


def _adversarial(i, n_frames, base_fps, rng):
    """Tenant 0 saturates (50x nominal, one long burst); everyone else
    trickles at base_fps/8 — the starvation scenario `_pick_group`'s
    oldest-eligible-head rule exists for."""
    if i == 0:
        fps = 50.0 * base_fps
        return [k / fps for k in range(n_frames)], fps, 0.0, None
    fps = base_fps / 8.0
    phase = mixed_phase(i, base_fps)
    return [phase + k / fps for k in range(n_frames)], fps, 0.0, None


_GENERATORS = {"steady": _steady, "burst": _burst,
               "diurnal_ramp": _diurnal_ramp, "churn": _churn,
               "adversarial": _adversarial}
assert tuple(_GENERATORS) == PROFILES


def generate_trace(profile: str, *, n_streams: int = 4,
                   n_frames: int = 16, base_fps: float = 120.0,
                   seed: int = 0) -> Trace:
    """Deterministic seeded trace for one of the named load profiles.

    Stream i is named ``probe{i}`` and carries ``n_frames`` arrival
    timestamps — the same tenant naming and count contract as
    `make_mixed_streams`, so `make_trace_streams` replays a generated
    trace onto the same config/seed assignment the uniform path uses.
    Identical (profile, n_streams, n_frames, base_fps, seed) always
    yields a byte-identical trace (PRNG seeded via `seed_space`).
    """
    if profile not in PROFILES:
        raise TraceError(f"unknown profile {profile!r} "
                         f"(expected one of {PROFILES})")
    if n_streams < 1:
        raise TraceError(f"n_streams must be >= 1 (got {n_streams})")
    if n_frames < 1:
        raise EmptyTraceError(f"n_frames must be >= 1 (got {n_frames})")
    if base_fps <= 0:
        raise TraceError(f"base_fps must be > 0 (got {base_fps})")

    gen = _GENERATORS[profile]
    streams = []
    for i in range(n_streams):
        arrivals, fps, start, stop = gen(i, n_frames, base_fps,
                                         _rng(seed, profile, i))
        streams.append(StreamTrace(
            stream_id=f"probe{i}", arrivals=tuple(arrivals), fps=fps,
            start_s=start, stop_s=stop))
    return Trace(streams=tuple(streams), profile=profile, seed=seed)
