"""Batch construction: real synthetic arrays (tests/train) and abstract
ShapeDtypeStruct specs (dry-run) share one schema per (family, kind).

Schema:
  train/prefill (LM):   tokens (B,S) i32, labels (B,S) i32
  vlm adds:             embeds (B,S,D), embed_mask (B,S), positions (B,3,S)
  audio (enc-dec):      enc_embeds (B,S,D) + tokens/labels (B,S)
  decode:               tokens (B,1) + cache + lengths (B,)
"""

from __future__ import annotations

from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dtype_of


def train_batch_spec(cfg: ModelConfig, batch: int, seq: int) -> Dict:
    i32 = jnp.int32
    act = dtype_of(cfg.compute_dtype)
    spec = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }
    if cfg.family == "vlm":
        spec["embeds"] = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), act)
        spec["embed_mask"] = jax.ShapeDtypeStruct((batch, seq), i32)
        spec["positions"] = jax.ShapeDtypeStruct((batch, 3, seq), i32)
    if cfg.family == "audio":
        spec["enc_embeds"] = jax.ShapeDtypeStruct(
            (batch, seq, cfg.d_model), act)
    return spec


def synth_train_batch(cfg: ModelConfig, batch: int, seq: int,
                      seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    act = dtype_of(cfg.compute_dtype)
    out = {
        "tokens": rng.integers(0, cfg.vocab_size, (batch, seq)).astype(
            np.int32),
        "labels": rng.integers(0, cfg.vocab_size, (batch, seq)).astype(
            np.int32),
    }
    if cfg.family == "vlm":
        n_img = seq // 4                      # leading image-patch region
        out["embeds"] = (0.02 * rng.standard_normal(
            (batch, seq, cfg.d_model))).astype(act)
        mask = np.zeros((batch, seq), np.int32)
        mask[:, :n_img] = 1
        out["embed_mask"] = mask
        # M-RoPE triplets: patches get (t=0, h, w) grid positions; text gets
        # sequential positions on all three axes.
        side = max(int(np.sqrt(n_img)), 1)
        pos = np.zeros((batch, 3, seq), np.int32)
        for i in range(n_img):
            pos[:, 0, i] = 0
            pos[:, 1, i] = i // side
            pos[:, 2, i] = i % side
        text = np.arange(seq - n_img)
        for ax in range(3):
            pos[:, ax, n_img:] = side + text
        out["positions"] = pos
    if cfg.family == "audio":
        out["enc_embeds"] = (0.02 * rng.standard_normal(
            (batch, seq, cfg.d_model))).astype(act)
    return jax.tree.map(jnp.asarray, out)


def decode_inputs_spec(cfg: ModelConfig, batch: int) -> Dict:
    return {
        "tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
        "lengths": jax.ShapeDtypeStruct((batch,), jnp.int32),
    }


def synth_decode_inputs(cfg: ModelConfig, batch: int, length: int,
                        seed: int = 0) -> Dict:
    rng = np.random.default_rng(seed)
    return {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (batch, 1)).astype(np.int32)),
        "lengths": jnp.full((batch,), length, dtype=jnp.int32),
    }
