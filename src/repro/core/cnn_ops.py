"""Deterministic CNN-expressible primitive operations.

The paper restricts the benchmarked forward path to "element-wise arithmetic,
convolutions, pooling or reductions, and simple nonlinearities (e.g., square
root and atan2 approximations)" (§II-C) and announces (§VII, Future Work) a
catalogue of classically non-CNN ops re-expressed with that operator set.
This module *is* that catalogue: every function below is a fixed, math-defined
composition of pointwise arithmetic, sqrt, and reductions — no data-dependent
control flow, no learned weights, bounded approximation error.

Conventions:
  * "select" is arithmetic blending, not lax.select, so that the same graph
    lowers to pure pointwise ops on any backend.
  * All approximations are validated against jnp oracles in
    tests/test_cnn_ops.py with documented error bounds.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Arithmetic control flow
# ---------------------------------------------------------------------------


def select(mask, a, b):
    """mask ? a : b as pure arithmetic. mask must be 0/1 valued (float)."""
    return mask * a + (1.0 - mask) * b


def ge_mask(x, y):
    """(x >= y) as a {0,1} float tensor (pointwise comparison)."""
    return (x >= y).astype(jnp.float32)


def clip(x, lo, hi):
    """Pointwise clamp via min/max (CNN-compatible saturation)."""
    return jnp.minimum(jnp.maximum(x, lo), hi)


# ---------------------------------------------------------------------------
# atan / atan2
# ---------------------------------------------------------------------------

# Hastings minimax polynomial for atan(z), |z| <= 1. Max abs error ~1.2e-5.
_ATAN_C1 = 0.9998660
_ATAN_C3 = -0.3302995
_ATAN_C5 = 0.1801410
_ATAN_C7 = -0.0851330
_ATAN_C9 = 0.0208351


def atan_poly(z):
    """atan(z) for |z| <= 1 via odd 9th-order minimax polynomial."""
    z2 = z * z
    return z * (_ATAN_C1 + z2 * (_ATAN_C3 + z2 * (
        _ATAN_C5 + z2 * (_ATAN_C7 + z2 * _ATAN_C9))))


def atan2_approx(y, x, eps: float = 1e-30):
    """Four-quadrant atan2 with bounded error (~1e-4 rad in float32).

    Range reduction: z = min(|x|,|y|) / max(|x|,|y|) keeps the polynomial
    argument in [0, 1]; quadrant reconstruction is arithmetic select only.
    """
    ax = jnp.abs(x)
    ay = jnp.abs(y)
    hi = jnp.maximum(ax, ay)
    lo = jnp.minimum(ax, ay)
    z = lo / (hi + eps)
    base = atan_poly(z)
    # If |y| > |x| the reduced angle is measured from the y-axis.
    swap = ge_mask(ay, ax)
    ang = select(swap, (np.pi / 2) - base, base)
    # Quadrant fixes from the signs of x and y.
    xneg = ge_mask(0.0, x) * ge_mask(jnp.abs(x), eps)  # x < 0 (treat -0 as +)
    ang = select(xneg, np.pi - ang, ang)
    yneg = ge_mask(0.0, y) * ge_mask(jnp.abs(y), eps)
    return select(yneg, -ang, ang)


# ---------------------------------------------------------------------------
# Logarithms
# ---------------------------------------------------------------------------


def ln_approx(x, n_sqrt: int = 16, eps: float = 1e-30):
    """ln(x) via the sqrt-composition identity ln(x) = 2^k (x^(1/2^k) - 1) + O().

    Uses k repeated square roots (the paper's allowed sqrt nonlinearity) and a
    first-order remainder. With k=16 the absolute error for x in [1e-8, 1e4]
    is < 2e-3 (i.e. < 0.01 dB after 20/ln10 scaling) — bounded and
    deterministic. Inputs are clamped to eps to avoid -inf.
    """
    y = jnp.maximum(x, eps)
    for _ in range(n_sqrt):
        y = jnp.sqrt(y)
    # y = x^(1/2^k); ln(x) ~= 2^k * (y - 1) * (2 / (1 + y)) (Pade-improved)
    scale = float(2 ** n_sqrt)
    return scale * (y - 1.0) * 2.0 / (1.0 + y)


_LN10 = float(np.log(10.0))


def log10_approx(x, n_sqrt: int = 16, eps: float = 1e-30):
    return ln_approx(x, n_sqrt=n_sqrt, eps=eps) / _LN10


def db20_approx(x, eps: float = 1e-30):
    """20*log10(x) with CNN-expressible log."""
    return 20.0 * log10_approx(x, eps=eps)


# ---------------------------------------------------------------------------
# Magnitude / normalization
# ---------------------------------------------------------------------------


def magnitude(re, im):
    """|z| = sqrt(re^2 + im^2) (paper-allowed sqrt nonlinearity)."""
    return jnp.sqrt(re * re + im * im)


def normalize_by_max(x, axis=None, eps: float = 1e-30):
    """x / max(x) via a reduction + pointwise division."""
    m = jnp.max(x, axis=axis, keepdims=True)
    return x / (m + eps)


# ---------------------------------------------------------------------------
# Complex arithmetic on (..., 2) real tensors
# ---------------------------------------------------------------------------
# Complex dtypes are avoided so the same graph runs on CNN-only backends; the
# final axis holds (real, imag).


def cpack(re, im):
    return jnp.stack([re, im], axis=-1)


def creal(z):
    return z[..., 0]


def cimag(z):
    return z[..., 1]


def cmul(a, b):
    """(a_re + i a_im) * (b_re + i b_im) — four pointwise multiplies."""
    ar, ai = a[..., 0], a[..., 1]
    br, bi = b[..., 0], b[..., 1]
    return cpack(ar * br - ai * bi, ar * bi + ai * br)


def cconj(z):
    return cpack(z[..., 0], -z[..., 1])


def cabs2(z):
    return z[..., 0] ** 2 + z[..., 1] ** 2
