"""Per-stage operator-lowering registry (xla / pallas, planned per stage).

The paper's central claim is that *operator formulation* decides
throughput per backend — and TINA/ConvBench show the win comes from
choosing the right primitive lowering per operator, not per pipeline.
The variant (dynamic / cnn / sparse) picks the *math formulation*; this
module picks, per stage, the *lowering* that executes it:

  * ``xla``    — the plain jax.numpy formulation (portable baseline;
    every stage op registers one).
  * ``pallas`` — a hand-tiled Pallas kernel (repro.kernels): the fused
    ``das_beamform`` kernel lowers the dynamic beamform, the
    scalar-prefetched ``bsr_spmm`` kernel lowers the sparse beamform.
    Compiled on TPU, interpret-mode everywhere else (the shared
    ``repro.kernels.pallas_compat.auto_interpret`` fallback).

Each registration carries a capability predicate ``available(cfg,
backend)`` (backend support, shape/tile constraints), so the planner
(repro.core.plan) only ever considers lowerings that can actually run.
`plan_pipeline` resolves one lowering per stage — preference table or
per-stage autotune — and `PipelinePlan.concretize` writes the mapping
into ``cfg.stage_lowerings``, from where `apply_stage` dispatches at
trace time. The resolved mapping participates in the canonical config
hash, so the multi-tenant scheduler never shares a compiled program
across different lowerings, and it is stamped into every NDJSON record
via the plan.

Invariants: every (stage, variant) op has an ``xla`` lowering (the
numeric reference — all lowerings of one op are allclose, asserted in
tests/test_lowering.py); registration is idempotent per key; the
registry is process-global and inspectable (tests extend it freely).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import beamform, bmode, demod, doppler
from repro.core.config import (LOWERING_NAMES, STAGE_NAMES, UltrasoundConfig,
                               Variant)

__all__ = ["Lowering", "register_lowering", "registered_lowerings",
           "available_lowerings", "resolve_apply", "apply_stage",
           "supported_subset", "DEFAULT_LOWERING"]

DEFAULT_LOWERING = "xla"


@dataclasses.dataclass(frozen=True)
class Lowering:
    """One way to execute a stage op.

    ``apply(cfg, consts, x) -> y`` is the runtime transform (same
    contract as `repro.core.stages.Stage.apply`); ``available(cfg,
    backend)`` gates it on backend support and shape/tile constraints.
    ``variant`` scopes the registration: None applies to every variant
    (demod, the heads), a concrete Variant only to that formulation of
    the stage (the three beamformers are three distinct ops).
    """

    stage: str
    name: str
    apply: Callable[[UltrasoundConfig, Dict, jnp.ndarray], jnp.ndarray]
    available: Callable[[UltrasoundConfig, str], bool]
    variant: Optional[Variant] = None


# (stage, variant value or None) -> {lowering name -> Lowering}
_REGISTRY: Dict[Tuple[str, Optional[str]], Dict[str, Lowering]] = {}


def _always(cfg: UltrasoundConfig, backend: str) -> bool:
    return True


def register_lowering(stage: str, name: str, apply: Callable, *,
                      variant: Optional[Variant] = None,
                      available: Optional[Callable] = None) -> Lowering:
    """Register (or replace) one lowering of a stage op."""
    if stage not in STAGE_NAMES:
        raise ValueError(f"unknown stage: {stage!r} "
                         f"(expected one of {STAGE_NAMES})")
    if name not in LOWERING_NAMES:
        raise ValueError(f"unknown lowering name: {name!r} "
                         f"(expected one of {LOWERING_NAMES})")
    low = Lowering(stage=stage, name=name, apply=apply,
                   available=available or _always, variant=variant)
    key = (stage, variant.value if variant is not None else None)
    _REGISTRY.setdefault(key, {})[name] = low
    return low


def _op_key(cfg: UltrasoundConfig, stage: str) -> Tuple[str, Optional[str]]:
    """The registry key for ``stage`` under ``cfg``'s variant.

    Variant-scoped registrations (the beamformers) win over
    variant-independent ones; the beamform stage of an AUTO config has
    no op until the planner resolves the variant.
    """
    if cfg.variant.concrete and (stage, cfg.variant.value) in _REGISTRY:
        return (stage, cfg.variant.value)
    return (stage, None)


def registered_lowerings(cfg: UltrasoundConfig,
                         stage: str) -> Dict[str, Lowering]:
    """Every lowering registered for this (stage, cfg.variant) op."""
    return dict(_REGISTRY.get(_op_key(cfg, stage), {}))


def available_lowerings(cfg: UltrasoundConfig, stage: str,
                        backend: str) -> Dict[str, Lowering]:
    """The registered lowerings whose capability predicate passes."""
    return {n: low for n, low in registered_lowerings(cfg, stage).items()
            if low.available(cfg, backend)}


def resolve_apply(cfg: UltrasoundConfig, stage: str) -> Callable:
    """The apply callable for ``cfg``'s chosen lowering of ``stage``.

    Stages left unspecified in ``cfg.stage_lowerings`` run the ``xla``
    reference — plan-resolved configs always specify every stage, so
    the default only serves raw (planner-less) graph construction.
    """
    name = cfg.stage_lowering(stage, DEFAULT_LOWERING)
    lows = registered_lowerings(cfg, stage)
    if name not in lows:
        have = sorted(lows) or ["<none>"]
        op = (f"{stage}/{cfg.variant.value}"
              if _op_key(cfg, stage)[1] is not None else stage)
        raise ValueError(
            f"no {name!r} lowering registered for stage op {op!r} "
            f"(registered: {have})")
    return lows[name].apply


def apply_stage(cfg: UltrasoundConfig, stage: str, consts: Dict,
                x: jnp.ndarray) -> jnp.ndarray:
    """Dispatch one stage through its configured lowering."""
    return resolve_apply(cfg, stage)(cfg, consts, x)


def supported_subset(cfg: UltrasoundConfig,
                     backend: Optional[str] = None
                     ) -> Tuple[Tuple[str, str], ...]:
    """``cfg.stage_lowerings`` pruned to entries this variant registers
    AND whose capability predicate passes on ``backend``.

    Used when probing concrete variants on behalf of ``Variant.AUTO``:
    an explicit {"beamform": "pallas"} must not crash the CNN probe
    (which registers no pallas beamform) — the final plan still
    validates explicit entries strictly against the resolved variant.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    return tuple((stage, name) for stage, name in cfg.stage_lowerings
                 if name in available_lowerings(cfg, stage, backend))


def supports_explicit(cfg: UltrasoundConfig, backend: str) -> bool:
    """True iff every explicit ``cfg.stage_lowerings`` entry is
    registered for this variant and available on this backend — the
    planner's variant-candidate filter (an AUTO config pinned to a
    pallas beamform must never resolve to a variant that cannot honor
    the pin)."""
    return supported_subset(cfg, backend) == cfg.stage_lowerings


# ---------------------------------------------------------------------------
# Default registrations: the stage-op x lowering matrix
# ---------------------------------------------------------------------------


def _beamform_dynamic_pallas(cfg, consts, iq):
    """Fused DAS gather+lerp+rotate+reduce in one Pallas kernel
    (repro.kernels.das_beamform; docs/kernels.md has the tile contract)."""
    from repro.kernels.das_beamform import das_beamform
    return das_beamform(consts["idx"], consts["frac"], consts["apod"],
                        consts["rot"], iq)


def _beamform_sparse_pallas(cfg, consts, iq):
    """Banded BSR SpMM via the scalar-prefetched Pallas kernel — the
    paper's V3-on-TPU story (repro.kernels.bsr_spmm). The wrapper owns
    the IQ sample-axis blocking; the kernel owns the block gather."""
    from repro.kernels.bsr_spmm import bsr_beamform
    blocks = consts["bsr_blocks"]                       # (n_c,n_pb,K,bp,bs,2)
    cols = consts["bsr_col_idx"]                        # (n_c, n_pb, K)
    bs = blocks.shape[4]
    n_s = iq.shape[0]
    n_sb = -(-n_s // bs)
    pad = n_sb * bs - n_s
    iq_p = jnp.pad(iq, ((0, pad), (0, 0), (0, 0), (0, 0)))
    iq_b = iq_p.reshape(n_sb, bs, iq.shape[1], iq.shape[2], 2)
    return bsr_beamform(cols, blocks, iq_b)[: cfg.n_pix]


def _das_pallas_available(cfg: UltrasoundConfig, backend: str) -> bool:
    # The wrapper pads the pixel axis to the tile size and the kernel
    # declares no other hard shape constraint, so the fused DAS kernel
    # is available everywhere (interpret mode off-TPU).
    return True


def _bsr_pallas_available(cfg: UltrasoundConfig, backend: str) -> bool:
    # Interpret mode accepts any block shape; the compiled TPU kernel
    # feeds (bp x bs) blocks straight to the MXU, so sublane alignment
    # (the config's documented "MXU-aligned multiples of 8" contract —
    # the shipped defaults satisfy it) is a hard tile constraint.
    if backend != "tpu":
        return True
    return cfg.sparse_block_p % 8 == 0 and cfg.sparse_block_s % 8 == 0


def _register_defaults() -> None:
    register_lowering(
        "demod", "xla",
        lambda cfg, consts, rf: demod.rf_to_iq(consts, rf, cfg.decim))
    for variant, fn in beamform.BEAMFORMERS.items():
        # each beamformer already has the Lowering.apply signature
        register_lowering("beamform", "xla", fn, variant=variant)
    register_lowering("beamform", "pallas", _beamform_dynamic_pallas,
                      variant=Variant.DYNAMIC,
                      available=_das_pallas_available)
    register_lowering("beamform", "pallas", _beamform_sparse_pallas,
                      variant=Variant.SPARSE,
                      available=_bsr_pallas_available)
    register_lowering(
        "bmode", "xla",
        lambda cfg, consts, bf: bmode.bmode_image(cfg, bf))
    register_lowering(
        "doppler", "xla",
        lambda cfg, consts, bf:
            doppler.color_doppler_image(cfg, consts, bf))
    register_lowering(
        "power_doppler", "xla",
        lambda cfg, consts, bf:
            doppler.power_doppler_image(cfg, consts, bf))


_register_defaults()
