"""Per-stage operator-lowering registry (xla / pallas, planned per stage).

The paper's central claim is that *operator formulation* decides
throughput per backend — and TINA/ConvBench show the win comes from
choosing the right primitive lowering per operator, not per pipeline.
The variant (dynamic / cnn / sparse) picks the *math formulation*; this
module picks, per stage, the *lowering* that executes it:

  * ``xla``    — the plain jax.numpy formulation (portable baseline;
    every stage op registers one).
  * ``pallas`` — a hand-tiled Pallas kernel (repro.kernels): the fused
    ``das_beamform`` kernel lowers the dynamic beamform, the
    scalar-prefetched ``bsr_spmm`` kernel lowers the sparse beamform.
    Compiled on TPU, interpret-mode everywhere else (the shared
    ``repro.kernels.pallas_compat.auto_interpret`` fallback).

Each registration carries a capability predicate ``available(cfg,
backend)`` (backend support, shape/tile constraints), so the planner
(repro.core.plan) only ever considers lowerings that can actually run.
`plan_pipeline` resolves one lowering per stage — preference table or
per-stage autotune — and `PipelinePlan.concretize` writes the mapping
into ``cfg.stage_lowerings``, from where `apply_stage` dispatches at
trace time. The resolved mapping participates in the canonical config
hash, so the multi-tenant scheduler never shares a compiled program
across different lowerings, and it is stamped into every NDJSON record
via the plan.

Invariants: every (stage, variant) op has an ``xla`` lowering (the
numeric reference — all lowerings of one op are allclose, asserted in
tests/test_lowering.py); registration is idempotent per key; the
registry is process-global and inspectable (tests extend it freely).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax.numpy as jnp

from repro.core import beamform, bmode, demod, doppler
from repro.core.config import (LOWERING_NAMES, Modality, PRECISION_NAMES,
                               STAGE_NAMES, UltrasoundConfig, Variant)

__all__ = ["Lowering", "register_lowering", "registered_lowerings",
           "available_lowerings", "resolve_apply", "apply_stage",
           "supported_subset", "DEFAULT_LOWERING", "FusedLowering",
           "register_fused_lowering", "registered_fused_lowerings",
           "resolve_fused"]

DEFAULT_LOWERING = "xla"


@dataclasses.dataclass(frozen=True)
class Lowering:
    """One way to execute a stage op.

    ``apply(cfg, consts, x) -> y`` is the runtime transform (same
    contract as `repro.core.stages.Stage.apply`); ``available(cfg,
    backend)`` gates it on backend support and shape/tile constraints.
    ``variant`` scopes the registration: None applies to every variant
    (demod, the heads), a concrete Variant only to that formulation of
    the stage (the three beamformers are three distinct ops).
    ``precisions`` names the compute precisions the lowering implements
    (config.PRECISION_NAMES); the xla references compute in f32 only,
    so a reduced-precision config resolves only onto kernels that
    declare it — the planner refuses anything else loudly.
    """

    stage: str
    name: str
    apply: Callable[[UltrasoundConfig, Dict, jnp.ndarray], jnp.ndarray]
    available: Callable[[UltrasoundConfig, str], bool]
    variant: Optional[Variant] = None
    precisions: Tuple[str, ...] = ("f32",)


# (stage, variant value or None) -> {lowering name -> Lowering}
_REGISTRY: Dict[Tuple[str, Optional[str]], Dict[str, Lowering]] = {}


def _always(cfg: UltrasoundConfig, backend: str) -> bool:
    return True


def register_lowering(stage: str, name: str, apply: Callable, *,
                      variant: Optional[Variant] = None,
                      available: Optional[Callable] = None,
                      precisions: Tuple[str, ...] = ("f32",)) -> Lowering:
    """Register (or replace) one lowering of a stage op."""
    if stage not in STAGE_NAMES:
        raise ValueError(f"unknown stage: {stage!r} "
                         f"(expected one of {STAGE_NAMES})")
    if name not in LOWERING_NAMES:
        raise ValueError(f"unknown lowering name: {name!r} "
                         f"(expected one of {LOWERING_NAMES})")
    _check_precisions(precisions)
    low = Lowering(stage=stage, name=name, apply=apply,
                   available=available or _always, variant=variant,
                   precisions=tuple(precisions))
    key = (stage, variant.value if variant is not None else None)
    _REGISTRY.setdefault(key, {})[name] = low
    return low


def _check_precisions(precisions) -> None:
    bad = sorted(set(precisions) - set(PRECISION_NAMES))
    if bad or not precisions:
        raise ValueError(f"invalid precisions {tuple(precisions)!r} "
                         f"(expected a non-empty subset of "
                         f"{PRECISION_NAMES})")


def _op_key(cfg: UltrasoundConfig, stage: str) -> Tuple[str, Optional[str]]:
    """The registry key for ``stage`` under ``cfg``'s variant.

    Variant-scoped registrations (the beamformers) win over
    variant-independent ones; the beamform stage of an AUTO config has
    no op until the planner resolves the variant.
    """
    if cfg.variant.concrete and (stage, cfg.variant.value) in _REGISTRY:
        return (stage, cfg.variant.value)
    return (stage, None)


def registered_lowerings(cfg: UltrasoundConfig,
                         stage: str) -> Dict[str, Lowering]:
    """Every lowering registered for this (stage, cfg.variant) op."""
    return dict(_REGISTRY.get(_op_key(cfg, stage), {}))


def available_lowerings(cfg: UltrasoundConfig, stage: str,
                        backend: str) -> Dict[str, Lowering]:
    """The registered lowerings whose capability predicate passes AND
    that implement ``cfg.precision`` — under reduced precision the xla
    references (f32-only) drop out, so resolution fails loudly for any
    stage no kernel covers rather than silently computing in f32."""
    return {n: low for n, low in registered_lowerings(cfg, stage).items()
            if cfg.precision in low.precisions
            and low.available(cfg, backend)}


def resolve_apply(cfg: UltrasoundConfig, stage: str) -> Callable:
    """The apply callable for ``cfg``'s chosen lowering of ``stage``.

    Stages left unspecified in ``cfg.stage_lowerings`` run the ``xla``
    reference — plan-resolved configs always specify every stage, so
    the default only serves raw (planner-less) graph construction.
    """
    name = cfg.stage_lowering(stage, DEFAULT_LOWERING)
    lows = registered_lowerings(cfg, stage)
    if name not in lows:
        have = sorted(lows) or ["<none>"]
        op = (f"{stage}/{cfg.variant.value}"
              if _op_key(cfg, stage)[1] is not None else stage)
        raise ValueError(
            f"no {name!r} lowering registered for stage op {op!r} "
            f"(registered: {have})")
    if cfg.precision not in lows[name].precisions:
        raise ValueError(
            f"lowering {name!r} for stage {stage!r} computes in "
            f"{lows[name].precisions} only, but the config requests "
            f"precision={cfg.precision!r} — reduced precision needs a "
            "kernel that declares it (set fusion='fused' for the "
            "megakernel, or precision='f32')")
    return lows[name].apply


def apply_stage(cfg: UltrasoundConfig, stage: str, consts: Dict,
                x: jnp.ndarray) -> jnp.ndarray:
    """Dispatch one stage through its configured lowering."""
    return resolve_apply(cfg, stage)(cfg, consts, x)


def supported_subset(cfg: UltrasoundConfig,
                     backend: Optional[str] = None
                     ) -> Tuple[Tuple[str, str], ...]:
    """``cfg.stage_lowerings`` pruned to entries this variant registers
    AND whose capability predicate passes on ``backend``.

    Used when probing concrete variants on behalf of ``Variant.AUTO``:
    an explicit {"beamform": "pallas"} must not crash the CNN probe
    (which registers no pallas beamform) — the final plan still
    validates explicit entries strictly against the resolved variant.
    """
    if backend is None:
        import jax
        backend = jax.default_backend()
    return tuple((stage, name) for stage, name in cfg.stage_lowerings
                 if name in available_lowerings(cfg, stage, backend))


def supports_explicit(cfg: UltrasoundConfig, backend: str) -> bool:
    """True iff every explicit ``cfg.stage_lowerings`` entry is
    registered for this variant and available on this backend — the
    planner's variant-candidate filter (an AUTO config pinned to a
    pallas beamform must never resolve to a variant that cannot honor
    the pin)."""
    return supported_subset(cfg, backend) == cfg.stage_lowerings


# ---------------------------------------------------------------------------
# Fused (stage-span) lowerings
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedLowering:
    """One lowering claiming a contiguous SPAN of stages.

    ``apply(cfg, consts, x) -> y`` maps the first spanned stage's input
    straight to the last spanned stage's output (for the full-pipeline
    span: RF -> image) — the per-stage dispatch never runs inside the
    span. A fused lowering is scoped to one (variant, modality) cell:
    the span's math is the composition of that variant's stage ops, so
    a single registration cannot honestly serve two formulations.

    ``stages`` must be a contiguous run of the modality's graph order
    ``(demod, beamform, <head>)``, length >= 2 — a 1-stage "span" is a
    per-stage lowering and belongs in the flat registry.
    """

    stages: Tuple[str, ...]
    name: str
    variant: Variant
    modality: Modality
    apply: Callable[[UltrasoundConfig, Dict, jnp.ndarray], jnp.ndarray]
    available: Callable[[UltrasoundConfig, str], bool]
    precisions: Tuple[str, ...] = ("f32",)

    @property
    def group(self) -> str:
        """Canonical fusion-group label, e.g. ``demod+beamform+bmode`` —
        the plan stamp, NDJSON field, and stage_fns key for the span."""
        return "+".join(self.stages)


# (variant value, modality value) -> {lowering name -> FusedLowering}
_FUSED_REGISTRY: Dict[Tuple[str, str], Dict[str, FusedLowering]] = {}


def _graph_order(modality: Modality) -> Tuple[str, ...]:
    # Mirrors stages.build_graph without importing it (stages imports us).
    return ("demod", "beamform", modality.value)


def register_fused_lowering(stages: Tuple[str, ...], name: str,
                            apply: Callable, *, variant: Variant,
                            modality: Modality,
                            available: Optional[Callable] = None,
                            precisions: Tuple[str, ...] = ("f32",)
                            ) -> FusedLowering:
    """Register (or replace) a fused lowering for one (variant, modality)."""
    if name not in LOWERING_NAMES:
        raise ValueError(f"unknown lowering name: {name!r} "
                         f"(expected one of {LOWERING_NAMES})")
    if not variant.concrete:
        raise ValueError("fused lowerings are scoped to concrete variants")
    _check_precisions(precisions)
    order = _graph_order(modality)
    stages = tuple(stages)
    runs = [tuple(order[i:i + len(stages)])
            for i in range(len(order) - len(stages) + 1)]
    if len(stages) < 2 or stages not in runs:
        raise ValueError(
            f"fused span {stages!r} is not a contiguous run (length >= 2) "
            f"of the {modality.value!r} graph {order!r}")
    fused = FusedLowering(stages=stages, name=name, apply=apply,
                          variant=variant, modality=modality,
                          available=available or _always,
                          precisions=tuple(precisions))
    key = (variant.value, modality.value)
    _FUSED_REGISTRY.setdefault(key, {})[name] = fused
    return fused


def registered_fused_lowerings(cfg: UltrasoundConfig
                               ) -> Dict[str, FusedLowering]:
    """Every fused lowering registered for (cfg.variant, cfg.modality)."""
    if not cfg.variant.concrete:
        return {}
    return dict(_FUSED_REGISTRY.get(
        (cfg.variant.value, cfg.modality.value), {}))


def resolve_fused(cfg: UltrasoundConfig, backend: str) -> FusedLowering:
    """THE fused lowering a ``fusion='fused'`` config executes, or a
    loud error naming exactly which gate failed (registration,
    precision, capability) — a fused request must run or fail at plan
    time, never silently fall back to per-stage dispatch."""
    cell = f"({cfg.variant.value}, {cfg.modality.value})"
    registered = registered_fused_lowerings(cfg)
    if not registered:
        raise ValueError(
            f"fusion='fused' but no fused lowering is registered for "
            f"{cell} — set fusion='none' or register one "
            "(repro.core.lowering.register_fused_lowering)")
    usable = {n: f for n, f in registered.items()
              if cfg.precision in f.precisions}
    if not usable:
        raise ValueError(
            f"no fused lowering for {cell} implements "
            f"precision={cfg.precision!r} "
            f"(registered: { {n: f.precisions for n, f in registered.items()} })")
    live = {n: f for n, f in usable.items() if f.available(cfg, backend)}
    if not live:
        raise ValueError(
            f"fused lowering(s) {sorted(usable)} for {cell} are "
            f"registered but not available on backend {backend!r} for "
            "this geometry (capability predicate failed — see "
            "docs/kernels.md for the tile constraints)")
    # One fused lowering per cell today; deterministic pick if extended.
    return live[sorted(live)[0]]


def fused_supported(cfg: UltrasoundConfig, backend: str) -> bool:
    """True iff ``resolve_fused`` would succeed (planner candidate
    filter — AUTO resolution must never land on a variant whose fused
    cell cannot run)."""
    try:
        resolve_fused(cfg, backend)
        return True
    except ValueError:
        return False


# ---------------------------------------------------------------------------
# Default registrations: the stage-op x lowering matrix
# ---------------------------------------------------------------------------


def _beamform_dynamic_pallas(cfg, consts, iq):
    """Fused DAS gather+lerp+rotate+reduce in one Pallas kernel
    (repro.kernels.das_beamform; docs/kernels.md has the tile contract)."""
    from repro.kernels.das_beamform import das_beamform
    return das_beamform(consts["idx"], consts["frac"], consts["apod"],
                        consts["rot"], iq, precision=cfg.precision)


def _beamform_sparse_pallas(cfg, consts, iq):
    """Banded BSR SpMM via the scalar-prefetched Pallas kernel — the
    paper's V3-on-TPU story (repro.kernels.bsr_spmm). The wrapper owns
    the IQ sample-axis blocking; the kernel owns the block gather."""
    from repro.kernels.bsr_spmm import bsr_beamform
    from repro.kernels.pallas_compat import block_sample_axis
    blocks = consts["bsr_blocks"]                       # (n_c,n_pb,K,bp,bs,2)
    cols = consts["bsr_col_idx"]                        # (n_c, n_pb, K)
    iq_b = block_sample_axis(iq, blocks.shape[4])
    return bsr_beamform(cols, blocks, iq_b,
                        precision=cfg.precision)[: cfg.n_pix]


def _fused_dynamic_bmode_pallas(cfg, consts, rf):
    """demod→DAS beamform→envelope in ONE Pallas megakernel, then the
    reference global epilogue (normalize + dB compression) — the fusion
    boundary documented in repro.kernels.fused_pipeline.kernel."""
    from repro.kernels.fused_pipeline import fused_rf_to_envelope
    env = fused_rf_to_envelope(
        consts["carrier"], consts["lpf"], consts["idx"], consts["frac"],
        consts["apod"], consts["rot"], rf, decim=cfg.decim,
        bp=cfg.fusion_block, precision=cfg.precision)
    return bmode.compress_envelope(cfg, env)


def _fused_dynamic_power_pallas(cfg, consts, rf):
    """demod→DAS beamform→wall filter→R0 in ONE Pallas megakernel, then
    the reference global epilogue (normalize + dB + spatial smooth)."""
    from repro.kernels.fused_pipeline import fused_rf_to_power
    r0 = fused_rf_to_power(
        consts["carrier"], consts["lpf"], consts["idx"], consts["frac"],
        consts["apod"], consts["rot"], consts["wall_taps"], rf,
        decim=cfg.decim, bp=cfg.fusion_block, precision=cfg.precision)
    return doppler.power_compress(cfg, consts, r0)


def _das_pallas_available(cfg: UltrasoundConfig, backend: str) -> bool:
    # The wrapper pads the pixel axis to the tile size and the kernel
    # declares no other hard shape constraint, so the fused DAS kernel
    # is available everywhere (interpret mode off-TPU).
    return True


def _bsr_pallas_available(cfg: UltrasoundConfig, backend: str) -> bool:
    # Interpret mode accepts any block shape; the compiled TPU kernel
    # feeds (bp x bs) blocks straight to the MXU, so sublane alignment
    # (the config's documented "MXU-aligned multiples of 8" contract —
    # the shipped defaults satisfy it) is a hard tile constraint.
    if backend != "tpu":
        return True
    return cfg.sparse_block_p % 8 == 0 and cfg.sparse_block_s % 8 == 0


def _register_defaults() -> None:
    register_lowering(
        "demod", "xla",
        lambda cfg, consts, rf: demod.rf_to_iq(consts, rf, cfg.decim))
    for variant, fn in beamform.BEAMFORMERS.items():
        # each beamformer already has the Lowering.apply signature
        register_lowering("beamform", "xla", fn, variant=variant)
    register_lowering("beamform", "pallas", _beamform_dynamic_pallas,
                      variant=Variant.DYNAMIC,
                      available=_das_pallas_available,
                      precisions=("f32", "bf16", "f16"))
    register_lowering("beamform", "pallas", _beamform_sparse_pallas,
                      variant=Variant.SPARSE,
                      available=_bsr_pallas_available,
                      precisions=("f32", "bf16", "f16"))
    register_lowering(
        "bmode", "xla",
        lambda cfg, consts, bf: bmode.bmode_image(cfg, bf))
    register_lowering(
        "doppler", "xla",
        lambda cfg, consts, bf:
            doppler.color_doppler_image(cfg, consts, bf))
    register_lowering(
        "power_doppler", "xla",
        lambda cfg, consts, bf:
            doppler.power_doppler_image(cfg, consts, bf))
    register_fused_lowering(
        ("demod", "beamform", "bmode"), "pallas",
        _fused_dynamic_bmode_pallas,
        variant=Variant.DYNAMIC, modality=Modality.BMODE,
        available=_das_pallas_available,
        precisions=("f32", "bf16", "f16"))
    register_fused_lowering(
        ("demod", "beamform", "power_doppler"), "pallas",
        _fused_dynamic_power_pallas,
        variant=Variant.DYNAMIC, modality=Modality.POWER_DOPPLER,
        available=_das_pallas_available,
        precisions=("f32", "bf16", "f16"))


_register_defaults()
