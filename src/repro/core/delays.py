"""Delay-and-sum geometry tables (precomputed at init, excluded from timing).

Plane-wave (0 deg) transmit, dynamic-aperture receive:

  tau(p, c) = ( z_p + sqrt(z_p^2 + (x_p - x_c)^2) ) / c_sound

The IQ-domain DAS interpolates the decimated IQ signal at s = tau * fs_iq and
applies the phase rotation exp(+j 2 pi f0 tau) to compensate demodulation.

All tables are numpy float32/int32; they are constants of the pipeline.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.core.config import UltrasoundConfig
from repro.core import geometry


@dataclasses.dataclass(frozen=True)
class DelayTables:
    """Per (pixel, channel) gather/interp/apodization/rotation constants.

    idx   : (n_pix, n_c) int32 — floor sample index into IQ axis (clamped)
    frac  : (n_pix, n_c) f32   — linear interpolation fraction in [0, 1)
    valid : (n_pix, n_c) f32   — 1.0 where the delay lands inside the trace
    apod  : (n_pix, n_c) f32   — dynamic-aperture Hann apodization (masked)
    rot   : (n_pix, n_c, 2) f32 — unit phasor exp(+j 2 pi f0 tau) as (re, im)
    """

    idx: np.ndarray
    frac: np.ndarray
    valid: np.ndarray
    apod: np.ndarray
    rot: np.ndarray


def compute_delay_tables(cfg: UltrasoundConfig) -> DelayTables:
    zp, xp = geometry.flat_grid(cfg)                       # (n_pix,)
    xc = geometry.element_positions(cfg)                   # (n_c,)

    # Two-way time of flight [s]: plane-wave transmit + receive path.
    dz = zp[:, None]                                       # (n_pix, 1)
    dx = xp[:, None] - xc[None, :]                         # (n_pix, n_c)
    tau = (dz + np.sqrt(dz * dz + dx * dx)) / cfg.c_sound  # (n_pix, n_c)

    # Fractional sample position in the decimated IQ trace.
    s = tau * cfg.fs_iq
    idx = np.floor(s).astype(np.int64)
    frac = (s - idx).astype(np.float32)
    valid = ((idx >= 0) & (idx < cfg.n_s - 1)).astype(np.float32)
    idx = np.clip(idx, 0, cfg.n_s - 2).astype(np.int32)

    # Dynamic receive aperture: accept elements with |dx| <= z / (2 F#),
    # tapered with a Hann window across the active aperture.
    half_aperture = dz / (2.0 * cfg.f_number)              # (n_pix, 1)
    rel = np.clip(np.abs(dx) / np.maximum(half_aperture, 1e-9), 0.0, 1.0)
    apod = (0.5 + 0.5 * np.cos(np.pi * rel)).astype(np.float32)
    apod *= (np.abs(dx) <= half_aperture).astype(np.float32)
    apod *= valid
    # Normalize so each pixel's weights sum to ~1 (keeps dynamic range flat).
    norm = apod.sum(axis=1, keepdims=True)
    apod = (apod / np.maximum(norm, 1e-9)).astype(np.float32)

    phase = 2.0 * np.pi * cfg.f0 * tau
    rot = np.stack([np.cos(phase), np.sin(phase)], axis=-1).astype(np.float32)

    return DelayTables(
        idx=idx,
        frac=frac,
        valid=valid,
        apod=apod,
        rot=rot,
    )


# ---------------------------------------------------------------------------
# Dense one-hot interpolation operator (V2 — Full CNN)
# ---------------------------------------------------------------------------


def interp_matrix(cfg: UltrasoundConfig, tables: DelayTables) -> np.ndarray:
    """Complex DAS operator as a dense (n_c, n_pix, n_s, 2) tensor.

    Row (c, p) has two nonzeros (linear interpolation) scaled by apodization
    and rotated by the steering phasor:

        M[c, p, s] = apod * rot * ((1-frac) [s == idx] + frac [s == idx+1])

    Applying it is a per-channel (n_pix x n_s) @ (n_s x n_f) complex matmul
    — i.e. a 1x1 convolution with n_s input channels and n_pix output
    channels, the canonical CNN re-expression of a gather (TINA-style).
    """
    n_pix, n_c, n_s = cfg.n_pix, cfg.n_c, cfg.n_s
    M = np.zeros((n_c, n_pix, n_s, 2), dtype=np.float32)
    rows = np.arange(n_pix)
    for c in range(n_c):
        w = tables.apod[:, c]
        re = tables.rot[:, c, 0] * w
        im = tables.rot[:, c, 1] * w
        i0 = tables.idx[:, c]
        f = tables.frac[:, c]
        # scatter-add the two interpolation taps (init-time numpy, untimed)
        np.add.at(M[c, :, :, 0], (rows, i0), re * (1.0 - f))
        np.add.at(M[c, :, :, 1], (rows, i0), im * (1.0 - f))
        np.add.at(M[c, :, :, 0], (rows, i0 + 1), re * f)
        np.add.at(M[c, :, :, 1], (rows, i0 + 1), im * f)
    return M


# ---------------------------------------------------------------------------
# Banded block-sparse operator (V3 — structured sparse, TPU-adapted)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BsrOperator:
    """Block-sparse row (BSR) form of the DAS operator, per channel.

    The delay profile s(p) is piecewise-smooth in the pixel index, so the
    (n_pix x n_s) operator is *banded*: each pixel-block of bp rows touches a
    bounded window of sample columns. We store, for every (channel,
    pixel-block), K sample-block indices plus the dense (bp x bs) blocks —
    a static-shape structure whose only irregularity is a *block-level*
    gather (the TPU adaptation of the paper's V3: gather granularity is
    raised to MXU-aligned tiles, matmuls stay dense).

    blocks  : (n_c, n_pb, K, bp, bs, 2) f32
    col_idx : (n_c, n_pb, K) int32 — sample-block column for each stored block
    """

    blocks: np.ndarray
    col_idx: np.ndarray
    bp: int
    bs: int
    nnz_ratio: float  # stored / dense block count (reported in benchmarks)


def bsr_operator(cfg: UltrasoundConfig, tables: DelayTables) -> BsrOperator:
    bp, bs = cfg.sparse_block_p, cfg.sparse_block_s
    n_pix, n_c, n_s = cfg.n_pix, cfg.n_c, cfg.n_s
    n_pb = (n_pix + bp - 1) // bp
    n_sb = (n_s + bs - 1) // bs
    pad_p, pad_s = n_pb * bp, n_sb * bs

    dense = interp_matrix(cfg, tables)  # (n_c, n_pix, n_s, 2)
    dense_p = np.zeros((n_c, pad_p, pad_s, 2), dtype=np.float32)
    dense_p[:, :n_pix, :n_s] = dense
    # (n_c, n_pb, bp, n_sb, bs, 2) block view
    blk = dense_p.reshape(n_c, n_pb, bp, n_sb, bs, 2)
    occupied = np.abs(blk).sum(axis=(2, 4, 5)) > 0  # (n_c, n_pb, n_sb)

    K = max(int(occupied.sum(axis=2).max()), 1)
    blocks = np.zeros((n_c, n_pb, K, bp, bs, 2), dtype=np.float32)
    col_idx = np.zeros((n_c, n_pb, K), dtype=np.int32)
    for c in range(n_c):
        for i in range(n_pb):
            cols = np.nonzero(occupied[c, i])[0]
            for k, sb in enumerate(cols):
                blocks[c, i, k] = blk[c, i, :, sb]
                col_idx[c, i, k] = sb
            # unused K-slots keep col 0 with all-zero data (contribute 0)

    nnz_ratio = float(occupied.sum()) / float(n_c * n_pb * n_sb)
    return BsrOperator(blocks=blocks, col_idx=col_idx, bp=bp, bs=bs,
                       nnz_ratio=nnz_ratio)
