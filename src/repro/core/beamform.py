"""Delay-and-sum beamforming — the paper's three implementation variants.

All variants compute the same math (validated to allclose in
tests/test_beamform_variants.py):

    y[p, f] = sum_c apod[p,c] * rot[p,c] * lerp(IQ[:, c, f], s[p,c])

V1 DYNAMIC — per-channel gather (take) + pointwise lerp. The irregular
    memory access pattern the paper shows is fast on GPU, slow on TPU.
V2 CNN     — the gather folded into a precomputed one-hot interpolation
    operator; the whole beamform is a per-channel dense complex matmul
    (a 1x1 conv), which maps onto the MXU.
V3 SPARSE  — the same operator in banded block-sparse (BSR) form; dense
    MXU tiles over the nonzero band, irregularity confined to a
    *block-level* gather (TPU adaptation of the paper's sparse variant;
    the paper could not run V3 on TPU at all).

Input : IQ (n_s, n_c, n_f, 2)
Output: beamformed (n_pix, n_f, 2)
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core import cnn_ops
from repro.core.config import UltrasoundConfig, Variant


# ---------------------------------------------------------------------------
# V1 — dynamic indexing
# ---------------------------------------------------------------------------


def beamform_dynamic(cfg: UltrasoundConfig, consts: Dict[str, jnp.ndarray],
                     iq: jnp.ndarray) -> jnp.ndarray:
    idx, frac = consts["idx"], consts["frac"]            # (n_pix, n_c)
    apod, rot = consts["apod"], consts["rot"]            # (..., 2)

    iq_c = iq.transpose(1, 0, 2, 3)                      # (n_c, n_s, n_f, 2)

    def one_channel(iq_1, idx_1, frac_1, apod_1, rot_1):
        s0 = jnp.take(iq_1, idx_1, axis=0)               # (n_pix, n_f, 2)
        s1 = jnp.take(iq_1, idx_1 + 1, axis=0)
        f = frac_1[:, None, None]
        v = s0 * (1.0 - f) + s1 * f
        v = cnn_ops.cmul(v, rot_1[:, None, :])
        return v * apod_1[:, None, None]

    per_c = jax.vmap(one_channel, in_axes=(0, 1, 1, 1, 1))(
        iq_c, idx, frac, apod, rot)                      # (n_c, n_pix, n_f, 2)
    return per_c.sum(axis=0)


# ---------------------------------------------------------------------------
# V2 — full CNN (one-hot interpolation matmul)
# ---------------------------------------------------------------------------


def beamform_cnn(cfg: UltrasoundConfig, consts: Dict[str, jnp.ndarray],
                 iq: jnp.ndarray) -> jnp.ndarray:
    M = consts["interp_matrix"]                          # (n_c, n_pix, n_s, 2)
    # Two real einsums realize the complex matmul; each is a stack of
    # per-channel (n_pix x n_s) @ (n_s x n_f) matmuls == 1x1 convolutions.
    a = jnp.einsum("cps,scfr->pfr", M[..., 0], iq)       # M_re * (IQre, IQim)
    b = jnp.einsum("cps,scfr->pfr", M[..., 1], iq)       # M_im * (IQre, IQim)
    return cnn_ops.cpack(a[..., 0] - b[..., 1], a[..., 1] + b[..., 0])


# ---------------------------------------------------------------------------
# V3 — structured block-sparse
# ---------------------------------------------------------------------------


def beamform_sparse(cfg: UltrasoundConfig, consts: Dict[str, jnp.ndarray],
                    iq: jnp.ndarray) -> jnp.ndarray:
    blocks = consts["bsr_blocks"]                        # (n_c,n_pb,K,bp,bs,2)
    col_idx = consts["bsr_col_idx"]                      # (n_c, n_pb, K)
    n_c, n_pb, K, bp, bs, _ = blocks.shape
    n_s, _, n_f, _ = iq.shape
    n_sb = -(-n_s // bs)

    pad = n_sb * bs - n_s
    iq_p = jnp.pad(iq, ((0, pad), (0, 0), (0, 0), (0, 0)))
    iq_b = iq_p.reshape(n_sb, bs, n_c, n_f, 2)           # blocked IQ

    def one_channel(blocks_1, cols_1, iq_1):
        # iq_1: (n_sb, bs, n_f, 2); cols_1: (n_pb, K)
        g = jnp.take(iq_1, cols_1, axis=0)               # (n_pb, K, bs, n_f, 2)
        a = jnp.einsum("ikps,iksfr->ipfr", blocks_1[..., 0], g)
        b = jnp.einsum("ikps,iksfr->ipfr", blocks_1[..., 1], g)
        return cnn_ops.cpack(a[..., 0] - b[..., 1], a[..., 1] + b[..., 0])

    per_c = jax.vmap(one_channel, in_axes=(0, 0, 2))(
        blocks, col_idx, iq_b)                           # (n_c, n_pb, bp, n_f, 2)
    y = per_c.sum(axis=0).reshape(n_pb * bp, n_f, 2)
    return y[: cfg.n_pix]


# ---------------------------------------------------------------------------


# The XLA formulations per variant — each is also registered as the
# "xla" lowering of the beamform stage op (repro.core.lowering); the
# Pallas lowerings of DYNAMIC (kernels/das_beamform) and SPARSE
# (kernels/bsr_spmm) live in the registry, selected per plan.
BEAMFORMERS = {
    Variant.DYNAMIC: beamform_dynamic,
    Variant.CNN: beamform_cnn,
    Variant.SPARSE: beamform_sparse,
}


def beamform(cfg: UltrasoundConfig, consts: Dict[str, jnp.ndarray],
             iq: jnp.ndarray) -> jnp.ndarray:
    """Pure-XLA beamform dispatch (the monolithic oracle's reference
    path — lowering-aware execution goes through the stage graph)."""
    return BEAMFORMERS[cfg.variant](cfg, consts, iq)
