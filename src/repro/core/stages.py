"""Stage graph for the RF-to-image pipelines (execution substrate).

The pipeline is declared as an ordered graph of named stages

    demod -> beamform -> {bmode | doppler | power_doppler}

Each stage exposes two pure functions:

  * ``init_consts(cfg)``  — precompute that stage's constants (numpy,
    untimed, deterministic; the paper's §II-C module-initialization
    contract, now attributable per stage), and
  * ``apply(cfg, consts, x)`` — the stage's runtime transform. ``consts``
    is the *merged* graph constant dict so stages stay composable with
    the legacy monolithic function signature.

`graph_fn(cfg)` composes the stages back into the monolithic
(consts, rf) -> image function — same jaxpr as the legacy monolith when
every stage runs its ``xla`` lowering, so jit/pjit callers are
unchanged — while `stage_fns(cfg)` returns each stage as its own
(consts, x) -> y callable so stages can be jitted and timed
individually (per-stage telemetry, §II-E breakdown).

Each stage's runtime transform dispatches through the per-stage
operator-lowering registry (repro.core.lowering): the lowering named in
``cfg.stage_lowerings`` (plan-resolved) executes; stages left
unspecified run the ``xla`` reference formulation.

When ``cfg.fusion == "fused"`` the composition routes the registered
fused lowering's stage SPAN through its single apply (the megakernel)
and composes the remaining stages around it; ``stage_fns`` then exposes
the span under its fusion-group key (e.g. ``demod+beamform+bmode``) so
the per-stage telemetry and the bench breakdown never pretend the fused
stages were timed individually.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import numpy as np

import jax.numpy as jnp

from repro.core import delays, demod, doppler, lowering
from repro.core.config import Modality, UltrasoundConfig, Variant


@dataclasses.dataclass(frozen=True)
class Stage:
    """One named node of the pipeline graph."""

    name: str
    init_consts: Callable[[UltrasoundConfig], Dict[str, np.ndarray]]
    apply: Callable[[UltrasoundConfig, Dict, jnp.ndarray], jnp.ndarray]


# ---------------------------------------------------------------------------
# Stage definitions
# ---------------------------------------------------------------------------


def _dispatch(stage_name):
    """Bind a stage's apply to the lowering registry at call time, so a
    plan-resolved ``cfg.stage_lowerings`` decides which formulation
    traces (xla reference or Pallas kernel)."""
    return (lambda cfg, consts, x:
            lowering.apply_stage(cfg, stage_name, consts, x))


def _beamform_consts(cfg: UltrasoundConfig) -> Dict[str, np.ndarray]:
    if not cfg.variant.concrete:
        raise ValueError(
            "Variant.AUTO has no constants — resolve it with "
            "repro.core.plan.plan_pipeline before building the graph")
    consts: Dict[str, np.ndarray] = {}
    tables = delays.compute_delay_tables(cfg)
    if cfg.variant == Variant.DYNAMIC:
        consts.update(idx=tables.idx, frac=tables.frac,
                      apod=tables.apod, rot=tables.rot)
    elif cfg.variant == Variant.CNN:
        consts["interp_matrix"] = delays.interp_matrix(cfg, tables)
    elif cfg.variant == Variant.SPARSE:
        op = delays.bsr_operator(cfg, tables)
        consts["bsr_blocks"] = op.blocks
        consts["bsr_col_idx"] = op.col_idx
    else:  # pragma: no cover
        raise ValueError(cfg.variant)
    return consts


def _doppler_consts(cfg: UltrasoundConfig) -> Dict[str, np.ndarray]:
    return {"wall_taps": doppler.wall_filter_taps(cfg),
            "smooth": doppler.smoothing_kernel(cfg)}


DEMOD = Stage("demod", lambda cfg: dict(demod.demod_consts(cfg)),
              _dispatch("demod"))

BEAMFORM = Stage("beamform", _beamform_consts, _dispatch("beamform"))

HEADS: Dict[Modality, Stage] = {
    Modality.BMODE: Stage("bmode", lambda cfg: {}, _dispatch("bmode")),
    Modality.DOPPLER: Stage("doppler", _doppler_consts,
                            _dispatch("doppler")),
    Modality.POWER_DOPPLER: Stage("power_doppler", _doppler_consts,
                                  _dispatch("power_doppler")),
}


# ---------------------------------------------------------------------------
# Graph construction / composition
# ---------------------------------------------------------------------------


def build_graph(cfg: UltrasoundConfig) -> Tuple[Stage, ...]:
    """Ordered stage graph for the configured modality."""
    if cfg.modality not in HEADS:  # pragma: no cover
        raise ValueError(cfg.modality)
    return (DEMOD, BEAMFORM, HEADS[cfg.modality])


def init_graph_consts(cfg: UltrasoundConfig) -> Dict[str, np.ndarray]:
    """Merged constants of every stage (untimed, deterministic)."""
    consts: Dict[str, np.ndarray] = {}
    for stage in build_graph(cfg):
        news = stage.init_consts(cfg)
        dup = set(news) & set(consts)
        assert not dup, f"stage {stage.name} redefines consts {dup}"
        consts.update(news)
    return consts


def _fused_span(cfg: UltrasoundConfig):
    """The FusedLowering a ``fusion='fused'`` config routes through
    (None for ``fusion='none'``). Resolution is loud: a fused request
    with no runnable registration raises here rather than silently
    composing per-stage."""
    if cfg.fusion != "fused":
        return None
    import jax
    return lowering.resolve_fused(cfg, jax.default_backend())


def _split_span(stages: Tuple[Stage, ...], fused):
    """(prefix stages, suffix stages) around the fused lowering's span."""
    names = [stage.name for stage in stages]
    i0 = names.index(fused.stages[0])
    assert tuple(names[i0:i0 + len(fused.stages)]) == fused.stages, (
        names, fused.stages)  # registration validated contiguity
    return stages[:i0], stages[i0 + len(fused.stages):]


def graph_fn(cfg: UltrasoundConfig) -> Callable:
    """Pure (consts, rf) -> image composition of the stage graph."""
    stages = build_graph(cfg)
    fused = _fused_span(cfg)
    if fused is None:
        def run(consts, rf):
            x = rf
            for stage in stages:
                x = stage.apply(cfg, consts, x)
            return x
        return run

    prefix, suffix = _split_span(stages, fused)

    def run_fused(consts, rf):
        x = rf
        for stage in prefix:
            x = stage.apply(cfg, consts, x)
        x = fused.apply(cfg, consts, x)
        for stage in suffix:
            x = stage.apply(cfg, consts, x)
        return x

    return run_fused


def stage_fns(cfg: UltrasoundConfig) -> Dict[str, Callable]:
    """Each schedulable unit as its own jittable (consts, x) -> y callable.

    Insertion order is execution order (bench_stages chains the dict).
    Under ``fusion='fused'`` the spanned stages collapse into ONE entry
    keyed by the fusion group (``'+'.join(span)``) — the megakernel is
    the timeable unit; its interior stages have no individual timings.
    """
    def bind(stage):
        return lambda consts, x: stage.apply(cfg, consts, x)

    stages = build_graph(cfg)
    fused = _fused_span(cfg)
    if fused is None:
        return {stage.name: bind(stage) for stage in stages}

    prefix, suffix = _split_span(stages, fused)
    fns: Dict[str, Callable] = {stage.name: bind(stage) for stage in prefix}
    fns[fused.group] = lambda consts, x: fused.apply(cfg, consts, x)
    fns.update({stage.name: bind(stage) for stage in suffix})
    return fns
