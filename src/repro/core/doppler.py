"""Doppler heads: Color (lag-1 autocorrelation) and Power Doppler.

Color Doppler (Kasai autocorrelator):
  RF -> IQ -> beamformed IQ ensemble -> wall filter (FIR along frames) ->
  R1 = sum_f z[f+1] conj(z[f]) -> v = atan2(Im R1, Re R1) -> spatial smooth.

Power Doppler:
  same front end -> R0 = sum_f |z[f]|^2 -> 10 log10 -> dynamic range scale.

Every stage is pointwise arithmetic, a fixed FIR conv, or a reduction.
The atan2/log10 use the CNN-expressible approximations when
cfg.cnn_transcendentals is set (paper §II-C, §VII).
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp
from jax import lax

from repro.core import cnn_ops
from repro.core.config import UltrasoundConfig


def wall_filter_taps(cfg: UltrasoundConfig) -> np.ndarray:
    """Binomial high-pass FIR: (n-1)-fold convolution of [1, -1].

    A standard static clutter filter: removes the DC/slow (tissue) component
    of the slow-time signal before velocity estimation.
    """
    taps = np.array([1.0], dtype=np.float64)
    for _ in range(max(cfg.wall_filter_taps - 1, 1)):
        taps = np.convolve(taps, [1.0, -1.0])
    # Normalize to unit l2 gain at Nyquist.
    taps /= np.sqrt((taps ** 2).sum())
    return taps.astype(np.float32)


def smoothing_kernel(cfg: UltrasoundConfig) -> np.ndarray:
    k = cfg.smooth_kernel
    return np.full((k, k), 1.0 / (k * k), dtype=np.float32)


def apply_wall_filter(consts, bf: jnp.ndarray) -> jnp.ndarray:
    """(n_pix, n_f, 2) -> (n_pix, n_f', 2) FIR high-pass along frames.

    Explicitly ordered shift-and-add rather than lax.conv, for the same
    reason as demod.rf_to_iq: XLA:CPU conv codegen is context-dependent
    (1-ulp drift inside loop bodies / pallas grids), and the wall filter
    is the pipeline's most cancellation-amplified stage — the high-pass
    residual is orders of magnitude below the partial sums, so a 1-ulp
    upstream difference is visible in the final image. Pinning the tap
    order keeps it bit-identical in every execution context.
    """
    taps = consts["wall_taps"]                        # (k,)
    k = taps.shape[0]
    n_fp = bf.shape[1] - k + 1                        # VALID along frames
    acc = jnp.zeros(bf.shape[:1] + (n_fp, 2), jnp.float32)
    for t in range(k):  # static unroll; ascending tap order is the contract
        acc = acc + taps[t] * bf[:, t:t + n_fp, :]
    return acc


def _smooth(cfg: UltrasoundConfig, consts, img: jnp.ndarray) -> jnp.ndarray:
    """(nz, nx) -> (nz, nx) box smoothing, SAME padding (a real 2-D conv)."""
    k = consts["smooth"]                              # (k, k)
    x = img[None, None, :, :]
    out = lax.conv_general_dilated(
        x, k[None, None, :, :], window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return out[0, 0]


def color_doppler_image(cfg: UltrasoundConfig, consts,
                        bf: jnp.ndarray) -> jnp.ndarray:
    """(n_pix, n_f, 2) -> (nz, nx) velocity map, normalized to [-1, 1]."""
    z = apply_wall_filter(consts, bf)                 # (n_pix, n_f', 2)
    z0, z1 = z[:, :-1], z[:, 1:]
    # R1 = sum_f z1 * conj(z0): pointwise products + frame reduction.
    re = (z1[..., 0] * z0[..., 0] + z1[..., 1] * z0[..., 1]).sum(axis=1)
    im = (z1[..., 1] * z0[..., 0] - z1[..., 0] * z0[..., 1]).sum(axis=1)
    if cfg.cnn_transcendentals:
        phase = cnn_ops.atan2_approx(im, re)
    else:
        phase = jnp.arctan2(im, re)
    v = phase / np.pi                                 # Nyquist-normalized
    return _smooth(cfg, consts, v.reshape(cfg.nz, cfg.nx))


def power_from_ensemble(consts, bf: jnp.ndarray) -> jnp.ndarray:
    """(n_pix, n_f, 2) -> (n_pix,) wall-filtered power R0.

    The tile-local half of the power-doppler head (per-pixel FIR along
    frames + per-pixel frame reduction) — the part the fused megakernel
    computes on tile-resident beamformed IQ.
    """
    z = apply_wall_filter(consts, bf)
    return cnn_ops.cabs2(z).sum(axis=1)               # (n_pix,)


def power_compress(cfg: UltrasoundConfig, consts,
                   r0: jnp.ndarray) -> jnp.ndarray:
    """(n_pix,) R0 -> (nz, nx) power map in [0, 1].

    The global half: normalize_by_max over all pixels plus the SAME-conv
    spatial smooth — the fused lowering's fusion boundary, shared
    verbatim with the monolithic reference (see bmode.compress_envelope
    for the contract rationale).
    """
    r0 = cnn_ops.normalize_by_max(r0)
    if cfg.cnn_transcendentals:
        db = 10.0 * cnn_ops.log10_approx(r0)
    else:
        db = 10.0 * jnp.log10(jnp.maximum(r0, 1e-30))
    dr = cfg.dynamic_range_db
    img = (cnn_ops.clip(db, -dr, 0.0) + dr) / dr
    return _smooth(cfg, consts, img.reshape(cfg.nz, cfg.nx))


def power_doppler_image(cfg: UltrasoundConfig, consts,
                        bf: jnp.ndarray) -> jnp.ndarray:
    """(n_pix, n_f, 2) -> (nz, nx) power map in [0, 1]."""
    return power_compress(cfg, consts, power_from_ensemble(consts, bf))
