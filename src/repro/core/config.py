"""Configuration for the deterministic ultrasound pipelines.

Everything geometry-dependent is *precomputed at module initialization* and
excluded from timing, per the paper's §II-C ("Operator Constraints and
Determinism"). The config is a frozen dataclass so pipelines are fully
reproducible from the config alone.

Default geometry reproduces the paper's fixed input size of 5.472 MB per
forward pass: int16 RF of shape (n_l=1336, n_c=64, n_f=32)
= 1336*64*32*2 bytes = 5,472,256 bytes.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
import warnings
from typing import Collection, Mapping, Tuple


class Variant(str, enum.Enum):
    """Paper §II-B implementation variants.

    DYNAMIC - V1: explicit gather / dynamic indexing.
    CNN     - V2: convolutions, pointwise ops, matmuls (1x1 convs), reductions.
    SPARSE  - V3: structured (block-) sparse matrices.
    AUTO    - planner placeholder: resolved to one of the above per backend
              by ``repro.core.plan.plan_pipeline`` before any consts are
              built or code is compiled. Never executable directly.
    """

    DYNAMIC = "dynamic"
    CNN = "cnn"
    SPARSE = "sparse"
    AUTO = "auto"

    @property
    def concrete(self) -> bool:
        return self is not Variant.AUTO


class Modality(str, enum.Enum):
    """Paper §II-A pipeline modalities."""

    BMODE = "bmode"
    DOPPLER = "doppler"
    POWER_DOPPLER = "power_doppler"


# Batch-mapping strategies the executors accept (config.exec_map).
EXEC_MAPS = ("vmap", "map")

# Stage names of the pipeline graph (repro.core.stages builds it) and the
# operator lowerings each stage op may register (repro.core.lowering).
# Declared here — not in stages/lowering — so config stays import-root.
STAGE_NAMES = ("demod", "beamform", "bmode", "doppler", "power_doppler")
LOWERING_NAMES = ("xla", "pallas")

# Paper table names, e.g. RF2IQ_DAS_BMODE.
PIPELINE_NAMES = {
    Modality.BMODE: "RF2IQ_DAS_BMODE",
    Modality.DOPPLER: "RF2IQ_DAS_DOPPLER",
    Modality.POWER_DOPPLER: "RF2IQ_DAS_POWERDOPPLER",
}


@dataclasses.dataclass(frozen=True)
class UltrasoundConfig:
    """Full configuration of an RF-to-image pipeline."""

    # --- acquisition ----------------------------------------------------
    n_l: int = 1336          # axial RF samples per channel
    n_c: int = 64            # receive channels (array elements)
    n_f: int = 32            # temporal frames per forward pass
    fs: float = 20e6         # RF sampling frequency [Hz]
    f0: float = 5e6          # probe center frequency [Hz]
    c_sound: float = 1540.0  # speed of sound [m/s]
    prf: float = 4000.0      # pulse repetition frequency [Hz] (Doppler scale)
    pitch: float = 3.08e-4   # element pitch [m] (lambda at 5 MHz)
    rf_dtype: str = "int16"  # raw RF on the wire

    # --- demodulation (RF -> IQ) ----------------------------------------
    decim: int = 4           # decimation factor; fs_iq = fs / decim
    lpf_taps: int = 31       # FIR low-pass length (odd)
    lpf_cutoff: float = 0.5  # cutoff as a fraction of f0

    # --- image grid ------------------------------------------------------
    nz: int = 128            # axial pixels
    nx: int = 128            # lateral pixels
    z_min: float = 5e-3      # [m]
    z_max: float = 45e-3     # [m]
    f_number: float = 1.5    # dynamic receive aperture

    # --- processing ------------------------------------------------------
    modality: Modality = Modality.BMODE
    variant: Variant = Variant.CNN
    dynamic_range_db: float = 60.0  # B-mode compression range
    wall_filter_taps: int = 4       # Doppler clutter filter length
    smooth_kernel: int = 3          # Doppler spatial smoothing (square)

    # --- sparse (V3) block structure -------------------------------------
    sparse_block_p: int = 64  # pixel-block rows (MXU-aligned multiples of 8)
    sparse_block_s: int = 64  # sample-block cols

    # --- numerics ---------------------------------------------------------
    # When True, transcendental ops (atan2, log10) use the CNN-expressible
    # bounded-error approximations from cnn_ops; when False, jnp natives.
    # The CNN variant always uses approximations (portability contract).
    cnn_transcendentals: bool = True

    # --- operator lowerings ------------------------------------------------
    # Explicit per-stage lowering overrides: a mapping (or pair tuple) of
    # stage name -> lowering name, e.g. {"beamform": "pallas"}. Stages left
    # unspecified are resolved by the planner (repro.core.plan) through the
    # per-stage lowering registry (repro.core.lowering) — preference table
    # or per-stage autotune — and `plan.concretize(cfg)` writes the resolved
    # mapping back here, so the executed config (and its canonical hash,
    # which groups multi-tenant streams) always names its lowerings.
    # Normalized to a sorted tuple of pairs at construction.
    stage_lowerings: Tuple[Tuple[str, str], ...] = ()

    # DEPRECATED alias for stage_lowerings={"beamform": "pallas"} (the fused
    # DAS Pallas kernel). Normalized away at construction — the field is
    # always False afterwards, so it never reaches the canonical hash.
    use_das_kernel: bool = False

    # --- batched execution (stage-graph engine) ---------------------------
    # How the Batched/Sharded executors map the stage graph over the
    # leading acquisition-batch axis: "vmap" vectorizes (one fused
    # program, peak memory scales with batch), "map" sequentializes via
    # lax.map (constant memory, serial latency). Validated at
    # construction so a typo fails before any planning or compilation.
    exec_map: str = "vmap"

    def __post_init__(self):
        if self.exec_map not in EXEC_MAPS:
            raise ValueError(
                f"unknown exec_map: {self.exec_map!r} "
                f"(expected one of {EXEC_MAPS})")
        lowerings = self.stage_lowerings
        if isinstance(lowerings, Mapping):
            lowerings = tuple(lowerings.items())
        lowerings = {stage: name for stage, name in lowerings}
        if self.use_das_kernel:
            # The legacy flag was read only by the dynamic beamformer, so
            # the alias applies to DYNAMIC (and to AUTO, which the planner
            # then restricts to pin-honoring variants); on CNN/SPARSE it
            # was — and stays — a no-op, now a loud one. Normalized away
            # in every case so the canonical hash matches the
            # explicit-stage_lowerings config.
            if self.variant in (Variant.DYNAMIC, Variant.AUTO):
                warnings.warn(
                    "UltrasoundConfig.use_das_kernel is deprecated; use "
                    "stage_lowerings={'beamform': 'pallas'}",
                    DeprecationWarning, stacklevel=3)
                lowerings.setdefault("beamform", "pallas")
            else:
                warnings.warn(
                    "UltrasoundConfig.use_das_kernel is deprecated and "
                    f"ignored for variant={self.variant.value!r} (the "
                    "fused DAS kernel lowers only the dynamic beamform); "
                    "use stage_lowerings={'beamform': 'pallas'} on a "
                    "dynamic config", DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "use_das_kernel", False)
        for stage, name in lowerings.items():
            if stage not in STAGE_NAMES:
                raise ValueError(
                    f"unknown stage in stage_lowerings: {stage!r} "
                    f"(expected one of {STAGE_NAMES})")
            if name not in LOWERING_NAMES:
                raise ValueError(
                    f"unknown lowering for stage {stage!r}: {name!r} "
                    f"(expected one of {LOWERING_NAMES})")
        object.__setattr__(self, "stage_lowerings",
                           tuple(sorted(lowerings.items())))

    def stage_lowering(self, stage: str, default: str = "xla") -> str:
        """The lowering this config requests for ``stage`` (or default)."""
        return dict(self.stage_lowerings).get(stage, default)

    # ---------------------------------------------------------------------
    @property
    def fs_iq(self) -> float:
        return self.fs / self.decim

    @property
    def n_s(self) -> int:
        """IQ samples per channel after decimation."""
        return self.n_l // self.decim

    @property
    def n_pix(self) -> int:
        return self.nz * self.nx

    @property
    def rf_shape(self) -> Tuple[int, int, int]:
        return (self.n_l, self.n_c, self.n_f)

    @property
    def input_bytes(self) -> int:
        """B_in for the throughput metric (paper eq. 2)."""
        itemsize = 2 if self.rf_dtype == "int16" else 4
        return self.n_l * self.n_c * self.n_f * itemsize

    @property
    def name(self) -> str:
        return PIPELINE_NAMES[self.modality]

    def with_(self, **kwargs) -> "UltrasoundConfig":
        return dataclasses.replace(self, **kwargs)

    def canonical_hash(self, exclude: Collection[str] = ()) -> str:
        return config_hash(self, exclude=exclude)


# Bump when the meaning of a config field (and hence of any artifact keyed
# on the hash — consts cache entries, autotune memos) changes incompatibly.
# v2: stage_lowerings joined the config (use_das_kernel normalized away).
CONFIG_HASH_SCHEMA = "ultrasound-cfg-v2"


def config_hash(cfg: UltrasoundConfig, *,
                exclude: Collection[str] = ()) -> str:
    """Canonical content hash of a config (hex, 16 chars).

    Every dataclass field participates unless listed in ``exclude``
    (e.g. the planner memoizes autotune results per config *ignoring*
    ``variant``, the axis it searches over). Enum fields serialize as
    their string values and floats via repr, so the hash is stable
    across processes — it keys the on-disk constants cache.
    """
    d = dataclasses.asdict(cfg)
    for name in exclude:
        if name not in d:
            raise KeyError(f"unknown config field: {name!r}")
        del d[name]
    payload = json.dumps([CONFIG_HASH_SCHEMA, d], sort_keys=True,
                         default=lambda o: o.value)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def paper_config(**overrides) -> UltrasoundConfig:
    """The paper's benchmark geometry: 5.472 MB int16 RF per forward pass."""
    cfg = UltrasoundConfig()
    assert cfg.input_bytes == 5_472_256
    return cfg.with_(**overrides) if overrides else cfg


def tiny_config(**overrides) -> UltrasoundConfig:
    """Reduced geometry for unit tests: same structure, ~1000x smaller.

    n_l=512 records ~19.7 mm of depth at fs=20 MHz; the grid stays inside
    that coverage so every pixel has valid delays.
    """
    cfg = UltrasoundConfig(
        n_l=512, n_c=8, n_f=4, nz=24, nx=16,
        z_min=4e-3, z_max=16e-3, lpf_taps=15,
        sparse_block_p=16, sparse_block_s=16,
    )
    return cfg.with_(**overrides) if overrides else cfg
