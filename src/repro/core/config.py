"""Configuration for the deterministic ultrasound pipelines.

Everything geometry-dependent is *precomputed at module initialization* and
excluded from timing, per the paper's §II-C ("Operator Constraints and
Determinism"). The config is a frozen dataclass so pipelines are fully
reproducible from the config alone.

Default geometry reproduces the paper's fixed input size of 5.472 MB per
forward pass: int16 RF of shape (n_l=1336, n_c=64, n_f=32)
= 1336*64*32*2 bytes = 5,472,256 bytes.
"""

from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
import warnings
from typing import Collection, Mapping, Optional, Tuple


class Variant(str, enum.Enum):
    """Paper §II-B implementation variants.

    DYNAMIC - V1: explicit gather / dynamic indexing.
    CNN     - V2: convolutions, pointwise ops, matmuls (1x1 convs), reductions.
    SPARSE  - V3: structured (block-) sparse matrices.
    AUTO    - planner placeholder: resolved to one of the above per backend
              by ``repro.core.plan.plan_pipeline`` before any consts are
              built or code is compiled. Never executable directly.
    """

    DYNAMIC = "dynamic"
    CNN = "cnn"
    SPARSE = "sparse"
    AUTO = "auto"

    @property
    def concrete(self) -> bool:
        return self is not Variant.AUTO


class Modality(str, enum.Enum):
    """Paper §II-A pipeline modalities."""

    BMODE = "bmode"
    DOPPLER = "doppler"
    POWER_DOPPLER = "power_doppler"


# Batch-mapping strategies the executors accept (config.exec_map).
EXEC_MAPS = ("vmap", "map")

# Stage names of the pipeline graph (repro.core.stages builds it) and the
# operator lowerings each stage op may register (repro.core.lowering).
# Declared here — not in stages/lowering — so config stays import-root.
STAGE_NAMES = ("demod", "beamform", "bmode", "doppler", "power_doppler")
LOWERING_NAMES = ("xla", "pallas")

# Fusion modes: "none" dispatches per stage through the lowering registry;
# "fused" asks the planner for a registered fused lowering spanning a
# contiguous stage group (repro.core.lowering.FusedLowering) and refuses
# loudly when no span covers this (variant, modality, precision).
FUSION_NAMES = ("none", "fused")

# Compute precisions for kernel lowerings. "f32" is the determinism
# contract's reference: every lowering of one op is bit-compatible at the
# per-stage contraction level and tracks the xla reference to <=1e-5 at
# image level (bit-exact for bmode/power_doppler at test geometry).
# "bf16"/"f16" request reduced-precision *matmul operands* with f32
# accumulation (preferred_element_type=f32) inside kernels that implement
# them; pointwise math stays f32. The xla reference formulations compute
# in f32 only, so reduced precision is satisfiable only where a Pallas
# (fused) kernel registers it — the planner enforces this.
PRECISION_NAMES = ("f32", "bf16", "f16")

# The documented determinism/tolerance contract, per (precision, modality):
# (rtol, atol) bounds on the final image vs. the f32 monolithic oracle,
# enforced against the golden fixtures by tests/test_fused_pipeline.py.
# f32 is exact (allclose at 0 tolerance == array_equal). The reduced
# precision bounds are calibrated empirically at test geometry and carry
# ~4x headroom; images are normalized to O(1) ranges so atol and rtol act
# on comparable scales. bf16 (8-bit mantissa) is looser than f16 (11-bit)
# — the dots accumulate in f32 either way, so the error is operand
# rounding, not accumulation drift.
PRECISION_TOLERANCES = {
    ("f32", Modality.BMODE): (0.0, 0.0),
    ("f32", Modality.POWER_DOPPLER): (0.0, 0.0),
    ("bf16", Modality.BMODE): (7.5e-2, 7.5e-2),
    ("bf16", Modality.POWER_DOPPLER): (1.5e-1, 1.5e-1),
    ("f16", Modality.BMODE): (5e-3, 5e-3),
    ("f16", Modality.POWER_DOPPLER): (2.5e-2, 2.5e-2),
}


def precision_tolerance(precision: str, modality: "Modality"):
    """(rtol, atol) image-level bound for a (precision, modality) cell.

    Raises KeyError for cells outside the documented contract (e.g. no
    fused lowering registers the color-doppler head yet).
    """
    return PRECISION_TOLERANCES[(precision, modality)]

# Paper table names, e.g. RF2IQ_DAS_BMODE.
PIPELINE_NAMES = {
    Modality.BMODE: "RF2IQ_DAS_BMODE",
    Modality.DOPPLER: "RF2IQ_DAS_DOPPLER",
    Modality.POWER_DOPPLER: "RF2IQ_DAS_POWERDOPPLER",
}


@dataclasses.dataclass(frozen=True)
class UltrasoundConfig:
    """Full configuration of an RF-to-image pipeline."""

    # --- acquisition ----------------------------------------------------
    n_l: int = 1336          # axial RF samples per channel
    n_c: int = 64            # receive channels (array elements)
    n_f: int = 32            # temporal frames per forward pass
    fs: float = 20e6         # RF sampling frequency [Hz]
    f0: float = 5e6          # probe center frequency [Hz]
    c_sound: float = 1540.0  # speed of sound [m/s]
    prf: float = 4000.0      # pulse repetition frequency [Hz] (Doppler scale)
    pitch: float = 3.08e-4   # element pitch [m] (lambda at 5 MHz)
    rf_dtype: str = "int16"  # raw RF on the wire

    # --- demodulation (RF -> IQ) ----------------------------------------
    decim: int = 4           # decimation factor; fs_iq = fs / decim
    lpf_taps: int = 31       # FIR low-pass length (odd)
    lpf_cutoff: float = 0.5  # cutoff as a fraction of f0

    # --- image grid ------------------------------------------------------
    nz: int = 128            # axial pixels
    nx: int = 128            # lateral pixels
    z_min: float = 5e-3      # [m]
    z_max: float = 45e-3     # [m]
    f_number: float = 1.5    # dynamic receive aperture

    # --- processing ------------------------------------------------------
    modality: Modality = Modality.BMODE
    variant: Variant = Variant.CNN
    dynamic_range_db: float = 60.0  # B-mode compression range
    wall_filter_taps: int = 4       # Doppler clutter filter length
    smooth_kernel: int = 3          # Doppler spatial smoothing (square)

    # --- sparse (V3) block structure -------------------------------------
    sparse_block_p: int = 64  # pixel-block rows (MXU-aligned multiples of 8)
    sparse_block_s: int = 64  # sample-block cols

    # --- numerics ---------------------------------------------------------
    # When True, transcendental ops (atan2, log10) use the CNN-expressible
    # bounded-error approximations from cnn_ops; when False, jnp natives.
    # The CNN variant always uses approximations (portability contract).
    cnn_transcendentals: bool = True

    # --- operator lowerings ------------------------------------------------
    # Explicit per-stage lowering overrides: a mapping (or pair tuple) of
    # stage name -> lowering name, e.g. {"beamform": "pallas"}. Stages left
    # unspecified are resolved by the planner (repro.core.plan) through the
    # per-stage lowering registry (repro.core.lowering) — preference table
    # or per-stage autotune — and `plan.concretize(cfg)` writes the resolved
    # mapping back here, so the executed config (and its canonical hash,
    # which groups multi-tenant streams) always names its lowerings.
    # Normalized to a sorted tuple of pairs at construction.
    stage_lowerings: Tuple[Tuple[str, str], ...] = ()

    # --- fusion + precision (megakernel axes) ------------------------------
    # fusion="fused" replaces the per-stage dispatch of a registered stage
    # span (demod→beamform→head) with one tile-resident Pallas megakernel
    # (repro.kernels.fused_pipeline); the planner resolves WHICH fused
    # lowering and stamps its group. Both axes participate in the canonical
    # hash, so the multi-tenant scheduler never batches fused and unfused
    # (or mixed-precision) streams into one compiled program.
    fusion: str = "none"
    precision: str = "f32"
    # Pixel-tile rows of the fused kernel's grid. None lets the planner
    # decide (autotune over the fusion-group candidates, or the kernel
    # default under fixed/heuristic); plan.concretize() writes the
    # resolved value back. Planner-decided, so it is excluded from the
    # plan's geometry key (like stage_lowerings).
    fusion_block: Optional[int] = None

    # DEPRECATED alias for stage_lowerings={"beamform": "pallas"} (the fused
    # DAS Pallas kernel). Normalized away at construction — the field is
    # always False afterwards, so it never reaches the canonical hash.
    use_das_kernel: bool = False

    # --- batched execution (stage-graph engine) ---------------------------
    # How the Batched/Sharded executors map the stage graph over the
    # leading acquisition-batch axis: "vmap" vectorizes (one fused
    # program, peak memory scales with batch), "map" sequentializes via
    # lax.map (constant memory, serial latency). Validated at
    # construction so a typo fails before any planning or compilation.
    exec_map: str = "vmap"

    def __post_init__(self):
        if self.exec_map not in EXEC_MAPS:
            raise ValueError(
                f"unknown exec_map: {self.exec_map!r} "
                f"(expected one of {EXEC_MAPS})")
        if self.fusion not in FUSION_NAMES:
            raise ValueError(
                f"unknown fusion: {self.fusion!r} "
                f"(expected one of {FUSION_NAMES})")
        if self.precision not in PRECISION_NAMES:
            raise ValueError(
                f"unknown precision: {self.precision!r} "
                f"(expected one of {PRECISION_NAMES})")
        if self.fusion_block is not None:
            if self.fusion == "none":
                raise ValueError(
                    "fusion_block is a fused-kernel tile size — set "
                    "fusion='fused' or leave fusion_block=None")
            if not (isinstance(self.fusion_block, int)
                    and self.fusion_block > 0):
                raise ValueError(
                    f"fusion_block must be a positive int, got "
                    f"{self.fusion_block!r}")
        lowerings = self.stage_lowerings
        if isinstance(lowerings, Mapping):
            lowerings = tuple(lowerings.items())
        lowerings = {stage: name for stage, name in lowerings}
        if self.use_das_kernel:
            # The legacy flag was read only by the dynamic beamformer, so
            # the alias applies to DYNAMIC (and to AUTO, which the planner
            # then restricts to pin-honoring variants); on CNN/SPARSE it
            # was — and stays — a no-op, now a loud one. Normalized away
            # in every case so the canonical hash matches the
            # explicit-stage_lowerings config.
            if self.variant in (Variant.DYNAMIC, Variant.AUTO):
                warnings.warn(
                    "UltrasoundConfig.use_das_kernel is deprecated; use "
                    "stage_lowerings={'beamform': 'pallas'}",
                    DeprecationWarning, stacklevel=3)
                lowerings.setdefault("beamform", "pallas")
            else:
                warnings.warn(
                    "UltrasoundConfig.use_das_kernel is deprecated and "
                    f"ignored for variant={self.variant.value!r} (the "
                    "fused DAS kernel lowers only the dynamic beamform); "
                    "use stage_lowerings={'beamform': 'pallas'} on a "
                    "dynamic config", DeprecationWarning, stacklevel=3)
            object.__setattr__(self, "use_das_kernel", False)
        for stage, name in lowerings.items():
            if stage not in STAGE_NAMES:
                raise ValueError(
                    f"unknown stage in stage_lowerings: {stage!r} "
                    f"(expected one of {STAGE_NAMES})")
            if name not in LOWERING_NAMES:
                raise ValueError(
                    f"unknown lowering for stage {stage!r}: {name!r} "
                    f"(expected one of {LOWERING_NAMES})")
        object.__setattr__(self, "stage_lowerings",
                           tuple(sorted(lowerings.items())))

    def stage_lowering(self, stage: str, default: str = "xla") -> str:
        """The lowering this config requests for ``stage`` (or default)."""
        return dict(self.stage_lowerings).get(stage, default)

    # ---------------------------------------------------------------------
    @property
    def fs_iq(self) -> float:
        return self.fs / self.decim

    @property
    def n_s(self) -> int:
        """IQ samples per channel after decimation."""
        return self.n_l // self.decim

    @property
    def n_pix(self) -> int:
        return self.nz * self.nx

    @property
    def rf_shape(self) -> Tuple[int, int, int]:
        return (self.n_l, self.n_c, self.n_f)

    @property
    def input_bytes(self) -> int:
        """B_in for the throughput metric (paper eq. 2)."""
        itemsize = 2 if self.rf_dtype == "int16" else 4
        return self.n_l * self.n_c * self.n_f * itemsize

    @property
    def name(self) -> str:
        return PIPELINE_NAMES[self.modality]

    def with_(self, **kwargs) -> "UltrasoundConfig":
        return dataclasses.replace(self, **kwargs)

    def canonical_hash(self, exclude: Collection[str] = ()) -> str:
        return config_hash(self, exclude=exclude)


# Bump when the meaning of a config field (and hence of any artifact keyed
# on the hash — consts cache entries, autotune memos) changes incompatibly.
# v2: stage_lowerings joined the config (use_das_kernel normalized away).
# v3: fusion / precision / fusion_block joined the config (the fused
#     megakernel axes) — every hash-keyed artifact re-keys.
CONFIG_HASH_SCHEMA = "ultrasound-cfg-v3"


def config_hash(cfg: UltrasoundConfig, *,
                exclude: Collection[str] = ()) -> str:
    """Canonical content hash of a config (hex, 16 chars).

    Every dataclass field participates unless listed in ``exclude``
    (e.g. the planner memoizes autotune results per config *ignoring*
    ``variant``, the axis it searches over). Enum fields serialize as
    their string values and floats via repr, so the hash is stable
    across processes — it keys the on-disk constants cache.
    """
    d = dataclasses.asdict(cfg)
    for name in exclude:
        if name not in d:
            raise KeyError(f"unknown config field: {name!r}")
        del d[name]
    payload = json.dumps([CONFIG_HASH_SCHEMA, d], sort_keys=True,
                         default=lambda o: o.value)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def paper_config(**overrides) -> UltrasoundConfig:
    """The paper's benchmark geometry: 5.472 MB int16 RF per forward pass."""
    cfg = UltrasoundConfig()
    assert cfg.input_bytes == 5_472_256
    return cfg.with_(**overrides) if overrides else cfg


def tiny_config(**overrides) -> UltrasoundConfig:
    """Reduced geometry for unit tests: same structure, ~1000x smaller.

    n_l=512 records ~19.7 mm of depth at fs=20 MHz; the grid stays inside
    that coverage so every pixel has valid delays.
    """
    cfg = UltrasoundConfig(
        n_l=512, n_c=8, n_f=4, nz=24, nx=16,
        z_min=4e-3, z_max=16e-3, lpf_taps=15,
        sparse_block_p=16, sparse_block_s=16,
    )
    return cfg.with_(**overrides) if overrides else cfg
