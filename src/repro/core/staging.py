"""Preallocated host staging rings for the serving dispatch path.

Before this module, every coalesced dispatch paid TWO host copies on
the admit thread before the device ever saw a byte:
``np.stack([f.rf for f in batch])`` materialized a fresh batch array,
then ``executor._pad_rows`` concatenated it with zeros into a second
fresh array of the padded shape. Both allocations and both memcpys sat
on the serving critical path, every batch, forever.

`StagingRing` fuses them into zero extra copies: a ring of
preallocated ``(pad_to, *frame_shape)`` host buffers, pre-zeroed once
at construction. Coalescing writes each admitted frame's RF directly
into the next ring slot (one row-copy per frame — the minimum any
host->device path pays), and the pad region needs re-zeroing only when
a previous occupant left stale rows beyond the new occupancy. The slot
is handed to the executor's ``place``/``dispatch_staged`` pair as-is —
no stack, no concatenate, no allocation.

Ring sizing (the aliasing contract, tested in tests/test_staging.py):
a slot may be rewritten only after the dispatch that read it no longer
needs the host buffer. The scheduler launches a group's batch m+1 only
while strictly fewer than ``in_flight`` batches are pending globally,
and a group's batches retire FIFO — so when slot ``i`` comes around
again after ``slots`` stagings, the batch that last used it is at
least ``slots`` launches back and (with ``slots >= depth + 1``) is
provably no longer pending: its transfer and compute both finished.
An undersized ring (``slots < depth + 1``) could hand the device a
buffer the admit thread is concurrently overwriting, so construction
refuses it outright.

Timing: `stage` accumulates its own wall time (``stage_copy_s``) so
the scheduler can stamp the staging cost into the transfer telemetry
instead of losing it inside the dispatch latency.
"""

from __future__ import annotations

import time
from typing import Sequence, Tuple

import numpy as np

__all__ = ["StagingRing"]


class StagingRing:
    """Ring of preallocated padded host batch buffers for one group.

    ``depth`` is the scheduler's ``in_flight`` bound; ``slots`` defaults
    to ``depth + 1`` (the minimum safe size — see the module docstring)
    and may only be grown, never shrunk, past it.
    """

    def __init__(self, pad_to: int, frame_shape: Sequence[int], dtype, *,
                 depth: int, slots: int = None):
        if pad_to < 1:
            raise ValueError(f"pad_to must be >= 1 (got {pad_to})")
        if depth < 1:
            raise ValueError(f"depth must be >= 1 (got {depth})")
        if slots is None:
            slots = depth + 1
        if slots < depth + 1:
            raise ValueError(
                f"staging ring of {slots} slots cannot back in_flight="
                f"{depth} pending dispatches — a slot could be rewritten "
                f"while the device still reads it; need >= {depth + 1}")
        self.pad_to = pad_to
        self.depth = depth
        self.slots = slots
        self.frame_shape = tuple(frame_shape)
        self.dtype = np.dtype(dtype)
        # Pre-zeroed ONCE: a full batch never re-zeros, a partial batch
        # re-zeros only rows a previous occupant dirtied past its own
        # occupancy.
        self._bufs = [np.zeros((pad_to,) + self.frame_shape, self.dtype)
                      for _ in range(slots)]
        self._fill = [0] * slots       # dirtied rows per slot
        self._next = 0
        self.stage_copy_s = 0.0        # accumulated host-copy wall time
        self.batches_staged = 0

    def stage(self, frames_rf: Sequence[np.ndarray]
              ) -> Tuple[np.ndarray, int]:
        """Write a coalesced batch into the next slot; (buffer, b).

        Returns the full ``(pad_to, *frame_shape)`` padded buffer —
        rows past ``b`` are guaranteed zero — ready for
        ``executor.place`` / ``dispatch_staged``. The returned buffer is
        OWNED by the ring: it is valid until ``slots`` further `stage`
        calls, which is exactly what the scheduler's in-flight bound
        guarantees (see class docstring).
        """
        b = len(frames_rf)
        if b < 1:
            raise ValueError("empty RF batch")
        if b > self.pad_to:
            raise ValueError(
                f"batch of {b} exceeds pad_to={self.pad_to} — the "
                "scheduler must never coalesce past its policy's "
                "max_batch")
        t0 = time.perf_counter()
        i = self._next
        self._next = (i + 1) % self.slots
        buf = self._bufs[i]
        for r, rf in enumerate(frames_rf):
            buf[r] = rf
        if self._fill[i] > b:          # stale rows from a fuller occupant
            buf[b:self._fill[i]] = 0
        self._fill[i] = b
        self.stage_copy_s += time.perf_counter() - t0
        self.batches_staged += 1
        return buf, b
