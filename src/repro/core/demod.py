"""RF -> IQ quadrature demodulation, expressed as CNN primitives.

Stages (all static, deterministic):
  1. pointwise mix with the precomputed carrier (cos / -sin at f0),
  2. FIR low-pass + decimation as an explicitly ordered shift-and-add
     (a strided 1-D conv with the tap accumulation order pinned).

The carrier vectors and FIR taps are init-time constants (paper §II-C).
Complex IQ is carried as a trailing (re, im) axis — no complex dtypes.
"""

from __future__ import annotations

from typing import Dict

import numpy as np

import jax.numpy as jnp
from jax import lax

from repro.core.config import UltrasoundConfig


def design_lowpass(cfg: UltrasoundConfig) -> np.ndarray:
    """Hamming-windowed sinc FIR, cutoff = lpf_cutoff * f0 (one-sided)."""
    n = cfg.lpf_taps
    assert n % 2 == 1, "FIR length must be odd for linear phase"
    fc = cfg.lpf_cutoff * cfg.f0 / cfg.fs  # normalized cutoff (cycles/sample)
    m = np.arange(n) - (n - 1) / 2.0
    h = 2 * fc * np.sinc(2 * fc * m)
    h *= np.hamming(n)
    h /= h.sum()
    return h.astype(np.float32)


def demod_consts(cfg: UltrasoundConfig) -> Dict[str, np.ndarray]:
    t = np.arange(cfg.n_l, dtype=np.float64) / cfg.fs
    ph = 2.0 * np.pi * cfg.f0 * t
    # Factor 2 restores the analytic-signal amplitude after low-pass.
    carrier = np.stack([2.0 * np.cos(ph), -2.0 * np.sin(ph)], axis=-1)
    return {
        "carrier": carrier.astype(np.float32),      # (n_l, 2)
        "lpf": design_lowpass(cfg),                 # (taps,)
    }


def rf_to_iq(consts: Dict[str, jnp.ndarray], rf: jnp.ndarray,
             decim: int) -> jnp.ndarray:
    """(n_l, n_c, n_f) RF -> (n_s, n_c, n_f, 2) IQ.

    The mix is pointwise; the low-pass + decimation is a strided FIR over
    the axial axis with 'SAME' padding (output length ceil(n_l / decim)),
    written as an explicitly ordered shift-and-add over the taps rather
    than lax.conv: XLA:CPU emits differently-rounded (1-ulp) conv code for
    this strided shape inside loop bodies (fori_loop / pallas grids), so a
    conv-based reference could never be matched bitwise by a fused kernel.
    Pinning the tap accumulation order makes the demod bit-identical in
    every execution context at identical cost (k FMAs per output sample).
    """
    n_l, n_c, n_f = rf.shape
    x = rf.astype(jnp.float32)
    mixed = x[..., None] * consts["carrier"][:, None, None, :]  # (n_l,c,f,2)

    lpf = consts["lpf"]                                        # (k,)
    k = lpf.shape[0]
    pad_lo, pad_hi = _same_pad(n_l, k, decim)
    m = jnp.pad(mixed, ((pad_lo, pad_hi), (0, 0), (0, 0), (0, 0)))
    n_s = -(-n_l // decim)
    acc = jnp.zeros((n_s, n_c, n_f, 2), jnp.float32)
    for t in range(k):  # static unroll; ascending tap order is the contract
        acc = acc + lpf[t] * lax.slice_in_dim(
            m, t, t + (n_s - 1) * decim + 1, stride=decim, axis=0)
    return acc


def _same_pad(length: int, k: int, stride: int):
    """TF-style SAME padding for output length ceil(length / stride)."""
    out = -(-length // stride)
    total = max((out - 1) * stride + k - length, 0)
    lo = total // 2
    return (lo, total - lo)
