"""End-to-end RF-to-image pipelines (paper §II-A modalities).

Built on the stage graph in `repro.core.stages`:
`init_pipeline(cfg)` merges every stage's precomputed constants (geometry
tables, FIR taps, interpolation operators) — module initialization,
excluded from timing. `pipeline_fn(cfg)` is the stage-graph composition:
a pure (consts, rf) -> image function suitable for jax.jit / pjit; rf is
the only runtime input.

The SAME code runs every variant and every backend; variant selection is
configuration, preserving the paper's "no backend-specific rewrites"
invariant (§II-E). `monolithic_pipeline_fn` keeps the pre-stage-graph
single-function form as a reference oracle (tests assert the graph
composition reproduces it exactly).

For batched multi-acquisition execution see `repro.core.executor`.
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import beamform, bmode, demod, doppler, stages
from repro.core.config import Modality, UltrasoundConfig


def init_pipeline(cfg: UltrasoundConfig) -> Dict[str, np.ndarray]:
    """Precompute all pipeline constants (untimed, deterministic)."""
    return stages.init_graph_consts(cfg)


def pipeline_fn(cfg: UltrasoundConfig) -> Callable:
    """Pure (consts, rf) -> image function for the configured modality."""
    return stages.graph_fn(cfg)


def monolithic_pipeline_fn(cfg: UltrasoundConfig) -> Callable:
    """Legacy single-function pipeline, kept as the reference oracle."""

    def run(consts, rf):
        iq = demod.rf_to_iq(consts, rf, cfg.decim)       # (n_s, n_c, n_f, 2)
        bf = beamform.beamform(cfg, consts, iq)          # (n_pix, n_f, 2)
        if cfg.modality == Modality.BMODE:
            return bmode.bmode_image(cfg, bf)            # (nz, nx, n_f)
        if cfg.modality == Modality.DOPPLER:
            return doppler.color_doppler_image(cfg, consts, bf)
        if cfg.modality == Modality.POWER_DOPPLER:
            return doppler.power_doppler_image(cfg, consts, bf)
        raise ValueError(cfg.modality)  # pragma: no cover

    return run


class UltrasoundPipeline:
    """Convenience wrapper: init once, jit once, call many times."""

    def __init__(self, cfg: UltrasoundConfig):
        self.cfg = cfg
        self.consts = jax.tree.map(jnp.asarray, init_pipeline(cfg))
        self._fn = jax.jit(pipeline_fn(cfg))

    def __call__(self, rf: jnp.ndarray) -> jnp.ndarray:
        return self._fn(self.consts, rf)

    def stage_callables(self) -> Dict[str, Callable]:
        """Per-stage jitted (consts, x) -> y functions, in graph order.

        Feeding each stage's output to the next reproduces `__call__`;
        used for the per-stage timing breakdown (§II-E telemetry).
        """
        return {name: jax.jit(fn)
                for name, fn in stages.stage_fns(self.cfg).items()}

    @property
    def input_bytes(self) -> int:
        return self.cfg.input_bytes

    @property
    def name(self) -> str:
        return f"{self.cfg.name}:{self.cfg.variant.value}"
