"""End-to-end RF-to-image pipelines (paper §II-A modalities).

`init_pipeline(cfg)` precomputes every constant (geometry tables, FIR taps,
interpolation operators) — this is module initialization, excluded from
timing. `pipeline_fn(cfg)` returns a pure function (consts, rf) -> image
suitable for jax.jit / pjit; rf is the only runtime input.

The SAME code runs every variant and every backend; variant selection is
configuration, preserving the paper's "no backend-specific rewrites"
invariant (§II-E).
"""

from __future__ import annotations

from typing import Callable, Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import beamform, bmode, delays, demod, doppler
from repro.core.config import Modality, UltrasoundConfig, Variant


def init_pipeline(cfg: UltrasoundConfig) -> Dict[str, np.ndarray]:
    """Precompute all pipeline constants (untimed, deterministic)."""
    consts: Dict[str, np.ndarray] = dict(demod.demod_consts(cfg))
    tables = delays.compute_delay_tables(cfg)

    if cfg.variant == Variant.DYNAMIC:
        consts.update(idx=tables.idx, frac=tables.frac,
                      apod=tables.apod, rot=tables.rot)
    elif cfg.variant == Variant.CNN:
        consts["interp_matrix"] = delays.interp_matrix(cfg, tables)
    elif cfg.variant == Variant.SPARSE:
        op = delays.bsr_operator(cfg, tables)
        consts["bsr_blocks"] = op.blocks
        consts["bsr_col_idx"] = op.col_idx
    else:  # pragma: no cover
        raise ValueError(cfg.variant)

    if cfg.modality in (Modality.DOPPLER, Modality.POWER_DOPPLER):
        consts["wall_taps"] = doppler.wall_filter_taps(cfg)
        consts["smooth"] = doppler.smoothing_kernel(cfg)
    return consts


def pipeline_fn(cfg: UltrasoundConfig) -> Callable:
    """Pure (consts, rf) -> image function for the configured modality."""

    def run(consts, rf):
        iq = demod.rf_to_iq(consts, rf, cfg.decim)       # (n_s, n_c, n_f, 2)
        bf = beamform.beamform(cfg, consts, iq)          # (n_pix, n_f, 2)
        if cfg.modality == Modality.BMODE:
            return bmode.bmode_image(cfg, bf)            # (nz, nx, n_f)
        if cfg.modality == Modality.DOPPLER:
            return doppler.color_doppler_image(cfg, consts, bf)
        if cfg.modality == Modality.POWER_DOPPLER:
            return doppler.power_doppler_image(cfg, consts, bf)
        raise ValueError(cfg.modality)  # pragma: no cover

    return run


class UltrasoundPipeline:
    """Convenience wrapper: init once, jit once, call many times."""

    def __init__(self, cfg: UltrasoundConfig):
        self.cfg = cfg
        self.consts = jax.tree.map(jnp.asarray, init_pipeline(cfg))
        self._fn = jax.jit(pipeline_fn(cfg))

    def __call__(self, rf: jnp.ndarray) -> jnp.ndarray:
        return self._fn(self.consts, rf)

    @property
    def input_bytes(self) -> int:
        return self.cfg.input_bytes

    @property
    def name(self) -> str:
        return f"{self.cfg.name}:{self.cfg.variant.value}"
