"""End-to-end RF-to-image pipelines (paper §II-A modalities).

Built on the stage graph in `repro.core.stages`:
`init_pipeline(cfg)` merges every stage's precomputed constants (geometry
tables, FIR taps, interpolation operators) — module initialization,
excluded from timing. `pipeline_fn(cfg)` is the stage-graph composition:
a pure (consts, rf) -> image function suitable for jax.jit / pjit; rf is
the only runtime input.

The SAME code runs every variant and every backend; variant selection is
configuration — `Variant.AUTO` additionally delegates the choice to the
backend-aware planner (`repro.core.plan`), preserving the paper's
"no backend-specific rewrites" invariant (§II-E) without a hand-picked
variant. `monolithic_pipeline_fn` keeps the pre-stage-graph
single-function form as a reference oracle (tests assert the graph
composition reproduces it exactly).

Constants are served through a two-tier cache — an in-process dict plus
an optional on-disk ``.npz`` store, both keyed by the canonical config
hash — so the delay-table / interp-matrix precompute is paid once across
variant sweeps, repeated benchmarks, and serve restarts. The disk tier
reads `REPRO_CONSTS_CACHE_DIR` (set to "" / "0" to disable); entries are
bit-exact round trips of the numpy constants.

For batched multi-acquisition execution see `repro.core.executor`.
"""

from __future__ import annotations

import collections
import dataclasses
import os
import tempfile
from typing import Callable, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import beamform, bmode, demod, doppler, stages
from repro.core.config import Modality, UltrasoundConfig, Variant, \
    config_hash


# ---------------------------------------------------------------------------
# Two-tier constants cache
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ConstsCacheStats:
    """Hit/miss counters for the constants cache (reset per process).

    ``misses`` counts actual delay-table recomputations — the acceptance
    check "repeated init for the same config recomputes nothing" is
    literally `misses` staying flat.
    """

    mem_hits: int = 0
    disk_hits: int = 0
    misses: int = 0

    def reset(self) -> None:
        self.mem_hits = self.disk_hits = self.misses = 0


CONSTS_CACHE_STATS = ConstsCacheStats()

# Bump whenever the *meaning* of precomputed constants changes (delay-table
# math, interp-matrix layout, BSR packing) — the config hash alone cannot
# see code changes, and a stale disk entry would silently corrupt images.
CONSTS_SCHEMA = "consts-v1"

# LRU memory tier, bounded in bytes: a paper-scale variant sweep must not
# pin multi-GB CNN operators for process lifetime. Entries larger than the
# budget are served uncached.
MEM_CACHE_MAX_BYTES = int(os.environ.get(
    "REPRO_CONSTS_CACHE_MEM_MAX_BYTES", 1024 * 1024 * 1024))
_MEM_CACHE: "collections.OrderedDict[str, Dict[str, np.ndarray]]" = \
    collections.OrderedDict()

# Per-ENTRY disk cap: paper-scale CNN operators reach GBs and are cheaper
# to recompute than to read back. The directory's total is NOT bounded —
# entries are never evicted (wipe with clear_consts_cache(disk=True)).
DISK_CACHE_MAX_BYTES = int(os.environ.get(
    "REPRO_CONSTS_CACHE_MAX_BYTES", 256 * 1024 * 1024))


def _consts_nbytes(consts: Dict[str, np.ndarray]) -> int:
    return sum(a.nbytes for a in consts.values())


def _mem_put(key: str, consts: Dict[str, np.ndarray]) -> None:
    if _consts_nbytes(consts) > MEM_CACHE_MAX_BYTES:
        return
    _MEM_CACHE[key] = consts
    _MEM_CACHE.move_to_end(key)
    while (len(_MEM_CACHE) > 1 and
           sum(map(_consts_nbytes, _MEM_CACHE.values()))
           > MEM_CACHE_MAX_BYTES):
        _MEM_CACHE.popitem(last=False)         # evict least-recently used

_UNSET = object()
_disk_cache_dir: Optional[str] = None
_disk_cache_resolved = False


def _default_disk_dir() -> Optional[str]:
    env = os.environ.get("REPRO_CONSTS_CACHE_DIR", _UNSET)
    if env is _UNSET:
        return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "consts")
    return env if env and env != "0" else None


def consts_cache_dir() -> Optional[str]:
    """Active on-disk cache directory (None = disk tier disabled)."""
    global _disk_cache_dir, _disk_cache_resolved
    if not _disk_cache_resolved:
        _disk_cache_dir = _default_disk_dir()
        _disk_cache_resolved = True
    return _disk_cache_dir


def set_consts_cache_dir(path: Optional[str]) -> None:
    """Point the disk tier somewhere else (tests), or disable it (None)."""
    global _disk_cache_dir, _disk_cache_resolved
    _disk_cache_dir = path
    _disk_cache_resolved = True


def clear_consts_cache(*, memory: bool = True, disk: bool = False) -> None:
    if memory:
        _MEM_CACHE.clear()
    if disk and consts_cache_dir() and os.path.isdir(consts_cache_dir()):
        for name in os.listdir(consts_cache_dir()):
            if name.endswith(".npz"):
                os.remove(os.path.join(consts_cache_dir(), name))


def _disk_path(key: str) -> Optional[str]:
    d = consts_cache_dir()
    return os.path.join(d, f"{key}.npz") if d else None


def _disk_load(key: str) -> Optional[Dict[str, np.ndarray]]:
    path = _disk_path(key)
    if path is None or not os.path.exists(path):
        return None
    try:
        with np.load(path) as z:
            return {k: z[k] for k in z.files}
    except Exception:   # noqa: BLE001 — corrupt entry: recompute, rewrite
        return None


def _disk_store(key: str, consts: Dict[str, np.ndarray]) -> None:
    path = _disk_path(key)
    if path is None:
        return
    if sum(a.nbytes for a in consts.values()) > DISK_CACHE_MAX_BYTES:
        return
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path),
                                   suffix=".npz.tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **consts)
            os.replace(tmp, path)   # atomic publish: readers never see partials
        finally:
            if os.path.exists(tmp):
                os.remove(tmp)
    except OSError:
        pass                        # cache is best-effort; compute still wins


def _freeze(consts: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Make cached arrays read-only: they are shared across every consumer
    of this config hash, so in-place mutation would corrupt the process."""
    for a in consts.values():
        a.flags.writeable = False
    return consts


def init_pipeline(cfg: UltrasoundConfig, *,
                  cache: bool = True) -> Dict[str, np.ndarray]:
    """Precompute all pipeline constants (untimed, deterministic, cached).

    Memory tier first, then disk, then recompute (populating both). The
    returned dict is a fresh shallow copy — add/remove keys freely — but
    the arrays themselves are the cached (read-only) buffers; copy one
    before mutating it. ``exec_map``, ``stage_lowerings``, ``fusion``,
    ``precision``, and ``fusion_block`` are excluded from the cache key:
    they change how the graph is mapped / which kernels execute it /
    what the matmul operands are cast to, never its constants (the
    Pallas lowerings — fused included — consume the same delay tables
    as their xla references).
    """
    if not cfg.variant.concrete:
        raise ValueError(
            "cannot build constants for Variant.AUTO — resolve it first "
            "via repro.core.plan.plan_pipeline")
    if not cache:
        return stages.init_graph_consts(cfg)

    excl = ("exec_map", "stage_lowerings", "fusion", "precision",
            "fusion_block")
    key = f"{CONSTS_SCHEMA}-{config_hash(cfg, exclude=excl)}"
    if key in _MEM_CACHE:
        CONSTS_CACHE_STATS.mem_hits += 1
        _MEM_CACHE.move_to_end(key)
        return dict(_MEM_CACHE[key])

    consts = _disk_load(key)
    if consts is not None:
        CONSTS_CACHE_STATS.disk_hits += 1
        _mem_put(key, _freeze(consts))
        return dict(consts)

    CONSTS_CACHE_STATS.misses += 1
    consts = stages.init_graph_consts(cfg)
    _mem_put(key, _freeze(consts))
    _disk_store(key, consts)
    return dict(consts)


# ---------------------------------------------------------------------------
# Pipeline functions
# ---------------------------------------------------------------------------


def pipeline_fn(cfg: UltrasoundConfig) -> Callable:
    """Pure (consts, rf) -> image function for the configured modality."""
    return stages.graph_fn(cfg)


def monolithic_pipeline_fn(cfg: UltrasoundConfig) -> Callable:
    """Legacy single-function pipeline, kept as the reference oracle."""

    def run(consts, rf):
        iq = demod.rf_to_iq(consts, rf, cfg.decim)       # (n_s, n_c, n_f, 2)
        bf = beamform.beamform(cfg, consts, iq)          # (n_pix, n_f, 2)
        if cfg.modality == Modality.BMODE:
            return bmode.bmode_image(cfg, bf)            # (nz, nx, n_f)
        if cfg.modality == Modality.DOPPLER:
            return doppler.color_doppler_image(cfg, consts, bf)
        if cfg.modality == Modality.POWER_DOPPLER:
            return doppler.power_doppler_image(cfg, consts, bf)
        raise ValueError(cfg.modality)  # pragma: no cover

    return run


def _resolve_plan(cfg: UltrasoundConfig, plan, policy: Optional[str],
                  donate: Optional[bool] = None):
    """Shared plan resolution for the pipeline/executor constructors.

    No plan + no policy keeps today's behavior for concrete variants
    ("fixed") and falls back to the free deterministic resolver
    ("heuristic") when the config says AUTO — so
    `UltrasoundPipeline(cfg.with_(variant=Variant.AUTO))` just works.
    """
    from repro.core import plan as plan_lib
    if plan is not None:
        if policy is not None and policy != plan.policy:
            raise ValueError(
                f"both plan (policy={plan.policy!r}) and policy="
                f"{policy!r} given — pass one")
        if not plan.matches(cfg):
            raise ValueError(
                "plan was built for a different config geometry "
                f"(plan geometry_key={plan.geometry_key}) — its telemetry "
                "stamp would misattribute this pipeline; re-plan with "
                "plan_pipeline(cfg)")
        if cfg.variant.concrete and cfg.variant != plan.variant:
            raise ValueError(
                f"cfg explicitly requests variant={cfg.variant.value!r} but "
                f"the plan resolved {plan.variant.value!r} — an explicit "
                "variant is always honored, so pass a matching plan (or an "
                "AUTO config)")
        planned = dict(plan.stage_lowerings)
        for stage, name in cfg.stage_lowerings:
            if planned.get(stage, name) != name:
                raise ValueError(
                    f"cfg explicitly requests lowering {name!r} for stage "
                    f"{stage!r} but the plan resolved "
                    f"{planned[stage]!r} — an explicit lowering is always "
                    "honored, so pass a matching plan (or drop the "
                    "override)")
        if (cfg.fusion_block is not None
                and cfg.fusion_block != plan.fusion_block):
            raise ValueError(
                f"cfg explicitly requests fusion_block="
                f"{cfg.fusion_block} but the plan resolved "
                f"{plan.fusion_block} — an explicit block size is always "
                "honored, so pass a matching plan (or drop the override)")
        if plan.exec_map != cfg.exec_map:
            # The planner never decides exec_map (it copies the config's);
            # an explicit cfg.exec_map — e.g. "map" to bound peak memory —
            # must win over the value recorded at planning time, and the
            # telemetry stamp must reflect what actually runs.
            plan = dataclasses.replace(plan, exec_map=cfg.exec_map)
        return plan
    if policy is None:
        policy = "fixed" if cfg.variant.concrete else "heuristic"
    return plan_lib.plan_pipeline(cfg, policy=policy, donate=donate)


class UltrasoundPipeline:
    """Convenience wrapper: plan once, init once, jit once, call many times.

    Accepts an explicit `PipelinePlan` (or a `policy` name to build one);
    `self.cfg` is the plan-resolved config (concrete variant), `self.plan`
    records the decision for telemetry.
    """

    def __init__(self, cfg: UltrasoundConfig, *, plan=None,
                 policy: Optional[str] = None):
        self.plan = _resolve_plan(cfg, plan, policy)
        self.cfg = self.plan.concretize(cfg)
        self.consts = jax.tree.map(jnp.asarray, init_pipeline(self.cfg))
        self._fn = jax.jit(pipeline_fn(self.cfg))

    def __call__(self, rf: jnp.ndarray) -> jnp.ndarray:
        return self._fn(self.consts, rf)

    @property
    def jitted(self) -> Callable:
        """The compiled (consts, rf) -> image callable (public handle)."""
        return self._fn

    def stage_callables(self) -> Dict[str, Callable]:
        """Per-stage (consts, x) -> y functions, in graph order.

        Feeding each stage's output to the next reproduces `__call__`;
        used for the per-stage timing breakdown (§II-E telemetry). Each
        stage is jitted unless the plan toggles it off.
        """
        return {name: jax.jit(fn) if self.plan.stage_jit(name) else fn
                for name, fn in stages.stage_fns(self.cfg).items()}

    @property
    def input_bytes(self) -> int:
        return self.cfg.input_bytes

    @property
    def name(self) -> str:
        return f"{self.cfg.name}:{self.cfg.variant.value}"
