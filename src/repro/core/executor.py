"""Batched + multi-device sharded stage-graph executors.

The paper (and the legacy `UltrasoundPipeline`) times one acquisition per
call. Production traffic wants N acquisitions per dispatch so the fixed
dispatch/launch overhead amortizes and the compiler sees the whole batch
— and past one device, wants that batch *split across every local
device* so throughput scales with hardware instead of clock speed.

Public API
----------
`BatchedExecutor`  — init once, jit once, run (B, n_l, n_c, n_f)
    batches many times on the default device. The batch axis carries the
    logical "batch" sharding name, so under an active mesh binding
    (runtime/sharding.py) it composes with the LM half's meshes.

Both executors expose the serving tier's dispatch granularities:
``__call__`` (synchronous semantics, caller blocks when it reads),
``call_padded`` (fixed-shape ragged dispatch, valid rows sliced off —
the one-batch-at-a-time scheduler entry), ``dispatch_padded`` (the
ASYNC form of call_padded: returns the *padded, unsynchronized* device
array immediately so the host keeps coalescing and launching while the
device executes — the caller slices valid rows after it drains; see
repro.launch.scheduler's in-flight ring), and the zero-copy staged
pair ``place`` + ``dispatch_staged`` (the batch was already padded
into a `repro.core.staging.StagingRing` slot: `place` is the timed H2D
commit, `dispatch_staged` is launch-only — `dispatch_padded` is now
exactly ``dispatch_staged(place(_pad_rows(...)))``). Donation stays
safe across all of them: every dispatch consumes a freshly-placed
device batch, never a caller-retained device array (host ring slots
are reused, their device copies are not). `install_aot` (fed by
repro.core.aot) pins an ahead-of-time-compiled executable for one
padded shape; the padded entry points prefer it over re-entering jit.
`ShardedExecutor`  — the same contract, data-parallel over an explicit
    1-D ``jax.sharding.Mesh`` of local devices ("data" axis): consts are
    replicated, the acquisition batch axis is split via `NamedSharding`,
    and outputs come back batch-sharded. Uneven batches (B % devices
    != 0) are zero-padded to the next multiple and the padding is
    sliced off the result — callers never see it. On hosts with one
    physical device, force a multi-device CPU mesh anywhere with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (set before
    JAX initializes; see benchmarks/scaling.py and
    tests/test_sharded_executor.py).

Both executors map the composed stage graph over the leading batch axis:

  * ``cfg.exec_map == "vmap"`` — vectorize: one fused program over the
    batch (throughput-optimal; peak memory scales with batch size),
  * ``cfg.exec_map == "map"``  — sequentialize via ``lax.map`` (constant
    memory; use when the vmapped CNN-variant operator would not fit).

Execution decisions (variant — possibly ``Variant.AUTO`` —, exec_map,
donation) resolve through a `PipelinePlan` (repro.core.plan); pass one
explicitly or let the constructor build it (`policy=` selects fixed /
heuristic / autotune). The `ShardedExecutor` stamps its device topology
into the plan (`PipelinePlan.with_devices`) so every telemetry record
downstream names the mesh it ran on. Constants come from the shared
two-tier cache, so a serve restart or a variant sweep pays the
delay-table precompute once.

Invariants: executors are immutable after construction (one compiled
program each); a sharded and a single-device executor built from the
same config produce allclose images for any batch size (asserted in
tests/test_sharded_executor.py); the RF input buffer is donated only on
accelerator backends (each batch is consumed exactly once in the
streaming loop).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.config import UltrasoundConfig
from repro.core.pipeline import _resolve_plan, init_pipeline
from repro.core.stages import graph_fn
from repro.runtime import sharding


def _mapped_graph_fn(cfg: UltrasoundConfig):
    """The stage graph mapped over the leading batch axis per exec_map."""
    fn = graph_fn(cfg)
    if cfg.exec_map == "vmap":
        return jax.vmap(fn, in_axes=(None, 0))
    # UltrasoundConfig.__post_init__ already validated against EXEC_MAPS
    assert cfg.exec_map == "map", cfg.exec_map

    def mapped(consts, rf_b):
        return jax.lax.map(lambda rf: fn(consts, rf), rf_b)
    return mapped


def _pad_rows(rf_batch, pad_to: int) -> tuple:
    """Zero-pad a ragged batch up to ``pad_to`` rows; returns (batch, b).

    Shared by the executors' ``call_padded`` fixed-shape dispatch: the
    multi-tenant scheduler coalesces heterogeneous arrivals into batches
    of any occupancy 1..pad_to, but every dispatch must hit the SAME
    compiled program — a recompile per occupancy would stall the serving
    loop. Pad rows are zeros; per-example mapping (vmap / lax.map) keeps
    them from influencing the valid rows, and callers slice them off.

    Host (numpy) batches pad on the host: the concatenate then costs a
    memcpy instead of an op-by-op XLA program per distinct occupancy —
    which would be exactly the hidden first-dispatch compile the AOT
    warm-start contract forbids. Device arrays keep the jnp path (their
    pad program caches after one occupancy-shaped compile).
    """
    b = rf_batch.shape[0]
    if b < 1:
        raise ValueError("empty RF batch")
    if b > pad_to:
        raise ValueError(
            f"batch of {b} exceeds pad_to={pad_to} — the scheduler must "
            "never coalesce past its policy's max_batch")
    if b == pad_to:
        return rf_batch, b
    xp = np if isinstance(rf_batch, np.ndarray) else jnp
    fill = xp.zeros((pad_to - b,) + rf_batch.shape[1:], rf_batch.dtype)
    return xp.concatenate([rf_batch, fill]), b


def _resolve_donate(donate: Optional[bool], plan) -> bool:
    """Donation precedence: constructor arg > plan > backend default.

    It is a no-op warning on the CPU stand-in; enable it only where the
    runtime can actually alias the buffer.
    """
    if donate is None:
        donate = plan.donate
    if donate is None:
        donate = jax.default_backend() != "cpu"
    return donate


class BatchedExecutor:
    """Init once, jit once, run (B, n_l, n_c, n_f) batches many times."""

    def __init__(self, cfg: UltrasoundConfig, *,
                 donate: Optional[bool] = None, plan=None,
                 policy: Optional[str] = None):
        self.plan = _resolve_plan(cfg, plan, policy, donate=donate)
        self.cfg = self.plan.concretize(cfg)
        self.consts = jax.tree.map(jnp.asarray, init_pipeline(self.cfg))
        mapped = _mapped_graph_fn(self.cfg)

        def run(consts, rf_b):
            rf_b = sharding.shard_pin(rf_b, d0="batch")
            return mapped(consts, rf_b)

        self.donate = _resolve_donate(donate, self.plan)
        self._fn = jax.jit(run, donate_argnums=(1,) if self.donate else ())
        self._aot: dict = {}              # pad_to -> AOT-compiled executable

    def __call__(self, rf_batch: jnp.ndarray) -> jnp.ndarray:
        """(B, n_l, n_c, n_f) RF batch -> (B, *image_shape)."""
        return self._fn(self.consts, rf_batch)

    def install_aot(self, pad_to: int, compiled) -> None:
        """Pin an AOT-compiled executable for the ``pad_to`` dispatch
        shape (built by `repro.core.aot.aot_warm` — lower+compile,
        never a live first-dispatch compilation)."""
        self._aot[pad_to] = compiled

    def place(self, rf_batch) -> jnp.ndarray:
        """H2D: commit an already-padded host batch to the device.

        The staging-ring entry (repro.core.staging): the buffer is a
        ring slot the caller keeps reusing, so this ALWAYS produces a
        fresh device array — the host slot is free to be rewritten
        once the in-flight bound says its dispatch settled, and the
        device array is safe to donate into the compiled program.
        """
        return jnp.asarray(rf_batch)

    def dispatch_staged(self, dev_batch, pad_to: int) -> jnp.ndarray:
        """Async dispatch of an already-placed ``(pad_to, ...)`` batch.

        The zero-copy serving entry: the batch was padded by a staging
        ring and moved by `place`, so this is launch-only — through the
        AOT executable when one is installed. With donation enabled the
        compiled program consumes ``dev_batch``; callers must not
        reuse the device array (the host ring slot stays theirs).
        """
        fn = self._aot.get(pad_to, self._fn)
        return fn(self.consts, dev_batch)

    def dispatch_padded(self, rf_batch, pad_to: int) -> jnp.ndarray:
        """Async fixed-shape dispatch: the PADDED, UNSYNCED output.

        The in-flight serving entry: pads to ``pad_to`` rows, launches
        (through the AOT executable when one is installed), and returns
        the device array without blocking or slicing — the caller
        tracks how many rows are valid and slices after it drains
        (`jax.block_until_ready` / ``.is_ready()``). Donation-safe:
        the launched buffer is the freshly-padded batch, never an array
        the caller still holds.
        """
        rf_batch, _ = _pad_rows(rf_batch, pad_to)
        return self.dispatch_staged(self.place(rf_batch), pad_to)

    def call_padded(self, rf_batch: jnp.ndarray,
                    pad_to: int) -> jnp.ndarray:
        """Fixed-shape dispatch of a ragged batch (B <= pad_to rows).

        Heterogeneous-arrival entry point for the dynamic-batching
        scheduler (repro.launch.scheduler): zero-pads the batch to
        ``pad_to`` rows so every occupancy 1..pad_to reuses one compiled
        program, then slices the valid rows off the result. Pad rows
        cost compute, never a recompile.
        """
        b = rf_batch.shape[0]
        out = self.dispatch_padded(rf_batch, pad_to)
        return out[:b] if b != pad_to else out

    @property
    def jitted(self):
        """The compiled (consts, rf_batch) -> images callable."""
        return self._fn

    @property
    def input_bytes_per_acq(self) -> int:
        """B_in of one acquisition (paper eq. 2 normalization)."""
        return self.cfg.input_bytes

    @property
    def name(self) -> str:
        return (f"{self.cfg.name}:{self.cfg.variant.value}"
                f":{self.cfg.exec_map}")


class ShardedExecutor:
    """Data-parallel `BatchedExecutor` over a 1-D mesh of local devices.

    The acquisition batch axis is split across the "data" mesh axis with
    `NamedSharding`; constants are replicated. One jitted SPMD program
    serves every call; XLA partitions it so each device runs the stage
    graph on its batch shard with no cross-device communication (the
    pipeline is embarrassingly parallel over acquisitions).

    ``devices=None`` takes every local device. Uneven batches are
    zero-padded up to a device multiple and the pad rows sliced off the
    returned images, so any B >= 1 is accepted — at the cost of one
    wasted device-row of compute for remainders (callers streaming for
    throughput should keep B a multiple of ``n_devices``).
    """

    def __init__(self, cfg: UltrasoundConfig, *,
                 devices: Optional[Sequence] = None,
                 donate: Optional[bool] = None, plan=None,
                 policy: Optional[str] = None):
        devs = tuple(devices) if devices is not None \
            else tuple(jax.local_devices())
        if not devs:
            raise ValueError("ShardedExecutor needs at least one device")
        self.devices = devs
        self.n_devices = len(devs)
        base = _resolve_plan(cfg, plan, policy, donate=donate)
        self.plan = base.with_devices(self.n_devices,
                                      (("data", self.n_devices),))
        self.cfg = self.plan.concretize(cfg)

        self.mesh = Mesh(np.asarray(devs), ("data",))
        self._consts_sharding = NamedSharding(self.mesh, P())
        self._batch_sharding = NamedSharding(self.mesh, P("data"))
        self.consts = jax.device_put(
            jax.tree.map(jnp.asarray, init_pipeline(self.cfg)),
            self._consts_sharding)
        mapped = _mapped_graph_fn(self.cfg)
        if self.cfg.exec_map == "map":
            # lax.map is a sequential scan GSPMD cannot partition — left
            # to the partitioner it would all-gather the batch and run
            # it whole on every device. shard_map keeps the contract:
            # each device scans only its local batch shard (constant
            # memory per device, still data-parallel, no collectives).
            from jax.experimental.shard_map import shard_map
            mapped = shard_map(mapped, mesh=self.mesh,
                               in_specs=(P(), P("data")),
                               out_specs=P("data"))

        def run(consts, rf_b):
            return mapped(consts, rf_b)

        self.donate = _resolve_donate(donate, self.plan)
        self._fn = jax.jit(
            run,
            in_shardings=(self._consts_sharding, self._batch_sharding),
            out_shardings=self._batch_sharding,
            donate_argnums=(1,) if self.donate else ())
        self._aot: dict = {}              # pad_to -> AOT-compiled executable

    def _pad(self, rf_batch: jnp.ndarray) -> tuple:
        b = rf_batch.shape[0]
        if b < 1:
            raise ValueError("empty RF batch")
        pad = -b % self.n_devices
        if pad:
            fill = jnp.zeros((pad,) + rf_batch.shape[1:], rf_batch.dtype)
            rf_batch = jnp.concatenate([rf_batch, fill])
        return rf_batch, b, pad

    def __call__(self, rf_batch: jnp.ndarray) -> jnp.ndarray:
        """(B, n_l, n_c, n_f) RF batch -> (B, *image_shape), any B >= 1."""
        rf_batch, b, pad = self._pad(rf_batch)
        out = self._fn(self.consts, rf_batch)
        return out[:b] if pad else out

    def dispatch(self, rf_batch: jnp.ndarray) -> jnp.ndarray:
        """Like ``__call__`` but keeps the (padded) batch-sharded result.

        The streaming loop uses this to track per-device shards of the
        in-flight output; B must already be a device multiple so no
        host-side slicing re-synchronizes the stream.
        """
        b = rf_batch.shape[0]
        if b < 1:
            raise ValueError("empty RF batch")
        if b % self.n_devices:
            raise ValueError(
                f"dispatch() needs batch % n_devices == 0 "
                f"(got B={b}, n_devices={self.n_devices}); use __call__ "
                "for remainder-padded one-shot execution")
        return self._fn(self.consts, rf_batch)

    def install_aot(self, pad_to: int, compiled) -> None:
        """Pin an AOT-compiled SPMD executable for the ``pad_to`` shape
        (built by `repro.core.aot.aot_warm`)."""
        self._aot[pad_to] = compiled

    def place(self, rf_batch) -> jnp.ndarray:
        """H2D: commit an already-padded host batch to the mesh.

        Sharded counterpart of `BatchedExecutor.place`: the batch is
        committed to the batch sharding explicitly so the AOT
        executable — which, unlike jit, does not re-resolve placements
        — always sees its compiled-for layout. Always a fresh device
        array, so the staging-ring slot stays the caller's and the
        device copy is safe to donate.
        """
        return jax.device_put(jnp.asarray(rf_batch),
                              self._batch_sharding)

    def dispatch_staged(self, dev_batch, pad_to: int) -> jnp.ndarray:
        """Async dispatch of an already-placed ``(pad_to, ...)`` batch
        (`place` committed it to the mesh; ``pad_to`` must be a device
        multiple — one SPMD shape per mesh)."""
        if pad_to % self.n_devices:
            raise ValueError(
                f"dispatch_staged needs pad_to % n_devices == 0 "
                f"(got pad_to={pad_to}, n_devices={self.n_devices})")
        fn = self._aot.get(pad_to, self._fn)
        return fn(self.consts, dev_batch)

    def dispatch_padded(self, rf_batch, pad_to: int) -> jnp.ndarray:
        """Async fixed-shape dispatch: the PADDED, UNSYNCED device array.

        Sharded counterpart of `BatchedExecutor.dispatch_padded`:
        ``pad_to`` must be a device multiple (one SPMD shape per mesh).
        """
        if pad_to % self.n_devices:
            raise ValueError(
                f"dispatch_padded needs pad_to % n_devices == 0 "
                f"(got pad_to={pad_to}, n_devices={self.n_devices})")
        rf_batch, _ = _pad_rows(rf_batch, pad_to)
        return self.dispatch_staged(self.place(rf_batch), pad_to)

    def call_padded(self, rf_batch: jnp.ndarray,
                    pad_to: int) -> jnp.ndarray:
        """Fixed-shape dispatch of a ragged batch (B <= pad_to rows).

        The sharded counterpart of `BatchedExecutor.call_padded`:
        ``pad_to`` must be a device multiple so the one compiled SPMD
        shape splits evenly across the mesh (the scheduler enforces
        ``max_batch % n_devices == 0`` at construction).
        """
        if pad_to % self.n_devices:
            raise ValueError(
                f"call_padded needs pad_to % n_devices == 0 "
                f"(got pad_to={pad_to}, n_devices={self.n_devices})")
        b = rf_batch.shape[0]
        out = self.dispatch_padded(rf_batch, pad_to)
        return out[:b] if b != pad_to else out

    @property
    def jitted(self):
        """The compiled SPMD (consts, rf_batch) -> images callable."""
        return self._fn

    @property
    def input_bytes_per_acq(self) -> int:
        """B_in of one acquisition (paper eq. 2 normalization)."""
        return self.cfg.input_bytes

    @property
    def name(self) -> str:
        return (f"{self.cfg.name}:{self.cfg.variant.value}"
                f":{self.cfg.exec_map}:d{self.n_devices}")
