"""Batched stage-graph executor: N acquisitions per dispatch.

The paper (and the legacy `UltrasoundPipeline`) times one acquisition per
call. Production traffic wants N acquisitions per dispatch so the fixed
dispatch/launch overhead amortizes and the compiler sees the whole batch.
`BatchedExecutor` maps the composed stage graph over a leading batch axis:

  * ``cfg.exec_map == "vmap"`` — vectorize: one fused program over the
    batch (throughput-optimal; peak memory scales with batch size),
  * ``cfg.exec_map == "map"``  — sequentialize via ``lax.map`` (constant
    memory; use when the vmapped CNN-variant operator would not fit).

Execution decisions (variant — possibly ``Variant.AUTO`` —, exec_map,
donation) resolve through a `PipelinePlan` (repro.core.plan); pass one
explicitly or let the constructor build it (`policy=` selects fixed /
heuristic / autotune). Constants come from the shared two-tier cache, so
a serve restart or a variant sweep pays the delay-table precompute once.

The batch axis carries the logical "batch" sharding name, so under an
active mesh binding (runtime/sharding.py) acquisitions shard across the
data axis with zero code changes — the same single-source portability
contract the LM half uses. The RF input buffer is donated on accelerator
backends (each batch is consumed exactly once in the streaming loop).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import UltrasoundConfig
from repro.core.pipeline import _resolve_plan, init_pipeline
from repro.core.stages import graph_fn
from repro.runtime import sharding


class BatchedExecutor:
    """Init once, jit once, run (B, n_l, n_c, n_f) batches many times."""

    def __init__(self, cfg: UltrasoundConfig, *,
                 donate: Optional[bool] = None, plan=None,
                 policy: Optional[str] = None):
        self.plan = _resolve_plan(cfg, plan, policy, donate=donate)
        self.cfg = self.plan.concretize(cfg)
        self.consts = jax.tree.map(jnp.asarray, init_pipeline(self.cfg))
        fn = graph_fn(self.cfg)

        if self.cfg.exec_map == "vmap":
            mapped = jax.vmap(fn, in_axes=(None, 0))
        elif self.cfg.exec_map == "map":
            def mapped(consts, rf_b):
                return jax.lax.map(lambda rf: fn(consts, rf), rf_b)
        else:
            raise ValueError(f"unknown exec_map: {self.cfg.exec_map!r}")

        def run(consts, rf_b):
            rf_b = sharding.shard_pin(rf_b, d0="batch")
            return mapped(consts, rf_b)

        # Donation precedence: constructor arg > plan > backend default.
        # It is a no-op warning on the CPU stand-in; enable it only where
        # the runtime can actually alias the buffer.
        if donate is None:
            donate = self.plan.donate
        if donate is None:
            donate = jax.default_backend() != "cpu"
        self.donate = donate
        self._fn = jax.jit(run, donate_argnums=(1,) if donate else ())

    def __call__(self, rf_batch: jnp.ndarray) -> jnp.ndarray:
        """(B, n_l, n_c, n_f) RF batch -> (B, *image_shape)."""
        return self._fn(self.consts, rf_batch)

    @property
    def jitted(self):
        """The compiled (consts, rf_batch) -> images callable."""
        return self._fn

    @property
    def input_bytes_per_acq(self) -> int:
        """B_in of one acquisition (paper eq. 2 normalization)."""
        return self.cfg.input_bytes

    @property
    def name(self) -> str:
        return (f"{self.cfg.name}:{self.cfg.variant.value}"
                f":{self.cfg.exec_map}")
