"""AOT warm-start compilation + the persistent compilation cache.

The multi-tenant scheduler pays one compilation per config group — and
before this module it paid it *live*, on the group's first dispatch (or
a zero-batch warm-up run), silently excluded from every metric. The
ROADMAP's serving tier wants the production shape instead: compile each
group's ONE padded program ahead of time (`jax.jit(...).lower()
.compile()`), measure and stamp the cost, and back the whole thing with
JAX's persistent compilation cache so a *fresh process* — a serve
restart, a new autoscaled replica — starts warm instead of re-paying
XLA from scratch.

Three pieces:

  * `configure_persistent_cache()` — points JAX's persistent
    compilation cache at an env-configurable directory
    (``REPRO_COMPILE_CACHE_DIR``; same resolution discipline as the
    consts cache: unset -> ``~/.cache/repro/xla``, ""/"0" -> disabled).
    JAX owns the entry format and writes entries atomically
    (temp file + rename, like the consts cache's publish step), so
    concurrent serve processes can share one directory. Safe to call
    any time: when JAX already memoized its "is the cache enabled?"
    decision (it checks once, at first compile), the memo is reset so
    the new directory takes effect.
  * `aot_warm(engine, pad_to)` — lower + compile the executor's
    fixed-shape padded dispatch program for ``(pad_to, *rf_shape)``
    WITHOUT executing it, install the executable on the engine (its
    ``dispatch_padded`` / ``call_padded`` prefer it over re-tracing
    through jit), optionally run one zero batch to pre-touch the
    allocator, and return an `AotProgram` carrying the measured
    ``compile_s`` / ``warmup_s`` — the number the scheduler stamps
    instead of silently excluding.
  * `warm_pool(specs, ...)` — the serving front door: one warm
    executor per distinct plan-resolved config group of a stream set,
    keyed exactly like the scheduler groups
    (canonical config hash, pad_to, n_devices, donate), so
    `serve_multitenant` and `benchmarks/multitenant.py` can build the
    pool once and start every window — every sweep cell — warm.

Keying: programs are keyed by the *plan geometry* — the canonical hash
of the plan-concretized config (every field that reaches the compiled
program: geometry, modality, resolved variant, lowerings, fusion,
precision) plus the padded batch shape, the device count, and the
resolved input-donation signature (donate_argnums is baked into the
compiled executable). Two specs that the scheduler would coalesce
share one pool entry; two that it would not can never collide.

Invariants (tests/test_aot.py): an AOT-warmed executor's outputs are
bit-identical to the un-warmed jit path; ``compile_s > 0`` and is
actually ahead of the serving window; with a populated persistent
cache a fresh process's warm-up is cheaper than the cold one and its
first dispatch shows no compile spike.
"""

from __future__ import annotations

import dataclasses
import os
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

import jax

__all__ = ["AotProgram", "WarmPool", "aot_warm", "compile_cache_dir",
           "configure_persistent_cache", "set_compile_cache_dir",
           "warm_pool"]

_UNSET = object()
_cache_dir: Optional[str] = None
_cache_resolved = False
_cache_configured: Optional[str] = None


def _default_cache_dir() -> Optional[str]:
    env = os.environ.get("REPRO_COMPILE_CACHE_DIR", _UNSET)
    if env is _UNSET:
        return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                            "xla")
    return env if env and env != "0" else None


def compile_cache_dir() -> Optional[str]:
    """Active persistent-compilation-cache dir (None = disabled)."""
    global _cache_dir, _cache_resolved
    if not _cache_resolved:
        _cache_dir = _default_cache_dir()
        _cache_resolved = True
    return _cache_dir


def set_compile_cache_dir(path: Optional[str]) -> None:
    """Point the compile cache somewhere else (tests), or disable (None).

    Takes effect at the next `configure_persistent_cache()` call — the
    warm-pool builders call it on every pool, so in practice the next
    warm-up.
    """
    global _cache_dir, _cache_resolved
    _cache_dir = path
    _cache_resolved = True


def configure_persistent_cache() -> Optional[str]:
    """Wire JAX's persistent compilation cache to `compile_cache_dir()`.

    Returns the directory in effect (None = disabled). Idempotent and
    cheap when nothing changed. JAX checks "should I use the cache?"
    once, at the first compilation of the process, and memoizes the
    answer — so enabling the cache *after* something already compiled
    needs that memo reset, which this handles (the private import is
    fenced: if a future JAX moves it, the cache silently stays in
    whatever state the config flags put it, never a crash).
    """
    global _cache_configured
    d = compile_cache_dir()
    if d == _cache_configured:
        return d
    if d is not None:
        os.makedirs(d, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", d)
    if d is not None:
        # Cache every program: serve programs are small and tiny-geometry
        # CI programs compile fast — the default size/time floors would
        # skip exactly the entries the warm-start contract needs.
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:   # noqa: BLE001 — best-effort; config flags still set
        pass
    _cache_configured = d
    return d


@dataclasses.dataclass(frozen=True)
class AotProgram:
    """One ahead-of-time-compiled padded serve program, with its cost."""

    key: str                 # canonical hash of the concretized config
    pad_to: int              # padded dispatch shape (rows)
    devices: int             # device count the program was built for
    compile_s: float         # lower+compile wall time (this process)
    warmup_s: float          # compile_s + the optional first execution
    cache_dir: Optional[str]     # persistent cache in effect, if any


def aot_warm(engine, pad_to: int, *, execute: bool = True) -> AotProgram:
    """AOT-compile ``engine``'s fixed-shape padded program; install it.

    Lowers and compiles ``engine.jitted`` for a ``(pad_to, *rf_shape)``
    RF batch via the AOT path (`.lower().compile()`), so the cost is
    paid — and *measured* — here, never on a tenant's first frame. The
    executable is installed on the engine: `dispatch_padded` /
    `call_padded` at this shape run it directly, skipping jit's
    trace-cache lookup. With ``execute`` (default) one zero batch runs
    through the fresh executable so first-dispatch allocator work is
    also out of the serving window; both costs land in ``warmup_s``.
    """
    if pad_to < 1:
        raise ValueError(f"pad_to must be >= 1 (got {pad_to})")
    cache_dir = configure_persistent_cache()
    shape = (pad_to,) + engine.cfg.rf_shape
    dtype = np.dtype(engine.cfg.rf_dtype)
    t0 = time.perf_counter()
    compiled = engine.jitted.lower(
        engine.consts, jax.ShapeDtypeStruct(shape, dtype)).compile()
    compile_s = time.perf_counter() - t0
    engine.install_aot(pad_to, compiled)
    if execute:
        jax.block_until_ready(
            engine.dispatch_padded(np.zeros(shape, dtype), pad_to))
    warmup_s = time.perf_counter() - t0
    return AotProgram(
        key=engine.cfg.canonical_hash(), pad_to=pad_to,
        devices=getattr(engine, "n_devices", 1),
        compile_s=compile_s, warmup_s=warmup_s, cache_dir=cache_dir)


@dataclasses.dataclass
class WarmEntry:
    """One warm executor + the measured cost of making it warm."""

    engine: object           # Batched/ShardedExecutor, AOT program installed
    program: AotProgram


# (config hash, pad_to, n_devices, donate). Donation is part of the
# COMPILED program — donate_argnums changes the executable's aliasing
# contract — so a warm executor is only cache-valid for callers that
# resolved the same donation signature.
PoolKey = Tuple[str, int, int, bool]


class WarmPool:
    """Plan-geometry-keyed pool of AOT-warmed serve executors.

    Keys are ``(canonical config hash of the plan-concretized config,
    pad_to, n_devices, donate)`` — exactly the scheduler's grouping
    plus the compiled shape and donation signature, so a pool built
    once serves every window (every sweep cell) that would have built
    the same executors, and a donating window can never be handed a
    non-donating executable (or vice versa).
    """

    def __init__(self):
        self._entries: Dict[PoolKey, WarmEntry] = {}

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: PoolKey) -> bool:
        return key in self._entries

    def keys(self) -> Tuple[PoolKey, ...]:
        return tuple(self._entries)

    def get(self, key: PoolKey) -> Optional[WarmEntry]:
        return self._entries.get(key)

    def put(self, key: PoolKey, entry: WarmEntry) -> None:
        self._entries[key] = entry

    @property
    def warmup_s(self) -> float:
        """Total measured warm-up cost across every pooled program."""
        return sum(e.program.warmup_s for e in self._entries.values())


def warm_pool(specs: Sequence, *, max_batch: int, devices=None,
              plan_policy: Optional[str] = None,
              pool: Optional[WarmPool] = None,
              donate: Optional[bool] = None) -> WarmPool:
    """One AOT-warmed executor per distinct config group of ``specs``.

    ``specs`` are `repro.launch.scheduler.StreamSpec`s (anything with a
    ``.cfg``); grouping matches `serve_multitenant` exactly — the
    plan-resolved canonical hash — at the padded dispatch shape
    ``max_batch`` over ``devices`` with the donation signature
    ``donate`` (None resolves through the plan / backend default,
    exactly as the executors themselves do). Pass an existing ``pool``
    to extend it incrementally (already-warm groups are not
    recompiled), e.g. across the cells of a benchmark sweep.
    """
    from repro.core.executor import (BatchedExecutor, ShardedExecutor,
                                     _resolve_donate)
    from repro.core.pipeline import _resolve_plan

    if max_batch < 1:
        raise ValueError(f"max_batch must be >= 1 (got {max_batch})")
    sharded = devices is not None and len(devices) > 1
    n_devices = len(devices) if sharded else 1
    if sharded and max_batch % n_devices:
        raise ValueError(
            f"max_batch={max_batch} must be a multiple of "
            f"n_devices={n_devices} for sharded dispatch")
    pool = pool if pool is not None else WarmPool()
    for spec in specs:
        plan = _resolve_plan(spec.cfg, None, plan_policy)
        key = (plan.concretize(spec.cfg).canonical_hash(), max_batch,
               n_devices, _resolve_donate(donate, plan))
        if key in pool:
            continue
        engine = (ShardedExecutor(spec.cfg, devices=devices, plan=plan,
                                  donate=donate)
                  if sharded else BatchedExecutor(spec.cfg, plan=plan,
                                                  donate=donate))
        program = aot_warm(engine, max_batch)
        pool.put(key, WarmEntry(engine=engine, program=program))
    return pool
