"""Probe geometry and image grid.

A fixed Cartesian image grid and linear-array probe geometry are defined
prior to execution and reused across all experiments (paper §II-D). All
arrays here are plain numpy: they are init-time constants, never traced.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.config import UltrasoundConfig


def element_positions(cfg: UltrasoundConfig) -> np.ndarray:
    """Lateral x-positions [m] of the n_c array elements, centered at 0."""
    idx = np.arange(cfg.n_c, dtype=np.float64)
    return (idx - (cfg.n_c - 1) / 2.0) * cfg.pitch


def image_grid(cfg: UltrasoundConfig) -> Tuple[np.ndarray, np.ndarray]:
    """(z, x) pixel coordinates [m]; z axial (depth), x lateral.

    Returns (Z, X) each of shape (nz, nx). Lateral extent matches the
    physical aperture so the grid is probe-consistent across configs.
    """
    half_ap = (cfg.n_c - 1) / 2.0 * cfg.pitch
    z = np.linspace(cfg.z_min, cfg.z_max, cfg.nz, dtype=np.float64)
    x = np.linspace(-half_ap, half_ap, cfg.nx, dtype=np.float64)
    Z, X = np.meshgrid(z, x, indexing="ij")
    return Z, X


def flat_grid(cfg: UltrasoundConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Flattened (n_pix,) pixel coordinates, z-major ordering.

    z-major (z varies fastest within a column? No: row-major over (nz, nx),
    i.e. x varies fastest) — the ordering only matters for the banded
    structure exploited by the sparse variant, which is derived from the
    actual delay tables, not assumed.
    """
    Z, X = image_grid(cfg)
    return Z.reshape(-1), X.reshape(-1)
