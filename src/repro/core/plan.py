"""Backend-aware pipeline planning (variant auto-selection).

The paper's portability contract says ONE source tree runs on every
backend — but *which* operator formulation (dynamic / cnn / sparse) wins
is backend-dependent (paper Table I; ConvBench), so leaving the variant
choice to the user reintroduces exactly the hardware-specific decision
the contract is supposed to eliminate. This module closes that gap:

    plan = plan_pipeline(cfg.with_(variant=Variant.AUTO), policy=...)

produces a frozen `PipelinePlan` — the resolved variant plus every other
execution decision (exec_map, donation, per-stage jit toggles) and full
provenance — which `UltrasoundPipeline`, `BatchedExecutor`, the serve
loop, and the benchmark drivers all accept and stamp into telemetry, so
every reported MB/s–FPS row is attributable to an exact
(backend, variant, exec_map) decision.

Three policies:

  * ``fixed``     — today's behavior: honor ``cfg.variant`` verbatim.
    Refuses ``Variant.AUTO`` (a fixed plan has nothing to resolve it with).
  * ``heuristic`` — resolve AUTO from a per-backend preference registry
    encoding the structure of the paper's Table I: gather-friendly
    backends (cpu/gpu) run V1-dynamic fastest; matmul-unit backends
    (tpu) want the dense CNN formulation. Deterministic and free.
  * ``autotune``  — measure every concrete variant end-to-end for a few
    warm runs through the bench harness and pick the winner. Memoized per
    (config-hash-ignoring-variant, backend) so sweeps pay the search once.

The registry and the autotune memo are process-global; both are plain
dicts so tests (and future multi-backend sweeps) can inspect or reset
them.

Public API
----------
`plan_pipeline(cfg, policy=...)`        — resolve a config into a plan.
`PipelinePlan`                          — the frozen result; consumed by
    `UltrasoundPipeline`, `BatchedExecutor`, `ShardedExecutor`,
    `serve_ultrasound_stream` and stamped (``json_dict()``) into every
    telemetry record. ``with_devices(n, mesh_shape)`` derives the
    multi-device form the `ShardedExecutor` stamps — ``devices``/
    ``mesh_shape`` keep every NDJSON row attributable to the exact
    device topology that produced it.
`register_backend_preference`           — extend the heuristic registry.
`clear_autotune_memo`                   — reset the measurement memo.

Invariants: plans are frozen; ``variant`` is always concrete (never
AUTO); ``matches(cfg)`` gates consumption so a plan's telemetry stamp
can never be attached to a pipeline with different geometry; the
planner never decides ``exec_map`` or ``devices`` — it records what the
config/executor chose.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.config import UltrasoundConfig, Variant, config_hash

POLICIES = ("fixed", "heuristic", "autotune")

# Paper Table I structure, keyed on jax.default_backend(): backends with a
# fast irregular-access path prefer the explicit-gather formulation; systolic
# matmul-unit backends prefer the dense CNN re-expression. Extendable via
# register_backend_preference (e.g. a future backend measured differently).
BACKEND_VARIANT_PREFERENCE: Dict[str, Variant] = {
    "cpu": Variant.DYNAMIC,
    "gpu": Variant.DYNAMIC,
    "cuda": Variant.DYNAMIC,
    "rocm": Variant.DYNAMIC,
    "tpu": Variant.CNN,
}
# Unknown backends get the portable dense formulation (runs everywhere the
# paper tested, never pathological — the conservative Table I read).
DEFAULT_PREFERENCE = Variant.CNN

CONCRETE_VARIANTS = (Variant.DYNAMIC, Variant.CNN, Variant.SPARSE)

# (geometry key, backend, runs, warmup) -> tuple of (variant value, t_avg_s)
_AUTOTUNE_MEMO: Dict[Tuple[str, str, int, int],
                     Tuple[Tuple[str, float], ...]] = {}


def register_backend_preference(backend: str, variant: Variant) -> None:
    """Extend/override the heuristic registry (measured, not assumed)."""
    if not variant.concrete:
        raise ValueError("preference must be a concrete variant")
    BACKEND_VARIANT_PREFERENCE[backend] = variant


def clear_autotune_memo() -> None:
    _AUTOTUNE_MEMO.clear()


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A fully resolved execution plan for one pipeline config.

    Everything a consumer needs to build and run the pipeline — and
    everything telemetry needs to attribute a throughput number to an
    exact decision. ``variant`` is always concrete (never AUTO).
    """

    variant: Variant
    exec_map: str                                  # "vmap" | "map"
    donate: Optional[bool]                         # None = backend default
    jit_stages: Tuple[Tuple[str, bool], ...]       # (stage name, jit?) pairs
    backend: str                                   # jax.default_backend()
    policy: str                                    # member of POLICIES
    config_key: str                                # hash of the REQUESTED cfg
    geometry_key: str                              # hash sans variant/exec_map
    provenance: str                                # how the variant was chosen
    autotune_t_s: Optional[Tuple[Tuple[str, float], ...]] = None
    # Device topology the plan executes on. 1/None = single-device (the
    # BatchedExecutor default); the ShardedExecutor stamps its mesh via
    # with_devices() so every telemetry record names its topology.
    devices: int = 1
    mesh_shape: Optional[Tuple[Tuple[str, int], ...]] = None

    def __post_init__(self):
        assert self.variant.concrete, "plan must carry a concrete variant"
        assert self.devices >= 1, "plan needs at least one device"
        if self.mesh_shape is not None:
            n = 1
            for _, extent in self.mesh_shape:
                n *= extent
            assert n == self.devices, \
                f"mesh_shape {self.mesh_shape} != devices {self.devices}"

    def with_devices(self, devices: int,
                     mesh_shape: Optional[Tuple[Tuple[str, int], ...]] = None
                     ) -> "PipelinePlan":
        """This plan, stamped with the executing device topology.

        The decision axes (variant/exec_map/policy/provenance) are
        unchanged — sharding scales the plan out, it never re-plans.
        """
        if mesh_shape is None:
            mesh_shape = (("data", devices),)
        return dataclasses.replace(self, devices=devices,
                                   mesh_shape=mesh_shape)

    def matches(self, cfg: UltrasoundConfig) -> bool:
        """True iff this plan was built for ``cfg``'s geometry.

        Variant and exec_map are the axes the plan itself decides, so
        they are excluded — a plan built on an AUTO config matches the
        resolved config and vice versa. Everything else differing means
        the plan's decision (and its telemetry stamp) belongs to some
        other pipeline.
        """
        return self.geometry_key == _geometry_key(cfg)

    def concretize(self, cfg: UltrasoundConfig) -> UltrasoundConfig:
        """The requested config with every planned decision applied."""
        return cfg.with_(variant=self.variant, exec_map=self.exec_map)

    def stage_jit(self, stage_name: str) -> bool:
        return dict(self.jit_stages).get(stage_name, True)

    def json_dict(self) -> dict:
        d = {
            "policy": self.policy,
            "backend": self.backend,
            "variant": self.variant.value,
            "exec_map": self.exec_map,
            "donate": self.donate,
            "jit_stages": {k: v for k, v in self.jit_stages},
            "config_key": self.config_key,
            "geometry_key": self.geometry_key,
            "provenance": self.provenance,
            "devices": self.devices,
            "mesh_shape": ([[name, extent] for name, extent
                            in self.mesh_shape]
                           if self.mesh_shape is not None else None),
        }
        if self.autotune_t_s is not None:
            d["autotune_t_s"] = {k: v for k, v in self.autotune_t_s}
        return d


def _geometry_key(cfg: UltrasoundConfig) -> str:
    return config_hash(cfg, exclude=("variant", "exec_map"))


def _default_measure(cfg: UltrasoundConfig, variant: Variant, *,
                     runs: int, warmup: int) -> float:
    """T_avg of one concrete variant, via the paper's bench methodology."""
    import jax.numpy as jnp

    from repro.bench.harness import bench_callable
    from repro.core.pipeline import UltrasoundPipeline
    from repro.data import synth_rf

    c = cfg.with_(variant=variant)
    pipe = UltrasoundPipeline(c)                  # consts cached; untimed
    rf = jnp.asarray(synth_rf(c, seed=0))
    res = bench_callable(
        f"autotune/{c.name}/{variant.value}", None, (pipe.consts, rf),
        input_bytes=c.input_bytes, warmup=warmup, runs=runs,
        jitted=pipe.jitted)
    return res.t_avg_s


def _autotune_timings(cfg: UltrasoundConfig, backend: str, *,
                      runs: int, warmup: int,
                      measure: Optional[Callable]
                      ) -> Tuple[Tuple[str, float], ...]:
    # Probe settings are part of the key (2-run timings must not answer a
    # 50-run request); an injected `measure` is not — tests that swap
    # probes call clear_autotune_memo(). exec_map is excluded too: the
    # probe times single-acquisition pipelines, which never read it.
    memo_key = (_geometry_key(cfg), backend, runs, warmup)
    if memo_key in _AUTOTUNE_MEMO:
        return _AUTOTUNE_MEMO[memo_key]
    measure = measure or _default_measure
    timings = tuple(
        (v.value, float(measure(cfg, v, runs=runs, warmup=warmup)))
        for v in CONCRETE_VARIANTS)
    _AUTOTUNE_MEMO[memo_key] = timings
    return timings


def _stage_jit_defaults(cfg: UltrasoundConfig) -> Tuple[Tuple[str, bool],
                                                        ...]:
    # Imported here: stages imports config, and plan must stay importable
    # from config-only contexts.
    from repro.core.stages import build_graph
    return tuple((s.name, True) for s in build_graph(cfg))


def plan_pipeline(cfg: UltrasoundConfig, policy: str = "fixed", *,
                  donate: Optional[bool] = None,
                  autotune_runs: int = 3, autotune_warmup: int = 1,
                  measure: Optional[Callable] = None) -> PipelinePlan:
    """Resolve a config (possibly ``Variant.AUTO``) into a PipelinePlan.

    ``measure(cfg, variant, runs=, warmup=)`` overrides the autotune
    timing probe (tests inject deterministic timings through it).
    An explicitly concrete ``cfg.variant`` is honored under every policy
    — the planner only ever decides what the user left open.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown plan policy: {policy!r} "
                         f"(expected one of {POLICIES})")
    backend = jax.default_backend()
    key = config_hash(cfg)
    autotune_t_s = None

    if cfg.variant.concrete:
        variant = cfg.variant
        provenance = f"explicit:{variant.value}"
    elif policy == "fixed":
        raise ValueError(
            "policy 'fixed' cannot resolve Variant.AUTO — pass a concrete "
            "variant or use policy='heuristic' / 'autotune'")
    elif policy == "heuristic":
        variant = BACKEND_VARIANT_PREFERENCE.get(backend, DEFAULT_PREFERENCE)
        known = backend in BACKEND_VARIANT_PREFERENCE
        provenance = (f"heuristic:{backend}->{variant.value}"
                      f"{'' if known else ' (default: unknown backend)'}")
    else:  # autotune
        autotune_t_s = _autotune_timings(
            cfg, backend, runs=autotune_runs, warmup=autotune_warmup,
            measure=measure)
        winner = min(autotune_t_s, key=lambda kv: kv[1])
        variant = Variant(winner[0])
        provenance = (f"autotune:{backend}->{variant.value} "
                      f"(t_avg={winner[1]:.3e}s over "
                      f"{len(autotune_t_s)} variants)")

    # The modality decides the head stage, so jit toggles come from the
    # resolved graph. Default: jit every stage (today's behavior).
    resolved = cfg.with_(variant=variant)
    return PipelinePlan(
        variant=variant, exec_map=cfg.exec_map, donate=donate,
        jit_stages=_stage_jit_defaults(resolved), backend=backend,
        policy=policy, config_key=key, geometry_key=_geometry_key(cfg),
        provenance=provenance, autotune_t_s=autotune_t_s)
