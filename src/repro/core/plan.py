"""Backend-aware pipeline planning (variant auto-selection).

The paper's portability contract says ONE source tree runs on every
backend — but *which* operator formulation (dynamic / cnn / sparse) wins
is backend-dependent (paper Table I; ConvBench), so leaving the variant
choice to the user reintroduces exactly the hardware-specific decision
the contract is supposed to eliminate. This module closes that gap:

    plan = plan_pipeline(cfg.with_(variant=Variant.AUTO), policy=...)

produces a frozen `PipelinePlan` — the resolved variant plus every other
execution decision (exec_map, donation, per-stage jit toggles) and full
provenance — which `UltrasoundPipeline`, `BatchedExecutor`, the serve
loop, and the benchmark drivers all accept and stamp into telemetry, so
every reported MB/s–FPS row is attributable to an exact
(backend, variant, exec_map) decision.

Three policies:

  * ``fixed``     — today's behavior: honor ``cfg.variant`` verbatim.
    Refuses ``Variant.AUTO`` (a fixed plan has nothing to resolve it with).
  * ``heuristic`` — resolve AUTO from a per-backend preference registry
    encoding the structure of the paper's Table I: gather-friendly
    backends (cpu/gpu) run V1-dynamic fastest; matmul-unit backends
    (tpu) want the dense CNN formulation. Deterministic and free.
  * ``autotune``  — measure every concrete variant end-to-end for a few
    warm runs through the bench harness and pick the winner. Memoized per
    (config-hash-ignoring-variant, backend) so sweeps pay the search once.

Planning is two-level. The variant decides the *math formulation*; the
planner then resolves one operator *lowering* per stage (``xla`` or a
Pallas kernel — the per-stage registry in repro.core.lowering) into
``PipelinePlan.stage_lowerings``. Explicit ``cfg.stage_lowerings``
entries are always honored (and refused loudly when unregistered for
the resolved variant); unspecified stages consult the per-backend
lowering preference table under fixed/heuristic, or are measured per
stage via the bench harness's stage breakdown under autotune — memoized
alongside the variant memo so sweeps pay each search once.

The registries and the autotune memos are process-global; all are plain
dicts so tests (and future multi-backend sweeps) can inspect or reset
them.

Public API
----------
`plan_pipeline(cfg, policy=...)`        — resolve a config into a plan.
`PipelinePlan`                          — the frozen result; consumed by
    `UltrasoundPipeline`, `BatchedExecutor`, `ShardedExecutor`,
    `serve_ultrasound_stream` and stamped (``json_dict()``) into every
    telemetry record. ``with_devices(n, mesh_shape)`` derives the
    multi-device form the `ShardedExecutor` stamps — ``devices``/
    ``mesh_shape`` keep every NDJSON row attributable to the exact
    device topology that produced it.
`register_backend_preference`           — extend the heuristic registry.
`clear_autotune_memo`                   — reset the measurement memo.

Invariants: plans are frozen; ``variant`` is always concrete (never
AUTO); ``matches(cfg)`` gates consumption so a plan's telemetry stamp
can never be attached to a pipeline with different geometry; the
planner never decides ``exec_map`` or ``devices`` — it records what the
config/executor chose.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.config import UltrasoundConfig, Variant, config_hash

POLICIES = ("fixed", "heuristic", "autotune")

# Paper Table I structure, keyed on jax.default_backend(): backends with a
# fast irregular-access path prefer the explicit-gather formulation; systolic
# matmul-unit backends prefer the dense CNN re-expression. Extendable via
# register_backend_preference (e.g. a future backend measured differently).
BACKEND_VARIANT_PREFERENCE: Dict[str, Variant] = {
    "cpu": Variant.DYNAMIC,
    "gpu": Variant.DYNAMIC,
    "cuda": Variant.DYNAMIC,
    "rocm": Variant.DYNAMIC,
    "tpu": Variant.CNN,
}
# Unknown backends get the portable dense formulation (runs everywhere the
# paper tested, never pathological — the conservative Table I read).
DEFAULT_PREFERENCE = Variant.CNN

CONCRETE_VARIANTS = (Variant.DYNAMIC, Variant.CNN, Variant.SPARSE)

# (geometry key, explicit stage_lowerings, backend, runs, warmup)
#   -> tuple of (variant value, t_avg_s)
_AUTOTUNE_MEMO: Dict[Tuple, Tuple[Tuple[str, float], ...]] = {}

# Per-backend lowering preference, consulted for stages the config leaves
# open under fixed/heuristic: backend -> {(stage, variant value or None)
# -> lowering name}. Anything unlisted runs the "xla" reference. The TPU
# rows encode the kernels' design intent — the fused DAS kernel keeps the
# dynamic gather in VMEM, and the scalar-prefetched BSR SpMM is the
# paper's V3-on-TPU story — gated by each lowering's capability
# predicate, so an unsatisfiable tile constraint falls back to xla.
BACKEND_LOWERING_PREFERENCE: Dict[str, Dict[Tuple[str, Optional[str]],
                                            str]] = {
    "tpu": {
        ("beamform", Variant.DYNAMIC.value): "pallas",
        ("beamform", Variant.SPARSE.value): "pallas",
    },
}

# (resolved-config key sans lowerings, explicit stage_lowerings, backend,
#  runs, warmup) -> tuple of ("stage:lowering", t_avg_s)
_LOWERING_MEMO: Dict[Tuple, Tuple[Tuple[str, float], ...]] = {}

# Pixel-tile candidates the autotune policy probes for the fused
# megakernel's block size (cfg.fusion_block left open). The per-stage
# autotune memo generalizes to fusion groups: probes key into
# _LOWERING_MEMO as "<group>:<name>@bp<bp>" so sweeps pay the search
# once per geometry.
FUSION_BLOCK_CANDIDATES = (64, 128, 256)


def register_backend_preference(backend: str, variant: Variant) -> None:
    """Extend/override the heuristic registry (measured, not assumed)."""
    if not variant.concrete:
        raise ValueError("preference must be a concrete variant")
    BACKEND_VARIANT_PREFERENCE[backend] = variant


def register_lowering_preference(backend: str, stage: str,
                                 variant: Optional[Variant],
                                 lowering_name: str) -> None:
    """Extend/override the per-backend lowering preference table."""
    BACKEND_LOWERING_PREFERENCE.setdefault(backend, {})[
        (stage, variant.value if variant is not None else None)] = \
        lowering_name


def clear_autotune_memo() -> None:
    _AUTOTUNE_MEMO.clear()
    _LOWERING_MEMO.clear()


@dataclasses.dataclass(frozen=True)
class PipelinePlan:
    """A fully resolved execution plan for one pipeline config.

    Everything a consumer needs to build and run the pipeline — and
    everything telemetry needs to attribute a throughput number to an
    exact decision. ``variant`` is always concrete (never AUTO).
    """

    variant: Variant
    exec_map: str                                  # "vmap" | "map"
    donate: Optional[bool]                         # None = backend default
    jit_stages: Tuple[Tuple[str, bool], ...]       # (stage name, jit?) pairs
    backend: str                                   # jax.default_backend()
    policy: str                                    # member of POLICIES
    config_key: str                                # hash of the REQUESTED cfg
    geometry_key: str                              # hash sans planned axes
    provenance: str                                # how the variant was chosen
    # One resolved operator lowering per stage of the graph ("xla" or
    # "pallas"; repro.core.lowering) — concretize() writes these into
    # cfg.stage_lowerings so the executed config, its canonical hash
    # (multi-tenant grouping), and every telemetry stamp agree.
    stage_lowerings: Tuple[Tuple[str, str], ...] = ()
    # Fusion/precision contract stamp. ``fusion``/``precision`` echo the
    # config's request (both are geometry-key axes — a fused plan can
    # never be consumed by an unfused pipeline or vice versa);
    # ``fusion_group`` names the claimed span ("demod+beamform+bmode");
    # ``fusion_block`` is the planner-DECIDED pixel-tile size (None =
    # kernel default), excluded from the geometry key like the other
    # planned axes.
    fusion: str = "none"
    precision: str = "f32"
    fusion_group: Optional[str] = None
    fusion_block: Optional[int] = None
    autotune_t_s: Optional[Tuple[Tuple[str, float], ...]] = None
    # Per-stage lowering timings when autotune had to measure (pairs of
    # ("stage:lowering", t_avg_s)); None when the table decided.
    lowering_t_s: Optional[Tuple[Tuple[str, float], ...]] = None
    # Device topology the plan executes on. 1/None = single-device (the
    # BatchedExecutor default); the ShardedExecutor stamps its mesh via
    # with_devices() so every telemetry record names its topology.
    devices: int = 1
    mesh_shape: Optional[Tuple[Tuple[str, int], ...]] = None
    # Serving-mode stamps (with_serving): how the program got warm
    # (None = live first-dispatch compile, "aot" = ahead-of-time
    # lower+compile this window — repro.core.aot — possibly against the
    # persistent compilation cache, "pool" = reused an already-warm
    # WarmPool executor) and the scheduler's in-flight dispatch depth
    # (None = offline / one-at-a-time semantics). Neither is a
    # planning decision — like devices/mesh_shape they record the
    # execution context so every NDJSON row stays attributable.
    warm_start: Optional[str] = None
    in_flight: Optional[int] = None

    def __post_init__(self):
        assert self.variant.concrete, "plan must carry a concrete variant"
        assert self.devices >= 1, "plan needs at least one device"
        if self.fusion == "fused":
            assert self.fusion_group, \
                "a fused plan must name its fusion group"
        else:
            assert self.fusion_group is None and self.fusion_block is None, \
                "an unfused plan cannot carry fusion_group/fusion_block"
        jitted = {name for name, _ in self.jit_stages}
        lowered = {name for name, _ in self.stage_lowerings}
        assert lowered == jitted, (
            f"plan must resolve a lowering for every stage of the graph "
            f"(got {sorted(lowered)}, graph has {sorted(jitted)})")
        if self.mesh_shape is not None:
            n = 1
            for _, extent in self.mesh_shape:
                n *= extent
            assert n == self.devices, \
                f"mesh_shape {self.mesh_shape} != devices {self.devices}"

    def with_devices(self, devices: int,
                     mesh_shape: Optional[Tuple[Tuple[str, int], ...]] = None
                     ) -> "PipelinePlan":
        """This plan, stamped with the executing device topology.

        The decision axes (variant/exec_map/policy/provenance) are
        unchanged — sharding scales the plan out, it never re-plans.
        """
        if mesh_shape is None:
            mesh_shape = (("data", devices),)
        return dataclasses.replace(self, devices=devices,
                                   mesh_shape=mesh_shape)

    def with_serving(self, *, warm_start: Optional[str],
                     in_flight: Optional[int]) -> "PipelinePlan":
        """This plan, stamped with its serving execution context.

        Like `with_devices`, a pure telemetry stamp: the scheduler
        records how the group's program was warmed ("aot" / "pool")
        and the window's in-flight dispatch depth so overlap numbers
        stay attributable. Decision axes unchanged.
        """
        if in_flight is not None and in_flight < 1:
            raise ValueError(f"in_flight must be >= 1 (got {in_flight})")
        return dataclasses.replace(self, warm_start=warm_start,
                                   in_flight=in_flight)

    def matches(self, cfg: UltrasoundConfig) -> bool:
        """True iff this plan was built for ``cfg``'s geometry.

        Variant, exec_map, and stage_lowerings are the axes the plan
        itself decides, so they are excluded — a plan built on an AUTO
        config matches the resolved config and vice versa. Everything
        else differing means the plan's decision (and its telemetry
        stamp) belongs to some other pipeline.
        """
        return self.geometry_key == _geometry_key(cfg)

    def concretize(self, cfg: UltrasoundConfig) -> UltrasoundConfig:
        """The requested config with every planned decision applied."""
        return cfg.with_(variant=self.variant, exec_map=self.exec_map,
                         stage_lowerings=self.stage_lowerings,
                         fusion_block=self.fusion_block)

    def stage_jit(self, stage_name: str) -> bool:
        return dict(self.jit_stages).get(stage_name, True)

    def json_dict(self) -> dict:
        d = {
            "policy": self.policy,
            "backend": self.backend,
            "variant": self.variant.value,
            "exec_map": self.exec_map,
            "donate": self.donate,
            "jit_stages": {k: v for k, v in self.jit_stages},
            "stage_lowerings": {k: v for k, v in self.stage_lowerings},
            "fusion": self.fusion,
            "precision": self.precision,
            "fusion_group": self.fusion_group,
            "fusion_block": self.fusion_block,
            "config_key": self.config_key,
            "geometry_key": self.geometry_key,
            "provenance": self.provenance,
            "devices": self.devices,
            "mesh_shape": ([[name, extent] for name, extent
                            in self.mesh_shape]
                           if self.mesh_shape is not None else None),
            "warm_start": self.warm_start,
            "in_flight": self.in_flight,
        }
        if self.autotune_t_s is not None:
            d["autotune_t_s"] = {k: v for k, v in self.autotune_t_s}
        if self.lowering_t_s is not None:
            d["lowering_t_s"] = {k: v for k, v in self.lowering_t_s}
        return d


def _geometry_key(cfg: UltrasoundConfig) -> str:
    # fusion/precision stay IN the key (user-requested program axes: the
    # scheduler must never batch fused and unfused — or f32 and bf16 —
    # acquisitions into one program); fusion_block joins the excluded
    # planner-decided axes.
    return config_hash(cfg,
                       exclude=("variant", "exec_map", "stage_lowerings",
                                "fusion_block"))


def _default_measure(cfg: UltrasoundConfig, variant: Variant, *,
                     runs: int, warmup: int) -> float:
    """T_avg of one concrete variant, via the paper's bench methodology."""
    import jax.numpy as jnp

    from repro.bench.harness import bench_callable
    from repro.core import lowering as lowering_lib
    from repro.core.pipeline import UltrasoundPipeline
    from repro.data import synth_rf

    c = cfg.with_(variant=variant)
    # Explicit lowering entries the probed variant does not register
    # (e.g. a pallas beamform while probing CNN) must not crash the
    # probe; the final plan still validates against the winner.
    c = c.with_(stage_lowerings=lowering_lib.supported_subset(c))
    pipe = UltrasoundPipeline(c)                  # consts cached; untimed
    rf = jnp.asarray(synth_rf(c, seed=0))
    res = bench_callable(
        f"autotune/{c.name}/{variant.value}", None, (pipe.consts, rf),
        input_bytes=c.input_bytes, warmup=warmup, runs=runs,
        jitted=pipe.jitted)
    return res.t_avg_s


def _default_stage_measure(cfg: UltrasoundConfig, stage: str, *,
                           runs: int, warmup: int) -> float:
    """Mean per-stage time of ``stage`` under ``cfg``'s lowerings, via
    the existing bench_stages breakdown (§II-E per-stage protocol)."""
    import jax.numpy as jnp

    from repro.bench.harness import bench_stages
    from repro.data import synth_rf

    rf = jnp.asarray(synth_rf(cfg, seed=0))
    breakdown = bench_stages(cfg, rf, warmup=warmup, runs=runs)
    return breakdown[stage].mean_s


def _variant_candidates(cfg: UltrasoundConfig,
                        backend: str) -> Tuple[Variant, ...]:
    """Concrete variants able to honor every explicit lowering entry.

    With no explicit entries this is all three; a pinned pallas
    beamform excludes CNN (nothing registered) so AUTO resolution can
    never land on a variant that would refuse the pin. A
    ``fusion='fused'`` config additionally filters to variants whose
    (variant, modality) cell has a runnable fused registration.
    """
    from repro.core import lowering as lowering_lib
    candidates = tuple(
        v for v in CONCRETE_VARIANTS
        if lowering_lib.supports_explicit(cfg.with_(variant=v), backend)
        and (cfg.fusion != "fused"
             or lowering_lib.fused_supported(cfg.with_(variant=v),
                                             backend)))
    if not candidates:
        raise ValueError(
            f"no concrete variant supports the explicit stage_lowerings "
            f"{dict(cfg.stage_lowerings)}"
            + (" with fusion='fused'" if cfg.fusion == "fused" else "")
            + f" on backend {backend!r} — drop "
            "an override or register the missing lowering")
    return candidates


def _autotune_timings(cfg: UltrasoundConfig, backend: str, *,
                      variants: Tuple[Variant, ...],
                      runs: int, warmup: int,
                      measure: Optional[Callable]
                      ) -> Tuple[Tuple[str, float], ...]:
    # Probe settings are part of the key (2-run timings must not answer a
    # 50-run request); an injected `measure` is not — tests that swap
    # probes call clear_autotune_memo(). exec_map is excluded too: the
    # probe times single-acquisition pipelines, which never read it.
    # Explicit stage_lowerings ARE part of the key: the probe runs under
    # them, so timings measured with a pallas beamform must not answer
    # for a plain config (telemetry stays attributable). So is the
    # candidate set — registry extensions change it without changing
    # the config.
    memo_key = (_geometry_key(cfg), cfg.stage_lowerings,
                tuple(v.value for v in variants), backend, runs, warmup)
    if memo_key in _AUTOTUNE_MEMO:
        return _AUTOTUNE_MEMO[memo_key]
    measure = measure or _default_measure
    timings = tuple(
        (v.value, float(measure(cfg, v, runs=runs, warmup=warmup)))
        for v in variants)
    _AUTOTUNE_MEMO[memo_key] = timings
    return timings


def _stage_jit_defaults(cfg: UltrasoundConfig) -> Tuple[Tuple[str, bool],
                                                        ...]:
    # Imported here: stages imports config, and plan must stay importable
    # from config-only contexts.
    from repro.core.stages import build_graph
    return tuple((s.name, True) for s in build_graph(cfg))


def _preferred_lowering(cfg: UltrasoundConfig, stage: str,
                        backend: str, candidates: Dict) -> str:
    """Table pick among ``candidates`` (available lowerings), else xla."""
    from repro.core import lowering as lowering_lib
    table = BACKEND_LOWERING_PREFERENCE.get(backend, {})
    for op_key in ((stage, cfg.variant.value), (stage, None)):
        want = table.get(op_key)
        if want is not None and want in candidates:
            return want
    return (lowering_lib.DEFAULT_LOWERING
            if lowering_lib.DEFAULT_LOWERING in candidates
            else sorted(candidates)[0])


def _resolve_stage_lowerings(cfg: UltrasoundConfig, backend: str, *,
                             policy: str, runs: int, warmup: int,
                             measure_stage: Optional[Callable]
                             ) -> Tuple[Tuple[Tuple[str, str], ...],
                                        Optional[Tuple[Tuple[str, float],
                                                       ...]]]:
    """One lowering per stage of ``cfg``'s (variant-resolved) graph.

    Explicit ``cfg.stage_lowerings`` entries are honored verbatim —
    and refused loudly at plan time when the registry has no such
    lowering for the resolved variant, or when its capability predicate
    rejects this backend/geometry (an explicit ask must run or fail
    here, never silently fall back or die deep inside kernel
    compilation). Open stages consult the per-backend preference table
    (fixed/heuristic) or measure every available candidate through the
    per-stage bench breakdown (autotune, memoized). Returns the
    resolved pairs plus the ("stage:lowering", t) timings when autotune
    measured (None otherwise).

    Under ``fusion='fused'`` the resolved fused lowering CLAIMS its
    span: every spanned stage is stamped with the fused lowering's name
    (an explicit pin naming anything else is a contradiction and fails
    here), and only the stages outside the span go through per-stage
    resolution.
    """
    from repro.core import lowering as lowering_lib
    from repro.core.stages import build_graph

    fused = (lowering_lib.resolve_fused(cfg, backend)
             if cfg.fusion == "fused" else None)
    explicit = dict(cfg.stage_lowerings)
    graph_stages = {s.name for s in build_graph(cfg)}
    stray = sorted(set(explicit) - graph_stages)
    if stray:
        # A pin for a stage this modality's graph never runs would be
        # silently dropped by concretize() — a typo like pinning "bmode"
        # on a doppler config must fail here, not run something else.
        raise ValueError(
            f"stage_lowerings pins stage(s) {stray} that are not in "
            f"this pipeline's graph ({sorted(graph_stages)} for "
            f"modality {cfg.modality.value!r})")
    resolved = []
    to_tune = []
    for stage in build_graph(cfg):
        if fused is not None and stage.name in fused.stages:
            pin = explicit.get(stage.name)
            if pin is not None and pin != fused.name:
                raise ValueError(
                    f"stage_lowerings pins {stage.name!r} to {pin!r}, "
                    f"but fusion='fused' claims the "
                    f"{fused.group!r} span with the {fused.name!r} "
                    "lowering — drop the pin or set fusion='none'")
            resolved.append((stage.name, fused.name))
            continue
        if stage.name in explicit:
            name = explicit[stage.name]
            registered = lowering_lib.registered_lowerings(cfg, stage.name)
            if name not in registered:
                raise ValueError(
                    f"config requests lowering {name!r} for stage "
                    f"{stage.name!r}, but the registry has no such "
                    f"lowering for variant {cfg.variant.value!r} — "
                    "register one (repro.core.lowering) or drop the "
                    "override")
            if not registered[name].available(cfg, backend):
                raise ValueError(
                    f"lowering {name!r} for stage {stage.name!r} is "
                    f"registered but not available on backend "
                    f"{backend!r} for this geometry (capability "
                    "predicate failed — see docs/kernels.md for the "
                    "tile constraints)")
            resolved.append((stage.name, name))
            continue
        candidates = lowering_lib.available_lowerings(cfg, stage.name,
                                                      backend)
        if not candidates:
            # Reachable under reduced precision: the f32-only xla
            # reference drops out of the candidate set, so any stage
            # without a reduced-precision kernel fails here loudly.
            raise ValueError(
                f"no available lowering for stage {stage.name!r} on "
                f"backend {backend!r} at precision {cfg.precision!r} — "
                "reduced precision needs a kernel that declares it "
                "(set fusion='fused' for the megakernel, or "
                "precision='f32')")
        if policy == "autotune" and len(candidates) > 1:
            to_tune.append((stage.name, sorted(candidates)))
            resolved.append((stage.name, None))      # filled below
        else:
            resolved.append((stage.name, _preferred_lowering(
                cfg, stage.name, backend, candidates)))

    timings: Optional[Tuple[Tuple[str, float], ...]] = None
    if to_tune:
        timings = _lowering_timings(
            cfg, backend,
            base=tuple((s, n) for s, n in resolved if n is not None),
            to_tune=tuple((s, tuple(c)) for s, c in to_tune),
            runs=runs, warmup=warmup, measure_stage=measure_stage)
        winners = {}
        for key, t in timings:
            stage_name, low_name = key.split(":", 1)
            if (stage_name not in winners
                    or t < winners[stage_name][1]):
                winners[stage_name] = (low_name, t)
        resolved = [(s, n if n is not None else winners[s][0])
                    for s, n in resolved]
    return tuple(resolved), timings


def _lowering_timings(cfg: UltrasoundConfig, backend: str, *,
                      base: Tuple[Tuple[str, str], ...],
                      to_tune: Tuple[Tuple[str, Tuple[str, ...]], ...],
                      runs: int, warmup: int,
                      measure_stage: Optional[Callable]
                      ) -> Tuple[Tuple[str, float], ...]:
    """Measured ("stage:lowering", t_avg_s) pairs, memoized like the
    variant search. The memo keys on the explicit-entry set AND the
    contested (stage, candidates) set itself — `register_lowering` can
    grow the latter at any time without touching the config, and a
    stale entry missing a newly contested stage must miss, not crash.
    Injected probes are not part of the key (tests that swap them call
    clear_autotune_memo())."""
    memo_key = (config_hash(cfg, exclude=("exec_map", "stage_lowerings")),
                cfg.stage_lowerings, to_tune, backend, runs, warmup)
    if memo_key in _LOWERING_MEMO:
        return _LOWERING_MEMO[memo_key]
    measure_stage = measure_stage or _default_stage_measure
    explicit = dict(cfg.stage_lowerings)
    timings = []
    for stage_name, candidates in to_tune:
        for name in candidates:
            assignment = dict(base)
            assignment.update(explicit)
            assignment[stage_name] = name
            probe_cfg = cfg.with_(
                stage_lowerings=tuple(sorted(assignment.items())))
            t = float(measure_stage(probe_cfg, stage_name,
                                    runs=runs, warmup=warmup))
            timings.append((f"{stage_name}:{name}", t))
    result = tuple(timings)
    _LOWERING_MEMO[memo_key] = result
    return result


def _fusion_block_timings(cfg: UltrasoundConfig, backend: str, fused, *,
                          stage_lowerings: Tuple[Tuple[str, str], ...],
                          runs: int, warmup: int,
                          measure_stage: Optional[Callable]
                          ) -> Tuple[Tuple[str, float], ...]:
    """Measured ("<group>:<name>@bp<bp>", t_avg_s) pairs for the fused
    megakernel's pixel-tile candidates — the per-stage autotune memo
    generalized to a fusion group. The probe times the group entry of
    the bench_stages breakdown (stage_fns exposes the span under its
    group key), memoized per geometry like the per-stage search."""
    from repro.kernels.pallas_compat import next_multiple

    n_pix = cfg.nz * cfg.nx
    cap = next_multiple(n_pix, 8)
    bps = tuple(sorted({min(bp, cap) for bp in FUSION_BLOCK_CANDIDATES}))
    memo_key = (config_hash(cfg, exclude=("exec_map", "stage_lowerings",
                                          "fusion_block")),
                cfg.stage_lowerings, fused.group, bps, backend, runs,
                warmup)
    if memo_key in _LOWERING_MEMO:
        return _LOWERING_MEMO[memo_key]
    measure_stage = measure_stage or _default_stage_measure
    timings = []
    for bp in bps:
        probe_cfg = cfg.with_(stage_lowerings=stage_lowerings,
                              fusion_block=bp)
        t = float(measure_stage(probe_cfg, fused.group,
                                runs=runs, warmup=warmup))
        timings.append((f"{fused.group}:{fused.name}@bp{bp}", t))
    result = tuple(timings)
    _LOWERING_MEMO[memo_key] = result
    return result


def plan_pipeline(cfg: UltrasoundConfig, policy: str = "fixed", *,
                  donate: Optional[bool] = None,
                  autotune_runs: int = 3, autotune_warmup: int = 1,
                  measure: Optional[Callable] = None,
                  measure_stage: Optional[Callable] = None) -> PipelinePlan:
    """Resolve a config (possibly ``Variant.AUTO``) into a PipelinePlan.

    ``measure(cfg, variant, runs=, warmup=)`` overrides the autotune
    variant probe and ``measure_stage(cfg, stage, runs=, warmup=)`` the
    per-stage lowering probe (tests inject deterministic timings through
    both). An explicitly concrete ``cfg.variant`` — and any explicit
    ``cfg.stage_lowerings`` entry — is honored under every policy; the
    planner only ever decides what the user left open.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown plan policy: {policy!r} "
                         f"(expected one of {POLICIES})")
    backend = jax.default_backend()
    key = config_hash(cfg)
    autotune_t_s = None

    if cfg.variant.concrete:
        variant = cfg.variant
        provenance = f"explicit:{variant.value}"
    elif policy == "fixed":
        raise ValueError(
            "policy 'fixed' cannot resolve Variant.AUTO — pass a concrete "
            "variant or use policy='heuristic' / 'autotune'")
    elif policy == "heuristic":
        candidates = _variant_candidates(cfg, backend)
        variant = BACKEND_VARIANT_PREFERENCE.get(backend, DEFAULT_PREFERENCE)
        known = backend in BACKEND_VARIANT_PREFERENCE
        provenance = (f"heuristic:{backend}->{variant.value}"
                      f"{'' if known else ' (default: unknown backend)'}")
        if variant not in candidates:
            # The preferred variant cannot honor an explicit lowering
            # pin — fall to the first candidate that can, and say so.
            variant = candidates[0]
            provenance += (f" -> {variant.value} (preference cannot honor "
                           f"explicit stage_lowerings)")
    else:  # autotune
        autotune_t_s = _autotune_timings(
            cfg, backend, variants=_variant_candidates(cfg, backend),
            runs=autotune_runs, warmup=autotune_warmup,
            measure=measure)
        winner = min(autotune_t_s, key=lambda kv: kv[1])
        variant = Variant(winner[0])
        provenance = (f"autotune:{backend}->{variant.value} "
                      f"(t_avg={winner[1]:.3e}s over "
                      f"{len(autotune_t_s)} variants)")

    # The modality decides the head stage, so jit toggles (and the
    # per-stage lowering resolution) come from the resolved graph.
    # Default: jit every stage (today's behavior).
    resolved = cfg.with_(variant=variant)
    stage_lowerings, lowering_t_s = _resolve_stage_lowerings(
        resolved, backend, policy=policy,
        runs=autotune_runs, warmup=autotune_warmup,
        measure_stage=measure_stage)

    # Fusion-group resolution: the fused lowering was validated inside
    # _resolve_stage_lowerings; here the planner decides the block size
    # (explicit cfg.fusion_block honored, autotune measures the
    # candidates, fixed/heuristic defer to the kernel default).
    fusion_group = None
    fusion_block = None
    if cfg.fusion == "fused":
        from repro.core import lowering as lowering_lib
        fused = lowering_lib.resolve_fused(resolved, backend)
        fusion_group = fused.group
        fusion_block = cfg.fusion_block
        if fusion_block is None and policy == "autotune":
            bp_t = _fusion_block_timings(
                resolved, backend, fused, stage_lowerings=stage_lowerings,
                runs=autotune_runs, warmup=autotune_warmup,
                measure_stage=measure_stage)
            best = min(bp_t, key=lambda kv: kv[1])
            fusion_block = int(best[0].rsplit("@bp", 1)[1])
            lowering_t_s = (lowering_t_s or ()) + bp_t

    return PipelinePlan(
        variant=variant, exec_map=cfg.exec_map, donate=donate,
        jit_stages=_stage_jit_defaults(resolved), backend=backend,
        policy=policy, config_key=key, geometry_key=_geometry_key(cfg),
        provenance=provenance, stage_lowerings=stage_lowerings,
        fusion=cfg.fusion, precision=cfg.precision,
        fusion_group=fusion_group, fusion_block=fusion_block,
        autotune_t_s=autotune_t_s, lowering_t_s=lowering_t_s)
