"""repro.core — deterministic CNN-expressed ultrasound DSP pipelines.

The paper's contribution: complete RF-to-image pipelines (B-mode, Color
Doppler, Power Doppler) built from a restricted, deterministic operator set,
in three implementation variants (dynamic / cnn / sparse).
"""

from repro.core.config import (  # noqa: F401
    Modality,
    PIPELINE_NAMES,
    UltrasoundConfig,
    Variant,
    paper_config,
    tiny_config,
)
from repro.core.pipeline import (  # noqa: F401
    UltrasoundPipeline,
    init_pipeline,
    pipeline_fn,
)
