"""repro.core — deterministic CNN-expressed ultrasound DSP pipelines.

The paper's contribution: complete RF-to-image pipelines (B-mode, Color
Doppler, Power Doppler) built from a restricted, deterministic operator set,
in three implementation variants (dynamic / cnn / sparse).

Module map (details in docs/architecture.md): config -> stages ->
plan -> pipeline/executor. `UltrasoundPipeline` is the one-acquisition
convenience wrapper; `BatchedExecutor` / `ShardedExecutor` are the
batched single-/multi-device engines the serving loop drives.
"""

from repro.core.config import (  # noqa: F401
    EXEC_MAPS,
    LOWERING_NAMES,
    Modality,
    PIPELINE_NAMES,
    STAGE_NAMES,
    UltrasoundConfig,
    Variant,
    config_hash,
    paper_config,
    tiny_config,
)
from repro.core.aot import (  # noqa: F401
    AotProgram,
    WarmPool,
    aot_warm,
    compile_cache_dir,
    configure_persistent_cache,
    set_compile_cache_dir,
    warm_pool,
)
from repro.core.lowering import (  # noqa: F401
    Lowering,
    apply_stage,
    available_lowerings,
    register_lowering,
    registered_lowerings,
)
from repro.core.pipeline import (  # noqa: F401
    CONSTS_CACHE_STATS,
    UltrasoundPipeline,
    clear_consts_cache,
    consts_cache_dir,
    init_pipeline,
    monolithic_pipeline_fn,
    pipeline_fn,
    set_consts_cache_dir,
)
from repro.core.plan import (  # noqa: F401
    PipelinePlan,
    clear_autotune_memo,
    plan_pipeline,
    register_backend_preference,
    register_lowering_preference,
)
from repro.core.stages import (  # noqa: F401
    Stage,
    build_graph,
    graph_fn,
    init_graph_consts,
    stage_fns,
)
from repro.core.executor import (  # noqa: F401
    BatchedExecutor,
    ShardedExecutor,
)

__all__ = [
    # config
    "EXEC_MAPS",
    "LOWERING_NAMES",
    "Modality",
    "PIPELINE_NAMES",
    "STAGE_NAMES",
    "UltrasoundConfig",
    "Variant",
    "config_hash",
    "paper_config",
    "tiny_config",
    # AOT warm start + persistent compilation cache
    "AotProgram",
    "WarmPool",
    "aot_warm",
    "compile_cache_dir",
    "configure_persistent_cache",
    "set_compile_cache_dir",
    "warm_pool",
    # operator lowerings
    "Lowering",
    "apply_stage",
    "available_lowerings",
    "register_lowering",
    "registered_lowerings",
    # pipeline + consts cache
    "CONSTS_CACHE_STATS",
    "UltrasoundPipeline",
    "clear_consts_cache",
    "consts_cache_dir",
    "init_pipeline",
    "monolithic_pipeline_fn",
    "pipeline_fn",
    "set_consts_cache_dir",
    # planning
    "PipelinePlan",
    "clear_autotune_memo",
    "plan_pipeline",
    "register_backend_preference",
    "register_lowering_preference",
    # stage graph
    "Stage",
    "build_graph",
    "graph_fn",
    "init_graph_consts",
    "stage_fns",
    # executors
    "BatchedExecutor",
    "ShardedExecutor",
]
