"""B-mode head: envelope detection + dynamic-range compression.

RF -> IQ -> beamformed IQ -> |.| -> 20 log10 -> clip to dynamic range
-> normalized [0, 1] image (paper §II-A). One forward pass emits all
n_f frames simultaneously (the paper's B-mode batches N_f = 32 images
per call).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import cnn_ops
from repro.core.config import UltrasoundConfig


def envelope(bf: jnp.ndarray) -> jnp.ndarray:
    """(n_pix, n_f, 2) beamformed IQ -> (n_pix, n_f) envelope.

    The tile-local half of the head: pure pointwise magnitude, so the
    fused megakernel (repro.kernels.fused_pipeline) computes it per
    pixel tile without leaving VMEM.
    """
    return cnn_ops.magnitude(bf[..., 0], bf[..., 1])


def compress_envelope(cfg: UltrasoundConfig, env: jnp.ndarray) -> jnp.ndarray:
    """(n_pix, n_f) envelope -> (nz, nx, n_f) image in [0, 1].

    The global half of the head: normalize_by_max reduces over ALL
    pixels, so it cannot be tile-local — this is the fused lowering's
    documented fusion boundary (docs/kernels.md). Shared verbatim by the
    monolithic reference and the fused epilogue so the two paths cannot
    drift numerically.
    """
    env = cnn_ops.normalize_by_max(env, axis=0)
    if cfg.cnn_transcendentals:
        db = cnn_ops.db20_approx(env)
    else:
        db = 20.0 * jnp.log10(jnp.maximum(env, 1e-30))
    dr = cfg.dynamic_range_db
    img = (cnn_ops.clip(db, -dr, 0.0) + dr) / dr
    return img.reshape(cfg.nz, cfg.nx, -1)


def bmode_image(cfg: UltrasoundConfig, bf: jnp.ndarray) -> jnp.ndarray:
    """(n_pix, n_f, 2) beamformed IQ -> (nz, nx, n_f) image in [0, 1]."""
    return compress_envelope(cfg, envelope(bf))
