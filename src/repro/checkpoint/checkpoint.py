"""Checkpointing: atomic, async, elastic-restore.

Layout:
  <dir>/step_000123.npz.tmp -> fsync -> rename to step_000123.npz  (atomic)
  <dir>/MANIFEST.json        latest committed step + tree metadata

Properties needed at cluster scale, reproduced here:
  * atomicity — a preempted save never corrupts the latest checkpoint
    (write-to-temp + rename; the manifest is updated only after commit).
  * async — `AsyncCheckpointer` snapshots to host memory on-thread
    (device_get), then serializes on a background thread so the train loop
    never stalls on disk.
  * elastic restore — arrays are stored with full logical shapes; `restore`
    re-places them under *any* sharding (different mesh shape / device
    count), enabling restart on a resized slice.

Production note: for multi-host models that exceed host RAM, swap the npz
backend for tensorstore/OCDBT per-shard writes; the interface (save /
restore / latest_step) is the stable contract.
"""

from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Dict, Optional

import numpy as np

import jax


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = np.asarray(jax.device_get(leaf))
        if arr.dtype.kind == "V" or str(arr.dtype) in (
                "bfloat16", "float8_e4m3fn", "float8_e5m2"):
            # npz can't round-trip ml_dtypes; widen for storage, restore
            # narrows back to the template dtype.
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def _unflatten_into(template, flat: Dict[str, np.ndarray]):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, leaf in paths:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        arr = flat[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(ckpt_dir: str, step: int, tree) -> str:
    """Atomic synchronous save. Returns the committed path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **flat)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)
    manifest = {"latest_step": step, "time": time.time(),
                "n_arrays": len(flat)}
    mtmp = os.path.join(ckpt_dir, "MANIFEST.json.tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
    os.rename(mtmp, os.path.join(ckpt_dir, "MANIFEST.json"))
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    mpath = os.path.join(ckpt_dir, "MANIFEST.json")
    if not os.path.exists(mpath):
        return None
    with open(mpath) as f:
        return int(json.load(f)["latest_step"])


def restore(ckpt_dir: str, step: int, template,
            shardings=None):
    """Load step; re-place under `shardings` (elastic) if given."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as z:
        flat = {k: z[k] for k in z.files}
    tree = _unflatten_into(template, flat)
    if shardings is not None:
        tree = jax.tree.map(jax.device_put, tree, shardings)
    return tree


class AsyncCheckpointer:
    """Snapshot on-call, serialize on a daemon thread (non-blocking)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree) -> None:
        self.wait()  # at most one in-flight save
        host_tree = jax.tree.map(lambda a: np.asarray(jax.device_get(a)),
                                 tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
