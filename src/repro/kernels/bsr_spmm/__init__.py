from repro.kernels.bsr_spmm.ops import bsr_beamform, bsr_spmm  # noqa: F401
