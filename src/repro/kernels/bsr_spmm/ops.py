"""Public wrapper for the BSR SpMM kernel (complex, multi-channel DAS V3).

`bsr_spmm` is the raw real-valued primitive. `bsr_beamform` composes it into
the complex multi-channel beamform used by repro.core's sparse variant:
channels vmapped, complex arithmetic as four real SpMMs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bsr_spmm import kernel as _k
from repro.kernels.pallas_compat import auto_interpret


def bsr_spmm(cols, blocks, x, *, precision="f32", interpret=None):
    return _k.bsr_spmm_pallas(
        cols, blocks.astype(jnp.float32), x.astype(jnp.float32),
        precision=precision, interpret=auto_interpret(interpret))


def bsr_beamform(cols, blocks, iq_b, *, precision="f32", interpret=None):
    """Complex multi-channel beamform via block-sparse matmuls.

    Args:
      cols:   (n_c, n_pb, K) int32.
      blocks: (n_c, n_pb, K, bp, bs, 2) f32 (complex as trailing re/im).
      iq_b:   (n_sb, bs, n_c, n_f, 2) f32 blocked IQ.
      precision: SpMM-operand dtype, "f32" | "bf16" | "f16"
        (accumulation is always f32).
    Returns:
      (n_pb * bp, n_f, 2) f32 beamformed output, summed over channels.
    """
    interpret = auto_interpret(interpret)

    def one_channel(cols_1, blocks_1, iq_1):
        # iq_1: (n_sb, bs, n_f, 2)
        a = bsr_spmm(cols_1, blocks_1[..., 0], iq_1[..., 0],
                     precision=precision, interpret=interpret)   # re*re
        b = bsr_spmm(cols_1, blocks_1[..., 1], iq_1[..., 1],
                     precision=precision, interpret=interpret)   # im*im
        c = bsr_spmm(cols_1, blocks_1[..., 0], iq_1[..., 1],
                     precision=precision, interpret=interpret)   # re*im
        d = bsr_spmm(cols_1, blocks_1[..., 1], iq_1[..., 0],
                     precision=precision, interpret=interpret)   # im*re
        return jnp.stack([a - b, c + d], axis=-1)   # (n_pb, bp, n_f, 2)

    per_c = jax.vmap(one_channel, in_axes=(0, 0, 2))(cols, blocks, iq_b)
    y = per_c.sum(axis=0)
    n_pb, bp = y.shape[0], y.shape[1]
    return y.reshape(n_pb * bp, *y.shape[2:])
