"""Block-sparse (BSR) SpMM Pallas kernel — the paper's V3 on TPU.

The paper could not run its sparse-matrix variant on TPU ("structured sparse
operators are not fully supported by the current TPU execution backend").
This kernel is the TPU-native adaptation: sparsity is expressed at MXU-tile
granularity (BSR blocks), block column indices are *scalar-prefetched* so
the Pallas pipeline can schedule the HBM->VMEM DMA of the right x-block
before each grid step, and each step is one dense (bp x bs) @ (bs x nf)
MXU matmul accumulated into the output tile.

  y[i] = sum_k blocks[i, k] @ x[cols[i, k]]        i = 0..n_pb-1

Grid: (n_pb, K) with the K axis sequential (accumulation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams


# Matmul-operand compute dtypes (accumulation stays f32 via
# preferred_element_type; "f32" is the identity cast / bit-exact path).
COMPUTE_DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "f16": jnp.float16,
}


def _kernel(cols_ref, block_ref, x_ref, y_ref, *, precision):
    cdt = COMPUTE_DTYPES[precision]
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    y_ref[...] += jnp.dot(
        block_ref[0, 0].astype(cdt), x_ref[0].astype(cdt),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("precision", "interpret"))
def bsr_spmm_pallas(cols, blocks, x, *, precision: str = "f32",
                    interpret: bool = True):
    """y[i] = sum_k blocks[i,k] @ x[cols[i,k]].

    Args:
      cols:   (n_pb, K) int32 block-column indices.
      blocks: (n_pb, K, bp, bs) f32 dense blocks.
      x:      (n_sb, bs, nf) f32 blocked dense operand.
      precision: matmul-operand dtype, "f32" | "bf16" | "f16"
        (accumulation is always f32).
    Returns:
      (n_pb, bp, nf) f32.
    """
    n_pb, K, bp, bs = blocks.shape
    n_sb, _, nf = x.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_pb, K),
        in_specs=[
            pl.BlockSpec((1, 1, bp, bs), lambda i, k, cols: (i, k, 0, 0)),
            pl.BlockSpec((1, bs, nf), lambda i, k, cols: (cols[i, k], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bp, nf), lambda i, k, cols: (i, 0, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, precision=precision),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_pb, bp, nf), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
    )(cols, blocks, x)
