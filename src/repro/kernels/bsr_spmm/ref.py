"""Pure-jnp oracle for the BSR SpMM kernel."""

from __future__ import annotations

import jax.numpy as jnp


def bsr_spmm_ref(cols, blocks, x):
    """y[i] = sum_k blocks[i,k] @ x[cols[i,k]].

    cols (n_pb, K) i32; blocks (n_pb, K, bp, bs); x (n_sb, bs, nf).
    Returns (n_pb, bp, nf).
    """
    g = jnp.take(x, cols, axis=0)          # (n_pb, K, bs, nf)
    return jnp.einsum("ikps,iksf->ipf", blocks, g)
