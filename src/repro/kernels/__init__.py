"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as a subpackage with three modules:
  kernel.py - pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    - jitted public wrapper (padding, vmapping, dtype handling)
  ref.py    - pure-jnp oracle used by the allclose test sweeps

All kernels are validated on CPU with interpret=True; on TPU the same code
lowers through Mosaic. Kernels are opt-in (config flag) - the XLA paths in
repro.core / repro.models remain the portable default, per the paper's
single-source portability contract.

The public entry points are re-exported here so callers (and the lowering
registry) do not need to know the subpackage layout. The raw ``bsr_spmm``
primitive is deliberately NOT re-exported: the name would shadow the
``repro.kernels.bsr_spmm`` subpackage attribute that tests patch; reach it
via ``repro.kernels.bsr_spmm.bsr_spmm``.
"""

from repro.kernels.das_beamform.ops import das_beamform
from repro.kernels.bsr_spmm.ops import bsr_beamform
from repro.kernels.fused_pipeline.ops import (
    fused_rf_to_envelope,
    fused_rf_to_power,
)

__all__ = [
    "das_beamform",
    "bsr_beamform",
    "fused_rf_to_envelope",
    "fused_rf_to_power",
]
