"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships as a subpackage with three modules:
  kernel.py - pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    - jitted public wrapper (padding, vmapping, dtype handling)
  ref.py    - pure-jnp oracle used by the allclose test sweeps

All kernels are validated on CPU with interpret=True; on TPU the same code
lowers through Mosaic. Kernels are opt-in (config flag) - the XLA paths in
repro.core / repro.models remain the portable default, per the paper's
single-source portability contract.
"""
