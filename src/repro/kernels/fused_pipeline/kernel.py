"""Fused demod→beamform→head Pallas megakernel (TPU target).

The per-stage lowering registry (repro.core.lowering) still pays one
kernel launch and a full HBM round trip of the activation at every stage
boundary: RF → IQ (n_s·n_c·n_f·2 floats) → beamformed IQ → head. This
kernel executes the whole RF-to-{envelope, wall-filtered power} chain in
ONE pallas_call, keeping every intermediate tile-resident:

  grid = (n_pix // bp,)                     one pixel tile per step
  step 0:   demod the FULL RF block into a VMEM scratch (the IQ cube is
            shared by every pixel tile, so it is computed once and
            persists across the sequential grid — this is the HBM
            traffic the fusion removes);
  step i:   build the (bp, n_s) one-hot DAS interpolation weights in
            VMEM from the compact delay tables (the das_beamform
            technique), contract them against the scratch IQ on the
            MXU, rotate/apodize/channel-reduce, then run the head's
            tile-local half: |z| envelope (bmode) or wall-filter + R0
            frame power (power_doppler).

The head's *global* half (normalize_by_max over all pixels, dB
compression, power-doppler's 2-D smooth) is NOT in the kernel — a
single-pass tiled kernel cannot see the global max. The fused lowering
runs it as a pointwise XLA epilogue reusing the reference head's own
``compress`` functions verbatim (repro.core.bmode / doppler), so the
boundary adds no numeric drift. See docs/kernels.md.

Determinism contract
--------------------
``precision="f32"`` + interpret mode executes the *reference modules'
own expressions* inside the kernel body: ``demod.rf_to_iq`` and
``doppler.apply_wall_filter`` are imported and called on the VMEM
blocks, and the beamform uses the das_beamform one-hot-dot formulation
(zero terms add exactly in f32; channel reduce is ONE materialized sum)
— so the fused f32 path is bit-exact against the monolithic oracle by
construction, asserted in tests/test_fused_pipeline.py. The compiled
path (TPU) re-expresses both FIRs as banded weight matrices built in
VMEM and fed to the MXU (Mosaic lowers matmuls, not conv_general) and
is held to the same ≤1e-5 image tolerance as every other lowering.

``precision="bf16"/"f16"`` casts the MATMUL OPERANDS (banded demod FIR,
one-hot DAS weights, and their IQ counterparts) to the reduced dtype
with f32 accumulation (preferred_element_type); all pointwise math
stays f32. The image-level error bounds live in
``repro.core.config.PRECISION_TOLERANCES``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

# The interpret/f32 path reuses the reference stage expressions verbatim
# (the bit-exactness contract above). Safe import direction: repro.core
# never imports repro.kernels at module scope.
from repro.core import cnn_ops, demod, doppler

DEFAULT_BP = 128  # pixel-tile rows (MXU-aligned), same default as das

_COMPUTE_DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16,
                   "f16": jnp.float16}


def _banded_fir(lpf, n_s: int, n_l: int, decim: int, pad_lo: int,
                n_taps: int):
    """The decimating SAME-padded FIR as a dense banded (n_s, n_l) matrix.

    W[s, l] = lpf[k] where l = s*decim + k - pad_lo. Out-of-range taps
    simply never match — the implicit zero padding of the conv. Built in
    VMEM per kernel invocation (n_s*n_l f32; the one-hot trick of
    das_beamform applied to the demod FIR).
    """
    row = lax.broadcasted_iota(jnp.int32, (n_s, n_l), 0)
    col = lax.broadcasted_iota(jnp.int32, (n_s, n_l), 1)
    w = jnp.zeros((n_s, n_l), dtype=jnp.float32)
    for k in range(n_taps):       # static tap loop
        w = w + jnp.where(col == row * decim + (k - pad_lo), lpf[k], 0.0)
    return w


def _demod_matmul(carrier, lpf, rf, *, decim, n_s, pad_lo, n_taps, cdt):
    """Compiled-path demod: mix, then the banded FIR as one MXU matmul."""
    n_l, n_c, n_f = rf.shape
    x = rf.astype(jnp.float32)
    mixed_re = x * carrier[:, 0][:, None, None]          # (n_l, n_c, n_f)
    mixed_im = x * carrier[:, 1][:, None, None]
    w = _banded_fir(lpf, n_s, n_l, decim, pad_lo, n_taps).astype(cdt)
    out_re = jnp.dot(w, mixed_re.reshape(n_l, -1).astype(cdt),
                     preferred_element_type=jnp.float32)
    out_im = jnp.dot(w, mixed_im.reshape(n_l, -1).astype(cdt),
                     preferred_element_type=jnp.float32)
    return jnp.stack([out_re.reshape(n_s, n_c, n_f),
                      out_im.reshape(n_s, n_c, n_f)], axis=-1)


def _beamform_tile(idx, frac, apod, rot, iq, *, cdt):
    """One pixel tile of the das_beamform one-hot DAS (kernel-body copy
    operating on the scratch IQ; see das_beamform/kernel.py for the
    bit-exactness rationale — zero one-hot terms add exactly, rot/apod
    post-dot in the gather path's f32 expression order, channel reduce
    as ONE materialized sum)."""
    bp, n_c = idx.shape
    n_s, _, n_f, _ = iq.shape
    iota = lax.broadcasted_iota(jnp.int32, (bp, n_s), 1)

    def channel_body(c, per_c):
        per_re, per_im = per_c
        idx_c = idx[:, c][:, None]                       # (bp, 1)
        frac_c = frac[:, c][:, None]
        apod_c = apod[:, c][:, None]
        w = (jnp.where(iota == idx_c, 1.0 - frac_c, 0.0) +
             jnp.where(iota == idx_c + 1, frac_c, 0.0))  # (bp, n_s)
        v_re = jnp.dot(w.astype(cdt), iq[:, c, :, 0].astype(cdt),
                       preferred_element_type=jnp.float32)
        v_im = jnp.dot(w.astype(cdt), iq[:, c, :, 1].astype(cdt),
                       preferred_element_type=jnp.float32)
        rot_re = rot[:, c, 0][:, None]
        rot_im = rot[:, c, 1][:, None]
        per_re = lax.dynamic_update_index_in_dim(
            per_re, (v_re * rot_re - v_im * rot_im) * apod_c, c, 0)
        per_im = lax.dynamic_update_index_in_dim(
            per_im, (v_re * rot_im + v_im * rot_re) * apod_c, c, 0)
        return per_re, per_im

    zero = jnp.zeros((n_c, bp, n_f), dtype=jnp.float32)
    per_re, per_im = lax.fori_loop(0, n_c, channel_body, (zero, zero))
    return per_re.sum(axis=0), per_im.sum(axis=0)        # 2x (bp, n_f)


def _wall_power_tile(wall, bf_re, bf_im, *, exact):
    """Tile-local power-doppler front: FIR along frames -> R0 power."""
    if exact:
        # Reference expression, verbatim (bit-exact in interpret mode).
        z = doppler.apply_wall_filter(
            {"wall_taps": wall}, jnp.stack([bf_re, bf_im], axis=-1))
        return cnn_ops.cabs2(z).sum(axis=1)              # (bp,)
    kw = wall.shape[0]
    n_fp = bf_re.shape[1] - kw + 1
    acc_re = jnp.zeros((bf_re.shape[0], n_fp), dtype=jnp.float32)
    acc_im = acc_re
    for t in range(kw):                                  # static tap loop
        acc_re = acc_re + wall[t] * bf_re[:, t:t + n_fp]
        acc_im = acc_im + wall[t] * bf_im[:, t:t + n_fp]
    return (acc_re * acc_re + acc_im * acc_im).sum(axis=1)


def _make_kernel(head: str, *, decim, n_s, pad_lo, n_taps, precision,
                 exact):
    cdt = _COMPUTE_DTYPES[precision]

    def kernel(carrier_ref, lpf_ref, idx_ref, frac_ref, apod_ref, rot_ref,
               *rest):
        if head == "power_doppler":
            wall_ref, rf_ref, out_ref, iq_ref = rest
        else:
            rf_ref, out_ref, iq_ref = rest

        i = pl.program_id(0)

        @pl.when(i == 0)
        def _demod_once():
            # The IQ cube is pixel-independent: computed on the first
            # grid step only, persisted in scratch across the sequential
            # steps — the HBM round trip the fusion eliminates.
            if exact:
                iq_ref[...] = demod.rf_to_iq(
                    {"carrier": carrier_ref[...], "lpf": lpf_ref[0]},
                    rf_ref[...], decim)
            else:
                iq_ref[...] = _demod_matmul(
                    carrier_ref[...], lpf_ref[0], rf_ref[...],
                    decim=decim, n_s=n_s, pad_lo=pad_lo, n_taps=n_taps,
                    cdt=cdt)

        bf_re, bf_im = _beamform_tile(
            idx_ref[...], frac_ref[...], apod_ref[...], rot_ref[...],
            iq_ref[...], cdt=cdt)

        if head == "bmode":
            out_ref[...] = cnn_ops.magnitude(bf_re, bf_im)   # (bp, n_f)
        else:
            out_ref[:, 0] = _wall_power_tile(
                wall_ref[0], bf_re, bf_im, exact=exact)      # (bp,)

    return kernel


@functools.partial(
    jax.jit,
    static_argnames=("head", "decim", "bp", "precision", "interpret"))
def fused_pipeline_pallas(carrier, lpf, idx, frac, apod, rot, rf,
                          wall=None, *, head: str, decim: int,
                          bp: int = DEFAULT_BP, precision: str = "f32",
                          interpret: bool = True):
    """(n_l, n_c, n_f) RF -> (n_pix, n_f) envelope [bmode] or
    (n_pix,) wall-filtered power R0 [power_doppler].

    n_pix must be a multiple of bp (ops.py pads); lpf arrives (1, k) and
    wall (1, kw) so every VMEM block is >= 2-D.
    """
    n_pix, n_c = idx.shape
    n_l = rf.shape[0]
    n_s = n_l // decim
    assert n_pix % bp == 0, (n_pix, bp)
    n_taps = lpf.shape[-1]
    pad_lo = demod._same_pad(n_l, n_taps, decim)[0]
    # Reference-expression path: only meaningful where the interpreter
    # executes real XLA convs; the compiled path feeds the MXU matmul
    # re-expressions. Reduced precision always takes the matmul path —
    # the operand casts ARE the precision contract.
    exact = precision == "f32" and interpret

    kernel = _make_kernel(head, decim=decim, n_s=n_s, pad_lo=pad_lo,
                          n_taps=n_taps, precision=precision, exact=exact)

    in_specs = [
        pl.BlockSpec(carrier.shape, lambda i: (0, 0)),          # carrier
        pl.BlockSpec(lpf.shape, lambda i: (0, 0)),              # lpf
        pl.BlockSpec((bp, n_c), lambda i: (i, 0)),              # idx
        pl.BlockSpec((bp, n_c), lambda i: (i, 0)),              # frac
        pl.BlockSpec((bp, n_c), lambda i: (i, 0)),              # apod
        pl.BlockSpec((bp, n_c, 2), lambda i: (i, 0, 0)),        # rot
    ]
    args = [carrier, lpf, idx, frac, apod, rot]
    if head == "power_doppler":
        in_specs.append(pl.BlockSpec(wall.shape, lambda i: (0, 0)))
        args.append(wall)
        out_spec = pl.BlockSpec((bp, 1), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((n_pix, 1), jnp.float32)
    elif head == "bmode":
        n_f = rf.shape[2]
        out_spec = pl.BlockSpec((bp, n_f), lambda i: (i, 0))
        out_shape = jax.ShapeDtypeStruct((n_pix, n_f), jnp.float32)
    else:
        raise ValueError(f"unsupported fused head: {head!r}")
    in_specs.append(pl.BlockSpec(rf.shape, lambda i: (0, 0, 0)))  # rf
    args.append(rf)

    return pl.pallas_call(
        kernel,
        grid=(n_pix // bp,),
        in_specs=in_specs,
        out_specs=out_spec,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((n_s, n_c, rf.shape[2], 2),
                                   jnp.float32)],
        interpret=interpret,
    )(*args)
