from repro.kernels.fused_pipeline.ops import (  # noqa: F401
    DEFAULT_BP,
    fused_rf_to_envelope,
    fused_rf_to_power,
)
