"""Public wrappers for the fused demod→beamform→head megakernel.

`fused_rf_to_envelope` / `fused_rf_to_power` own the padding, dtype, and
interpret policy; the head's global epilogue (normalize + compress +
smooth) stays OUTSIDE — the fused lowering in repro.core.lowering runs
it via the reference head's own compress functions on the sliced
(pad-free) kernel output, so the global max never sees pad rows.

Padding contract (same as das_beamform): the pixel axis is padded to a
``bp`` multiple with zero apodization, so pad rows beamform to exactly
zero (envelope 0 / power 0) and are sliced off before returning.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.fused_pipeline import kernel as _k
from repro.kernels.pallas_compat import auto_interpret, next_multiple

DEFAULT_BP = _k.DEFAULT_BP


def _pad_tables(idx, frac, apod, rot, bp):
    n_pix = idx.shape[0]
    bp = min(bp, next_multiple(n_pix, 8))
    pad = next_multiple(n_pix, bp) - n_pix
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        frac = jnp.pad(frac, ((0, pad), (0, 0)))
        apod = jnp.pad(apod, ((0, pad), (0, 0)))  # zero apod => zero output
        rot = jnp.pad(rot, ((0, pad), (0, 0), (0, 0)))
    return idx, frac, apod, rot, bp


def fused_rf_to_envelope(carrier, lpf, idx, frac, apod, rot, rf, *,
                         decim: int, bp=None, precision: str = "f32",
                         interpret=None):
    """Fused RF -> B-mode envelope (demod + DAS beamform + |z|).

    Args:
      carrier: (n_l, 2) f32 demod carrier (cos / -sin).
      lpf:  (taps,) f32 decimating FIR.
      idx:  (n_pix, n_c) int32 floor sample indices.
      frac / apod: (n_pix, n_c) f32.
      rot:  (n_pix, n_c, 2) f32 unit phasors.
      rf:   (n_l, n_c, n_f) RF (any real dtype; cast to f32).
      bp:   pixel-tile rows (None -> DEFAULT_BP), clamped + padded.
      precision: "f32" | "bf16" | "f16" matmul-operand precision
        (f32 accumulate); see repro.core.config.PRECISION_TOLERANCES.
    Returns:
      (n_pix, n_f) f32 envelope — feed repro.core.bmode.compress_envelope.
    """
    n_pix = idx.shape[0]
    idx, frac, apod, rot, bp = _pad_tables(idx, frac, apod, rot,
                                           bp or DEFAULT_BP)
    env = _k.fused_pipeline_pallas(
        carrier, jnp.reshape(lpf, (1, -1)), idx, frac, apod, rot,
        rf.astype(jnp.float32), head="bmode", decim=decim, bp=bp,
        precision=precision, interpret=auto_interpret(interpret))
    return env[:n_pix]


def fused_rf_to_power(carrier, lpf, idx, frac, apod, rot, wall, rf, *,
                      decim: int, bp=None, precision: str = "f32",
                      interpret=None):
    """Fused RF -> power-doppler R0 (demod + DAS + wall filter + power).

    Same table arguments as `fused_rf_to_envelope`, plus ``wall``: the
    (kw,) f32 wall-filter taps. Returns (n_pix,) f32 R0 — feed
    repro.core.doppler.power_compress.
    """
    n_pix = idx.shape[0]
    idx, frac, apod, rot, bp = _pad_tables(idx, frac, apod, rot,
                                           bp or DEFAULT_BP)
    r0 = _k.fused_pipeline_pallas(
        carrier, jnp.reshape(lpf, (1, -1)), idx, frac, apod, rot,
        rf.astype(jnp.float32), jnp.reshape(wall, (1, -1)),
        head="power_doppler", decim=decim, bp=bp,
        precision=precision, interpret=auto_interpret(interpret))
    return r0[:n_pix, 0]
