"""Pure-jnp oracle for the fused megakernel (kernel-level allclose).

Standalone re-statement of demod (strided SAME conv) + dynamic DAS
(gather + lerp + rotate + apodize + channel sum) + the head's tile-local
half, with no repro.core config dependency — mirrors the other kernel
packages' ref.py convention. The pipeline-level bit-exactness contract
is asserted separately against `monolithic_pipeline_fn` in
tests/test_fused_pipeline.py; this oracle exists so a kernel regression
localizes to the kernel, not the whole pipeline.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def _demod_ref(carrier, lpf, rf, decim):
    n_l, n_c, n_f = rf.shape
    x = rf.astype(jnp.float32)
    mixed = x[..., None] * carrier[:, None, None, :]
    k = lpf.shape[0]
    n_s = -(-n_l // decim)
    total = max((n_s - 1) * decim + k - n_l, 0)
    lo = total // 2
    m = jnp.pad(mixed, ((lo, total - lo), (0, 0), (0, 0), (0, 0)))
    acc = jnp.zeros((n_s, n_c, n_f, 2), jnp.float32)
    for t in range(k):  # ascending tap order — the demod contract
        acc = acc + lpf[t] * lax.slice_in_dim(
            m, t, t + (n_s - 1) * decim + 1, stride=decim, axis=0)
    return acc


def _beamform_ref(idx, frac, apod, rot, iq):
    import jax
    iq_c = iq.transpose(1, 0, 2, 3)                  # (n_c, n_s, n_f, 2)

    def one_channel(iq_1, idx_1, frac_1, apod_1, rot_1):
        s0 = jnp.take(iq_1, idx_1, axis=0)           # (n_pix, n_f, 2)
        s1 = jnp.take(iq_1, idx_1 + 1, axis=0)
        f = frac_1[:, None, None]
        v = s0 * (1.0 - f) + s1 * f
        r = rot_1[:, None, :]
        v = jnp.stack([v[..., 0] * r[..., 0] - v[..., 1] * r[..., 1],
                       v[..., 0] * r[..., 1] + v[..., 1] * r[..., 0]],
                      axis=-1)
        return v * apod_1[:, None, None]

    per_c = jax.vmap(one_channel, in_axes=(0, 1, 1, 1, 1))(
        iq_c, idx, frac, apod, rot)                  # (n_c, n_pix, n_f, 2)
    return per_c.sum(axis=0)


def fused_ref(carrier, lpf, idx, frac, apod, rot, rf, *, decim,
              head="bmode", wall=None):
    """RF -> (n_pix, n_f) envelope or (n_pix,) R0, pure jnp."""
    iq = _demod_ref(carrier, lpf, rf, decim)
    bf = _beamform_ref(idx, frac, apod, rot, iq)     # (n_pix, n_f, 2)
    if head == "bmode":
        return jnp.sqrt(bf[..., 0] ** 2 + bf[..., 1] ** 2)
    k = wall.shape[0]
    n_fp = bf.shape[1] - k + 1                       # VALID along frames
    z = jnp.zeros(bf.shape[:1] + (n_fp, 2), jnp.float32)
    for t in range(k):  # ascending tap order — the wall-filter contract
        z = z + wall[t] * bf[:, t:t + n_fp, :]
    return (z[..., 0] ** 2 + z[..., 1] ** 2).sum(axis=1)
