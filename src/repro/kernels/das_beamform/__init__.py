from repro.kernels.das_beamform.ops import das_beamform  # noqa: F401
