"""Public wrapper for the fused DAS beamform kernel."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.das_beamform import kernel as _k
from repro.kernels.pallas_compat import auto_interpret, next_multiple


def das_beamform(idx, frac, apod, rot, iq, *, bp: int = _k.DEFAULT_BP,
                 precision: str = "f32", interpret=None):
    """Fused delay-and-sum beamform.

    Args:
      idx:  (n_pix, n_c) int32 floor sample indices (clamped to n_s - 2).
      frac: (n_pix, n_c) f32 interpolation fractions.
      apod: (n_pix, n_c) f32 apodization (0 disables a (pixel, channel)).
      rot:  (n_pix, n_c, 2) f32 unit phasors.
      iq:   (n_s, n_c, n_f, 2) f32.
      precision: matmul-operand dtype, "f32" | "bf16" | "f16"
        (accumulation is always f32; "f32" is bit-exact).
    Returns:
      (n_pix, n_f, 2) f32 beamformed IQ.
    """
    interpret = auto_interpret(interpret)
    n_pix = idx.shape[0]
    bp = min(bp, next_multiple(n_pix, 8))
    pad = next_multiple(n_pix, bp) - n_pix
    if pad:
        idx = jnp.pad(idx, ((0, pad), (0, 0)))
        frac = jnp.pad(frac, ((0, pad), (0, 0)))
        apod = jnp.pad(apod, ((0, pad), (0, 0)))  # zero apod => no output
        rot = jnp.pad(rot, ((0, pad), (0, 0), (0, 0)))
    out = _k.das_beamform_pallas(
        idx, frac, apod, rot, iq.astype(jnp.float32),
        bp=bp, precision=precision, interpret=interpret)
    return out[:n_pix]
