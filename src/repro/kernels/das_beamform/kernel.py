"""Fused DAS beamform Pallas kernel (TPU target).

The paper's V2 "full CNN" variant materializes the one-hot interpolation
operator in HBM — (n_c, n_pix, n_s) floats, 2.7 GB at the paper's geometry
(their Table I peak-memory column). This kernel is the TPU-native fusion of
V1 and V2: the one-hot interpolation weights are *built on the fly in VMEM*
from the compact (n_pix, n_c) delay tables and immediately consumed by an
MXU matmul, so the gather becomes matrix work without the O(n_pix * n_s)
HBM footprint. This is a beyond-paper optimization enabled by rethinking
the op for the TPU memory hierarchy (HBM -> VMEM -> MXU).

Tiling:
  grid  = (n_pix // BP,)                       one pixel tile per step
  VMEM  = idx/frac/apod (BP, n_c), rot (BP, n_c, 2),
          iq (n_s, n_c, n_f, 2) resident across steps,
          one (BP, n_s) weight tile built per channel iteration.

For MXU efficiency BP and n_s should be multiples of 128 / 8 respectively;
the ops.py wrapper pads. All accumulation is f32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
import jax.experimental.pallas as pl


DEFAULT_BP = 128  # pixel-tile rows (MXU-aligned)

# Matmul-operand compute dtypes for the mixed-precision contract: only the
# one-hot weight tile and the IQ operand are cast; accumulation stays f32
# (preferred_element_type) and everything pointwise stays f32. "f32" is the
# identity cast, so the bit-exact path is untouched.
COMPUTE_DTYPES = {
    "f32": jnp.float32,
    "bf16": jnp.bfloat16,
    "f16": jnp.float16,
}


def _kernel(idx_ref, frac_ref, apod_ref, rot_ref, iq_ref, out_ref, *,
            precision):
    cdt = COMPUTE_DTYPES[precision]
    bp, n_c = idx_ref.shape
    n_s = iq_ref.shape[0]
    n_f = iq_ref.shape[2]

    iota = lax.broadcasted_iota(jnp.int32, (bp, n_s), 1)

    def channel_body(c, per_c):
        per_re, per_im = per_c
        idx = idx_ref[:, c][:, None]                     # (bp, 1)
        frac = frac_ref[:, c][:, None]
        apod = apod_ref[:, c][:, None]
        # One-hot interpolation weights, built in VMEM, consumed by the
        # MXU. Apodization and rotation are applied AFTER the dot, in the
        # same f32 expression order as the XLA dynamic beamformer
        # (lerp -> cmul(rot) -> *apod) — the one-hot contraction's zero
        # terms add exactly, so per-channel values match the gather path
        # bit for bit.
        w = (jnp.where(iota == idx, 1.0 - frac, 0.0) +
             jnp.where(iota == idx + 1, frac, 0.0)).astype(cdt)  # (bp, n_s)
        iq_re = iq_ref[:, c, :, 0].astype(cdt)           # (n_s, n_f)
        iq_im = iq_ref[:, c, :, 1].astype(cdt)
        v_re = jnp.dot(w, iq_re, preferred_element_type=jnp.float32)
        v_im = jnp.dot(w, iq_im, preferred_element_type=jnp.float32)
        rot_re = rot_ref[:, c, 0][:, None]               # (bp, 1)
        rot_im = rot_ref[:, c, 1][:, None]
        per_re = lax.dynamic_update_index_in_dim(
            per_re, (v_re * rot_re - v_im * rot_im) * apod, c, 0)
        per_im = lax.dynamic_update_index_in_dim(
            per_im, (v_re * rot_im + v_im * rot_re) * apod, c, 0)
        return per_re, per_im

    # Channel values are materialized (n_c, bp, n_f) and reduced with ONE
    # sum — the same reduce the XLA gather path runs over its per-channel
    # axis — instead of a sequential loop-carried accumulator, so the
    # channel-sum rounding order matches the reference bit for bit (the
    # determinism contract extends across lowerings). VMEM cost:
    # n_c * bp * n_f f32 x2, ~2 MB at paper geometry with bp=128.
    zero = jnp.zeros((n_c, bp, n_f), dtype=jnp.float32)
    per_re, per_im = lax.fori_loop(0, n_c, channel_body, (zero, zero))
    out_ref[:, :, 0] = per_re.sum(axis=0)
    out_ref[:, :, 1] = per_im.sum(axis=0)


@functools.partial(jax.jit,
                   static_argnames=("bp", "precision", "interpret"))
def das_beamform_pallas(idx, frac, apod, rot, iq, *, bp: int = DEFAULT_BP,
                        precision: str = "f32", interpret: bool = True):
    """(n_pix, n_c) tables + (n_s, n_c, n_f, 2) IQ -> (n_pix, n_f, 2).

    n_pix must be a multiple of bp (ops.py pads). `precision` selects the
    matmul-operand dtype (f32 | bf16 | f16); accumulation is always f32.
    """
    n_pix, n_c = idx.shape
    n_s, _, n_f, _ = iq.shape
    assert n_pix % bp == 0, (n_pix, bp)
    grid = (n_pix // bp,)

    return pl.pallas_call(
        functools.partial(_kernel, precision=precision),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, n_c), lambda i: (i, 0)),
            pl.BlockSpec((bp, n_c), lambda i: (i, 0)),
            pl.BlockSpec((bp, n_c), lambda i: (i, 0)),
            pl.BlockSpec((bp, n_c, 2), lambda i: (i, 0, 0)),
            pl.BlockSpec((n_s, n_c, n_f, 2), lambda i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, n_f, 2), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pix, n_f, 2), jnp.float32),
        interpret=interpret,
    )(idx, frac, apod, rot, iq)
