"""Pure-jnp oracle for the fused DAS beamform kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def das_beamform_ref(idx, frac, apod, rot, iq):
    """(n_pix, n_c) tables + (n_s, n_c, n_f, 2) IQ -> (n_pix, n_f, 2)."""
    iq_c = iq.transpose(1, 0, 2, 3)  # (n_c, n_s, n_f, 2)

    def one_channel(iq_1, idx_1, frac_1, apod_1, rot_1):
        s0 = jnp.take(iq_1, idx_1, axis=0)
        s1 = jnp.take(iq_1, idx_1 + 1, axis=0)
        f = frac_1[:, None, None]
        v = s0 * (1.0 - f) + s1 * f
        re = v[..., 0] * rot_1[:, None, 0] - v[..., 1] * rot_1[:, None, 1]
        im = v[..., 0] * rot_1[:, None, 1] + v[..., 1] * rot_1[:, None, 0]
        return jnp.stack([re, im], axis=-1) * apod_1[:, None, None]

    per_c = jax.vmap(one_channel, in_axes=(0, 1, 1, 1, 1))(
        iq_c, idx, frac, apod, rot)
    return per_c.sum(axis=0)
