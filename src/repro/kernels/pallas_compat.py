"""Version-compat shims shared by the Pallas kernels.

jax<0.5 exposes TPU compiler params as ``pltpu.TPUCompilerParams``; 0.5+
renamed it ``CompilerParams``. Resolve once here so the next rename is a
one-line fix.
"""

from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu


def _resolve_compiler_params():
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:  # pragma: no cover — future rename
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; update repro.kernels.pallas_compat "
            "for this jax version")
    return cls


CompilerParams = _resolve_compiler_params()
