"""Compat + convenience shims shared by every Pallas kernel wrapper.

jax<0.5 exposes TPU compiler params as ``pltpu.TPUCompilerParams``; 0.5+
renamed it ``CompilerParams``. Resolve once here so the next rename is a
one-line fix. `auto_interpret` is the shared interpret-mode fallback
policy (compiled only where Mosaic runs, interpret everywhere else) and
`next_multiple` the shared tile-padding helper — one definition each,
so the kernels' portability contract cannot fork per package.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import jax.experimental.pallas.tpu as pltpu


def auto_interpret(interpret: Optional[bool]) -> bool:
    """Resolve the ``interpret=None`` default of every kernel wrapper.

    None means "compiled where the Mosaic TPU backend exists, interpret
    mode everywhere else" — the fallback that keeps one source tree
    runnable on every backend (the repo's portability contract; the
    registry's capability predicates assume it).
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return interpret


def next_multiple(x: int, m: int) -> int:
    """Smallest multiple of ``m`` >= ``x`` (tile-padding contract)."""
    return ((x + m - 1) // m) * m


def block_sample_axis(iq: jnp.ndarray, bs: int) -> jnp.ndarray:
    """(n_s, n_c, n_f, 2) -> (n_sb, bs, n_c, n_f, 2) sample-axis blocking.

    Zero-pads the sample axis to a multiple of ``bs`` and reshapes it into
    blocks — the shared contract between the BSR delay-table builder (which
    indexes sample *blocks*) and the kernel wrappers that consume blocked
    IQ. Zero padding is exact: padded samples are only ever multiplied by
    structurally-zero BSR blocks.
    """
    n_s = iq.shape[0]
    pad = next_multiple(n_s, bs) - n_s
    if pad:
        iq = jnp.pad(iq, ((0, pad),) + ((0, 0),) * (iq.ndim - 1))
    return iq.reshape((-1, bs) + iq.shape[1:])


def _resolve_compiler_params():
    cls = getattr(pltpu, "CompilerParams",
                  getattr(pltpu, "TPUCompilerParams", None))
    if cls is None:  # pragma: no cover — future rename
        raise ImportError(
            "jax.experimental.pallas.tpu exposes neither CompilerParams "
            "nor TPUCompilerParams; update repro.kernels.pallas_compat "
            "for this jax version")
    return cls


CompilerParams = _resolve_compiler_params()
