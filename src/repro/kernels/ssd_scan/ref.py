"""Pure-jnp oracle for the SSD scan: the naive step-by-step recurrence."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(log_a, x, b, c):
    """h_t = a_t h_{t-1} + B_t x_t^T;  y_t = C_t^T h_t.

    log_a (L, 1), x (L, P), b (L, N), c (L, N) -> y (L, P).
    """
    n, p = b.shape[1], x.shape[1]

    def step(h, inp):
        la_t, x_t, b_t, c_t = inp
        h = jnp.exp(la_t)[:, None] * h + b_t[:, None] * x_t[None, :]
        return h, c_t @ h

    h0 = jnp.zeros((n, p), dtype=jnp.float32)
    _, y = jax.lax.scan(step, h0, (log_a, x, b, c))
    return y
