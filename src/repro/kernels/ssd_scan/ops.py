"""Public wrapper for the SSD scan kernel: batch/head vmapping + padding."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan import kernel as _k
from repro.kernels.pallas_compat import auto_interpret


def ssd_scan(log_a, x, b, c, *, chunk: int = _k.DEFAULT_CHUNK,
             interpret=None):
    """Batched multi-head SSD scan.

    Args:
      log_a: (batch, L, H) log decays (<= 0).
      x:     (batch, L, H, P).
      b, c:  (batch, L, H, N) (per-head; broadcast groups upstream).
    Returns:
      y (batch, L, H, P), dtype of x.
    """
    interpret = auto_interpret(interpret)
    bsz, l, h, p = x.shape
    chunk_eff = min(chunk, l)
    pad = (-l) % chunk_eff
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def one(la_1, x_1, b_1, c_1):
        return _k.ssd_scan_pallas(
            la_1[:, None].astype(jnp.float32), x_1.astype(jnp.float32),
            b_1.astype(jnp.float32), c_1.astype(jnp.float32),
            chunk=chunk_eff, interpret=interpret)

    # vmap over batch (axis 0), then heads (axis 1 of each per-batch array).
    f = jax.vmap(jax.vmap(one, in_axes=(1, 1, 1, 1), out_axes=1),
                 in_axes=(0, 0, 0, 0))
    y = f(log_a, x, b, c)
    return y[:, :l].astype(x.dtype)
