"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel.

Computes the scalar-decay SSM recurrence used by Mamba2:

    h_t = a_t * h_{t-1} + B_t (outer) x_t          h in R^{N x P}
    y_t = C_t^T h_t

via the SSD chunk decomposition: within a chunk of Q steps the output is a
masked (Q x Q) matmul ("attention-like" duality); across chunks a compact
(N x P) state is carried in VMEM scratch. All heavy ops are MXU matmuls —
this is the TPU-native formulation of a recurrence that is classically
expressed with per-step dynamic updates (the paper's philosophy applied to
SSMs: irregular recurrence -> static matmul graph).

Grid: (L // Q,), sequential. Scratch: h (N, P) f32.
Inputs per head: log_a (L, 1) decay logs (<= 0), x (L, P), B (L, N), C (L, N).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

DEFAULT_CHUNK = 128


def _kernel(loga_ref, x_ref, b_ref, c_ref, y_ref, h_ref):
    ci = pl.program_id(0)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    la = jnp.cumsum(loga_ref[...], axis=0)         # (Q, 1) inclusive
    ea = jnp.exp(la)                               # decay chunk-start -> t
    x = x_ref[...]                                 # (Q, P)
    b = b_ref[...]                                 # (Q, N)
    c = c_ref[...]                                 # (Q, N)
    q = x.shape[0]

    # Intra-chunk: y_t += sum_{j<=t} exp(la_t - la_j) (C_t . B_j) x_j
    s = jnp.dot(c, b.T, preferred_element_type=jnp.float32)   # (Q, Q)
    rows = lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = lax.broadcasted_iota(jnp.int32, (q, q), 1)
    # clamp before exp: positive (future-position) log-decays overflow
    decay = jnp.exp(jnp.minimum(la - la.T, 0.0))   # la_i - la_j
    m = jnp.where(rows >= cols, s * decay, 0.0)
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)     # (Q, P)

    # Inter-chunk: y_t += exp(la_t) C_t . h_prev
    y = y + jnp.dot(c * ea, h_ref[...],
                    preferred_element_type=jnp.float32)

    # State update: h_new = exp(la_last) h_prev + sum_j exp(la_last - la_j) B_j x_j^T
    ea_last = jnp.exp(la[-1:, :])                  # (1, 1)
    w = jnp.exp(la[-1:, :] - la)                   # (Q, 1)
    h_ref[...] = ea_last * h_ref[...] + jnp.dot(
        (b * w).T, x, preferred_element_type=jnp.float32)

    y_ref[...] = y


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(log_a, x, b, c, *, chunk: int = DEFAULT_CHUNK,
                    interpret: bool = True):
    """Single-head SSD scan.

    Args:
      log_a: (L, 1) f32, log decay per step (<= 0 for stability).
      x:     (L, P) f32 inputs (dt already folded into B or x by caller).
      b:     (L, N) f32 input projections.
      c:     (L, N) f32 output projections.
    Returns:
      y (L, P) f32.
    """
    l, p = x.shape
    n = b.shape[1]
    assert l % chunk == 0, (l, chunk)
    grid = (l // chunk,)

    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((chunk, 1), lambda i: (i, 0)),
            pl.BlockSpec((chunk, p), lambda i: (i, 0)),
            pl.BlockSpec((chunk, n), lambda i: (i, 0)),
            pl.BlockSpec((chunk, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((chunk, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((l, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(log_a, x, b, c)
