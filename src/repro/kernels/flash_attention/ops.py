"""Public wrapper for flash attention: batching, GQA, padding, dtypes."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k
from repro.kernels.pallas_compat import auto_interpret, next_multiple


def flash_attention(q, k, v, *, causal: bool = True, scale=None,
                    bq: int = _k.DEFAULT_BQ, bk: int = _k.DEFAULT_BK,
                    interpret=None):
    """Batched GQA flash attention.

    Args:
      q: (batch, Lq, n_q_heads, d).
      k, v: (batch, Lk, n_kv_heads, d); n_q_heads % n_kv_heads == 0.
      causal: causal masking (requires Lq == Lk alignment at position 0).
    Returns:
      (batch, Lq, n_q_heads, d), dtype of q.
    """
    interpret = auto_interpret(interpret)
    b, lq, hq, d = q.shape
    _, lk, hkv, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    rep = hq // hkv
    if rep > 1:  # GQA: expand kv heads to match q heads
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)

    bq_eff = min(bq, next_multiple(lq, 8))
    bk_eff = min(bk, next_multiple(lk, 8))
    pq = next_multiple(lq, bq_eff) - lq
    pk = next_multiple(lk, bk_eff) - lk
    qf = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0))).astype(jnp.float32)
    kf = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0))).astype(jnp.float32)
    vf = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0))).astype(jnp.float32)
    # Padded k rows must never win the softmax: push their keys far negative
    # via an explicit mask folded into k? Simpler: rely on causal masking for
    # pq/pk tails when causal; for non-causal, mask via v zeros + key bias.
    if pk and not causal:
        # Give padded keys a huge negative inner product by appending a
        # constant large-magnitude component is fragile; instead mask by
        # recomputing with explicit bias is costly. We choose: pad keys with
        # zeros and subtract their contribution via weight renormalization
        # is also wrong. => disallow silently: caller must pass aligned Lk.
        raise ValueError("non-causal flash requires Lk % bk == 0 "
                         f"(got Lk={lk}, bk={bk_eff})")

    def per_batch(qb, kb, vb):
        return _k.flash_attention_pallas(
            qb.transpose(1, 0, 2), kb.transpose(1, 0, 2),
            vb.transpose(1, 0, 2), causal=causal, scale=scale,
            bq=bq_eff, bk=bk_eff, interpret=interpret).transpose(1, 0, 2)

    out = jax.vmap(per_batch)(qf, kf, vf)
    return out[:, :lq].astype(q.dtype)
