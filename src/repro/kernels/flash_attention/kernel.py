"""Flash attention Pallas kernel (TPU target).

Block-tiled online-softmax attention: O(L) VMEM, no (Lq x Lk) score
materialization in HBM. Used for the prefill_32k shapes where attention is
the dominant compute term.

Tiling:
  grid = (n_heads, Lq // BQ, Lk // BK); the BK axis is sequential
  ("arbitrary") and carries the online-softmax state in VMEM scratch:
  acc (BQ, d), m (BQ, 1) running max, l (BQ, 1) running sum.

Causal masking is arithmetic (mask to -1e30); fully-masked tiles contribute
exp(-1e30 - m) == 0. BQ/BK should be multiples of 128 on real TPU; the
ops.py wrapper pads and handles GQA head expansion.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
import jax.experimental.pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, causal: bool, scale: float, bq: int, bk: int):
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0]                                   # (bq, d)
    k = k_ref[0]                                   # (bk, d)
    v = v_ref[0]                                   # (bk, d)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale  # (bq, bk)

    if causal:
        qi = pl.program_id(1)
        rows = qi * bq + lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = kj * bk + lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                         # (bq, bk)
    alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True,
                           scale: float | None = None,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = True):
    """(h, Lq, d), (h, Lk, d), (h, Lk, d) -> (h, Lq, d). f32 in/out."""
    h, lq, d = q.shape
    _, lk, _ = k.shape
    assert lq % bq == 0 and lk % bk == 0, (lq, lk, bq, bk)
    if scale is None:
        scale = d ** -0.5
    grid = (h, lq // bq, lk // bk)

    kernel = functools.partial(
        _kernel, causal=causal, scale=float(scale), bq=bq, bk=bk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hh, qi, kj: (hh, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda hh, qi, kj: (hh, kj, 0)),
            pl.BlockSpec((1, bk, d), lambda hh, qi, kj: (hh, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hh, qi, kj: (hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, lq, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
