"""Pure-jnp oracle for flash attention."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale=None):
    """(h, Lq, d) x (h, Lk, d) x (h, Lk, d) -> (h, Lq, d)."""
    h, lq, d = q.shape
    lk = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    s = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        mask = jnp.tril(jnp.ones((lq, lk), dtype=bool), k=lk - lq)
        s = jnp.where(mask[None], s, -1e30)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)
