"""Gradient compression: int8 all-reduce over the data axis via shard_map.

A distributed-optimization trick for DCN-limited (cross-pod) gradient
sync: per-tensor symmetric int8 quantization before the psum, dequantize
after. 4x fewer bytes on the wire for the data-parallel all-reduce at the
cost of one extra max-reduce (the scale) and bounded quantization noise
(error feedback optional — the residual is returned so callers can carry
it).

Usage (inside shard_map with the data/pod axes visible):

    grads, residual = compressed_psum_mean(grads, ("pod", "data"), residual)
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def compressed_psum_mean(grads, axis_names, residual=None):
    """Quantize -> psum -> dequantize -> mean over `axis_names`.

    The quantization scale is agreed across shards first (one scalar pmax
    per tensor — negligible traffic), so every shard's int8 payload shares
    one codebook and the summed reconstruction is exact up to rounding:
    per-element error <= scale/2 = max|g| / 254 after the mean.

    grads: pytree of f32 per-shard gradients (shard_map context).
    residual: optional error-feedback tree (same structure) carried across
      steps; pass None to disable.
    Returns (mean_grads, new_residual).
    """
    n = 1
    for a in (axis_names if isinstance(axis_names, (tuple, list))
              else (axis_names,)):
        # jax<0.5 has no lax.axis_size; psum of 1 is the portable spelling.
        n *= (lax.axis_size(a) if hasattr(lax, "axis_size")
              else lax.psum(1, a))

    def one(g, r):
        gf = g.astype(jnp.float32)
        if r is not None:
            gf = gf + r
        local_scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
        scale = lax.pmax(local_scale, axis_names)   # shared codebook
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_r = gf - deq if r is not None else None
        # int8 on the wire: psum of int32-accumulated quantized values.
        summed = lax.psum(q.astype(jnp.int32), axis_names)
        mean = summed.astype(jnp.float32) * scale / n
        return mean, new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = (treedef.flatten_up_to(residual) if residual is not None
              else [None] * len(flat_g))
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    mean = treedef.unflatten([o[0] for o in out])
    new_res = (treedef.unflatten([o[1] for o in out])
               if residual is not None else None)
    return mean, new_res
