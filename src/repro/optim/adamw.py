"""AdamW on raw pytrees (no optax dependency) + schedule + clipping.

State layout mirrors the param tree:
  {"m": tree(f32), "v": tree(f32), "step": scalar i32}

m/v are f32 regardless of param dtype (bf16 params, f32 moments — the
standard mixed-precision recipe). ZeRO-1 is a *sharding* property: the
launcher assigns m/v PartitionSpecs with the data axis folded in
(runtime/param_sharding.py), so each data shard owns 1/N of the moments;
XLA inserts the reduce-scatter/all-gather pair automatically.
"""

from __future__ import annotations

from typing import Callable, Dict, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def cosine_schedule(tcfg: TrainConfig) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = tcfg.learning_rate * step / max(tcfg.warmup_steps, 1)
        t = (step - tcfg.warmup_steps) / max(
            tcfg.total_steps - tcfg.warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = 0.5 * tcfg.learning_rate * (1.0 + jnp.cos(np.pi * t))
        return jnp.where(step < tcfg.warmup_steps, warm, cos)
    return lr


def global_norm_clip(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), \
        gnorm


def adamw_init(params) -> Dict:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype=jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.copy, zeros),
            "step": jnp.zeros((), dtype=jnp.int32)}


def _decay_mask(path_leaf) -> bool:
    """Weight decay only matrices (skip norms, biases, 1-D tables)."""
    return path_leaf.ndim >= 2


def adamw_update(tcfg: TrainConfig, params, grads, state,
                 ) -> Tuple[Dict, Dict, Dict]:
    """-> (new_params, new_state, metrics). grads f32 (post-clip)."""
    step = state["step"] + 1
    lr = cosine_schedule(tcfg)(step)
    b1, b2, eps = tcfg.b1, tcfg.b2, tcfg.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m_new = b1 * m + (1.0 - b1) * g
        v_new = b2 * v + (1.0 - b2) * g * g
        mhat = m_new / c1
        vhat = v_new / c2
        delta = mhat / (jnp.sqrt(vhat) + eps)
        if _decay_mask(p):
            delta = delta + tcfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"lr": lr}
