"""Multi-tenant dynamic-batching scheduler: determinism oracle + policy.

The oracle is the PR's acceptance bar: every frame served through the
coalescing scheduler — batched with strangers, zero-padded to the policy
shape, dispatched in arrival order — must be BIT-IDENTICAL
(`np.array_equal`, not allclose) to the same frame run alone through
`monolithic_pipeline_fn`. Across all three variants and both
modalities: batching composition is an execution decision, and
execution decisions must never leak into pixels (paper §II-C).

Policy unit tests pin the scheduling invariants that no throughput
number can prove: a lone frame flushes once its queue delay reaches the
policy bound (it never waits forever for companions), occupancy never
exceeds ``max_batch``, eligible-head ties resolve deterministically,
and the idle loop's sleep horizon never admits a busy-spin.

The async in-flight tests re-run the oracle at dispatch-pipelining
depth >= 2 — including under an adversarial readiness gate that forces
completions to drain OUT of dispatch order — because overlap is an
execution decision too, and §II-C does not grant it an exemption.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Modality, Variant, tiny_config
from repro.core.executor import BatchedExecutor
from repro.core.pipeline import init_pipeline, monolithic_pipeline_fn
from repro.data import synth_rf
from repro.launch.scheduler import (BatchPolicy, StreamSpec,
                                    make_mixed_streams, serve_multitenant)

BURST = 1e9          # arrival rate that lands every frame at t ~ 0


def _mono_oracle(cfg, rf):
    """One frame, alone, through the pre-stage-graph reference."""
    consts = jax.tree.map(jnp.asarray, init_pipeline(cfg))
    return np.asarray(jax.jit(monolithic_pipeline_fn(cfg))(
        consts, jnp.asarray(rf)))


@pytest.mark.parametrize("variant", [Variant.DYNAMIC, Variant.CNN,
                                     Variant.SPARSE])
def test_scheduler_output_bit_identical_to_monolithic_oracle(variant):
    """Coalesced multi-tenant serving changes no output bit.

    Two tenants (B-mode + Color Doppler) burst-arrive so the scheduler
    coalesces aggressively; max_batch=3 against 5/4 frames forces both
    full and partial (zero-padded) dispatches. Every served image must
    equal the per-frame monolithic reference exactly.
    """
    cfg_b = tiny_config(variant=variant)
    cfg_d = tiny_config(modality=Modality.DOPPLER, variant=variant)
    streams = [
        StreamSpec("b", cfg_b, fps=BURST, n_frames=5, seed=3, pool=5),
        StreamSpec("d", cfg_d, fps=BURST, n_frames=4, seed=11, pool=4),
    ]
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=3, max_queue_delay_ms=2.0),
        collect_outputs=True)

    # modalities never share a compiled program; same-config tenants do
    assert len(stats["groups"]) == 2
    occ = stats["occupancy"]
    assert occ["frames"] == 9
    assert occ["max_occupancy"] <= 3
    assert occ["min_occupancy"] >= 1

    for sid, spec in (("b", streams[0]), ("d", streams[1])):
        outs = stats["outputs"][sid]
        assert len(outs) == spec.n_frames
        for k, out in enumerate(outs):
            rf = synth_rf(spec.cfg, seed=spec.frame_seed(k))
            want = _mono_oracle(spec.cfg, rf)
            assert out.dtype == want.dtype and out.shape == want.shape
            assert np.array_equal(out, want), (
                f"{sid}[{k}] ({variant.value}) drifted from the "
                f"monolithic oracle: max|d|="
                f"{np.abs(out - want).max()}")


@pytest.mark.parametrize("variant", [Variant.DYNAMIC, Variant.CNN])
@pytest.mark.parametrize("in_flight", [2, 3])
def test_async_in_flight_oracle_bit_identical(variant, in_flight):
    """Pipelined dispatch (depth >= 2) changes no output bit.

    Same two-tenant burst as the synchronous oracle test, but with the
    in-flight ring enabled: batches launch while earlier ones are still
    computing, and completions drain via non-blocking readiness checks.
    Every served image must still equal the per-frame monolithic
    reference exactly, and the ring telemetry must respect the bound.
    """
    cfg_b = tiny_config(variant=variant)
    cfg_d = tiny_config(modality=Modality.DOPPLER, variant=variant)
    streams = [
        StreamSpec("b", cfg_b, fps=BURST, n_frames=5, seed=3, pool=5),
        StreamSpec("d", cfg_d, fps=BURST, n_frames=4, seed=11, pool=4),
    ]
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=3, max_queue_delay_ms=2.0),
        in_flight=in_flight, collect_outputs=True)

    ifo = stats["in_flight_occupancy"]
    assert stats["in_flight"] == in_flight
    assert 1 <= ifo["max_depth"] <= in_flight
    assert 0.0 <= stats["overlap_frac"] <= stats["device_busy_frac"] <= 1.0
    assert stats["warmup_s"] > 0.0      # AOT compile measured, not hidden

    for sid, spec in (("b", streams[0]), ("d", streams[1])):
        outs = stats["outputs"][sid]
        assert len(outs) == spec.n_frames
        for k, out in enumerate(outs):
            rf = synth_rf(spec.cfg, seed=spec.frame_seed(k))
            want = _mono_oracle(spec.cfg, rf)
            assert np.array_equal(out, want), (
                f"{sid}[{k}] ({variant.value}, in_flight={in_flight}) "
                f"drifted from the monolithic oracle")


def test_out_of_order_drain_bit_identical(monkeypatch):
    """Cross-group out-of-order completion drains leave no pixel trace.

    An adversarial readiness gate holds back the FIRST launched batch
    until a later-launched batch (necessarily of the other group) has
    retired — forcing the drain order to differ from the dispatch
    order. Outputs are keyed by (stream, seq), so the oracle must still
    hold bit-for-bit; the gate also records the retire order so the
    test can prove the adversarial schedule actually happened.
    """
    import repro.launch.scheduler as sched

    real_ready = sched._ready
    launch_order = {}           # id(out) -> launch index (first-seen)
    keep = []                   # pin outs so ids can't be recycled
    retire_order = []
    refusals = {"n": 0}

    def gate(out):
        key = id(out)
        if key not in launch_order:
            launch_order[key] = len(launch_order)
            keep.append(out)
        # Hold the first launch until a later one retires (liveness
        # valve: give up the adversary after enough refusals so a
        # pathological timing can never deadlock the test).
        if (launch_order[key] == 0 and not retire_order
                and refusals["n"] < 5000):
            refusals["n"] += 1
            return False
        if not real_ready(out):
            return False
        retire_order.append(launch_order[key])
        return True

    monkeypatch.setattr(sched, "_ready", gate)

    cfg_b = tiny_config(variant=Variant.DYNAMIC)
    cfg_d = tiny_config(modality=Modality.DOPPLER, variant=Variant.DYNAMIC)
    streams = [
        StreamSpec("b", cfg_b, fps=BURST, n_frames=5, seed=3, pool=5),
        StreamSpec("d", cfg_d, fps=BURST, n_frames=4, seed=11, pool=4),
    ]
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=3, max_queue_delay_ms=2.0),
        in_flight=3, collect_outputs=True)

    # The adversarial schedule really ran: something retired before the
    # first launch did.
    assert retire_order[0] != 0, retire_order
    assert sorted(retire_order) == list(range(len(launch_order)))

    for sid, spec in (("b", streams[0]), ("d", streams[1])):
        for k, out in enumerate(stats["outputs"][sid]):
            rf = synth_rf(spec.cfg, seed=spec.frame_seed(k))
            assert np.array_equal(out, _mono_oracle(spec.cfg, rf)), (
                f"{sid}[{k}] drifted under out-of-order drains")


def test_in_flight_one_recovers_synchronous_loop():
    """Depth 1: the ring holds one slot, so every launch retires before
    the next — depth telemetry must be exactly 1 everywhere."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    stats = serve_multitenant(
        [StreamSpec("s", cfg, fps=BURST, n_frames=6)],
        policy=BatchPolicy(max_batch=2, max_queue_delay_ms=0.0),
        in_flight=1)
    ifo = stats["in_flight_occupancy"]
    assert ifo["max_depth"] == 1 and ifo["mean_depth"] == 1.0
    assert ifo["full_rate"] == 1.0
    with pytest.raises(ValueError, match="in_flight"):
        serve_multitenant([StreamSpec("s", cfg)], in_flight=0)


def test_pick_group_tie_break_is_stable_construction_order():
    """Two eligible heads with IDENTICAL arrival times: the first group
    in construction (= spec) order wins, and reversing the list flips
    the winner — the tie is broken by order, not by dict/hash
    accident, so a rerun with identical arrivals replays identical
    dispatch order."""
    from repro.launch.scheduler import _Frame, _Group, _pick_group

    policy = BatchPolicy(max_batch=4, max_queue_delay_ms=5.0)
    a = _Group("a", None, None)
    b = _Group("b", None, None)
    t = 1.000
    a.queue.append(_Frame(stream=0, seq=0, rf=None, t_arrival=t))
    b.queue.append(_Frame(stream=1, seq=0, rf=None, t_arrival=t))

    now = t + 0.006                     # both heads past the delay bound
    assert _pick_group([a, b], now, policy) is a
    assert _pick_group([b, a], now, policy) is b


def test_idle_horizon_never_busy_spins():
    """Whenever the idle horizon is <= now, progress is already due —
    an arrival to admit or an expired head `_pick_group` will flush —
    so the serving loop's `dt <= 0` branch can never spin without
    work. Future-only state yields a strictly positive horizon gap."""
    from repro.launch.scheduler import (_Frame, _Group, _idle_horizon,
                                        _pick_group)

    policy = BatchPolicy(max_batch=4, max_queue_delay_ms=5.0)
    delay_s = policy.max_queue_delay_ms / 1e3

    # Case 1: queue head past the delay bound -> horizon expired AND
    # _pick_group immediately offers that group.
    g = _Group("g", None, None)
    g.queue.append(_Frame(stream=0, seq=0, rf=None, t_arrival=1.0))
    now = 1.0 + delay_s + 0.001
    hz = _idle_horizon([], 0, [g], delay_s)
    assert hz is not None and hz <= now
    assert _pick_group([g], now, policy) is g

    # Case 2: un-admitted arrival in the past -> horizon expired AND the
    # admission sweep (frames[ai].t_arrival <= now) is already due.
    frames = [_Frame(stream=0, seq=0, rf=None, t_arrival=2.0)]
    hz = _idle_horizon(frames, 0, [_Group("e", None, None)], delay_s)
    assert hz == 2.0
    assert hz <= 2.5                    # due at any now >= arrival

    # Case 3: only future events -> strictly positive gap (the loop
    # sleeps, never spins); no events at all -> no horizon.
    now = 1.0
    frames = [_Frame(stream=0, seq=0, rf=None, t_arrival=1.5)]
    g2 = _Group("g2", None, None)
    g2.queue.append(_Frame(stream=0, seq=0, rf=None, t_arrival=now))
    hz = _idle_horizon(frames, 0, [g2], delay_s)
    assert hz is not None and hz > now
    assert _idle_horizon([], 0, [_Group("x", None, None)],
                         delay_s) is None


def test_deadline_miss_count_is_exact():
    """Misses are counted per frame, not reconstructed from the rounded
    miss_rate float: an impossible budget misses every frame of the
    budgeted stream and only those."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    streams = [
        StreamSpec("tight", cfg, fps=BURST, n_frames=3,
                   deadline_ms=1e-9),          # unmeetable -> all miss
        StreamSpec("free", cfg, fps=BURST, n_frames=2,
                   deadline_ms=None),          # unbudgeted -> excluded
    ]
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=2, max_queue_delay_ms=0.0))
    assert stats["per_stream"]["tight"]["deadline_miss_rate"] == 1.0
    # Aggregate rate counts budgeted frames only: 3 misses / 3 budgeted.
    assert stats["deadline_miss_rate"] == 1.0


def test_lone_frame_flushes_at_deadline_never_waits_forever():
    """A batch that will never fill must flush at max_queue_delay."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    stats = serve_multitenant(
        [StreamSpec("solo", cfg, fps=BURST, n_frames=1)],
        policy=BatchPolicy(max_batch=8, max_queue_delay_ms=50.0))
    qd = stats["queue_delay"]
    # The flush trigger is the policy bound, not a full batch: the one
    # frame waited at least 50 ms — and the window terminated, which is
    # the "never waits forever" half of the invariant.
    assert qd["n"] == 1
    assert 0.05 <= qd["p50_s"] < 5.0
    assert stats["occupancy"]["batches"] == 1
    assert stats["occupancy"]["max_occupancy"] == 1
    assert stats["acquisitions"] == 1


def test_occupancy_never_exceeds_max_batch():
    """A 10-frame burst under max_batch=4 dispatches as 4+4+2."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    stats = serve_multitenant(
        [StreamSpec("burst", cfg, fps=BURST, n_frames=10)],
        policy=BatchPolicy(max_batch=4, max_queue_delay_ms=0.0))
    occ = stats["occupancy"]
    assert occ["max_occupancy"] <= 4
    assert occ["frames"] == 10
    assert occ["batches"] == 3          # 4 + 4 + 2, FIFO
    (group,) = stats["groups"].values()
    assert group["batches"] == 3


def test_auto_variant_groups_with_explicit_twin():
    """An AUTO tenant resolves through the planner and shares the
    compiled program of an explicitly-configured twin."""
    cfg = tiny_config(variant=Variant.DYNAMIC)        # cpu heuristic pick
    auto = tiny_config(variant=Variant.AUTO)
    stats = serve_multitenant(
        [StreamSpec("explicit", cfg, fps=BURST, n_frames=2),
         StreamSpec("auto", auto, fps=BURST, n_frames=2)],
        policy=BatchPolicy(max_batch=4, max_queue_delay_ms=1.0),
        plan_policy="heuristic")
    (group,) = stats["groups"].values()
    assert sorted(group["streams"]) == ["auto", "explicit"]
    assert group["plan"]["variant"] == "dynamic"


def test_per_stream_deadlines_and_telemetry_shape():
    """Per-stream budgets produce per-stream miss rates; the record
    passes the shared NDJSON schema."""
    from repro.bench.schema import validate_record

    cfg = tiny_config(variant=Variant.DYNAMIC)
    streams = make_mixed_streams(
        2, cfg, cfg.with_(modality=Modality.DOPPLER),
        base_fps=200.0, n_frames=4, deadline_ms=1e6)   # un-missable
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=2, max_queue_delay_ms=2.0))
    assert stats["deadline_miss_rate"] == 0.0
    for s in stats["per_stream"].values():
        assert s["deadline_miss_rate"] == 0.0
        assert s["latency"]["n"] == 4
    validate_record({"kind": "multitenant", **stats})


def test_frame_pool_cycles_with_period_min_pool_n_frames():
    """The documented pool contract, pinned: frame RF cycles with
    period ``min(pool, n_frames)`` (never more pools than frames are
    synthesized — a 5-pool 3-frame stream has 3 distinct frames, not a
    phantom 5), and seeds within one period are distinct."""
    cfg = tiny_config()

    short = StreamSpec("s", cfg, n_frames=3, pool=5, seed=9)
    assert min(short.pool, short.n_frames) == 3
    assert len({short.frame_seed(k) for k in range(3)}) == 3

    long = StreamSpec("s", cfg, n_frames=10, pool=4, seed=9)
    assert long.frame_seed(4) == long.frame_seed(0)    # period 4
    assert long.frame_seed(9) == long.frame_seed(1)
    assert len({long.frame_seed(k) for k in range(4)}) == 4

    # Same (seed, stream_id, slot) -> same seed regardless of how the
    # period was reached: the pool bound changes WHICH slots exist,
    # never what a slot contains.
    assert short.frame_seed(0) == StreamSpec(
        "s", cfg, n_frames=8, pool=8, seed=9).frame_seed(0)


def test_streams_with_adjacent_seeds_share_no_frame():
    """Disjoint per-stream seed spaces: under the old additive scheme
    (``seed + i``) two tenants whose base seeds differ by less than the
    pool span served byte-identical RF (seed 0 frame 1 == seed 1 frame
    0). `frame_seed` hashes (seed, stream_id), so neither adjacent base
    seeds nor equal ones may collide across distinct streams."""
    cfg = tiny_config()
    a = StreamSpec("a", cfg, n_frames=4, pool=4, seed=0)
    b = StreamSpec("b", cfg, n_frames=4, pool=4, seed=1)   # adjacent
    c = StreamSpec("c", cfg, n_frames=4, pool=4, seed=0)   # equal
    pools = {s.stream_id: [synth_rf(cfg, seed=s.frame_seed(k))
                           for k in range(4)] for s in (a, b, c)}
    for x, y in (("a", "b"), ("a", "c"), ("b", "c")):
        for i, fx in enumerate(pools[x]):
            for j, fy in enumerate(pools[y]):
                assert not np.array_equal(fx, fy), (
                    f"streams {x}[{i}] and {y}[{j}] share a "
                    f"byte-identical frame")


def test_policy_and_spec_validation():
    cfg = tiny_config()
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=0)
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=4, max_queue_delay_ms=-1.0)
    with pytest.raises(ValueError):
        StreamSpec("s", cfg, fps=0.0)
    with pytest.raises(ValueError):
        StreamSpec("s", cfg, n_frames=0)
    with pytest.raises(ValueError):
        serve_multitenant([])
    with pytest.raises(ValueError, match="duplicate"):
        serve_multitenant([StreamSpec("x", cfg), StreamSpec("x", cfg)])


def test_saturated_tenant_cannot_starve_sparse_tenants_frame():
    """Oldest eligible head wins (pure flush-policy logic, no timing):
    a tenant whose queue is ALWAYS full must not keep winning the flush
    over another tenant's expired older frame — that would push the
    sparse tenant's queue delay unboundedly past max_queue_delay_ms."""
    from repro.launch.scheduler import _Frame, _Group, _pick_group

    def frame(t):
        return _Frame(stream=0, seq=0, rf=None, t_arrival=t)

    policy = BatchPolicy(max_batch=4, max_queue_delay_ms=5.0)
    hog = _Group("hog", None, None)
    hog.queue.extend(frame(1.000 + i * 1e-4) for i in range(8))  # full
    solo = _Group("solo", None, None)
    solo.queue.append(frame(0.999))                  # older, not full

    # solo's head not yet expired -> the full queue flushes
    assert _pick_group([hog, solo], now=1.001, policy=policy) is hog
    # solo's head expired and OLDER than the full queue's -> solo wins,
    # no matter how full hog is (full-queue-first starved it here)
    assert _pick_group([hog, solo], now=1.005, policy=policy) is solo
    # once solo drained, hog flushes again; nothing pending -> None
    solo.queue.clear()
    assert _pick_group([hog, solo], now=1.005, policy=policy) is hog
    hog.queue.clear()
    assert _pick_group([hog, solo], now=1.005, policy=policy) is None


def test_sharded_call_padded_degenerate_single_device_mesh():
    """ShardedExecutor.call_padded on the 1-device mesh: same contract
    as the batched path (the true multi-device run is the subprocess
    test below, same pattern as test_sharded_executor.py)."""
    from repro.core.executor import ShardedExecutor

    cfg = tiny_config(variant=Variant.DYNAMIC)
    eng = ShardedExecutor(cfg)        # all local devices (1 in-process)
    if eng.n_devices != 1:            # pragma: no cover - env-dependent
        pytest.skip("main process must see a single device")
    rf = jnp.asarray(np.stack([synth_rf(cfg, seed=s) for s in range(2)]))
    out = np.asarray(eng.call_padded(rf, 4))
    assert out.shape[0] == 2
    assert np.array_equal(out, np.asarray(eng(rf)))
    with pytest.raises(ValueError, match="exceeds pad_to"):
        eng.call_padded(rf, 1)


def test_call_padded_fixed_shape_contract():
    """The executor's heterogeneous-arrival entry point: any occupancy
    1..pad_to returns exactly the valid rows, and over- or empty
    batches are refused."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    eng = BatchedExecutor(cfg)
    rf3 = jnp.asarray(np.stack([synth_rf(cfg, seed=s) for s in range(3)]))
    full = np.asarray(eng(rf3))
    padded = np.asarray(eng.call_padded(rf3, 4))
    assert padded.shape == full.shape
    assert np.array_equal(padded, full)
    one = np.asarray(eng.call_padded(rf3[:1], 4))
    assert np.array_equal(one[0], full[0])
    with pytest.raises(ValueError, match="exceeds pad_to"):
        eng.call_padded(rf3, 2)
    with pytest.raises(ValueError, match="empty"):
        eng.call_padded(rf3[:0], 4)


# ---------------------------------------------------------------------------
# Subprocess: sharded multi-tenant dispatch on a forced 2-device CPU mesh
# (XLA locks the host device count at first jax init — same pattern as
# tests/test_sharded_executor.py)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import BatchedExecutor, Modality, ShardedExecutor, \
    Variant, tiny_config
from repro.core.pipeline import init_pipeline, monolithic_pipeline_fn
from repro.data import synth_rf
from repro.launch.scheduler import BatchPolicy, StreamSpec, \
    serve_multitenant

out = {"device_count": jax.device_count()}
cfg = tiny_config(variant=Variant.DYNAMIC)

# call_padded: fixed SPMD shape, valid rows match the batched oracle
eng = ShardedExecutor(cfg)
oracle = BatchedExecutor(cfg)
errs = {}
for B in (1, 3, 4):
    rf = jnp.stack([jnp.asarray(synth_rf(cfg, seed=i)) for i in range(B)])
    got = np.asarray(eng.call_padded(rf, 4))
    want = np.asarray(oracle(rf))
    errs[str(B)] = [list(got.shape) == list(want.shape),
                    float(np.abs(got - want).max())]
out["errs"] = errs
try:
    eng.call_padded(jnp.stack([jnp.asarray(synth_rf(cfg, seed=0))]), 3)
    out["pad_to_odd_raised"] = False
except ValueError:
    out["pad_to_odd_raised"] = True

# sharded multi-tenant window: plan stamps carry the mesh, outputs
# match the per-frame monolithic oracle
cfg_d = tiny_config(modality=Modality.DOPPLER, variant=Variant.DYNAMIC)
streams = [StreamSpec("b", cfg, fps=1e9, n_frames=3, pool=3),
           StreamSpec("d", cfg_d, fps=1e9, n_frames=2, pool=2)]
stats = serve_multitenant(
    streams, policy=BatchPolicy(max_batch=2, max_queue_delay_ms=2.0),
    devices=jax.local_devices(), collect_outputs=True)
max_err = 0.0
for sid, spec in (("b", streams[0]), ("d", streams[1])):
    consts = jax.tree.map(jnp.asarray, init_pipeline(spec.cfg))
    mono = jax.jit(monolithic_pipeline_fn(spec.cfg))
    for k, img in enumerate(stats["outputs"][sid]):
        want = np.asarray(mono(consts, jnp.asarray(
            synth_rf(spec.cfg, seed=spec.frame_seed(k)))))
        max_err = max(max_err, float(np.abs(img - want).max()))
out["mt_max_err"] = max_err
out["mt_plan_devices"] = [g["plan"]["devices"]
                          for g in stats["groups"].values()]
out["mt_occ_max"] = stats["occupancy"]["max_occupancy"]
out["mt_acqs"] = stats["acquisitions"]
try:
    serve_multitenant(streams, policy=BatchPolicy(max_batch=3),
                      devices=jax.local_devices())
    out["odd_max_batch_raised"] = False
except ValueError:
    out["odd_max_batch_raised"] = True
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results(tmp_path_factory):
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    # Sandbox the subprocess's persistent compile cache like conftest
    # does in-process (AOT warm-up must not touch the user cache dir).
    env["REPRO_COMPILE_CACHE_DIR"] = str(
        tmp_path_factory.mktemp("subproc-xla-cache"))
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_call_padded_matches_oracle(sharded_results):
    assert sharded_results["device_count"] == 2
    for b, (shape_ok, err) in sharded_results["errs"].items():
        assert shape_ok, f"batch {b}: shape mismatch"
        assert err < 1e-5, f"batch {b}: max abs err {err}"
    # pad_to must split evenly across the mesh
    assert sharded_results["pad_to_odd_raised"] is True


def test_sharded_multitenant_window(sharded_results):
    """The scheduler's sharded dispatch path: every served frame
    allclose to the monolithic oracle, plan stamps name the mesh,
    policy invariants hold, and an indivisible max_batch is refused."""
    r = sharded_results
    assert r["mt_max_err"] < 1e-5
    assert r["mt_plan_devices"] == [2, 2]
    assert r["mt_occ_max"] <= 2
    assert r["mt_acqs"] == 5
    assert r["odd_max_batch_raised"] is True


def test_drain_block_mode_bit_identical_and_validated():
    """drain='block' keeps the legacy detect-block-harvest retirement:
    same pixels, same schema stamps, only the transfer timing moves.
    Invalid modes are refused before any work happens."""
    cfg_b = tiny_config(variant=Variant.DYNAMIC)
    cfg_d = tiny_config(modality=Modality.DOPPLER,
                        variant=Variant.DYNAMIC)
    streams = [
        StreamSpec("b", cfg_b, fps=BURST, n_frames=5, seed=3, pool=5),
        StreamSpec("d", cfg_d, fps=BURST, n_frames=4, seed=11, pool=4),
    ]
    policy = BatchPolicy(max_batch=3, max_queue_delay_ms=2.0)
    blocked = serve_multitenant(streams, policy=policy, in_flight=2,
                                drain="block", collect_outputs=True)
    asynced = serve_multitenant(streams, policy=policy, in_flight=2,
                                drain="async", collect_outputs=True)
    assert blocked["drain"] == "block" and asynced["drain"] == "async"
    assert blocked["name"].count("/block/") == 1
    assert asynced["name"].count("/async/") == 1
    for sid in ("b", "d"):
        for a, b in zip(asynced["outputs"][sid], blocked["outputs"][sid]):
            assert np.array_equal(a, b)    # drain mode never moves bits

    with pytest.raises(ValueError, match="drain must be"):
        serve_multitenant(streams, policy=policy, drain="sideways")


def test_adaptive_poll_grain_bounded_by_horizon_and_cap():
    """The busy-poll sleep stretches toward the next arrival horizon
    but never past the completion-detection cap, never below the base
    grain, and falls back to the base when no horizon exists."""
    from repro.launch.scheduler import (_POLL_CAP_S, _POLL_S,
                                        _poll_base, _poll_grain)

    base = 2e-4
    # No horizon (all arrivals admitted): base grain.
    assert _poll_grain(1.0, None, base=base) == base
    # Distant horizon: capped at the detection bound.
    assert _poll_grain(1.0, 10.0, base=base) == _POLL_CAP_S
    # Near horizon: sleep exactly to it.
    assert _poll_grain(1.0, 1.0 + 1e-3, base=base) == pytest.approx(1e-3)
    # Past/immediate horizon: never below the base grain.
    assert _poll_grain(1.0, 0.5, base=base) == base
    assert _POLL_S <= _POLL_CAP_S


def test_poll_base_env_override(monkeypatch):
    from repro.launch import scheduler

    monkeypatch.delenv("REPRO_POLL_S", raising=False)
    assert scheduler._poll_base() == scheduler._POLL_S
    monkeypatch.setenv("REPRO_POLL_S", "0.002")
    assert scheduler._poll_base() == pytest.approx(0.002)
    # Invalid or non-positive overrides fall back, never crash.
    monkeypatch.setenv("REPRO_POLL_S", "banana")
    assert scheduler._poll_base() == scheduler._POLL_S
    monkeypatch.setenv("REPRO_POLL_S", "-1")
    assert scheduler._poll_base() == scheduler._POLL_S
