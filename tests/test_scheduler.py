"""Multi-tenant dynamic-batching scheduler: determinism oracle + policy.

The oracle is the PR's acceptance bar: every frame served through the
coalescing scheduler — batched with strangers, zero-padded to the policy
shape, dispatched in arrival order — must be BIT-IDENTICAL
(`np.array_equal`, not allclose) to the same frame run alone through
`monolithic_pipeline_fn`. Across all three variants and both
modalities: batching composition is an execution decision, and
execution decisions must never leak into pixels (paper §II-C).

Policy unit tests pin the two scheduling invariants that no throughput
number can prove: a lone frame flushes once its queue delay reaches the
policy bound (it never waits forever for companions), and occupancy
never exceeds ``max_batch``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Modality, Variant, tiny_config
from repro.core.executor import BatchedExecutor
from repro.core.pipeline import init_pipeline, monolithic_pipeline_fn
from repro.data import synth_rf
from repro.launch.scheduler import (BatchPolicy, StreamSpec,
                                    make_mixed_streams, serve_multitenant)

BURST = 1e9          # arrival rate that lands every frame at t ~ 0


def _mono_oracle(cfg, rf):
    """One frame, alone, through the pre-stage-graph reference."""
    consts = jax.tree.map(jnp.asarray, init_pipeline(cfg))
    return np.asarray(jax.jit(monolithic_pipeline_fn(cfg))(
        consts, jnp.asarray(rf)))


@pytest.mark.parametrize("variant", [Variant.DYNAMIC, Variant.CNN,
                                     Variant.SPARSE])
def test_scheduler_output_bit_identical_to_monolithic_oracle(variant):
    """Coalesced multi-tenant serving changes no output bit.

    Two tenants (B-mode + Color Doppler) burst-arrive so the scheduler
    coalesces aggressively; max_batch=3 against 5/4 frames forces both
    full and partial (zero-padded) dispatches. Every served image must
    equal the per-frame monolithic reference exactly.
    """
    cfg_b = tiny_config(variant=variant)
    cfg_d = tiny_config(modality=Modality.DOPPLER, variant=variant)
    streams = [
        StreamSpec("b", cfg_b, fps=BURST, n_frames=5, seed=3, pool=5),
        StreamSpec("d", cfg_d, fps=BURST, n_frames=4, seed=11, pool=4),
    ]
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=3, max_queue_delay_ms=2.0),
        collect_outputs=True)

    # modalities never share a compiled program; same-config tenants do
    assert len(stats["groups"]) == 2
    occ = stats["occupancy"]
    assert occ["frames"] == 9
    assert occ["max_occupancy"] <= 3
    assert occ["min_occupancy"] >= 1

    for sid, spec in (("b", streams[0]), ("d", streams[1])):
        outs = stats["outputs"][sid]
        assert len(outs) == spec.n_frames
        for k, out in enumerate(outs):
            rf = synth_rf(spec.cfg, seed=spec.seed + (k % spec.pool))
            want = _mono_oracle(spec.cfg, rf)
            assert out.dtype == want.dtype and out.shape == want.shape
            assert np.array_equal(out, want), (
                f"{sid}[{k}] ({variant.value}) drifted from the "
                f"monolithic oracle: max|d|="
                f"{np.abs(out - want).max()}")


def test_lone_frame_flushes_at_deadline_never_waits_forever():
    """A batch that will never fill must flush at max_queue_delay."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    stats = serve_multitenant(
        [StreamSpec("solo", cfg, fps=BURST, n_frames=1)],
        policy=BatchPolicy(max_batch=8, max_queue_delay_ms=50.0))
    qd = stats["queue_delay"]
    # The flush trigger is the policy bound, not a full batch: the one
    # frame waited at least 50 ms — and the window terminated, which is
    # the "never waits forever" half of the invariant.
    assert qd["n"] == 1
    assert 0.05 <= qd["p50_s"] < 5.0
    assert stats["occupancy"]["batches"] == 1
    assert stats["occupancy"]["max_occupancy"] == 1
    assert stats["acquisitions"] == 1


def test_occupancy_never_exceeds_max_batch():
    """A 10-frame burst under max_batch=4 dispatches as 4+4+2."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    stats = serve_multitenant(
        [StreamSpec("burst", cfg, fps=BURST, n_frames=10)],
        policy=BatchPolicy(max_batch=4, max_queue_delay_ms=0.0))
    occ = stats["occupancy"]
    assert occ["max_occupancy"] <= 4
    assert occ["frames"] == 10
    assert occ["batches"] == 3          # 4 + 4 + 2, FIFO
    (group,) = stats["groups"].values()
    assert group["batches"] == 3


def test_auto_variant_groups_with_explicit_twin():
    """An AUTO tenant resolves through the planner and shares the
    compiled program of an explicitly-configured twin."""
    cfg = tiny_config(variant=Variant.DYNAMIC)        # cpu heuristic pick
    auto = tiny_config(variant=Variant.AUTO)
    stats = serve_multitenant(
        [StreamSpec("explicit", cfg, fps=BURST, n_frames=2),
         StreamSpec("auto", auto, fps=BURST, n_frames=2)],
        policy=BatchPolicy(max_batch=4, max_queue_delay_ms=1.0),
        plan_policy="heuristic")
    (group,) = stats["groups"].values()
    assert sorted(group["streams"]) == ["auto", "explicit"]
    assert group["plan"]["variant"] == "dynamic"


def test_per_stream_deadlines_and_telemetry_shape():
    """Per-stream budgets produce per-stream miss rates; the record
    passes the shared NDJSON schema."""
    from repro.bench.schema import validate_record

    cfg = tiny_config(variant=Variant.DYNAMIC)
    streams = make_mixed_streams(
        2, cfg, cfg.with_(modality=Modality.DOPPLER),
        base_fps=200.0, n_frames=4, deadline_ms=1e6)   # un-missable
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=2, max_queue_delay_ms=2.0))
    assert stats["deadline_miss_rate"] == 0.0
    for s in stats["per_stream"].values():
        assert s["deadline_miss_rate"] == 0.0
        assert s["latency"]["n"] == 4
    validate_record({"kind": "multitenant", **stats})


def test_policy_and_spec_validation():
    cfg = tiny_config()
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=0)
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=4, max_queue_delay_ms=-1.0)
    with pytest.raises(ValueError):
        StreamSpec("s", cfg, fps=0.0)
    with pytest.raises(ValueError):
        StreamSpec("s", cfg, n_frames=0)
    with pytest.raises(ValueError):
        serve_multitenant([])
    with pytest.raises(ValueError, match="duplicate"):
        serve_multitenant([StreamSpec("x", cfg), StreamSpec("x", cfg)])


def test_saturated_tenant_cannot_starve_sparse_tenants_frame():
    """Oldest eligible head wins (pure flush-policy logic, no timing):
    a tenant whose queue is ALWAYS full must not keep winning the flush
    over another tenant's expired older frame — that would push the
    sparse tenant's queue delay unboundedly past max_queue_delay_ms."""
    from repro.launch.scheduler import _Frame, _Group, _pick_group

    def frame(t):
        return _Frame(stream=0, seq=0, rf=None, t_arrival=t)

    policy = BatchPolicy(max_batch=4, max_queue_delay_ms=5.0)
    hog = _Group("hog", None, None)
    hog.queue.extend(frame(1.000 + i * 1e-4) for i in range(8))  # full
    solo = _Group("solo", None, None)
    solo.queue.append(frame(0.999))                  # older, not full

    # solo's head not yet expired -> the full queue flushes
    assert _pick_group([hog, solo], now=1.001, policy=policy) is hog
    # solo's head expired and OLDER than the full queue's -> solo wins,
    # no matter how full hog is (full-queue-first starved it here)
    assert _pick_group([hog, solo], now=1.005, policy=policy) is solo
    # once solo drained, hog flushes again; nothing pending -> None
    solo.queue.clear()
    assert _pick_group([hog, solo], now=1.005, policy=policy) is hog
    hog.queue.clear()
    assert _pick_group([hog, solo], now=1.005, policy=policy) is None


def test_sharded_call_padded_degenerate_single_device_mesh():
    """ShardedExecutor.call_padded on the 1-device mesh: same contract
    as the batched path (the true multi-device run is the subprocess
    test below, same pattern as test_sharded_executor.py)."""
    from repro.core.executor import ShardedExecutor

    cfg = tiny_config(variant=Variant.DYNAMIC)
    eng = ShardedExecutor(cfg)        # all local devices (1 in-process)
    if eng.n_devices != 1:            # pragma: no cover - env-dependent
        pytest.skip("main process must see a single device")
    rf = jnp.asarray(np.stack([synth_rf(cfg, seed=s) for s in range(2)]))
    out = np.asarray(eng.call_padded(rf, 4))
    assert out.shape[0] == 2
    assert np.array_equal(out, np.asarray(eng(rf)))
    with pytest.raises(ValueError, match="exceeds pad_to"):
        eng.call_padded(rf, 1)


def test_call_padded_fixed_shape_contract():
    """The executor's heterogeneous-arrival entry point: any occupancy
    1..pad_to returns exactly the valid rows, and over- or empty
    batches are refused."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    eng = BatchedExecutor(cfg)
    rf3 = jnp.asarray(np.stack([synth_rf(cfg, seed=s) for s in range(3)]))
    full = np.asarray(eng(rf3))
    padded = np.asarray(eng.call_padded(rf3, 4))
    assert padded.shape == full.shape
    assert np.array_equal(padded, full)
    one = np.asarray(eng.call_padded(rf3[:1], 4))
    assert np.array_equal(one[0], full[0])
    with pytest.raises(ValueError, match="exceeds pad_to"):
        eng.call_padded(rf3, 2)
    with pytest.raises(ValueError, match="empty"):
        eng.call_padded(rf3[:0], 4)


# ---------------------------------------------------------------------------
# Subprocess: sharded multi-tenant dispatch on a forced 2-device CPU mesh
# (XLA locks the host device count at first jax init — same pattern as
# tests/test_sharded_executor.py)
# ---------------------------------------------------------------------------

_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import BatchedExecutor, Modality, ShardedExecutor, \
    Variant, tiny_config
from repro.core.pipeline import init_pipeline, monolithic_pipeline_fn
from repro.data import synth_rf
from repro.launch.scheduler import BatchPolicy, StreamSpec, \
    serve_multitenant

out = {"device_count": jax.device_count()}
cfg = tiny_config(variant=Variant.DYNAMIC)

# call_padded: fixed SPMD shape, valid rows match the batched oracle
eng = ShardedExecutor(cfg)
oracle = BatchedExecutor(cfg)
errs = {}
for B in (1, 3, 4):
    rf = jnp.stack([jnp.asarray(synth_rf(cfg, seed=i)) for i in range(B)])
    got = np.asarray(eng.call_padded(rf, 4))
    want = np.asarray(oracle(rf))
    errs[str(B)] = [list(got.shape) == list(want.shape),
                    float(np.abs(got - want).max())]
out["errs"] = errs
try:
    eng.call_padded(jnp.stack([jnp.asarray(synth_rf(cfg, seed=0))]), 3)
    out["pad_to_odd_raised"] = False
except ValueError:
    out["pad_to_odd_raised"] = True

# sharded multi-tenant window: plan stamps carry the mesh, outputs
# match the per-frame monolithic oracle
cfg_d = tiny_config(modality=Modality.DOPPLER, variant=Variant.DYNAMIC)
streams = [StreamSpec("b", cfg, fps=1e9, n_frames=3, pool=3),
           StreamSpec("d", cfg_d, fps=1e9, n_frames=2, pool=2)]
stats = serve_multitenant(
    streams, policy=BatchPolicy(max_batch=2, max_queue_delay_ms=2.0),
    devices=jax.local_devices(), collect_outputs=True)
max_err = 0.0
for sid, spec in (("b", streams[0]), ("d", streams[1])):
    consts = jax.tree.map(jnp.asarray, init_pipeline(spec.cfg))
    mono = jax.jit(monolithic_pipeline_fn(spec.cfg))
    for k, img in enumerate(stats["outputs"][sid]):
        want = np.asarray(mono(consts, jnp.asarray(
            synth_rf(spec.cfg, seed=spec.seed + (k % spec.pool)))))
        max_err = max(max_err, float(np.abs(img - want).max()))
out["mt_max_err"] = max_err
out["mt_plan_devices"] = [g["plan"]["devices"]
                          for g in stats["groups"].values()]
out["mt_occ_max"] = stats["occupancy"]["max_occupancy"]
out["mt_acqs"] = stats["acquisitions"]
try:
    serve_multitenant(streams, policy=BatchPolicy(max_batch=3),
                      devices=jax.local_devices())
    out["odd_max_batch_raised"] = False
except ValueError:
    out["odd_max_batch_raised"] = True
print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def sharded_results():
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_call_padded_matches_oracle(sharded_results):
    assert sharded_results["device_count"] == 2
    for b, (shape_ok, err) in sharded_results["errs"].items():
        assert shape_ok, f"batch {b}: shape mismatch"
        assert err < 1e-5, f"batch {b}: max abs err {err}"
    # pad_to must split evenly across the mesh
    assert sharded_results["pad_to_odd_raised"] is True


def test_sharded_multitenant_window(sharded_results):
    """The scheduler's sharded dispatch path: every served frame
    allclose to the monolithic oracle, plan stamps name the mesh,
    policy invariants hold, and an indivisible max_batch is refused."""
    r = sharded_results
    assert r["mt_max_err"] < 1e-5
    assert r["mt_plan_devices"] == [2, 2]
    assert r["mt_occ_max"] <= 2
    assert r["mt_acqs"] == 5
    assert r["odd_max_batch_raised"] is True
