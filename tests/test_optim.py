"""Optimizer: convergence on a quadratic, clipping, schedule shape."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig
from repro.optim import (adamw_init, adamw_update, cosine_schedule,
                         global_norm_clip)


def test_adamw_converges_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                       weight_decay=0.0)
    target = jnp.asarray(np.linspace(-1, 1, 8).astype(np.float32))
    params = {"w": jnp.zeros(8)}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": (params["w"] - target)}
        params, state, _ = adamw_update(tcfg, params, grads, state)
    assert float(jnp.abs(params["w"] - target).max()) < 0.05


def test_weight_decay_shrinks_matrices_only():
    tcfg = TrainConfig(learning_rate=0.01, warmup_steps=0,
                       total_steps=100, weight_decay=1.0)
    params = {"mat": jnp.ones((4, 4)), "scale": jnp.ones((4,))}
    state = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    params2, _, _ = adamw_update(tcfg, params, zeros, state)
    assert float(params2["mat"].max()) < 1.0       # decayed
    assert float(params2["scale"].min()) == 1.0    # 1-D: no decay


def test_global_norm_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = global_norm_clip(g, 1.0)
    assert abs(float(norm) - np.sqrt(1000.0)) < 1e-3
    total = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert abs(total - 1.0) < 1e-5


def test_cosine_schedule_shape():
    tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10,
                       total_steps=100)
    lr = cosine_schedule(tcfg)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 0.11
    assert float(lr(100)) < 0.01
    assert float(lr(50)) < float(lr(20))
