"""Statistical regression gate (benchmarks/gate.py): pure-logic tests.

The gate's job is narrow — compare CI smoke rows against the committed
baseline with the CI-exclusion rule — so the tests pin exactly the
decisions that matter: a true regression (interval entirely past the
factor) fails, a noisy-but-straddling cell passes (the false alarm the
statistical gate exists to kill), rows without run-level data degrade
to the legacy strict mean rule annotated ``(mean-only)``, a baseline
row MISSING from the current artifact fails loudly, malformed records
become *named* failures instead of KeyError tracebacks, and cells
match on their full identity (table1: name + devices; multitenant: the
sweep key including in-flight depth).
"""

import json

import pytest

from benchmarks.gate import (GateRecordError, gate_multitenant,
                             gate_table1, mt_key, run_gate, t1_key)


def _ci(means):
    return {"mean": sum(means) / len(means), "ci_lo": min(means),
            "ci_hi": max(means), "n_runs": len(means),
            "confidence": 0.95, "n_boot": 2000, "seed": 0,
            "method": "kalibera-jones-bootstrap",
            "run_means": list(means)}


def _t1(name, t, runs=None, devices=None):
    rec = {"name": name, "t_avg_s": t}
    if runs is not None:
        rec["ci"] = _ci(runs)
    if devices is not None:
        rec["plan"] = {"devices": devices}
    return rec


def _mt(clients, max_batch, delay_ms, in_flight, acq_per_s, runs=None,
        profile=None):
    rec = {"clients": clients,
           "policy": {"max_batch": max_batch,
                      "max_queue_delay_ms": delay_ms},
           "in_flight": in_flight, "acq_per_s": acq_per_s,
           "kind": "multitenant"}
    if runs is not None:
        rec["acq_per_s_ci"] = _ci(runs)
    if profile is not None:
        rec["load_profile"] = profile
    return rec


# ---------------------------------------------------------------------------
# Mean-only degradation (rows without repeats)
# ---------------------------------------------------------------------------

def test_gate_table1_mean_only_factor_and_missing():
    base = [_t1("a", 1.0), _t1("b", 1.0), _t1("c", 1.0)]
    cur = [_t1("a", 1.9),            # within 2x -> ok
           _t1("b", 2.1),            # beyond 2x -> fail
           _t1("extra", 99.0)]       # not in baseline -> ignored
    failures = gate_table1(base, cur, factor=2.0)
    assert len(failures) == 2
    assert any("'b devices=1'" in f and "(mean-only)" in f
               for f in failures)
    assert any("'c devices=1'" in f and "missing" in f for f in failures)
    assert gate_table1(base[:1], cur[:1], factor=2.0) == []


def test_gate_multitenant_keys_on_full_cell_identity():
    base = [_mt(2, 4, 5.0, 1, 100.0), _mt(2, 4, 5.0, 2, 200.0)]
    # depth-1 cell healthy, depth-2 cell regressed to depth-1 speed:
    # the per-depth key must catch it.
    cur = [_mt(2, 4, 5.0, 1, 95.0), _mt(2, 4, 5.0, 2, 90.0)]
    failures = gate_multitenant(base, cur, factor=2.0)
    assert len(failures) == 1
    assert "in_flight=2" in failures[0] and "acq_per_s" in failures[0]
    # A missing cell fails; an extra current cell does not.
    failures = gate_multitenant(base, cur[:1] + [_mt(8, 4, 5.0, 1, 1.0)],
                                factor=2.0)
    assert len(failures) == 1 and "missing" in failures[0]
    assert mt_key(base[0]) != mt_key(base[1])


def test_gate_multitenant_profile_is_part_of_cell_identity():
    """A burst window must never gate against a steady baseline cell —
    and a record without the stamp (pre-profile baseline) IS the steady
    cell it ran as."""
    base = [_mt(2, 4, 5.0, 2, 100.0, profile="steady"),
            _mt(2, 4, 5.0, 2, 60.0, profile="burst")]
    assert mt_key(base[0]) != mt_key(base[1])
    assert mt_key(base[0])[4] == "steady"
    # unstamped record == steady: backwards-compatible identity
    assert mt_key(_mt(2, 4, 5.0, 2, 100.0)) == mt_key(base[0])

    # a burst row at steady-regression speed satisfies its OWN cell but
    # must not stand in for the missing steady cell
    cur = [_mt(2, 4, 5.0, 2, 55.0, profile="burst")]
    failures = gate_multitenant(base, cur, factor=2.0)
    assert len(failures) == 1
    assert "missing" in failures[0] and "profile=steady" in failures[0]
    # both profiles present and healthy -> pass
    cur.append(_mt(2, 4, 5.0, 2, 95.0, profile="steady"))
    assert gate_multitenant(base, cur, factor=2.0) == []


# ---------------------------------------------------------------------------
# CI-exclusion decisions (rows with run-level data)
# ---------------------------------------------------------------------------

def test_within_noise_excursion_passes_with_ci():
    """The statistical gate's reason to exist: a point estimate past
    the factor whose ratio interval still straddles it is runner noise
    and must NOT fail — the legacy mean rule would have."""
    base = [_t1("a", 1.0, runs=[1.00, 1.02, 0.98])]
    cur = [_t1("a", 1.13, runs=[1.15, 1.00, 1.25])]
    assert gate_table1(base, cur, factor=1.05) == []
    # Sanity: the same point excursion WITHOUT intervals does fail.
    assert len(gate_table1([_t1("a", 1.0)], [_t1("a", 1.13)],
                           factor=1.05)) == 1


def test_true_regression_fails_with_ci():
    base = [_t1("a", 1.0, runs=[1.00, 1.02, 0.98])]
    cur = [_t1("a", 3.0, runs=[3.0, 3.1, 2.9])]
    failures = gate_table1(base, cur, factor=2.0)
    assert len(failures) == 1
    assert "entirely above" in failures[0]
    assert "(mean-only)" not in failures[0]


def test_multitenant_ci_exclusion_rule():
    base = [_mt(2, 4, 5.0, 2, 100.0, runs=[100.0, 102.0, 98.0])]
    # Noisy dip straddling the floor: pass.
    cur = [_mt(2, 4, 5.0, 2, 55.0, runs=[45.0, 55.0, 65.0])]
    assert gate_multitenant(base, cur, factor=2.0) == []
    # Collapsed throughput, tight interval: fail.
    cur = [_mt(2, 4, 5.0, 2, 30.0, runs=[30.0, 31.0, 29.0])]
    failures = gate_multitenant(base, cur, factor=2.0)
    assert len(failures) == 1 and "entirely below" in failures[0]


def test_table1_key_includes_devices():
    base = [_t1("a", 1.0, devices=2)]
    # Same name at devices=1 must NOT satisfy the devices=2 baseline.
    failures = gate_table1(base, [_t1("a", 1.0, devices=1)], factor=2.0)
    assert len(failures) == 1 and "missing" in failures[0]
    assert gate_table1(base, [_t1("a", 1.0, devices=2)],
                       factor=2.0) == []
    assert t1_key(_t1("a", 1.0)) == ("a", 1)      # no plan -> 1 device


# ---------------------------------------------------------------------------
# Malformed records: named failures, never KeyError tracebacks
# ---------------------------------------------------------------------------

def test_malformed_multitenant_record_is_named_failure():
    bad = {"kind": "multitenant", "name": "mt/broken",
           "clients": 2, "acq_per_s": 10.0}      # no policy/in_flight
    with pytest.raises(GateRecordError, match="mt/broken"):
        mt_key(bad)
    # In the current rows: reported once, the well-formed cells still
    # gate.
    base = [_mt(2, 4, 5.0, 1, 100.0)]
    failures = gate_multitenant(base, [bad] + [_mt(2, 4, 5.0, 1, 95.0)],
                                factor=2.0)
    assert len(failures) == 1 and "mt/broken" in failures[0]
    assert "cell-identity" in failures[0]
    # In the baseline rows: also a named failure, not a crash.
    failures = gate_multitenant([bad], [], factor=2.0)
    assert len(failures) == 1 and "mt/broken" in failures[0]


def test_malformed_table1_record_is_named_failure():
    bad = {"t_avg_s": 1.0}                        # no name
    with pytest.raises(GateRecordError, match="missing 'name'"):
        t1_key(bad)
    failures = gate_table1([bad], [], factor=2.0)
    assert len(failures) == 1 and "missing 'name'" in failures[0]
    # A named row without its metric is identified by name.
    base = [_t1("a", 1.0)]
    failures = gate_table1(base, [{"name": "a"}], factor=2.0)
    assert len(failures) == 1
    assert "'a'" in failures[0] and "t_avg_s" in failures[0]


# ---------------------------------------------------------------------------
# End to end over artifact files
# ---------------------------------------------------------------------------

def test_run_gate_end_to_end(tmp_path):
    baseline = {"results": [_t1("a", 1.0)],
                "multitenant": [_mt(2, 4, 5.0, 2, 100.0)]}
    (tmp_path / "base.json").write_text(json.dumps(baseline))
    (tmp_path / "cur.json").write_text(
        json.dumps({"results": [_t1("a", 1.5)]}))
    with open(tmp_path / "mt.ndjson", "w") as f:
        f.write(json.dumps(_mt(2, 4, 5.0, 2, 80.0)) + "\n")
        f.write(json.dumps({"kind": "summary"}) + "\n")   # skipped

    assert run_gate(str(tmp_path / "base.json"),
                    current_path=str(tmp_path / "cur.json"),
                    multitenant_path=str(tmp_path / "mt.ndjson")) == []

    (tmp_path / "cur.json").write_text(
        json.dumps({"results": [_t1("a", 2.5)]}))
    failures = run_gate(str(tmp_path / "base.json"),
                        current_path=str(tmp_path / "cur.json"),
                        multitenant_path=str(tmp_path / "mt.ndjson"),
                        factor=2.0)
    assert len(failures) == 1 and "'a devices=1'" in failures[0]

    # No multitenant baseline rows -> the NDJSON side is skipped.
    (tmp_path / "base2.json").write_text(
        json.dumps({"results": [_t1("a", 1.0)]}))
    assert run_gate(str(tmp_path / "base2.json"),
                    multitenant_path=str(tmp_path / "mt.ndjson")) == []


def test_run_gate_multiple_current_artifacts(tmp_path):
    """The CI workflow gates the default + lowering + fused smoke
    artifacts in one invocation: the union of their rows must cover
    every baseline cell."""
    baseline = {"results": [_t1("a", 1.0), _t1("b", 1.0)]}
    (tmp_path / "base.json").write_text(json.dumps(baseline))
    (tmp_path / "cur_a.json").write_text(
        json.dumps({"results": [_t1("a", 1.2)]}))
    (tmp_path / "cur_b.json").write_text(
        json.dumps({"results": [_t1("b", 1.2)]}))

    # Either artifact alone leaves a hole; together they cover.
    failures = run_gate(str(tmp_path / "base.json"),
                        current_path=str(tmp_path / "cur_a.json"))
    assert len(failures) == 1 and "'b devices=1'" in failures[0]
    assert run_gate(str(tmp_path / "base.json"),
                    current_path=[str(tmp_path / "cur_a.json"),
                                  str(tmp_path / "cur_b.json")]) == []


# ---------------------------------------------------------------------------
# Drain mode in the cell identity + overlap-telemetry gating
# ---------------------------------------------------------------------------

def _mt_overlap(clients, max_batch, delay_ms, in_flight, acq_per_s, *,
                drain=None, busy=None, busy_runs=None, overlap=None,
                overlap_runs=None, runs=None):
    rec = _mt(clients, max_batch, delay_ms, in_flight, acq_per_s,
              runs=runs)
    if drain is not None:
        rec["drain"] = drain
    if busy is not None:
        rec["device_busy_frac"] = busy
        if busy_runs is not None:
            rec["device_busy_frac_ci"] = _ci(busy_runs)
    if overlap is not None:
        rec["overlap_frac"] = overlap
        if overlap_runs is not None:
            rec["overlap_frac_ci"] = _ci(overlap_runs)
    return rec


def test_gate_multitenant_drain_is_part_of_cell_identity():
    """An async-drain window must never gate against a blocking
    baseline cell — and an unstamped (pre-drain) record IS the blocking
    cell it ran as."""
    base = [_mt_overlap(2, 4, 5.0, 2, 100.0, drain="block"),
            _mt_overlap(2, 4, 5.0, 2, 120.0, drain="async")]
    assert mt_key(base[0]) != mt_key(base[1])
    assert mt_key(base[0])[5] == "block"
    # unstamped record == block: backwards-compatible identity
    assert mt_key(_mt(2, 4, 5.0, 2, 100.0)) == mt_key(base[0])

    # an async row at block speed satisfies its own cell but must not
    # stand in for the missing block cell
    cur = [_mt_overlap(2, 4, 5.0, 2, 115.0, drain="async")]
    failures = gate_multitenant(base, cur, factor=2.0)
    assert len(failures) == 1
    assert "missing" in failures[0] and "drain=block" in failures[0]
    cur.append(_mt_overlap(2, 4, 5.0, 2, 95.0, drain="block"))
    assert gate_multitenant(base, cur, factor=2.0) == []


def test_gate_overlap_telemetry_regression_fails_named():
    """device_busy_frac / overlap_frac are gated like acq/s: a cell
    whose overlap machinery decayed fails by NAME even when acq/s still
    passes (arrival-rate slack can hide the loss)."""
    base = [_mt_overlap(2, 4, 5.0, 2, 100.0, runs=[99.0, 100.0, 101.0],
                        drain="async",
                        busy=0.8, busy_runs=[0.79, 0.80, 0.81],
                        overlap=0.6, overlap_runs=[0.59, 0.60, 0.61])]
    # acq/s healthy, overlap collapsed far past the factor-2 floor.
    cur = [_mt_overlap(2, 4, 5.0, 2, 98.0, runs=[97.0, 98.0, 99.0],
                       drain="async",
                       busy=0.78, busy_runs=[0.77, 0.78, 0.79],
                       overlap=0.1, overlap_runs=[0.09, 0.10, 0.11])]
    failures = gate_multitenant(base, cur, factor=2.0)
    assert len(failures) == 1
    assert "overlap_frac" in failures[0]
    assert "entirely below" in failures[0]
    assert "drain=async" in failures[0]
    assert "(mean-only)" not in failures[0]

    # Same shape through device_busy_frac.
    cur[0]["device_busy_frac"] = 0.2
    cur[0]["device_busy_frac_ci"] = _ci([0.19, 0.20, 0.21])
    failures = gate_multitenant(base, cur, factor=2.0)
    assert len(failures) == 2
    assert any("device_busy_frac" in f for f in failures)


def test_gate_overlap_noise_straddle_passes():
    """The CI-exclusion rule applies to the overlap columns too: a
    noisy dip whose ratio interval straddles the floor is not a
    regression."""
    base = [_mt_overlap(2, 4, 5.0, 2, 100.0, runs=[99.0, 100.0, 101.0],
                        overlap=0.5, overlap_runs=[0.45, 0.50, 0.55],
                        busy=0.8, busy_runs=[0.79, 0.80, 0.81])]
    cur = [_mt_overlap(2, 4, 5.0, 2, 100.0, runs=[99.0, 100.0, 101.0],
                       overlap=0.3, overlap_runs=[0.2, 0.3, 0.55],
                       busy=0.8, busy_runs=[0.79, 0.80, 0.81])]
    assert gate_multitenant(base, cur, factor=2.0) == []


def test_gate_overlap_zero_baseline_skipped():
    """A legitimately synchronous baseline cell (depth-1 overlap run
    mean 0.0) is skipped for that metric — the ratio is undefined — and
    a pre-telemetry baseline row (no overlap keys at all) gates acq/s
    only."""
    base = [_mt_overlap(2, 4, 5.0, 1, 100.0, runs=[99.0, 100.0, 101.0],
                        overlap=0.0, overlap_runs=[0.0, 0.0, 0.0],
                        busy=0.8, busy_runs=[0.79, 0.80, 0.81])]
    cur = [_mt_overlap(2, 4, 5.0, 1, 98.0, runs=[97.0, 98.0, 99.0],
                       overlap=0.0, overlap_runs=[0.0, 0.0, 0.0],
                       busy=0.78, busy_runs=[0.77, 0.78, 0.79])]
    assert gate_multitenant(base, cur, factor=2.0) == []

    # Pre-telemetry baseline: no overlap keys anywhere, still gates.
    assert gate_multitenant([_mt(2, 4, 5.0, 1, 100.0)],
                            [_mt(2, 4, 5.0, 1, 95.0)], factor=2.0) == []
    # Current missing a metric the baseline carries: named failure.
    cur_missing = [_mt(2, 4, 5.0, 1, 98.0)]
    failures = gate_multitenant(base, cur_missing, factor=2.0)
    assert len(failures) == 1
    assert "device_busy_frac" in failures[0]


def test_run_gate_multiple_multitenant_artifacts(tmp_path):
    """--multitenant is repeatable: the union of NDJSON artifacts must
    cover every baseline multitenant cell (the CI workflow feeds the
    steady and transfer-telemetry smoke files in one invocation)."""
    baseline = {"results": [],
                "multitenant": [
                    _mt_overlap(2, 4, 5.0, 2, 100.0, drain="block"),
                    _mt_overlap(2, 4, 5.0, 2, 110.0, drain="async")]}
    (tmp_path / "base.json").write_text(json.dumps(baseline))
    (tmp_path / "mt_block.ndjson").write_text(
        json.dumps(_mt_overlap(2, 4, 5.0, 2, 95.0, drain="block"))
        + "\n")
    (tmp_path / "mt_async.ndjson").write_text(
        json.dumps(_mt_overlap(2, 4, 5.0, 2, 105.0, drain="async"))
        + "\n")

    failures = run_gate(str(tmp_path / "base.json"),
                        multitenant_path=str(tmp_path
                                             / "mt_block.ndjson"))
    assert len(failures) == 1 and "drain=async" in failures[0]
    assert run_gate(
        str(tmp_path / "base.json"),
        multitenant_path=[str(tmp_path / "mt_block.ndjson"),
                          str(tmp_path / "mt_async.ndjson")]) == []
