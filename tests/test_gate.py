"""Throughput regression gate (benchmarks/gate.py): pure-logic tests.

The gate's job is narrow — compare CI smoke rows against the committed
baseline with a loose factor — so the tests pin exactly the decisions
that matter: a slow row fails, a within-factor row passes, a baseline
row MISSING from the current artifact fails loudly (a renamed row must
never open a silent hole), extra current rows are ignored, and
multitenant cells match on the full sweep key including the in-flight
depth (so a depth-2 overlap regression cannot hide behind a healthy
depth-1 cell).
"""

import json

from benchmarks.gate import (gate_multitenant, gate_table1, mt_key,
                             run_gate)


def _t1(name, t):
    return {"name": name, "t_avg_s": t}


def _mt(clients, max_batch, delay_ms, in_flight, acq_per_s):
    return {"clients": clients,
            "policy": {"max_batch": max_batch,
                       "max_queue_delay_ms": delay_ms},
            "in_flight": in_flight, "acq_per_s": acq_per_s,
            "kind": "multitenant"}


def test_gate_table1_factor_and_missing():
    base = [_t1("a", 1.0), _t1("b", 1.0), _t1("c", 1.0)]
    cur = [_t1("a", 1.9),            # within 2x -> ok
           _t1("b", 2.1),            # beyond 2x -> fail
           _t1("extra", 99.0)]       # not in baseline -> ignored
    failures = gate_table1(base, cur, factor=2.0)
    assert len(failures) == 2
    assert any("'b'" in f and "t_avg_s" in f for f in failures)
    assert any("'c'" in f and "missing" in f for f in failures)
    assert gate_table1(base[:1], cur[:1], factor=2.0) == []


def test_gate_multitenant_keys_on_full_cell_identity():
    base = [_mt(2, 4, 5.0, 1, 100.0), _mt(2, 4, 5.0, 2, 200.0)]
    # depth-1 cell healthy, depth-2 cell regressed to depth-1 speed:
    # the per-depth key must catch it.
    cur = [_mt(2, 4, 5.0, 1, 95.0), _mt(2, 4, 5.0, 2, 90.0)]
    failures = gate_multitenant(base, cur, factor=2.0)
    assert len(failures) == 1
    assert "in_flight=2" in failures[0] and "acq_per_s" in failures[0]
    # A missing cell fails; an extra current cell does not.
    failures = gate_multitenant(base, cur[:1] + [_mt(8, 4, 5.0, 1, 1.0)],
                                factor=2.0)
    assert len(failures) == 1 and "missing" in failures[0]
    assert mt_key(base[0]) != mt_key(base[1])


def test_run_gate_end_to_end(tmp_path):
    baseline = {"results": [_t1("a", 1.0)],
                "multitenant": [_mt(2, 4, 5.0, 2, 100.0)]}
    (tmp_path / "base.json").write_text(json.dumps(baseline))
    (tmp_path / "cur.json").write_text(
        json.dumps({"results": [_t1("a", 1.5)]}))
    with open(tmp_path / "mt.ndjson", "w") as f:
        f.write(json.dumps(_mt(2, 4, 5.0, 2, 80.0)) + "\n")
        f.write(json.dumps({"kind": "summary"}) + "\n")   # skipped

    assert run_gate(str(tmp_path / "base.json"),
                    current_path=str(tmp_path / "cur.json"),
                    multitenant_path=str(tmp_path / "mt.ndjson")) == []

    (tmp_path / "cur.json").write_text(
        json.dumps({"results": [_t1("a", 2.5)]}))
    failures = run_gate(str(tmp_path / "base.json"),
                        current_path=str(tmp_path / "cur.json"),
                        multitenant_path=str(tmp_path / "mt.ndjson"),
                        factor=2.0)
    assert len(failures) == 1 and "'a'" in failures[0]

    # No multitenant baseline rows -> the NDJSON side is skipped.
    (tmp_path / "base2.json").write_text(
        json.dumps({"results": [_t1("a", 1.0)]}))
    assert run_gate(str(tmp_path / "base2.json"),
                    multitenant_path=str(tmp_path / "mt.ndjson")) == []
