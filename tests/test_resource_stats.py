"""Resource metering: peak-memory high-water mark, energy gating, stamping.

The CPU stand-in exercises the `live_arrays` fallback and the
NVML-unavailable path (`energy_joules is None`, never a crash) — the
GPU allocator path is covered structurally via an injected fake.
"""

import json

import numpy as np

import jax
import jax.numpy as jnp

from repro.bench import (NvmlEnergyMeter, ResourceMeter, ResourceStats,
                         bench_callable)
from repro.bench.resources import device_peak_memory_bytes, live_array_bytes
from repro.core import tiny_config
from repro.launch.serve import serve_ultrasound_stream


def test_resource_stats_json_nulls_distinguish_unmeasured():
    st = ResourceStats()
    d = st.json_dict()
    assert d["peak_memory_bytes"] is None
    assert d["energy_joules"] is None
    assert d["memory_source"] is None
    assert json.loads(json.dumps(d)) == d       # JSON-serializable


def test_peak_memory_monotone_under_allocation():
    """The high-water mark grows with live allocations and never shrinks."""
    meter = ResourceMeter()
    meter.start()
    small = jnp.ones((64,), jnp.float32)
    jax.block_until_ready(small)
    meter.sample()
    peak_small = meter._peak
    big = jnp.ones((1_000_000,), jnp.float32)   # +4 MB live
    jax.block_until_ready(big)
    meter.sample()
    peak_big = meter._peak
    assert peak_big >= peak_small + 4_000_000 * 0.9
    del big
    meter.sample()                               # freeing never lowers peak
    st = meter.stop()
    assert st.peak_memory_bytes == peak_big
    assert st.memory_source == "live_arrays"     # CPU: no allocator stats
    assert st.devices == len(jax.local_devices())
    assert st.duration_s is not None and st.duration_s >= 0
    del small


def test_cpu_has_no_allocator_stats_but_live_arrays_counts():
    devs = jax.local_devices()
    assert device_peak_memory_bytes(devs) is None
    keep = jnp.ones((1024,), jnp.float32)
    jax.block_until_ready(keep)
    assert live_array_bytes(devs) >= keep.nbytes
    del keep


def test_allocator_peak_is_window_scoped(monkeypatch):
    """A process-lifetime allocator peak inherited from an earlier run
    must not be reported as this window's peak (falls back to sampled
    bytes_in_use); a new high-water mark set inside the window is."""
    from repro.bench import resources as res_lib

    readings = iter([
        [(5000, 5000)],   # start() baseline: lifetime peak 5000
        [(5000, 400)],    # sample 1: old peak stands -> report in_use 400
        [(5000, 900)],    # sample 2: still the old peak -> in_use 900
        [(7000, 6500)],   # sample 3: new high-water mark inside window
    ])
    monkeypatch.setattr(res_lib, "device_memory_stats_list",
                        lambda devices: next(readings))
    meter = res_lib.ResourceMeter(devices=jax.local_devices())
    meter.start()                    # consumes baseline + first sample
    assert meter._peak == 400
    assert meter._source == "device_bytes_in_use"
    meter.sample()
    assert meter._peak == 900
    meter.sample()
    assert meter._peak == 7000
    assert meter._source == "device_memory_stats"


def test_allocator_window_scoping_is_per_device(monkeypatch):
    """Device 0's huge pre-window lifetime peak must not be attributed
    to the window just because device 1 set a new (small) peak — the
    baseline comparison is per device, never on the sums."""
    from repro.bench import resources as res_lib

    readings = iter([
        [(10_000, 100), (500, 100)],   # baseline: dev0 has an old 10k peak
        [(10_000, 200), (800, 700)],   # window: dev1 peaks at 800, dev0 idle
    ])
    monkeypatch.setattr(res_lib, "device_memory_stats_list",
                        lambda devices: next(readings))
    meter = res_lib.ResourceMeter(devices=jax.local_devices())
    meter.start()
    assert meter._peak == 200 + 800                # not 10_000 + 800
    assert meter._source == "device_bytes_in_use"  # mixed -> lower bound


def test_energy_meter_none_off_gpu():
    """No pynvml / no GPU: available() False, stop() returns None cleanly."""
    meter = NvmlEnergyMeter()
    assert meter.available() is False
    meter.start()                                # must not raise
    assert meter.stop() is None
    st = ResourceMeter().stop()                  # stop without start: no crash
    assert st.energy_joules is None and st.energy_source is None


def test_energy_poll_integrates_tail_of_short_windows():
    """Even a window shorter than poll_s integrates at least the
    start->stop interval — a measured window never reports 0.0 J merely
    because no poll tick fired inside it."""
    class FakePower(NvmlEnergyMeter):
        def __init__(self):
            super().__init__(poll_s=60.0)        # no tick fires in-window
            self._handles = [object()]           # force available()
            self._calls = 0

        def _power_w(self):
            self._calls += 1
            return 10.0 if self._calls == 1 else 50.0   # idle 10W, then 50W

    meter = FakePower()
    assert meter.available()
    meter.start()
    import time
    time.sleep(0.02)
    joules = meter.stop()
    assert joules is not None and joules > 0.0   # 40W above idle, >0 s


def test_energy_none_when_every_power_read_fails():
    """Handles exist but power queries fail: None, never a fake 0.0 J."""
    class DeadPower(NvmlEnergyMeter):
        def __init__(self):
            super().__init__(poll_s=0.01)
            self._handles = [object()]

        def _power_w(self):
            return None                      # NVML_ERROR_NOT_SUPPORTED

    meter = DeadPower()
    assert meter.available()
    meter.start()                            # idle read fails -> no thread
    assert meter.stop() is None


def test_nvml_index_mapping_respects_visible_devices():
    from repro.bench.resources import nvml_indices_for_local_gpus as f
    assert f([0, 1], visible=None) == [0, 1]          # all boards visible
    assert f([0, 1], visible="2,3") == [2, 3]         # pinned job remaps
    assert f([1], visible="3,1,0") == [1]
    assert f([0], visible="GPU-aaaa-bbbb") is None    # UUID: unmappable
    assert f([2], visible="0,1") is None              # out of range


def test_injected_energy_meter_is_reported():
    class Fake:
        def available(self):
            return True

        def start(self):
            pass

        def stop(self):
            return 42.5

    meter = ResourceMeter(energy_meter=Fake())
    meter.start()
    st = meter.stop()
    assert st.energy_joules == 42.5
    assert st.energy_source == "nvml"


def test_bench_callable_stamps_resources_into_ndjson():
    res = bench_callable("t", lambda x: x * 2.0, (jnp.ones((32, 32)),),
                         input_bytes=1000, warmup=1, runs=3)
    assert res.resources is not None
    assert res.resources["energy_joules"] is None
    assert res.resources["peak_memory_bytes"] is not None
    recs = [json.loads(line) for line in res.ndjson_lines()]
    summary = recs[0]
    assert summary["kind"] == "summary"
    assert summary["resources"]["peak_memory_bytes"] \
        == res.resources["peak_memory_bytes"]
    for r in recs:
        if r["kind"] == "sample":
            assert r["resources"] == res.resources


def test_stream_stats_carry_resources():
    stats = serve_ultrasound_stream(tiny_config(), batch=2, n_batches=4,
                                    depth=2)
    res = stats["resources"]
    assert res["peak_memory_bytes"] is not None
    assert res["memory_source"] == "live_arrays"
    assert res["energy_joules"] is None          # graceful off-GPU
    json.dumps(stats["plan"])                    # stamp stays serializable
    assert stats["plan"]["devices"] == 1


def test_meter_survives_broken_energy_backend():
    class Exploding:
        def available(self):
            return True

        def start(self):
            raise RuntimeError("driver gone")

        def stop(self):
            raise RuntimeError("driver gone")

    meter = ResourceMeter(energy_meter=Exploding())
    meter.start()                                # exception-free contract
    st = meter.stop()
    assert isinstance(st, ResourceStats)
    assert st.energy_joules is None
