"""Staging-ring aliasing contract: mechanics, refusal, adversarial reuse.

The ring's one dangerous property is that `stage` returns a buffer it
will eventually hand to someone else. The unit tests pin the mechanics
(round-robin slot order, pad rows exactly zero after partial-over-full
reuse, copy accounting); the refusal test pins that an undersized ring
(slots < depth + 1) cannot even be constructed — the aliasing bug it
would permit is not detectable at stage time. The adversarial test is
the one that matters: it drives the real scheduler at in_flight >= 2 so
ring slots are rewritten while earlier dispatches are still pending,
and asserts every served frame is STILL bit-identical to the
monolithic per-frame oracle — if a slot were recycled one launch too
early, the device would read a half-overwritten batch and the oracle
would catch the torn rows.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Modality, Variant, tiny_config
from repro.core.staging import StagingRing
from repro.core.pipeline import init_pipeline, monolithic_pipeline_fn
from repro.data import synth_rf
from repro.launch.scheduler import (BatchPolicy, StreamSpec,
                                    serve_multitenant)

BURST = 1e9          # arrival rate that lands every frame at t ~ 0


def _frames(shape, dtype, n, start=0):
    return [np.full(shape, start + k, dtype=dtype) for k in range(n)]


class TestRingMechanics:
    def test_pad_rows_zero_and_rows_in_order(self):
        ring = StagingRing(4, (2, 3), np.float32, depth=2)
        frames = _frames((2, 3), np.float32, 3, start=1)
        buf, b = ring.stage(frames)
        assert b == 3
        assert buf.shape == (4, 2, 3) and buf.dtype == np.float32
        for r in range(3):
            assert np.array_equal(buf[r], frames[r])
        assert not buf[3:].any()

    def test_partial_after_full_rezeros_stale_tail(self):
        ring = StagingRing(4, (2,), np.float32, depth=1, slots=2)
        # Dirty both slots to full occupancy, then wrap with b=1: rows
        # 1..3 held slot 0's first batch and must come back as zeros.
        ring.stage(_frames((2,), np.float32, 4, start=10))
        ring.stage(_frames((2,), np.float32, 4, start=20))
        buf, b = ring.stage(_frames((2,), np.float32, 1, start=30))
        assert b == 1
        assert np.all(buf[0] == 30)
        assert not buf[1:].any()

    def test_slots_cycle_round_robin(self):
        ring = StagingRing(2, (1,), np.float32, depth=2)   # 3 slots
        bufs = [ring.stage(_frames((1,), np.float32, 1))[0]
                for _ in range(ring.slots + 1)]
        ids = [id(b) for b in bufs]
        assert len(set(ids[:ring.slots])) == ring.slots
        assert ids[ring.slots] == ids[0]      # wrapped back to slot 0
        assert ring.batches_staged == ring.slots + 1
        assert ring.stage_copy_s > 0.0

    def test_empty_and_oversized_batches_refused(self):
        ring = StagingRing(2, (1,), np.float32, depth=1)
        with pytest.raises(ValueError, match="empty RF batch"):
            ring.stage([])
        with pytest.raises(ValueError, match="exceeds pad_to"):
            ring.stage(_frames((1,), np.float32, 3))


class TestUndersizedRingRefused:
    @pytest.mark.parametrize("depth,slots", [(1, 1), (2, 2), (3, 2)])
    def test_slots_below_depth_plus_one_refused(self, depth, slots):
        with pytest.raises(ValueError,
                           match="cannot back in_flight"):
            StagingRing(4, (2,), np.float32, depth=depth, slots=slots)

    def test_invalid_geometry_refused(self):
        with pytest.raises(ValueError, match="pad_to"):
            StagingRing(0, (2,), np.float32, depth=1)
        with pytest.raises(ValueError, match="depth"):
            StagingRing(4, (2,), np.float32, depth=0)

    def test_minimum_legal_ring_constructs(self):
        ring = StagingRing(4, (2,), np.float32, depth=3, slots=4)
        assert ring.slots == 4


def _mono_oracle(cfg, rf):
    consts = jax.tree.map(jnp.asarray, init_pipeline(cfg))
    return np.asarray(jax.jit(monolithic_pipeline_fn(cfg))(
        consts, jnp.asarray(rf)))


@pytest.mark.parametrize("drain", ["async", "block"])
def test_adversarial_slot_reuse_keeps_bit_identity(drain):
    """Slots are rewritten under in-flight load; no output bit moves.

    Two burst tenants at max_batch=2 over 8/7 frames force each group's
    3-slot ring (in_flight=2) to wrap several times while up to two
    dispatches are pending — precisely the window in which a sizing bug
    would let the admit loop overwrite a buffer the device is still
    reading. Bit-identity against the monolithic oracle proves the
    aliasing contract held for every single wrap, in both drain modes.
    """
    cfg_b = tiny_config(variant=Variant.DYNAMIC)
    cfg_d = tiny_config(modality=Modality.DOPPLER,
                        variant=Variant.DYNAMIC)
    streams = [
        StreamSpec("b", cfg_b, fps=BURST, n_frames=8, seed=3, pool=8),
        StreamSpec("d", cfg_d, fps=BURST, n_frames=7, seed=11, pool=7),
    ]
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=2, max_queue_delay_ms=1.0),
        in_flight=2, drain=drain, collect_outputs=True)

    # The rings actually wrapped: each group staged more batches than
    # it has slots, so every slot was reused at least once.
    assert stats["drain"] == drain
    for g in stats["groups"].values():
        assert g["batches"] > 3        # > slots (= in_flight + 1)

    for sid, spec in (("b", streams[0]), ("d", streams[1])):
        outs = stats["outputs"][sid]
        assert len(outs) == spec.n_frames
        for k, out in enumerate(outs):
            rf = synth_rf(spec.cfg, seed=spec.frame_seed(k))
            want = _mono_oracle(spec.cfg, rf)
            assert np.array_equal(out, want), (
                f"{sid}[{k}] (drain={drain}) drifted from the "
                f"monolithic oracle after slot reuse: max|d|="
                f"{np.abs(out - want).max()}")


def test_transfer_telemetry_stamped_and_bounded():
    """stage_copy/h2d/d2h land in the record and respect the wall."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    streams = [StreamSpec("s", cfg, fps=BURST, n_frames=6, seed=5,
                          pool=6)]
    stats = serve_multitenant(
        streams, policy=BatchPolicy(max_batch=2, max_queue_delay_ms=1.0),
        in_flight=2)
    for key in ("stage_copy_s", "h2d_s", "d2h_s"):
        assert stats[key] >= 0.0
    assert stats["stage_copy_s"] > 0.0     # the ring path actually ran
    assert 0.0 <= stats["transfer_frac"] <= 1.0
