"""The paper's central determinism/equivalence claim: all three
implementation variants compute the same math."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Modality, UltrasoundPipeline, Variant, tiny_config)
from repro.core.delays import compute_delay_tables
from repro.core import geometry
from repro.data import synth_rf


@pytest.mark.parametrize("modality", list(Modality))
def test_variant_equivalence(modality):
    cfg0 = tiny_config(n_f=8, modality=modality)
    rf = jnp.asarray(synth_rf(cfg0, seed=3))
    outs = {}
    for v in Variant:
        if not v.concrete:          # AUTO resolves to one of these three
            continue
        pipe = UltrasoundPipeline(cfg0.with_(variant=v))
        outs[v] = np.asarray(pipe(rf))
    for v in [Variant.CNN, Variant.SPARSE]:
        np.testing.assert_allclose(
            outs[v], outs[Variant.DYNAMIC], rtol=1e-4, atol=1e-4,
            err_msg=f"{modality} {v} != dynamic")


def test_point_scatterer_localizes():
    """B-mode peak lands at (or next to) the simulated scatterer pixel."""
    cfg = tiny_config(nz=32, nx=16, n_f=2, n_c=8)
    from repro.data.rf_data import synth_rf as gen
    rf = gen(cfg, seed=7, n_scatter=1, flow_fraction=0.0)
    img = np.asarray(UltrasoundPipeline(cfg)(jnp.asarray(rf)))[..., 0]

    # find the scatterer ground truth from the generator's rng
    rng = np.random.default_rng(7)
    half_ap = (cfg.n_c - 1) / 2.0 * cfg.pitch
    zs = rng.uniform(cfg.z_min, cfg.z_max, 1)[0]
    xs = rng.uniform(-half_ap, half_ap, 1)[0]
    Z, X = geometry.image_grid(cfg)
    iz = np.abs(Z[:, 0] - zs).argmin()
    ix = np.abs(X[0, :] - xs).argmin()

    pz, px = np.unravel_index(img.argmax(), img.shape)
    assert abs(int(pz) - iz) <= 2 and abs(int(px) - ix) <= 2, \
        ((pz, px), (iz, ix))


def test_bsr_band_is_sparse():
    """The banded structure actually skips blocks on a tall grid."""
    cfg = tiny_config(nz=64, nx=8, n_l=512)
    from repro.core.delays import bsr_operator
    op = bsr_operator(cfg, compute_delay_tables(cfg))
    assert op.nnz_ratio < 0.7, op.nnz_ratio


def test_apodization_rows_normalized():
    cfg = tiny_config()
    t = compute_delay_tables(cfg)
    sums = t.apod.sum(axis=1)
    active = sums > 0
    np.testing.assert_allclose(sums[active], 1.0, atol=1e-5)
