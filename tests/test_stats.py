"""Statistics layer (repro.bench.stats): bootstrap CIs + the gate rule.

The deterministic tests pin the invariants the regression gate relies
on — the interval contains its point estimate, fixed seeds reproduce
exactly, run order cannot move an interval, wider confidence never
shrinks it, and the 95% interval actually covers ~95% on synthetic
noise (calibration, the property that makes "CI excludes the factor" a
meaningful verdict). The Hypothesis section re-checks the structural
invariants over randomized inputs when the library is installed
(requirements-dev.txt documents the auto-skip).
"""

import numpy as np
import pytest

from repro.bench.stats import (CIStats, bootstrap_ci, ci_ratio,
                               gate_ratio, run_means)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # container without dev extras: auto-skip
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# Deterministic invariants
# ---------------------------------------------------------------------------

def test_run_means_flat_nested_and_sorted():
    np.testing.assert_allclose(run_means([3.0, 1.0, 2.0]),
                               [1.0, 2.0, 3.0])
    # Nested per-run samples reduce to their means first (two-level).
    np.testing.assert_allclose(
        run_means([[2.0, 4.0], [1.0, 1.0]]), [1.0, 3.0])
    with pytest.raises(ValueError, match="at least one run"):
        run_means([])


def test_ci_contains_point_estimate():
    ci = bootstrap_ci([1.0, 1.2, 0.9, 1.1])
    assert ci.ci_lo <= ci.mean <= ci.ci_hi
    assert ci.n_runs == 4 and len(ci.run_means) == 4
    assert ci.method == "kalibera-jones-bootstrap"


def test_single_run_interval_is_degenerate():
    ci = bootstrap_ci([2.5])
    assert ci.ci_lo == ci.mean == ci.ci_hi == 2.5
    assert ci.n_runs == 1


def test_seed_reproducibility_exact():
    runs = [1.0, 1.3, 0.8, 1.1, 0.95]
    a = bootstrap_ci(runs, seed=7)
    b = bootstrap_ci(runs, seed=7)
    assert (a.ci_lo, a.ci_hi) == (b.ci_lo, b.ci_hi)
    c = bootstrap_ci(runs, seed=8)
    assert (a.ci_lo, a.ci_hi) != (c.ci_lo, c.ci_hi)   # seed matters


def test_permutation_invariance():
    runs = [1.0, 1.3, 0.8, 1.1, 0.95]
    a = bootstrap_ci(runs)
    b = bootstrap_ci(list(reversed(runs)))
    rng = np.random.default_rng(0)
    c = bootstrap_ci(list(rng.permutation(runs)))
    assert (a.ci_lo, a.ci_hi) == (b.ci_lo, b.ci_hi) == (c.ci_lo, c.ci_hi)


def test_interval_widens_with_confidence():
    runs = [1.0, 1.3, 0.8, 1.1, 0.95, 1.2]
    prev = bootstrap_ci(runs, confidence=0.5)
    for conf in (0.8, 0.9, 0.95, 0.99):
        ci = bootstrap_ci(runs, confidence=conf)
        assert ci.ci_lo <= prev.ci_lo and ci.ci_hi >= prev.ci_hi, conf
        prev = ci


def test_confidence_bounds_validated():
    for bad in (0.0, 1.0, -0.5, 2.0):
        with pytest.raises(ValueError, match="confidence"):
            bootstrap_ci([1.0, 2.0], confidence=bad)
        with pytest.raises(ValueError, match="confidence"):
            ci_ratio([1.0, 2.0], [1.0, 2.0], confidence=bad)


def test_median_statistic_supported():
    ci = bootstrap_ci([1.0, 1.0, 1.0, 100.0], statistic="median")
    assert ci.mean == 1.0            # robust to the outlier run
    with pytest.raises(KeyError):
        bootstrap_ci([1.0, 2.0], statistic="mode")


def test_calibrated_coverage_on_synthetic_noise():
    """~95% of 95% intervals cover the true mean on iid normal runs.

    The property that makes CI-exclusion gating meaningful: if the
    intervals were too narrow the gate would false-alarm on noise, too
    wide and it would never fire. Bootstrap-over-5-runs is known to
    undercover slightly, so the bar is a generous [0.80, 0.999]."""
    rng = np.random.default_rng(42)
    covered = 0
    n_data = 200
    for i in range(n_data):
        runs = rng.normal(loc=10.0, scale=1.0, size=5)
        ci = bootstrap_ci(list(runs), seed=i)
        covered += int(ci.ci_lo <= 10.0 <= ci.ci_hi)
    coverage = covered / n_data
    assert 0.80 <= coverage <= 0.999, coverage


def test_ci_ratio_point_and_degenerate():
    r = ci_ratio([2.0], [3.0])
    assert r.ratio == r.ci_lo == r.ci_hi == 1.5   # single-run degenerate
    r = ci_ratio([1.0, 1.1, 0.9], [2.0, 2.2, 1.8])
    assert r.ci_lo <= r.ratio <= r.ci_hi
    assert r.n_runs_baseline == r.n_runs_current == 3
    with pytest.raises(ValueError, match="zero"):
        ci_ratio([0.0, 1.0], [1.0, 2.0])


def test_gate_ratio_time_like_decisions():
    base = [1.0, 1.02, 0.98]
    # Point estimate past the factor but interval straddling it: pass.
    noisy = [1.15, 1.0, 1.25]
    dec = gate_ratio(base, noisy, factor=1.05, higher_is_better=False)
    assert dec.ok and "contains or undercuts" in dec.reason
    # Interval entirely past the factor: fail, no rerun will undo it.
    dec = gate_ratio(base, [3.0, 3.1, 2.9], factor=2.0,
                     higher_is_better=False)
    assert not dec.ok and "entirely above" in dec.reason
    with pytest.raises(ValueError, match="factor"):
        gate_ratio(base, noisy, factor=0.0, higher_is_better=False)


def test_gate_ratio_throughput_like_decisions():
    base = [100.0, 102.0, 98.0]
    dec = gate_ratio(base, [97.0, 101.0, 99.0], factor=2.0,
                     higher_is_better=True)
    assert dec.ok
    dec = gate_ratio(base, [30.0, 31.0, 29.0], factor=2.0,
                     higher_is_better=True)
    assert not dec.ok and "entirely below" in dec.reason


def test_gate_ratio_degenerate_collapses_to_strict_mean_rule():
    # One run each side: the legacy strict comparison, no invented noise.
    assert gate_ratio([1.0], [1.9], factor=2.0,
                      higher_is_better=False).ok
    assert not gate_ratio([1.0], [2.1], factor=2.0,
                          higher_is_better=False).ok
    assert gate_ratio([100.0], [51.0], factor=2.0,
                      higher_is_better=True).ok
    assert not gate_ratio([100.0], [49.0], factor=2.0,
                          higher_is_better=True).ok


def test_json_dict_round_trip_matches_schema_keys():
    from repro.bench.schema import CI_KEYS
    d = bootstrap_ci([1.0, 1.1, 0.9]).json_dict()
    assert set(d) == set(CI_KEYS)
    assert CIStats(**d).json_dict() == d


# ---------------------------------------------------------------------------
# Hypothesis properties (auto-skip without the dev extra)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    finite_runs = st.lists(
        st.floats(min_value=1e-3, max_value=1e3,
                  allow_nan=False, allow_infinity=False),
        min_size=1, max_size=12)

    @given(runs=finite_runs, seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_prop_ci_contains_sample_mean(runs, seed):
        ci = bootstrap_ci(runs, seed=seed)
        mean = float(np.mean(runs))
        assert ci.ci_lo <= mean + 1e-12 and ci.ci_hi >= mean - 1e-12

    @given(runs=finite_runs, seed=st.integers(0, 2**31 - 1),
           lo=st.sampled_from([0.5, 0.8]), hi=st.sampled_from([0.95,
                                                               0.99]))
    @settings(max_examples=50, deadline=None)
    def test_prop_interval_monotone_in_confidence(runs, seed, lo, hi):
        narrow = bootstrap_ci(runs, confidence=lo, seed=seed)
        wide = bootstrap_ci(runs, confidence=hi, seed=seed)
        assert wide.ci_lo <= narrow.ci_lo
        assert wide.ci_hi >= narrow.ci_hi

    @given(runs=st.lists(st.floats(min_value=1e-3, max_value=1e3,
                                   allow_nan=False,
                                   allow_infinity=False),
                         min_size=2, max_size=10),
           seed=st.integers(0, 2**31 - 1),
           perm_seed=st.integers(0, 1000))
    @settings(max_examples=50, deadline=None)
    def test_prop_permutation_invariance(runs, seed, perm_seed):
        rng = np.random.default_rng(perm_seed)
        shuffled = list(rng.permutation(runs))
        a = bootstrap_ci(runs, seed=seed)
        b = bootstrap_ci(shuffled, seed=seed)
        assert (a.ci_lo, a.ci_hi) == (b.ci_lo, b.ci_hi)

    @given(runs=finite_runs, seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=50, deadline=None)
    def test_prop_seed_reproducibility(runs, seed):
        a = bootstrap_ci(runs, seed=seed)
        b = bootstrap_ci(runs, seed=seed)
        assert a.json_dict() == b.json_dict()


# ---------------------------------------------------------------------------
# Variance decomposition (within-run vs between-run noise)
# ---------------------------------------------------------------------------

from repro.bench.stats import (VarianceDecomposition,      # noqa: E402
                               variance_decomposition)


def test_variance_decomposition_between_dominated():
    """Runs that are internally tight but far apart: the noise is
    between-run — only more --repeats averages it out."""
    vd = variance_decomposition([[1.00, 1.01, 0.99],
                                 [2.00, 2.01, 1.99],
                                 [3.00, 3.01, 2.99]])
    assert vd.n_runs == 3 and vd.mean_iters == 3.0
    assert vd.between_var > 0.0
    assert vd.between_share > 0.95
    assert abs(vd.within_share + vd.between_share - 1.0) < 1e-12


def test_variance_decomposition_within_dominated():
    """Runs whose means agree but whose iterations are noisy: the
    observed run-mean variance is explained by within-run sampling —
    longer runs beat more runs."""
    rng = np.random.default_rng(0)
    runs = [list(1.0 + 0.5 * rng.standard_normal(50)) for _ in range(4)]
    vd = variance_decomposition(runs)
    assert vd.within_var > 0.0
    assert vd.within_share > 0.5
    assert 0.0 <= vd.between_share <= 0.5


def test_variance_decomposition_degenerate_inputs():
    # One run: no between-run variance is claimable.
    vd = variance_decomposition([[1.0, 2.0, 3.0]])
    assert vd.n_runs == 1
    assert vd.within_share == 0.0 and vd.between_share == 0.0
    # Single-iteration runs: within variance undefined -> 0.0, the
    # observed spread is all between.
    vd = variance_decomposition([[1.0], [2.0], [3.0]])
    assert vd.within_var == 0.0
    assert vd.between_share == 1.0
    # Identical constant runs: zero total variance, zero shares.
    vd = variance_decomposition([[1.0, 1.0], [1.0, 1.0]])
    assert vd.within_share == 0.0 and vd.between_share == 0.0
    with pytest.raises(ValueError, match="at least one run"):
        variance_decomposition([])
    with pytest.raises(ValueError, match="non-empty 1-D"):
        variance_decomposition([[1.0], []])


def test_variance_decomposition_json_round_trip_matches_schema():
    from repro.bench.schema import VARIANCE_KEYS
    d = variance_decomposition([[1.0, 1.1], [1.2, 1.3]]).json_dict()
    assert set(d) == set(VARIANCE_KEYS)
    assert VarianceDecomposition(**d).json_dict() == d


def test_variance_decomposition_between_never_negative():
    """Method-of-moments subtraction is clamped: when sampling noise
    exceeds the observed run-mean variance the between estimate is 0.0,
    never negative."""
    # Two runs with huge internal spread but nearly equal means.
    vd = variance_decomposition([[0.0, 2.0], [0.01, 2.01]])
    assert vd.between_var == 0.0
    assert vd.between_share == 0.0 and vd.within_share == 1.0
