"""The committed benchmark baseline (BENCH_cpu.json) is gate-worthy.

Tier-1 guard on the artifact every CI gate decision depends on: the
rows are schema-valid summary/multitenant records, they carry real
bootstrap intervals (``--repeats >= 3`` — a baseline without run-level
data silently degrades every gate verdict to the mean-only rule), the
provenance note records the exact regeneration commands, the sweep
covers the default, pallas-lowering and fused-precision cells, and the
baseline self-gates at factor 1.0 (a baseline that cannot pass against
itself would fail every commit)."""

import json
import os

import pytest

from benchmarks.gate import run_gate
from repro.bench.schema import validate_record

BASELINE = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_cpu.json")


@pytest.fixture(scope="module")
def baseline():
    with open(BASELINE) as f:
        return json.load(f)


def test_table1_rows_schema_valid_with_real_intervals(baseline):
    rows = baseline["results"]
    assert len(rows) >= 9          # 3 variants x 3 modalities minimum
    for row in rows:
        assert validate_record({"kind": "summary", **row}) == "summary"
        ci = row["ci"]
        assert ci["n_runs"] >= 3, (
            f"{row['name']}: baseline needs --repeats >= 3 for a real "
            f"interval, got n_runs={ci['n_runs']}")
        assert len(ci["run_means"]) == ci["n_runs"]


def test_multitenant_rows_schema_valid_with_real_intervals(baseline):
    rows = baseline["multitenant"]
    assert rows
    for row in rows:
        assert validate_record(row) == "multitenant"
        assert row["acq_per_s_ci"]["n_runs"] >= 3, row["name"]


def test_sweep_covers_lowering_and_fusion_cells(baseline):
    names = [r["name"] for r in baseline["results"]]
    assert len(names) == len(set(names))         # keys are unique
    assert any("/xla" in n for n in names)
    assert any("/pallas" in n and "fused" not in n for n in names), (
        "no pallas-lowering cell in the baseline")
    assert any("fused@bf16" in n for n in names), (
        "no fused bf16 cell in the baseline")
    depths = {r["in_flight"] for r in baseline["multitenant"]}
    assert {1, 2} <= depths                      # overlap win is gated


def test_provenance_records_regeneration_commands(baseline):
    prov = baseline["provenance"]
    assert prov and all(p.startswith("python -m benchmarks.")
                        for p in prov)
    assert any("--repeats 3" in p for p in prov)
    assert any("benchmarks.multitenant" in p for p in prov)


def test_baseline_self_gates_at_factor_one(baseline, tmp_path):
    """Identical data on both sides must pass at factor 1.0: real
    run_means resample to an interval containing 1.0, degenerate rows
    compare equal. If this fails the gate would fail every commit."""
    mt_path = tmp_path / "mt.ndjson"
    with open(mt_path, "w") as f:
        for rec in baseline["multitenant"]:
            f.write(json.dumps(rec) + "\n")
    failures = run_gate(BASELINE, current_path=BASELINE,
                        multitenant_path=str(mt_path), factor=1.0)
    assert failures == []
