"""Distributed SPMD correctness, in a subprocess with 8 forced host
devices: the sharded train step must match the single-device result, and
the compressed all-reduce must approximate the exact mean.

(Subprocess because XLA locks the host device count at first jax init —
the main pytest process must keep seeing 1 device.)
"""

import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import TrainConfig, get_smoke
from repro.data.batches import synth_train_batch
from repro.models import get_model
from repro.runtime import sharding as shlib
from repro.runtime import param_sharding as psh
from repro.train import steps as steps_lib

out = {}

cfg = get_smoke("qwen3_8b").with_(remat=False)
model = get_model(cfg)
tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
key = jax.random.PRNGKey(0)
batch = synth_train_batch(cfg, 4, 32, seed=0)

# --- single device reference ---
state0 = steps_lib.init_train_state(model, key)
step = jax.jit(steps_lib.make_train_step(model, tcfg))
_, m_ref = step(state0, batch)
out["loss_ref"] = float(m_ref["loss"])

# jax<0.5 has no jax.set_mesh; the Mesh context manager is equivalent here.
def set_mesh(mesh):
    return jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh

# --- sharded (data=2, model=4) ---
mesh = jax.make_mesh((2, 4), ("data", "model"))
binding = shlib.Binding(shlib.SINGLE_POD_RULES,
                        dict(zip(mesh.axis_names, mesh.devices.shape)))
with set_mesh(mesh), shlib.use_binding(binding):
    state_abs = jax.eval_shape(
        lambda k: steps_lib.init_train_state(model, k), key)
    logical = psh.logical_param_axes(state_abs["params"])
    p_specs = psh.specs_from_logical(logical, state_abs["params"])
    p_shard = psh.shardings_for(mesh, p_specs)
    state = steps_lib.init_train_state(model, key)
    state = {
        "params": jax.tree.map(jax.device_put, state["params"], p_shard),
        "opt": {
            "m": jax.tree.map(jax.device_put, state["opt"]["m"], p_shard),
            "v": jax.tree.map(jax.device_put, state["opt"]["v"], p_shard),
            "step": state["opt"]["step"],
        },
    }
    batch_sh = jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(
            mesh, P(*( ("data",) + (None,) * (a.ndim - 1))))), batch)
    step_sh = jax.jit(steps_lib.make_train_step(model, tcfg))
    new_state, m_sh = step_sh(state, batch_sh)
    out["loss_sharded"] = float(m_sh["loss"])
    out["gnorm_ref"] = float(m_ref["grad_norm"])
    out["gnorm_sharded"] = float(m_sh["grad_norm"])

# --- compressed all-reduce vs exact mean ---
from jax.experimental.shard_map import shard_map
from repro.optim.compress import compressed_psum_mean

mesh2 = jax.make_mesh((8,), ("data",))
g = np.random.default_rng(0).standard_normal((8, 64)).astype(np.float32)

def body(gs):
    mean, _ = compressed_psum_mean({"g": gs[0]}, "data")
    return mean["g"][None]

with set_mesh(mesh2):
    got = shard_map(body, mesh=mesh2, in_specs=P("data"),
                    out_specs=P("data"))(jnp.asarray(g))
exact = g.mean(axis=0)
err = np.abs(np.asarray(got) - exact[None]).max()
out["compress_err"] = float(err)
out["compress_scale"] = float(np.abs(exact).max())

print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_sharded_loss_matches_single_device(results):
    assert abs(results["loss_sharded"] - results["loss_ref"]) < 2e-3, results


def test_sharded_gradnorm_matches(results):
    assert abs(results["gnorm_sharded"] - results["gnorm_ref"]) < 2e-2, \
        results


def test_compressed_allreduce_close(results):
    # int8 quantization: error bounded by ~scale/127
    assert results["compress_err"] <= results["compress_scale"] / 64, results
