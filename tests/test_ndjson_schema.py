"""One schema, every emitter: repro.bench.schema applied end to end.

Every NDJSON-producing path — the bench harness's summary / sample /
stage records (benchmarks/run.py), the streaming records
(stream_throughput.py), the scaling rows (scaling.py), and the
multi-tenant scheduler rows (multitenant.py) — is generated here
in-process at tiny geometry and pushed through the SAME
`validate_record` that CI runs against the artifact files, so the
schema cannot fork between what tests check and what CI enforces
(this replaces the former CI-only inline assert for scaling rows).
"""

import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.bench import bench_callable, bench_stages, write_ndjson
from repro.bench.schema import (SchemaError, validate_lines,
                                validate_ndjson, validate_record)
from repro.core import UltrasoundPipeline, Variant, tiny_config
from repro.data import synth_rf


def _tiny_cfg():
    return tiny_config(variant=Variant.DYNAMIC)


@pytest.fixture(scope="module")
def bench_result():
    """A full BenchResult exactly like a table1 row: plan stamp, latency
    distribution, stage breakdown."""
    cfg = _tiny_cfg()
    pipe = UltrasoundPipeline(cfg)
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    res = bench_callable(
        f"{cfg.name}:{cfg.variant.value}", None, (pipe.consts, rf),
        input_bytes=cfg.input_bytes, warmup=1, runs=3, deadline_s=10.0,
        jitted=pipe.jitted, plan=pipe.plan)
    res.stage_breakdown = bench_stages(cfg, rf, runs=2)
    return res


def test_harness_records_validate(bench_result):
    kinds = [validate_record(json.loads(line))
             for line in bench_result.ndjson_lines()]
    assert kinds[0] == "summary"
    assert kinds.count("sample") == 3
    assert kinds.count("stage") == 3          # demod, beamform, head


def test_write_ndjson_file_validates(tmp_path, bench_result):
    path = tmp_path / "bench.ndjson"
    write_ndjson(str(path), [bench_result])
    counts = validate_ndjson(str(path))
    assert counts == {"summary": 1, "sample": 3, "stage": 3}


def test_stream_emitter_validates():
    from benchmarks import stream_throughput
    _, records = stream_throughput.run(fast=True, cfg=_tiny_cfg())
    assert records
    for rec in records:
        assert validate_record(rec) == "stream"
        assert rec["plan"]["variant"] == "dynamic"
        assert rec["resources"]["devices"] >= 1


def test_scaling_emitter_validates():
    from benchmarks import scaling
    _, records = scaling.run(device_counts=[1], batch_sizes=(1,),
                             fast=True, cfg=_tiny_cfg())
    assert records
    for rec in records:
        assert validate_record(rec) == "scaling"
        assert rec["devices"] == 1
    # The multi-device cells run in CI's forced-2-device smoke row and
    # are validated there with the same module (python -m
    # repro.bench.schema SCALING_ci.ndjson --require-multidevice).


@pytest.fixture(scope="module")
def mt_records():
    """One real multitenant sweep (in-flight 1 and 2) for schema tests."""
    from benchmarks import multitenant
    cfg = _tiny_cfg()
    _, records = multitenant.run(
        client_counts=(2,), policies=((2, 1.0),), in_flights=(1, 2),
        fast=True, cfg_bmode=cfg)
    return records


def test_multitenant_emitter_validates(mt_records):
    assert len(mt_records) == 2
    for rec, depth in zip(mt_records, (1, 2)):
        assert validate_record(rec) == "multitenant"
        assert rec["clients"] == 2
        assert rec["in_flight"] == depth
        assert rec["warmup_s"] >= 0.0
        assert 0.0 <= rec["overlap_frac"] <= rec["device_busy_frac"] <= 1.0
        assert set(rec["per_stream"]) == {"probe0", "probe1"}
        for g in rec["groups"].values():
            assert g["plan"]["variant"] == "dynamic"
            assert g["plan"]["in_flight"] == depth
            assert g["warm_source"] in ("aot", "pool")
    # The sweep shares one warm pool: only the first cell pays AOT.
    assert mt_records[0]["warmup_s"] > 0.0
    assert mt_records[1]["warmup_s"] == 0.0


def test_validator_rejects_multitenant_overlap_violations(mt_records):
    """The new overlap/warm-start columns are REQUIRED and bounded — a
    producer that drops or corrupts one fails loudly."""
    import copy

    base = mt_records[1]
    validate_record(base)

    rec = copy.deepcopy(base)
    del rec["in_flight"]
    with pytest.raises(SchemaError, match="missing required key"):
        validate_record(rec)

    for key in ("warmup_s", "device_busy_s", "device_busy_frac",
                "overlap_frac", "in_flight_occupancy"):
        rec = copy.deepcopy(base)
        del rec[key]
        with pytest.raises(SchemaError, match="missing required key"):
            validate_record(rec)

    rec = copy.deepcopy(base)
    rec["device_busy_frac"] = 1.5
    with pytest.raises(SchemaError, match=r"fraction in \[0, 1\]"):
        validate_record(rec)

    rec = copy.deepcopy(base)
    del rec["in_flight_occupancy"]["mean_depth"]
    with pytest.raises(SchemaError, match="mean_depth"):
        validate_record(rec)

    gid = next(iter(base["groups"]))
    for key in ("warmup_s", "warm_source", "in_flight"):
        rec = copy.deepcopy(base)
        del rec["groups"][gid][key]
        with pytest.raises(SchemaError, match="missing required key"):
            validate_record(rec)

    # The serving-context plan stamp is part of PLAN_KEYS everywhere.
    rec = copy.deepcopy(base)
    del rec["groups"][gid]["plan"]["warm_start"]
    with pytest.raises(SchemaError, match="warm_start"):
        validate_record(rec)


def test_validator_rejects_bad_records():
    good = {"kind": "sample", "name": "x", "run": 0, "t_s": 0.1}
    validate_record(good)
    with pytest.raises(SchemaError, match="unknown kind"):
        validate_record({"kind": "nope"})
    with pytest.raises(SchemaError, match="missing required key"):
        validate_record({"kind": "sample", "name": "x", "run": 0})
    with pytest.raises(SchemaError, match="expected real"):
        validate_record({**good, "t_s": "fast"})
    with pytest.raises(SchemaError, match="expected int"):
        validate_record({**good, "run": 1.5})
    with pytest.raises(SchemaError, match="null not allowed"):
        validate_record({**good, "t_s": None})
    # bool must not satisfy int/real (True is an int in Python)
    with pytest.raises(SchemaError, match="expected int"):
        validate_record({**good, "run": True})


def _ci_block(means=(0.1,)):
    return {"mean": sum(means) / len(means), "ci_lo": min(means),
            "ci_hi": max(means), "n_runs": len(means),
            "confidence": 0.95, "n_boot": 2000, "seed": 0,
            "method": "kalibera-jones-bootstrap",
            "run_means": list(means)}


def test_validator_rejects_non_monotone_percentiles():
    lat = {"n": 2, "mean_s": 0.1, "std_s": 0.0, "p50_s": 0.2,
           "p95_s": 0.1, "p99_s": 0.3, "jitter_s": 0.0,
           "budget_s": None, "miss_rate": 0.0}
    rec = {"kind": "summary", "name": "x", "t_avg_s": 0.1, "fps": 10.0,
           "mbps": 1.0, "joules_per_run_model": 0.0, "peak_mem_gb": 0.0,
           "runs": 2, "latency": lat, "ci": _ci_block()}
    with pytest.raises(SchemaError, match="percentiles not monotone"):
        validate_record(rec)


def test_summary_requires_ci_block(bench_result):
    """The statistical gate needs an interval on every summary row —
    a producer that drops the ci block (or corrupts it) fails CI
    loudly, it does not degrade the gate silently."""
    summary = json.loads(bench_result.ndjson_lines()[0])
    validate_record(summary)
    assert summary["ci"]["n_runs"] >= 1

    rec = {k: v for k, v in summary.items() if k != "ci"}
    with pytest.raises(SchemaError, match="missing required key 'ci'"):
        validate_record(rec)
    with pytest.raises(SchemaError, match="null not allowed"):
        validate_record({**summary, "ci": None})
    # A ci block missing its level-one data cannot be re-bootstrapped.
    truncated = {k: v for k, v in summary["ci"].items()
                 if k != "run_means"}
    with pytest.raises(SchemaError, match="run_means"):
        validate_record({**summary, "ci": truncated})


def test_ci_block_internal_consistency_enforced():
    good = {"kind": "summary", "name": "x", "t_avg_s": 0.1, "fps": 10.0,
            "mbps": 1.0, "joules_per_run_model": 0.0, "peak_mem_gb": 0.0,
            "runs": 2, "ci": _ci_block((0.1, 0.12, 0.08)),
            "latency": {"n": 2, "mean_s": 0.1, "std_s": 0.0,
                        "p50_s": 0.1, "p95_s": 0.1, "p99_s": 0.1,
                        "jitter_s": 0.0, "budget_s": None,
                        "miss_rate": 0.0}}
    validate_record(good)
    # Interval must contain its point estimate.
    bad = {**good, "ci": {**good["ci"], "ci_lo": 0.11}}
    with pytest.raises(SchemaError, match="point estimate"):
        validate_record(bad)
    # run_means length must equal n_runs (re-bootstrappability).
    bad = {**good, "ci": {**good["ci"], "run_means": [0.1]}}
    with pytest.raises(SchemaError, match="n_runs=3"):
        validate_record(bad)
    bad = {**good, "ci": {**good["ci"], "n_runs": 0, "run_means": []}}
    with pytest.raises(SchemaError, match="n_runs"):
        validate_record(bad)


def test_multitenant_requires_load_profile_stamp(mt_records):
    """The load-profile provenance columns are REQUIRED: a multitenant
    row without its profile name, trace hash, or drop count could be
    mistaken for a different load scenario when gated."""
    import copy

    base = mt_records[0]
    assert base["load_profile"] == "steady"
    assert len(base["trace_sha256"]) == 64
    assert base["dropped"] == 0

    for key in ("load_profile", "trace_sha256", "dropped"):
        rec = copy.deepcopy(base)
        del rec[key]
        with pytest.raises(SchemaError, match="missing required key"):
            validate_record(rec)

    rec = copy.deepcopy(base)
    rec["trace_sha256"] = "not-a-hash"
    with pytest.raises(SchemaError, match="64 lowercase hex"):
        validate_record(rec)
    rec["trace_sha256"] = base["trace_sha256"][:-1] + "G"
    with pytest.raises(SchemaError, match="64 lowercase hex"):
        validate_record(rec)

    # A served stream may not carry a null latency block.
    rec = copy.deepcopy(base)
    sid = next(iter(rec["per_stream"]))
    rec["per_stream"][sid]["latency"] = None
    with pytest.raises(SchemaError, match="null but the stream served"):
        validate_record(rec)


def test_multitenant_requires_acq_per_s_ci(mt_records):
    import copy

    rec = copy.deepcopy(mt_records[0])
    validate_record(rec)
    assert rec["acq_per_s_ci"]["n_runs"] >= 1    # producer-stamped
    del rec["acq_per_s_ci"]
    with pytest.raises(SchemaError,
                       match="missing required key 'acq_per_s_ci'"):
        validate_record(rec)


def test_validator_rejects_bad_plan_stamp():
    rec = {"kind": "sample", "name": "x", "run": 0, "t_s": 0.1,
           "plan": {"policy": "fixed"}}          # truncated stamp
    with pytest.raises(SchemaError, match=r"plan: missing required key"):
        validate_record(rec)


def test_validator_rejects_bad_stage_lowerings_stamp():
    """repro-bench-v1 stays valid (the stamp is additive), but a plan
    stamp without — or with a malformed — stage_lowerings field fails."""
    plan = UltrasoundPipeline(_tiny_cfg()).plan.json_dict()
    rec = {"kind": "sample", "name": "x", "run": 0, "t_s": 0.1,
           "plan": plan}
    validate_record(rec)                         # the real stamp passes
    assert plan["stage_lowerings"] == {"demod": "xla", "beamform": "xla",
                                       "bmode": "xla"}
    truncated = {**plan}
    del truncated["stage_lowerings"]
    with pytest.raises(SchemaError,
                       match=r"missing required key 'stage_lowerings'"):
        validate_record({**rec, "plan": truncated})
    with pytest.raises(SchemaError, match=r"stage_lowerings: expected dict"):
        validate_record({**rec, "plan": {**plan,
                                         "stage_lowerings": "pallas"}})
    with pytest.raises(SchemaError, match=r"expected a\s+lowering name"):
        validate_record({**rec, "plan": {**plan,
                                         "stage_lowerings": {"demod": 3}}})


def test_validator_requires_fusion_precision_stamp():
    """repro-bench-v1 requires the fusion/precision contract columns in
    every plan stamp — a fused/bf16 row must never be mistakable for an
    unfused/f32 one because a producer dropped the field."""
    plan = UltrasoundPipeline(_tiny_cfg()).plan.json_dict()
    rec = {"kind": "sample", "name": "x", "run": 0, "t_s": 0.1,
           "plan": plan}
    validate_record(rec)                         # the real stamp passes
    assert plan["fusion"] == "none" and plan["precision"] == "f32"
    assert plan["fusion_group"] is None and plan["fusion_block"] is None
    for key in ("fusion", "precision"):
        truncated = {k: v for k, v in plan.items() if k != key}
        with pytest.raises(SchemaError,
                           match=f"missing required key '{key}'"):
            validate_record({**rec, "plan": truncated})
        with pytest.raises(SchemaError, match=f"{key}: null not allowed"):
            validate_record({**rec, "plan": {**plan, key: None}})
    with pytest.raises(SchemaError, match="fusion_block: expected int"):
        validate_record({**rec, "plan": {**plan, "fusion_block": "128"}})
    # A fused stamp (group + block set) is valid as-is.
    fused = {**plan, "fusion": "fused", "precision": "bf16",
             "fusion_group": "demod+beamform+bmode", "fusion_block": 128}
    validate_record({**rec, "plan": fused})


def test_fused_plan_stamp_validates():
    """A real fused plan's json_dict passes the schema with the group
    stamped — wired end to end, not just the hand-built dict above."""
    from repro.core.plan import plan_pipeline
    cfg = tiny_config(variant=Variant.DYNAMIC, fusion="fused")
    plan = plan_pipeline(cfg).json_dict()
    validate_record({"kind": "sample", "name": "x", "run": 0, "t_s": 0.1,
                     "plan": plan})
    assert plan["fusion"] == "fused"
    assert plan["fusion_group"] == "demod+beamform+bmode"
    assert set(plan["stage_lowerings"].values()) == {"pallas"}


def test_validate_lines_counts_and_empty():
    lines = [json.dumps({"kind": "sample", "name": "x", "run": i,
                         "t_s": 0.1}) for i in range(3)]
    assert validate_lines(lines) == {"sample": 3}
    with pytest.raises(SchemaError, match="no NDJSON records"):
        validate_lines([])
    with pytest.raises(SchemaError, match="invalid JSON"):
        validate_lines(["{not json"])


def test_numpy_scalars_do_not_sneak_past_json():
    """Emitters serialize through json.dumps — numpy scalars would raise
    there, so the validator only ever sees plain JSON types. Assert the
    round trip stays clean for a real multitenant record."""
    from repro.launch.scheduler import (BatchPolicy, StreamSpec,
                                        serve_multitenant)
    cfg = _tiny_cfg()
    stats = serve_multitenant(
        [StreamSpec("s0", cfg, fps=1e9, n_frames=2)],
        policy=BatchPolicy(max_batch=2, max_queue_delay_ms=1.0))
    line = json.dumps({"kind": "multitenant", **stats})
    assert validate_lines([line]) == {"multitenant": 1}
    assert not isinstance(json.loads(line)["fps"], np.ndarray)


def test_multitenant_requires_transfer_and_drain_stamp(mt_records):
    """The host-transfer telemetry is REQUIRED on multitenant rows: a
    record without its drain mode, transfer seconds, or overlap CI
    blocks could not be gated against the transfer baseline."""
    import copy

    base = mt_records[0]
    assert base["drain"] in ("async", "block")
    for key in ("stage_copy_s", "h2d_s", "d2h_s"):
        assert base[key] >= 0.0
    assert 0.0 <= base["transfer_frac"] <= 1.0
    assert base["device_busy_frac_ci"]["n_runs"] >= 1
    assert base["overlap_frac_ci"]["n_runs"] >= 1

    for key in ("drain", "stage_copy_s", "h2d_s", "d2h_s",
                "transfer_frac", "device_busy_frac_ci",
                "overlap_frac_ci"):
        rec = copy.deepcopy(base)
        del rec[key]
        with pytest.raises(SchemaError, match="missing required key"):
            validate_record(rec)

    rec = copy.deepcopy(base)
    rec["drain"] = "sideways"
    with pytest.raises(SchemaError, match="async.*block|drain"):
        validate_record(rec)

    rec = copy.deepcopy(base)
    rec["transfer_frac"] = 1.5
    with pytest.raises(SchemaError, match=r"fraction in \[0, 1\]"):
        validate_record(rec)


def test_optional_transfer_and_variance_blocks_validate():
    """Any record kind may carry an optional 'transfer' or 'variance'
    block; when present the blocks are checked, not waved through."""
    good = {"kind": "sample", "name": "x", "run": 0, "t_s": 0.1,
            "transfer": {"stage_copy_s": 0.01, "h2d_s": 0.02,
                         "d2h_s": 0.005, "transfer_frac": 0.2},
            "variance": {"n_runs": 3, "mean_iters": 10.0,
                         "within_var": 1e-6, "between_var": 2e-6,
                         "within_share": 0.3, "between_share": 0.7}}
    assert validate_record(good) == "sample"

    import copy
    rec = copy.deepcopy(good)
    del rec["transfer"]["h2d_s"]
    with pytest.raises(SchemaError, match="missing required key"):
        validate_record(rec)

    rec = copy.deepcopy(good)
    rec["transfer"]["transfer_frac"] = -0.1
    with pytest.raises(SchemaError, match=r"fraction in \[0, 1\]"):
        validate_record(rec)

    rec = copy.deepcopy(good)
    rec["variance"]["between_share"] = 1.2
    with pytest.raises(SchemaError, match=r"fraction in \[0, 1\]"):
        validate_record(rec)

    rec = copy.deepcopy(good)
    rec["variance"]["n_runs"] = "three"
    with pytest.raises(SchemaError, match="int"):
        validate_record(rec)
