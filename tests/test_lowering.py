"""Per-stage operator-lowering registry (repro.core.lowering + plan).

Contracts under test:
  * the registry exposes the variant x lowering matrix the kernels tree
    implements (pallas beamform for dynamic/sparse, xla everywhere);
  * every registered lowering of every stage matches the pure-XLA
    monolithic oracle allclose (<= 1e-5) on CPU interpret mode — in
    particular `beamform_sparse` via the `bsr_spmm` Pallas kernel;
  * explicit ``stage_lowerings`` entries are honored under every policy
    and refused loudly when unregistered for the resolved variant;
  * autotune measures per-stage candidates through the bench breakdown,
    picks the argmin, memoizes, and stamps `lowering_t_s`;
  * `use_das_kernel` is a warning-emitting alias producing an
    equivalent config hash and plan;
  * resolved lowerings flow through the canonical config hash, so the
    multi-tenant scheduler never groups different lowerings together.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (Modality, UltrasoundPipeline, Variant, config_hash,
                        monolithic_pipeline_fn, plan_pipeline,
                        registered_lowerings, tiny_config)
from repro.core import lowering as lowering_lib
from repro.core import plan as plan_lib
from repro.core.stages import build_graph
from repro.data import synth_rf


@pytest.fixture(autouse=True)
def _fresh_planner_state():
    plan_lib.clear_autotune_memo()
    yield
    plan_lib.clear_autotune_memo()


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_exposes_the_variant_x_lowering_matrix():
    cfg = tiny_config()
    assert set(registered_lowerings(
        cfg.with_(variant=Variant.DYNAMIC), "beamform")) == {"xla",
                                                             "pallas"}
    assert set(registered_lowerings(
        cfg.with_(variant=Variant.SPARSE), "beamform")) == {"xla",
                                                            "pallas"}
    # the dense matmul IS the MXU formulation — no kernel to prefer
    assert set(registered_lowerings(
        cfg.with_(variant=Variant.CNN), "beamform")) == {"xla"}
    for stage in ("demod", "bmode"):
        assert set(registered_lowerings(cfg, stage)) == {"xla"}


def test_every_stage_op_registers_an_xla_reference():
    for variant in (Variant.DYNAMIC, Variant.CNN, Variant.SPARSE):
        for modality in Modality:
            cfg = tiny_config(variant=variant, modality=modality)
            for stage in build_graph(cfg):
                lows = registered_lowerings(cfg, stage.name)
                assert "xla" in lows, (variant, stage.name)


def test_unregistered_explicit_lowering_is_refused():
    cfg = tiny_config(variant=Variant.CNN,
                      stage_lowerings={"beamform": "pallas"})
    with pytest.raises(ValueError, match="no such"):
        plan_pipeline(cfg, policy="fixed")
    with pytest.raises(ValueError, match="unknown stage"):
        tiny_config(stage_lowerings={"warp": "xla"})
    with pytest.raises(ValueError, match="unknown lowering"):
        tiny_config(stage_lowerings={"beamform": "mosaic"})
    # a known stage the modality's graph never runs is a refused typo,
    # not a silently dropped pin
    with pytest.raises(ValueError, match="not in\\s+this pipeline's graph"):
        plan_pipeline(tiny_config(modality=Modality.BMODE,
                                  stage_lowerings={"doppler": "xla"}),
                      policy="fixed")


# ---------------------------------------------------------------------------
# plan resolution
# ---------------------------------------------------------------------------


def test_plan_resolves_a_lowering_for_every_stage():
    for policy in ("fixed", "heuristic"):
        cfg = tiny_config(variant=Variant.DYNAMIC if policy == "fixed"
                          else Variant.AUTO)
        plan = plan_pipeline(cfg, policy=policy)
        stages = [s.name for s in build_graph(plan.concretize(cfg))]
        assert [s for s, _ in plan.stage_lowerings] == stages
        # CPU preference table: xla everywhere (interpret pallas is slow)
        assert all(n == "xla" for _, n in plan.stage_lowerings)
        assert plan.json_dict()["stage_lowerings"] == {
            s: "xla" for s in stages}


def test_explicit_lowering_is_honored_and_stamped():
    cfg = tiny_config(variant=Variant.DYNAMIC,
                      stage_lowerings={"beamform": "pallas"})
    plan = plan_pipeline(cfg, policy="fixed")
    assert dict(plan.stage_lowerings)["beamform"] == "pallas"
    pipe = UltrasoundPipeline(cfg, plan=plan)
    assert pipe.cfg.stage_lowering("beamform") == "pallas"
    # the concretized config matches the plan's geometry (round trip)
    assert plan.matches(pipe.cfg)


def test_pipeline_rejects_plan_conflicting_with_explicit_lowering():
    base = tiny_config(variant=Variant.DYNAMIC)
    plan = plan_pipeline(base, policy="fixed")      # resolves beamform=xla
    with pytest.raises(ValueError, match="explicit lowering"):
        UltrasoundPipeline(
            base.with_(stage_lowerings={"beamform": "pallas"}), plan=plan)


def test_lowering_preference_table_is_extensible():
    backend = jax.default_backend()
    prev = plan_lib.BACKEND_LOWERING_PREFERENCE.get(backend)
    try:
        plan_lib.register_lowering_preference(
            backend, "beamform", Variant.DYNAMIC, "pallas")
        plan = plan_pipeline(tiny_config(variant=Variant.DYNAMIC),
                             policy="fixed")
        assert dict(plan.stage_lowerings)["beamform"] == "pallas"
    finally:
        if prev is None:
            plan_lib.BACKEND_LOWERING_PREFERENCE.pop(backend, None)
        else:
            plan_lib.BACKEND_LOWERING_PREFERENCE[backend] = prev


def test_autotune_picks_argmin_lowering_and_memoizes():
    calls = []

    def fake_stage_measure(cfg, stage, *, runs, warmup):
        name = cfg.stage_lowering(stage)
        calls.append((stage, name))
        return {"xla": 2.0, "pallas": 1.0}[name]

    cfg = tiny_config(variant=Variant.DYNAMIC)
    plan = plan_pipeline(cfg, policy="autotune",
                         measure_stage=fake_stage_measure)
    assert dict(plan.stage_lowerings)["beamform"] == "pallas"
    # only the contested stage is measured, once per candidate
    assert sorted(calls) == [("beamform", "pallas"), ("beamform", "xla")]
    assert dict(plan.lowering_t_s) == {"beamform:pallas": 1.0,
                                       "beamform:xla": 2.0}
    assert plan.json_dict()["lowering_t_s"] == dict(plan.lowering_t_s)

    # memoized: same resolved config, same backend -> no re-timing
    plan2 = plan_pipeline(cfg, policy="autotune",
                          measure_stage=fake_stage_measure)
    assert plan2.stage_lowerings == plan.stage_lowerings
    assert len(calls) == 2
    # a geometry change invalidates the memo
    plan_pipeline(cfg.with_(nx=8), policy="autotune",
                  measure_stage=fake_stage_measure)
    assert len(calls) == 4


def test_auto_variant_search_is_restricted_to_pin_honoring_candidates():
    """An AUTO config pinned to a pallas beamform must never resolve to
    CNN (which registers none) — even when CNN would measure fastest."""
    cfg = tiny_config(variant=Variant.AUTO,
                      stage_lowerings={"beamform": "pallas"})
    measure = (lambda c, v, *, runs, warmup:
               {Variant.DYNAMIC: 3.0, Variant.CNN: 0.1,
                Variant.SPARSE: 2.0}[v])
    plan = plan_pipeline(cfg, policy="autotune", measure=measure,
                         measure_stage=lambda c, s, **kw: 1.0)
    assert set(dict(plan.autotune_t_s)) == {"dynamic", "sparse"}  # no cnn
    assert plan.variant == Variant.SPARSE
    assert dict(plan.stage_lowerings)["beamform"] == "pallas"

    # heuristic: cpu prefers dynamic, which honors the pin
    p2 = plan_pipeline(cfg, policy="heuristic")
    assert p2.variant.concrete
    assert dict(p2.stage_lowerings)["beamform"] == "pallas"

    # over-constrained: no variant can honor an impossible pin set
    plan_lib.clear_autotune_memo()
    only_cnn = lowering_lib._REGISTRY.pop(("beamform", "sparse"))
    only_dyn = lowering_lib._REGISTRY.pop(("beamform", "dynamic"))
    try:
        with pytest.raises(ValueError, match="no concrete variant"):
            plan_pipeline(cfg, policy="heuristic")
    finally:
        lowering_lib._REGISTRY[("beamform", "sparse")] = only_cnn
        lowering_lib._REGISTRY[("beamform", "dynamic")] = only_dyn


def test_lowering_memo_misses_after_registry_extension():
    """register_lowering can grow the contested-stage set at any time;
    a memo entry from before the extension must miss, not crash."""
    cfg = tiny_config(variant=Variant.DYNAMIC)
    stage_measure = lambda c, s, **kw: {"xla": 1.0, "pallas": 2.0}[
        c.stage_lowering(s)]
    plan = plan_pipeline(cfg, policy="autotune",
                         measure_stage=stage_measure)
    assert set(dict(plan.lowering_t_s)) == {"beamform:xla",
                                            "beamform:pallas"}
    added = lowering_lib.register_lowering(
        "demod", "pallas",
        lowering_lib.registered_lowerings(cfg, "demod")["xla"].apply)
    try:
        plan2 = plan_pipeline(cfg, policy="autotune",
                              measure_stage=stage_measure)
        assert set(dict(plan2.lowering_t_s)) == {
            "beamform:xla", "beamform:pallas",
            "demod:xla", "demod:pallas"}
    finally:
        del lowering_lib._REGISTRY[("demod", None)]["pallas"]


def test_autotune_real_lowering_timings_pick_the_measured_winner():
    """Acceptance: real per-stage probes resolve, memoize, and the pick
    is the argmin of the stamped timings."""
    cfg = tiny_config(variant=Variant.SPARSE)
    plan = plan_pipeline(cfg, policy="autotune",
                         autotune_runs=2, autotune_warmup=1)
    timings = dict(plan.lowering_t_s)
    assert set(timings) == {"beamform:xla", "beamform:pallas"}
    assert all(t > 0 for t in timings.values())
    want = min(timings, key=timings.get).split(":", 1)[1]
    assert dict(plan.stage_lowerings)["beamform"] == want


# ---------------------------------------------------------------------------
# numerics: every lowering against the monolithic XLA oracle
# ---------------------------------------------------------------------------


LOWERING_CELLS = [
    (variant, modality, stage.name, name)
    for variant in (Variant.DYNAMIC, Variant.CNN, Variant.SPARSE)
    for modality in (Modality.BMODE, Modality.DOPPLER)
    for stage in build_graph(tiny_config(variant=variant,
                                         modality=modality))
    for name in registered_lowerings(
        tiny_config(variant=variant, modality=modality), stage.name)
]


@pytest.mark.parametrize(
    "variant,modality,stage,name", LOWERING_CELLS,
    ids=[f"{v.value}-{m.value}-{s}-{n}" for v, m, s, n in LOWERING_CELLS])
def test_every_lowering_matches_monolithic_oracle(variant, modality,
                                                  stage, name):
    """Acceptance: for every (variant, lowering) registered on
    CPU-interpret, the pipeline output is allclose (<= 1e-5) to
    `monolithic_pipeline_fn` — the sparse/pallas cell exercises the
    bsr_spmm kernel as the hot path, not dead code."""
    cfg = tiny_config(n_f=8, variant=variant, modality=modality,
                      stage_lowerings={stage: name})
    pipe = UltrasoundPipeline(cfg)
    rf = jnp.asarray(synth_rf(cfg, seed=5))
    got = np.asarray(pipe(rf))
    mono = jax.jit(monolithic_pipeline_fn(pipe.cfg))
    want = np.asarray(mono(pipe.consts, rf))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sparse_pallas_lowering_runs_the_bsr_kernel(monkeypatch):
    """The wiring claim itself: the sparse pipeline's pallas lowering
    calls into repro.kernels.bsr_spmm (not a re-implementation)."""
    from repro.kernels import bsr_spmm as bsr_pkg
    calls = []
    real = bsr_pkg.bsr_beamform

    def spy(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(bsr_pkg, "bsr_beamform", spy)
    cfg = tiny_config(variant=Variant.SPARSE,
                      stage_lowerings={"beamform": "pallas"})
    UltrasoundPipeline(cfg)(jnp.asarray(synth_rf(cfg, seed=0)))
    assert calls, "pallas lowering did not reach kernels/bsr_spmm"


# ---------------------------------------------------------------------------
# use_das_kernel deprecation alias
# ---------------------------------------------------------------------------


def test_use_das_kernel_warns_and_maps_to_pallas_lowering():
    with pytest.warns(DeprecationWarning, match="use_das_kernel"):
        cfg = tiny_config(variant=Variant.DYNAMIC, use_das_kernel=True)
    assert cfg.use_das_kernel is False          # normalized away
    assert cfg.stage_lowerings == (("beamform", "pallas"),)

    explicit = tiny_config(variant=Variant.DYNAMIC,
                           stage_lowerings={"beamform": "pallas"})
    assert config_hash(cfg) == config_hash(explicit)
    assert (plan_pipeline(cfg, policy="fixed")
            == plan_pipeline(explicit, policy="fixed"))


def test_use_das_kernel_stays_a_noop_off_the_dynamic_variant():
    """The legacy flag was read only by the dynamic beamformer —
    CNN/SPARSE configs carrying it must keep planning (and hashing)
    exactly as without it, just loudly now."""
    for variant in (Variant.CNN, Variant.SPARSE):
        with pytest.warns(DeprecationWarning, match="ignored"):
            cfg = tiny_config(variant=variant, use_das_kernel=True)
        assert cfg.stage_lowerings == ()
        assert config_hash(cfg) == config_hash(tiny_config(variant=variant))
        plan = plan_pipeline(cfg, policy="fixed")    # must not raise
        assert dict(plan.stage_lowerings)["beamform"] == "xla"


def test_explicit_lowering_failing_capability_predicate_is_refused():
    """An explicit ask whose predicate rejects this backend/geometry
    fails at plan time, not deep inside kernel compilation."""
    never = lowering_lib.register_lowering(
        "beamform", "pallas",
        lowering_lib._beamform_dynamic_pallas, variant=Variant.DYNAMIC,
        available=lambda cfg, backend: False)
    try:
        cfg = tiny_config(variant=Variant.DYNAMIC,
                          stage_lowerings={"beamform": "pallas"})
        with pytest.raises(ValueError, match="capability predicate"):
            plan_pipeline(cfg, policy="fixed")
    finally:
        lowering_lib.register_lowering(      # restore the real lowering
            "beamform", "pallas", never.apply, variant=Variant.DYNAMIC,
            available=lowering_lib._das_pallas_available)


# ---------------------------------------------------------------------------
# scheduler grouping: lowerings are part of the compiled-program identity
# ---------------------------------------------------------------------------


def test_scheduler_groups_split_on_lowering():
    from repro.launch.scheduler import (BatchPolicy, StreamSpec,
                                        serve_multitenant)
    base = tiny_config(variant=Variant.DYNAMIC, n_f=2)
    stats = serve_multitenant(
        [StreamSpec("xla0", base, fps=1e9, n_frames=2),
         StreamSpec("pal0", base.with_(
             stage_lowerings={"beamform": "pallas"}), fps=1e9, n_frames=2),
         StreamSpec("xla1", base, fps=1e9, n_frames=2)],
        policy=BatchPolicy(max_batch=2, max_queue_delay_ms=1.0))
    groups = stats["groups"]
    assert len(groups) == 2          # one compiled program per lowering
    members = {frozenset(g["streams"]) for g in groups.values()}
    assert members == {frozenset({"xla0", "xla1"}), frozenset({"pal0"})}
    lowerings = {g["plan"]["stage_lowerings"]["beamform"]
                 for g in groups.values()}
    assert lowerings == {"xla", "pallas"}
