"""Data pipeline: step-addressable determinism (the fault-tolerance
contract) and learnable structure."""

import numpy as np

from repro.configs import get_smoke
from repro.data.tokens import TokenDataset


def test_batches_deterministic_by_step():
    cfg = get_smoke("qwen3_8b")
    d1 = TokenDataset(cfg, 4, 64, seed=9)
    d2 = TokenDataset(cfg, 4, 64, seed=9)
    for step in [0, 1, 17, 1000]:
        a, b = d1.batch_for_step(step), d2.batch_for_step(step)
        assert np.array_equal(a["tokens"], b["tokens"])
        assert np.array_equal(a["labels"], b["labels"])


def test_different_steps_differ():
    cfg = get_smoke("qwen3_8b")
    d = TokenDataset(cfg, 4, 64)
    assert not np.array_equal(d.batch_for_step(1)["tokens"],
                              d.batch_for_step(2)["tokens"])


def test_labels_are_next_tokens():
    cfg = get_smoke("qwen3_8b")
    b = TokenDataset(cfg, 2, 32).batch_for_step(5)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_increment_rule_dominates():
    """~95% of transitions follow the small-stride rule: learnable task."""
    cfg = get_smoke("qwen3_8b")
    d = TokenDataset(cfg, 8, 256)
    b = d.batch_for_step(0)
    inc = (b["labels"].astype(np.int64) -
           b["tokens"].astype(np.int64)) % cfg.vocab_size
    frac_rule = (inc <= 3).mean()
    assert frac_rule > 0.9, frac_rule


def test_iter_from_resumes():
    cfg = get_smoke("qwen3_8b")
    d = TokenDataset(cfg, 2, 16)
    it = d.iter_from(10)
    assert np.array_equal(next(it)["tokens"],
                          d.batch_for_step(10)["tokens"])
    assert np.array_equal(next(it)["tokens"],
                          d.batch_for_step(11)["tokens"])


def test_vlm_and_audio_extras():
    for arch, key_name in [("qwen2_vl_2b", "embeds"),
                           ("seamless_m4t_large_v2", "enc_embeds")]:
        cfg = get_smoke(arch)
        b = TokenDataset(cfg, 2, 16).batch_for_step(0)
        assert key_name in b
        assert b[key_name].shape == (2, 16, cfg.d_model)
