"""Loop-aware HLO cost parser: exact accounting on a known scanned module,
and regression vs XLA's body-counted-once behavior."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax import lax

from repro.launch import hlo_cost


@pytest.fixture(scope="module")
def scanned_module():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    return jax.jit(f).lower(x, w).compile()


def test_scan_flops_multiplied_by_trip_count(scanned_module):
    cost = hlo_cost.analyze(scanned_module.as_text())
    expected = 10 * 2 * 128 ** 3
    assert abs(cost.flops - expected) / expected < 0.05, cost.flops
    assert cost.unknown_loops == 0


def test_xla_cost_analysis_counts_body_once(scanned_module):
    """The reason hlo_cost exists (documented limitation of XLA)."""
    ca = scanned_module.cost_analysis()
    if isinstance(ca, list):        # jax<0.5 returns [dict], 0.5+ a dict
        ca = ca[0] if ca else {}
    xla_flops = ca.get("flops", 0.0)
    assert xla_flops < 2 * 2 * 128 ** 3   # ~one body, not ten


def test_dot_flops_from_contracting_dims():
    a = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 16), jnp.float32)
    compiled = jax.jit(lambda x, y: x @ y).lower(a, b).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    assert abs(cost.flops - 2 * 32 * 64 * 16) / (2 * 32 * 64 * 16) < 0.05


def test_gather_elems_counted():
    x = jax.ShapeDtypeStruct((1024, 8), jnp.float32)
    idx = jax.ShapeDtypeStruct((256,), jnp.int32)
    compiled = jax.jit(
        lambda x, i: jnp.take(x, i, axis=0)).lower(x, idx).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    assert cost.gather_elems >= 256 * 8, cost.gather_elems


def test_dus_counts_window_not_buffer():
    buf = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    upd = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def f(b, u):
        return lax.dynamic_update_slice(b, u, (jnp.int32(3), jnp.int32(0)))

    compiled = jax.jit(f).lower(buf, upd).compile()
    cost = hlo_cost.analyze(compiled.as_text())
    # The module holds one real full-buffer copy (param -> in-place dest,
    # 2 x 4 MB) plus the DUS *window* (2 x 4 KB). If the DUS result were
    # (wrongly) charged as the whole buffer the total would exceed 16 MB.
    assert cost.bytes_min < 10e6, cost.bytes_min
    assert cost.bytes_min > 8e6, cost.bytes_min
