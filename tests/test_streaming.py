"""Streaming serve loop + distribution telemetry (harness upgrade)."""

import json

import numpy as np

import jax.numpy as jnp

from repro.bench import bench_callable, bench_stages, latency_stats
from repro.core import tiny_config
from repro.data import synth_rf
from repro.launch.serve import (SyntheticAcquisitionSource,
                                serve_ultrasound_stream)


def test_latency_stats_percentiles_and_misses():
    samples = [i / 1000.0 for i in range(1, 101)]      # 1..100 ms
    st = latency_stats(samples, budget_s=0.050)
    assert st.n == 100
    np.testing.assert_allclose(st.p50_s, 0.0505, atol=1e-6)
    assert st.p50_s <= st.p95_s <= st.p99_s
    np.testing.assert_allclose(st.jitter_s, st.p95_s - st.p50_s, atol=1e-12)
    assert st.miss_rate == 0.5                          # 51..100 ms late
    assert latency_stats(samples).miss_rate == 0.0      # no budget set


def test_bench_callable_records_distribution():
    res = bench_callable("t", lambda x: x * 2.0, (jnp.ones((8, 8)),),
                         input_bytes=1_000_000, warmup=1, runs=4,
                         deadline_s=100.0)
    assert len(res.samples_s) == 4
    assert res.stats is not None and res.stats.n == 4
    np.testing.assert_allclose(res.t_avg_s, np.mean(res.samples_s))
    assert res.stats.miss_rate == 0.0                   # generous budget


def test_ndjson_telemetry_schema():
    res = bench_callable("t", lambda x: x + 1.0, (jnp.ones((4, 4)),),
                         input_bytes=1000, warmup=1, runs=3, deadline_s=1.0)
    cfg = tiny_config()
    res.stage_breakdown = bench_stages(cfg, jnp.asarray(synth_rf(cfg)),
                                       runs=2)
    recs = [json.loads(line) for line in res.ndjson_lines()]
    kinds = [r["kind"] for r in recs]
    assert kinds[0] == "summary" and kinds.count("sample") == 3
    assert {r["stage"] for r in recs if r["kind"] == "stage"} == {
        "demod", "beamform", "bmode"}
    summary = recs[0]
    lat = summary["latency"]
    assert lat["p50_s"] <= lat["p95_s"] <= lat["p99_s"]
    for r in recs:
        if r["kind"] == "sample":
            assert r["deadline_missed"] is False


def test_acquisition_source_shapes_and_cycling():
    cfg = tiny_config()
    src = SyntheticAcquisitionSource(cfg, batch=3, pool=2, seed=7)
    a, b, c = src.next(), src.next(), src.next()
    assert a.shape == (3,) + cfg.rf_shape
    assert not np.array_equal(a, b)                     # distinct sweeps
    assert np.array_equal(a, c)                         # pool of 2 cycles


def test_acquisition_sources_with_nearby_seeds_share_no_frame():
    """Disjoint per-source seed spaces: the old additive scheme
    (``seed + b * batch + i``) made two sources whose base seeds differ
    by less than ``pool * batch`` stream byte-identical frames (source
    0's frame 2 was source 2's frame 0). Hash-derived seed spaces must
    never collide across distinct base seeds."""
    cfg = tiny_config()
    src_a = SyntheticAcquisitionSource(cfg, batch=2, pool=2, seed=0)
    src_b = SyntheticAcquisitionSource(cfg, batch=2, pool=2, seed=2)
    frames_a = [f for batch in src_a._pool for f in batch]
    frames_b = [f for batch in src_b._pool for f in batch]
    for i, fa in enumerate(frames_a):
        for j, fb in enumerate(frames_b):
            assert not np.array_equal(fa, fb), (
                f"sources seed=0 frame {i} and seed=2 frame {j} are "
                f"byte-identical")
    # within one source every pooled frame is still distinct
    for i, fa in enumerate(frames_a):
        for fb in frames_a[i + 1:]:
            assert not np.array_equal(fa, fb)


def test_streaming_batched_throughput_beats_single_frame():
    """Acceptance: sustained MB/s at batch N>1 >= single-frame MB/s.

    At tiny geometry the batched engine wins by a wide margin (dispatch
    overhead dominates), but this is still a wall-clock inequality on a
    shared machine — so it is asserted with the CI-exclusion rule over
    three windows per side (fail only when the whole ratio interval
    says the batched engine is slower), replacing a retry loop that
    still flaked whenever two windows in a row caught a stall.
    """
    from repro.bench.stats import gate_ratio

    cfg = tiny_config()
    single_mbps, batched_mbps = [], []
    for _ in range(3):
        single_mbps.append(serve_ultrasound_stream(
            cfg, batch=1, n_batches=8, depth=1,
            deadline_s=1.0)["sustained_mbps"])
    for _ in range(3):
        batched_mbps.append(serve_ultrasound_stream(
            cfg, batch=8, n_batches=8, depth=2,
            deadline_s=1.0)["sustained_mbps"])
    batched = serve_ultrasound_stream(cfg, batch=8, n_batches=8, depth=2,
                                      deadline_s=1.0)
    # higher_is_better at factor 1.0: fail only when the batched/single
    # ratio CI sits entirely below 1.0.
    dec = gate_ratio(single_mbps, batched_mbps, factor=1.0,
                     higher_is_better=True)
    assert dec.ok, dec.reason
    assert batched["acquisitions"] == 64
    assert batched["frames"] == 64 * cfg.n_f
    lat = batched["latency"]
    assert lat.p50_s <= lat.p95_s <= lat.p99_s
    assert lat.budget_s == 8 * 1.0                      # batch * deadline
    assert lat.miss_rate == 0.0
