"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

Each kernel's tolerance reflects f32 accumulation-order differences only.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import tiny_config
from repro.core.delays import bsr_operator, compute_delay_tables
from repro.kernels.das_beamform import das_beamform
from repro.kernels.das_beamform.ref import das_beamform_ref
from repro.kernels.bsr_spmm import bsr_beamform, bsr_spmm
from repro.kernels.bsr_spmm.ref import bsr_spmm_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


# ---------------------------------------------------------------------------
# das_beamform
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_pix,n_c,n_s,n_f,bp", [
    (64, 4, 32, 2, 32),
    (96, 8, 64, 4, 32),     # n_pix % bp == 0, multiple blocks
    (100, 3, 40, 1, 32),    # ragged n_pix -> wrapper pads
])
def test_das_beamform_sweep(rng, n_pix, n_c, n_s, n_f, bp):
    idx = rng.integers(0, n_s - 1, (n_pix, n_c)).astype(np.int32)
    frac = rng.uniform(0, 1, (n_pix, n_c)).astype(np.float32)
    apod = rng.uniform(0, 1, (n_pix, n_c)).astype(np.float32)
    ph = rng.uniform(-np.pi, np.pi, (n_pix, n_c))
    rot = np.stack([np.cos(ph), np.sin(ph)], -1).astype(np.float32)
    iq = rng.standard_normal((n_s, n_c, n_f, 2)).astype(np.float32)

    args = tuple(jnp.asarray(a) for a in (idx, frac, apod, rot, iq))
    out = das_beamform(*args, bp=bp)
    ref = das_beamform_ref(*args)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_das_beamform_matches_pipeline_tables(rng):
    """Kernel on real geometry tables == the core dynamic beamformer."""
    cfg = tiny_config()
    t = compute_delay_tables(cfg)
    iq = rng.standard_normal((cfg.n_s, cfg.n_c, 2, 2)).astype(np.float32)
    out = das_beamform(jnp.asarray(t.idx), jnp.asarray(t.frac),
                       jnp.asarray(t.apod), jnp.asarray(t.rot),
                       jnp.asarray(iq), bp=64)
    ref = das_beamform_ref(jnp.asarray(t.idx), jnp.asarray(t.frac),
                           jnp.asarray(t.apod), jnp.asarray(t.rot),
                           jnp.asarray(iq))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# bsr_spmm
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_pb,K,bp,bs,n_sb,nf", [
    (4, 2, 16, 16, 6, 3),
    (8, 1, 8, 32, 4, 8),
    (3, 3, 32, 8, 9, 1),
])
def test_bsr_spmm_sweep(rng, n_pb, K, bp, bs, n_sb, nf):
    cols = rng.integers(0, n_sb, (n_pb, K)).astype(np.int32)
    blocks = rng.standard_normal((n_pb, K, bp, bs)).astype(np.float32)
    x = rng.standard_normal((n_sb, bs, nf)).astype(np.float32)
    out = bsr_spmm(jnp.asarray(cols), jnp.asarray(blocks), jnp.asarray(x))
    ref = bsr_spmm_ref(jnp.asarray(cols), jnp.asarray(blocks),
                       jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_bsr_beamform_matches_core_sparse(rng):
    """Pallas BSR beamform == repro.core sparse variant on real tables."""
    from repro.core.beamform import beamform_sparse
    cfg = tiny_config()
    t = compute_delay_tables(cfg)
    op = bsr_operator(cfg, t)
    n_f = 2
    iq = rng.standard_normal((cfg.n_s, cfg.n_c, n_f, 2)).astype(np.float32)

    n_sb = -(-cfg.n_s // op.bs)
    pad = n_sb * op.bs - cfg.n_s
    iq_b = np.pad(iq, ((0, pad), (0, 0), (0, 0), (0, 0))).reshape(
        n_sb, op.bs, cfg.n_c, n_f, 2)
    out = bsr_beamform(jnp.asarray(op.col_idx), jnp.asarray(op.blocks),
                       jnp.asarray(iq_b))[: cfg.n_pix]
    consts = {"bsr_blocks": jnp.asarray(op.blocks),
              "bsr_col_idx": jnp.asarray(op.col_idx)}
    ref = beamform_sparse(cfg, consts, jnp.asarray(iq))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("b,l,hq,hkv,d,causal", [
    (1, 64, 2, 2, 16, True),
    (2, 96, 4, 2, 32, True),     # GQA + ragged padding
    (1, 128, 4, 1, 64, True),    # MQA
    (2, 64, 2, 2, 16, False),
])
def test_flash_attention_sweep(rng, b, l, hq, hkv, d, causal):
    q = rng.standard_normal((b, l, hq, d)).astype(np.float32)
    k = rng.standard_normal((b, l, hkv, d)).astype(np.float32)
    v = rng.standard_normal((b, l, hkv, d)).astype(np.float32)
    out = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                          causal=causal, bq=32, bk=32)
    rep = hq // hkv
    kr, vr = np.repeat(k, rep, 2), np.repeat(v, rep, 2)
    ref = jax.vmap(lambda a, b_, c: attention_ref(
        a.transpose(1, 0, 2), b_.transpose(1, 0, 2), c.transpose(1, 0, 2),
        causal=causal).transpose(1, 0, 2))(
            jnp.asarray(q), jnp.asarray(kr), jnp.asarray(vr))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_flash_attention_bf16_close(rng):
    b, l, h, d = 1, 64, 2, 32
    q = (rng.standard_normal((b, l, h, d))).astype(np.float32)
    k = (rng.standard_normal((b, l, h, d))).astype(np.float32)
    v = (rng.standard_normal((b, l, h, d))).astype(np.float32)
    out16 = flash_attention(
        jnp.asarray(q, jnp.bfloat16), jnp.asarray(k, jnp.bfloat16),
        jnp.asarray(v, jnp.bfloat16), causal=True, bq=32, bk=32)
    out32 = flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, bq=32, bk=32)
    assert out16.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out16, dtype=np.float32),
                               np.asarray(out32), rtol=0.1, atol=0.05)


# ---------------------------------------------------------------------------
# ssd_scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bsz,L,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 100, 3, 16, 8, 32),    # ragged -> wrapper pads
    (1, 32, 1, 64, 16, 32),
])
def test_ssd_scan_sweep(rng, bsz, L, H, P, N, chunk):
    log_a = -np.abs(rng.standard_normal((bsz, L, H))).astype(
        np.float32) * 0.2
    x = rng.standard_normal((bsz, L, H, P)).astype(np.float32)
    b = (rng.standard_normal((bsz, L, H, N)) * 0.3).astype(np.float32)
    c = (rng.standard_normal((bsz, L, H, N)) * 0.3).astype(np.float32)
    y = ssd_scan(jnp.asarray(log_a), jnp.asarray(x), jnp.asarray(b),
                 jnp.asarray(c), chunk=chunk)
    for bi in range(bsz):
        for h in range(H):
            ref = ssd_scan_ref(
                jnp.asarray(log_a[bi, :, h:h + 1]), jnp.asarray(x[bi, :, h]),
                jnp.asarray(b[bi, :, h]), jnp.asarray(c[bi, :, h]))
            np.testing.assert_allclose(
                np.asarray(y[bi, :, h]), np.asarray(ref),
                rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_path(rng):
    """Pallas SSD == the model's chunked XLA implementation."""
    from repro.models.ssm import _ssd_chunked
    bsz, L, H, P, N = 2, 64, 2, 16, 8
    log_a = -np.abs(rng.standard_normal((bsz, L, H))).astype(
        np.float32) * 0.1
    x = rng.standard_normal((bsz, L, H, P)).astype(np.float32)
    bmat = (rng.standard_normal((bsz, L, N)) * 0.3).astype(np.float32)
    cmat = (rng.standard_normal((bsz, L, N)) * 0.3).astype(np.float32)

    y_model, _ = _ssd_chunked(jnp.asarray(log_a), jnp.asarray(x),
                              jnp.asarray(bmat), jnp.asarray(cmat), 16)
    bh = np.broadcast_to(bmat[:, :, None, :], (bsz, L, H, N))
    ch = np.broadcast_to(cmat[:, :, None, :], (bsz, L, H, N))
    y_kern = ssd_scan(jnp.asarray(log_a), jnp.asarray(x), jnp.asarray(bh),
                      jnp.asarray(ch), chunk=16)
    np.testing.assert_allclose(np.asarray(y_kern), np.asarray(y_model),
                               rtol=2e-4, atol=2e-4)
