"""The fused megakernel's determinism / mixed-precision contract.

Three layers, matching the documented contract
(docs/kernels.md, repro.core.config.PRECISION_TOLERANCES):

  1. f32 + interpret is BIT-EXACT against the monolithic oracle for
     both fused heads (bmode, power_doppler) — the fused kernel reuses
     the reference stage expressions verbatim on tile-resident
     intermediates, so equality is by construction, not by tolerance.
     The comparison traces fused span + epilogue under ONE jit, exactly
     as `UltrasoundPipeline` runs it (a split jit boundary reintroduces
     1-ulp context drift on XLA:CPU and would test the wrong program).
  2. bf16/f16 stay inside the per-(precision, modality) rtol/atol
     bounds of the golden fixtures — the same checked-in images
     tests/test_golden.py pins, so reduced precision is anchored to
     yesterday's numerics, not to a freshly computed (possibly
     co-drifted) oracle.
  3. The check has teeth: a kernel perturbed past its stated bound
     FAILS the golden check (negative test via monkeypatched kernel
     entry point).
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from make_goldens import RF_SEED, golden_path  # noqa: E402 (tests/ on path)
from repro.core import lowering
from repro.core.config import (Modality, Variant, precision_tolerance,
                               tiny_config)
from repro.core.pipeline import (UltrasoundPipeline, init_pipeline,
                                 monolithic_pipeline_fn)
from repro.data import synth_rf

FUSED_MODALITIES = (Modality.BMODE, Modality.POWER_DOPPLER)
MODALITY_IDS = [m.value for m in FUSED_MODALITIES]


def _cfg(modality, **kw):
    return tiny_config(modality=modality, variant=Variant.DYNAMIC,
                       fusion="fused", **kw)


def _fused_image(cfg, rf):
    """Fused span + global epilogue under one jit (the executed program)."""
    consts = init_pipeline(cfg)
    fl = lowering.resolve_fused(cfg, jax.default_backend())
    return np.asarray(jax.jit(lambda r: fl.apply(cfg, consts, r))(rf))


def _oracle_image(cfg, rf):
    """The f32 monolithic reference for this geometry."""
    f32 = cfg.with_(fusion="none", precision="f32", fusion_block=None)
    consts = init_pipeline(f32)
    fn = monolithic_pipeline_fn(f32)
    return np.asarray(jax.jit(lambda r: fn(consts, r))(rf))


def _golden_image(modality):
    with np.load(golden_path(modality, Variant.DYNAMIC)) as z:
        return z["image"], json.loads(str(z["meta"]))


def check_against_golden(got, want, precision, modality):
    """THE golden check: assert `got` within the documented
    (precision, modality) tolerance of the pinned image `want`."""
    rtol, atol = precision_tolerance(precision, modality)
    if rtol == atol == 0.0:
        ok = np.array_equal(got, np.asarray(want))
    else:
        ok = np.allclose(got, want, rtol=rtol, atol=atol)
    if not ok:
        d = np.abs(np.asarray(got, np.float64) - np.asarray(want, np.float64))
        raise AssertionError(
            f"fused {modality.value}@{precision} violates its contract "
            f"(rtol={rtol}, atol={atol}): max|d|={d.max():.3e}")


@pytest.mark.parametrize("modality", FUSED_MODALITIES, ids=MODALITY_IDS)
def test_f32_fused_bitexact_vs_monolith(modality):
    cfg = _cfg(modality)
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    fused = _fused_image(cfg, rf)
    oracle = _oracle_image(cfg, rf)
    np.testing.assert_array_equal(fused, oracle, err_msg=(
        f"f32 fused {modality.value} is not bit-exact against "
        "monolithic_pipeline_fn — the fused kernel must reuse the "
        "reference stage expressions verbatim (see docs/kernels.md)"))


@pytest.mark.parametrize("bp", [80, 128])
def test_f32_fused_bitexact_any_block_size(bp):
    """Tiling must not change the math: the per-tile channel reduce and
    FIR orders are pinned, so every block size gives the same bits."""
    cfg = _cfg(Modality.POWER_DOPPLER, fusion_block=bp)
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    np.testing.assert_array_equal(_fused_image(cfg, rf),
                                  _oracle_image(cfg, rf))


@pytest.mark.parametrize("modality", FUSED_MODALITIES, ids=MODALITY_IDS)
def test_f32_fused_matches_golden(modality):
    """End-to-end pipeline (plan -> fused lowering) against the pinned
    fixture — same seed and geometry as tests/test_golden.py."""
    want, meta = _golden_image(modality)
    cfg = _cfg(modality)
    assert meta["config_hash"] == cfg.with_(
        fusion="none").canonical_hash(), (
        "golden fixture geometry drifted from the fused test config — "
        "regenerate tests/goldens/ (tests/make_goldens.py)")
    rf = jnp.asarray(synth_rf(cfg, seed=RF_SEED))
    got = np.asarray(jax.block_until_ready(UltrasoundPipeline(cfg)(rf)))
    check_against_golden(got, want, "f32", modality)


@pytest.mark.parametrize("modality", FUSED_MODALITIES, ids=MODALITY_IDS)
@pytest.mark.parametrize("precision", ["bf16", "f16"])
def test_reduced_precision_within_contract(precision, modality):
    want, _ = _golden_image(modality)
    cfg = _cfg(modality, precision=precision)
    rf = jnp.asarray(synth_rf(cfg, seed=RF_SEED))
    got = _fused_image(cfg, rf)
    check_against_golden(got, want, precision, modality)


def test_out_of_tolerance_kernel_fails_golden_check(monkeypatch):
    """The contract is falsifiable: a kernel drifting past its stated
    bound must FAIL, not pass on slack tolerances."""
    from repro.kernels import fused_pipeline as fp
    modality = Modality.BMODE
    real = fp.fused_rf_to_envelope

    def drifted(*args, **kw):
        # Non-uniform drift (a uniform scale would wash out through the
        # epilogue's normalize_by_max): 4x on alternating pixels is a
        # ~12 dB structured error, far past every stated bound.
        env = real(*args, **kw)
        return env.at[::2].multiply(4.0)

    monkeypatch.setattr(fp, "fused_rf_to_envelope", drifted)
    want, _ = _golden_image(modality)
    cfg = _cfg(modality, precision="bf16")
    rf = jnp.asarray(synth_rf(cfg, seed=RF_SEED))
    got = _fused_image(cfg, rf)
    with pytest.raises(AssertionError, match="violates its contract"):
        check_against_golden(got, want, "bf16", modality)


def test_xla_lowerings_refuse_reduced_precision():
    """Unfused reduced precision must fail loudly at plan time — the
    f32-only xla references must never silently answer a bf16 ask."""
    from repro.core.plan import plan_pipeline
    cfg = tiny_config(modality=Modality.BMODE, variant=Variant.DYNAMIC,
                      precision="bf16")        # fusion="none"
    with pytest.raises(ValueError, match="precision"):
        plan = plan_pipeline(cfg)
        rf = jnp.asarray(synth_rf(cfg, seed=0))
        UltrasoundPipeline(cfg, plan=plan)(rf)
