"""Example smoke tests: the documented entry points must not rot.

Each test runs one example script end to end in a subprocess, exactly
as the README tells a user to (`PYTHONPATH=src python examples/...`),
and asserts on the script's own success markers — so a refactor that
breaks an import, an API rename, or a numerics drift that trips an
example's internal assertion fails CI even though no unit test imports
the example.

Marked ``examples`` and excluded from the default tier-1 run
(pytest.ini); CI executes them in their own step via
``pytest -m examples``.
"""

import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_example(script: str) -> str:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", script)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, (
        f"{script} exited {proc.returncode}\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout


@pytest.mark.examples
def test_quickstart_runs_end_to_end():
    out = _run_example("quickstart.py")
    # all three variants of all three modalities printed a metrics row
    for pipeline in ("RF2IQ_DAS_BMODE", "RF2IQ_DAS_DOPPLER",
                     "RF2IQ_DAS_POWERDOPPLER"):
        for variant in ("dynamic", "cnn", "sparse"):
            assert any(pipeline in line and variant in line
                       for line in out.splitlines()), (pipeline, variant)
    assert "planner:" in out           # Variant.AUTO demo resolved
    assert "B-mode (dynamic variant, frame 0):" in out


@pytest.mark.examples
def test_doppler_flow_recovers_programmed_velocity():
    out = _run_example("doppler_flow.py")
    # the script asserts variant agreement internally; its final line is
    # the success marker
    assert ("Kasai velocity estimates match programmed flow "
            "for all variants.") in out
    assert out.count("flow=") == 3     # all three programmed velocities
