"""RF->IQ demodulation: a pure tone at f0 demodulates to a constant
envelope; decimation produces exactly n_l // decim samples."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import tiny_config
from repro.core.demod import demod_consts, rf_to_iq


def test_tone_envelope_constant():
    cfg = tiny_config()
    t = np.arange(cfg.n_l) / cfg.fs
    rf = np.cos(2 * np.pi * cfg.f0 * t + 0.3)[:, None, None]
    rf = np.broadcast_to(rf, cfg.rf_shape).astype(np.float32)
    consts = jax.tree.map(jnp.asarray, demod_consts(cfg))
    iq = np.asarray(rf_to_iq(consts, jnp.asarray(rf), cfg.decim))
    assert iq.shape == (cfg.n_s, cfg.n_c, cfg.n_f, 2)
    env = np.sqrt(iq[..., 0] ** 2 + iq[..., 1] ** 2)
    # ignore filter edges
    mid = env[cfg.lpf_taps // cfg.decim + 2: -cfg.lpf_taps // cfg.decim - 2]
    assert np.all(np.abs(mid - 1.0) < 0.05), (mid.min(), mid.max())


def test_phase_tracks_offset():
    cfg = tiny_config()
    t = np.arange(cfg.n_l) / cfg.fs
    phi = 0.7
    rf = np.cos(2 * np.pi * cfg.f0 * t + phi)[:, None, None]
    rf = np.broadcast_to(rf, cfg.rf_shape).astype(np.float32)
    consts = jax.tree.map(jnp.asarray, demod_consts(cfg))
    iq = np.asarray(rf_to_iq(consts, jnp.asarray(rf), cfg.decim))
    mid = slice(10, cfg.n_s - 10)
    phase = np.arctan2(iq[mid, 0, 0, 1], iq[mid, 0, 0, 0])
    assert np.all(np.abs(phase - phi) < 0.05)


def test_int16_input_supported():
    cfg = tiny_config()
    rf = (np.random.default_rng(0).integers(
        -30000, 30000, cfg.rf_shape)).astype(np.int16)
    consts = jax.tree.map(jnp.asarray, demod_consts(cfg))
    iq = rf_to_iq(consts, jnp.asarray(rf), cfg.decim)
    assert iq.dtype == jnp.float32
    assert bool(jnp.isfinite(iq).all())
