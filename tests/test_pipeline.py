"""End-to-end pipeline behaviour: determinism, shapes, metrics geometry."""

import numpy as np

import jax.numpy as jnp

from repro.core import (Modality, UltrasoundPipeline, paper_config,
                        tiny_config)
from repro.data import synth_rf


def test_paper_input_bytes_exact():
    assert paper_config().input_bytes == 5_472_256  # 5.472 MB (paper §III)


def test_determinism_bitwise():
    cfg = tiny_config()
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    pipe = UltrasoundPipeline(cfg)
    a = np.asarray(pipe(rf))
    b = np.asarray(pipe(rf))
    assert np.array_equal(a, b)  # same graph, same input -> same bits


def test_bmode_batches_all_frames():
    cfg = tiny_config(n_f=8)
    img = UltrasoundPipeline(cfg)(jnp.asarray(synth_rf(cfg, seed=1)))
    assert img.shape == (cfg.nz, cfg.nx, 8)   # N_f frames per forward pass
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0


def test_doppler_outputs_velocity_map():
    cfg = tiny_config(n_f=16, modality=Modality.DOPPLER)
    img = np.asarray(UltrasoundPipeline(cfg)(jnp.asarray(
        synth_rf(cfg, seed=2))))
    assert img.shape == (cfg.nz, cfg.nx)
    assert np.abs(img).max() <= 1.0 + 1e-6    # Nyquist-normalized
    assert np.abs(img).max() > 1e-4           # moving scatterers detected


def test_power_doppler_in_range():
    cfg = tiny_config(n_f=16, modality=Modality.POWER_DOPPLER)
    img = np.asarray(UltrasoundPipeline(cfg)(jnp.asarray(
        synth_rf(cfg, seed=2))))
    assert img.shape == (cfg.nz, cfg.nx)
    assert float(img.min()) >= -1e-6 and float(img.max()) <= 1.0 + 1e-6


def test_das_kernel_lowering_matches_dynamic():
    """Pallas-lowered pipeline == XLA dynamic variant (CPU interpret
    mode). The full lowering x stage oracle lives in test_lowering.py."""
    from repro.core import Variant
    cfg = tiny_config(variant=Variant.DYNAMIC)
    rf = jnp.asarray(synth_rf(cfg, seed=0))
    a = np.asarray(UltrasoundPipeline(cfg)(rf))
    b = np.asarray(UltrasoundPipeline(
        cfg.with_(stage_lowerings={"beamform": "pallas"}))(rf))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_transcendental_toggle_close():
    """cnn_transcendentals=True stays within 0.01 of the jnp-native path
    (bounded-error contract)."""
    cfg = tiny_config(n_f=8)
    rf = jnp.asarray(synth_rf(cfg, seed=4))
    a = np.asarray(UltrasoundPipeline(
        cfg.with_(cnn_transcendentals=True))(rf))
    b = np.asarray(UltrasoundPipeline(
        cfg.with_(cnn_transcendentals=False))(rf))
    assert np.abs(a - b).max() < 0.01
