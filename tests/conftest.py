import numpy as np
import pytest

import jax


@pytest.fixture(scope="session", autouse=True)
def _sandbox_consts_cache(tmp_path_factory):
    """Keep the on-disk consts cache inside the test session.

    Tests must never read entries a previous checkout wrote to the user
    cache dir (stale constants would corrupt oracle comparisons), nor
    leave test-geometry entries behind.
    """
    from repro.core import set_consts_cache_dir
    set_consts_cache_dir(str(tmp_path_factory.mktemp("consts-cache")))
    yield


@pytest.fixture(scope="session", autouse=True)
def _sandbox_compile_cache(tmp_path_factory):
    """Keep the XLA persistent compilation cache inside the session.

    Same discipline as the consts cache: AOT warm-ups must never
    populate (or hit) the user's `~/.cache/repro/xla` from a test run —
    the warm-start tests assert cold-vs-warm timing differences that a
    pre-populated cache would erase.
    """
    from repro.core import set_compile_cache_dir
    set_compile_cache_dir(str(tmp_path_factory.mktemp("compile-cache")))
    yield


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)
