"""CNN-expressible primitive ops: bounded error vs jnp oracles (+ property
tests on their invariants)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax.numpy as jnp

from repro.core import cnn_ops

finite_f = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                     allow_subnormal=False, width=32)


def test_atan2_bounded_error(rng):
    y = rng.standard_normal(200_000).astype(np.float32) * 10
    x = rng.standard_normal(200_000).astype(np.float32) * 10
    got = np.asarray(cnn_ops.atan2_approx(jnp.asarray(y), jnp.asarray(x)))
    err = np.abs(got - np.arctan2(y, x))
    assert err.max() < 2e-4, err.max()


def test_ln_bounded_error(rng):
    x = rng.uniform(1e-8, 1e4, 100_000).astype(np.float32)
    got = np.asarray(cnn_ops.ln_approx(jnp.asarray(x)))
    assert np.abs(got - np.log(x)).max() < 1e-2


def test_db20_matches_oracle(rng):
    x = rng.uniform(1e-6, 1.0, 10_000).astype(np.float32)
    got = np.asarray(cnn_ops.db20_approx(jnp.asarray(x)))
    ref = 20 * np.log10(x)
    assert np.abs(got - ref).max() < 0.1  # < 0.1 dB over 120 dB range


@given(y=finite_f, x=finite_f)
@settings(max_examples=200, deadline=None)
def test_atan2_range(y, x):
    out = float(cnn_ops.atan2_approx(jnp.float32(y), jnp.float32(x)))
    assert -np.pi - 1e-3 <= out <= np.pi + 1e-3


@given(m=st.integers(0, 1), a=finite_f, b=finite_f)
@settings(max_examples=100, deadline=None)
def test_select_is_exact(m, a, b):
    out = float(cnn_ops.select(jnp.float32(m), jnp.float32(a),
                               jnp.float32(b)))
    assert out == (a if m else b)


@given(x=st.floats(min_value=2.0 ** -16, max_value=2.0 ** 13,
                   allow_subnormal=False, width=32))
@settings(max_examples=100, deadline=None)
def test_ln_monotone_neighborhood(x):
    e = float(cnn_ops.ln_approx(jnp.float32(x * 1.1))) - \
        float(cnn_ops.ln_approx(jnp.float32(x)))
    assert e > 0


def test_cmul_matches_complex(rng):
    a = rng.standard_normal((64, 2)).astype(np.float32)
    b = rng.standard_normal((64, 2)).astype(np.float32)
    got = np.asarray(cnn_ops.cmul(jnp.asarray(a), jnp.asarray(b)))
    ref = (a[:, 0] + 1j * a[:, 1]) * (b[:, 0] + 1j * b[:, 1])
    np.testing.assert_allclose(got[:, 0], ref.real, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(got[:, 1], ref.imag, rtol=1e-5, atol=1e-5)


def test_clip_normalize(rng):
    x = rng.uniform(-5, 5, 1000).astype(np.float32)
    out = np.asarray(cnn_ops.clip(jnp.asarray(x), -1.0, 1.0))
    assert out.min() >= -1.0 and out.max() <= 1.0
    n = np.asarray(cnn_ops.normalize_by_max(jnp.asarray(np.abs(x))))
    assert np.isclose(n.max(), 1.0, atol=1e-5)
