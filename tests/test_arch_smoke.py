"""Per-architecture smoke tests (reduced same-family configs, CPU):
one train step + one decode step, asserting shapes and finiteness.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, TrainConfig, get_config, get_smoke
from repro.data.batches import synth_decode_inputs, synth_train_batch
from repro.models import get_model
from repro.train import steps as steps_lib


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_and_decode(arch, key):
    cfg = get_smoke(arch)
    model = get_model(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    state = steps_lib.init_train_state(model, key)
    step = jax.jit(steps_lib.make_train_step(model, tcfg))

    B, S = 2, 32
    batch = synth_train_batch(cfg, B, S, seed=0)
    state, metrics = step(state, batch)
    loss0 = float(metrics["loss"])
    assert np.isfinite(loss0)
    # parameters actually moved
    assert int(state["opt"]["step"]) == 1

    # one more step on the same batch must reduce loss (lr warm but > 0)
    for _ in range(3):
        state, metrics = step(state, batch)
    assert float(metrics["loss"]) < loss0

    # decode step
    serve = jax.jit(steps_lib.make_serve_step(model))
    if cfg.family == "audio":
        cache = model.init_cache(B, 16, S)
    else:
        cache = model.init_cache(B, 16)
    dec = synth_decode_inputs(cfg, B, 3)
    tok, cache, lengths = serve(state["params"], dec["tokens"], cache,
                                dec["lengths"])
    assert tok.shape == (B, 1)
    assert int(lengths[0]) == 4


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The published numbers are wired exactly as assigned."""
    cfg = get_config(arch)
    expected = {
        "granite_moe_3b_a800m": dict(n_layers=32, d_model=1536, n_heads=24,
                                     n_kv_heads=8, vocab_size=49155,
                                     n_experts=40, n_experts_per_tok=8),
        "deepseek_v2_236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 vocab_size=102400, n_experts=160,
                                 n_experts_per_tok=6, kv_lora_rank=512),
        "zamba2_1p2b": dict(n_layers=38, d_model=2048, n_heads=32,
                            vocab_size=32000, ssm_state=64),
        "qwen2_vl_2b": dict(n_layers=28, d_model=1536, n_heads=12,
                            n_kv_heads=2, d_ff=8960, vocab_size=151936),
        "qwen3_8b": dict(n_layers=36, d_model=4096, n_heads=32,
                         n_kv_heads=8, d_ff=12288, vocab_size=151936,
                         qk_norm=True),
        "gemma3_1b": dict(n_layers=26, d_model=1152, n_heads=4,
                          n_kv_heads=1, d_ff=6912, vocab_size=262144,
                          local_global_pattern=5),
        "granite_3_8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab_size=49155),
        "llama3_405b": dict(n_layers=126, d_model=16384, n_heads=128,
                            n_kv_heads=8, d_ff=53248, vocab_size=128256),
        "mamba2_130m": dict(n_layers=24, d_model=768, vocab_size=50280,
                            ssm_state=128),
        "seamless_m4t_large_v2": dict(n_layers=24, n_enc_layers=24,
                                      d_model=1024, n_heads=16, d_ff=8192,
                                      vocab_size=256206),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_long_context_classification():
    """DESIGN.md §5 long_500k applicability is encoded in the configs."""
    runs = {a: get_config(a).supports_long_context for a in ARCHS}
    assert runs["mamba2_130m"] and runs["zamba2_1p2b"] and runs["gemma3_1b"]
    for a in ["qwen3_8b", "granite_3_8b", "llama3_405b", "qwen2_vl_2b",
              "deepseek_v2_236b", "granite_moe_3b_a800m",
              "seamless_m4t_large_v2"]:
        assert not runs[a], a
