"""Hypothesis property tests on system invariants."""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import jax
import jax.numpy as jnp

from repro.core.config import Variant
from repro.models.attention import cache_update
from repro.models.common import apply_rope, rmsnorm, rmsnorm_params


@given(seed=st.integers(0, 1000), s=st.sampled_from([4, 8, 16]),
       d=st.sampled_from([8, 16]))
@settings(max_examples=25, deadline=None)
def test_rope_preserves_norm(seed, s, d):
    """Rotary embedding is a rotation: per-position vector norms are
    invariant."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, s, 2, d)).astype(np.float32)
    pos = jnp.asarray(rng.integers(0, 1000, (1, s)).astype(np.int32))
    out = np.asarray(apply_rope(jnp.asarray(x), pos, 10_000.0))
    # norm preserved over paired rotation dims
    n_in = np.linalg.norm(x, axis=-1)
    n_out = np.linalg.norm(out, axis=-1)
    np.testing.assert_allclose(n_out, n_in, rtol=1e-4, atol=1e-4)


@given(seed=st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_rope_relative_phase(seed):
    """score(q_i, k_j) depends only on i - j (the rope contract)."""
    rng = np.random.default_rng(seed)
    d = 16
    q = rng.standard_normal((1, 1, 1, d)).astype(np.float32)
    k = rng.standard_normal((1, 1, 1, d)).astype(np.float32)

    def score(pi, pj):
        qr = apply_rope(jnp.asarray(q), jnp.asarray([[pi]], dtype=jnp.int32),
                        1e4)
        kr = apply_rope(jnp.asarray(k), jnp.asarray([[pj]], dtype=jnp.int32),
                        1e4)
        return float(jnp.sum(qr * kr))

    assert abs(score(5, 3) - score(10, 8)) < 1e-3
    assert abs(score(7, 0) - score(107, 100)) < 1e-3


@given(seed=st.integers(0, 500), b=st.sampled_from([1, 3]),
       s=st.sampled_from([4, 9]))
@settings(max_examples=25, deadline=None)
def test_cache_update_variants_agree(seed, b, s):
    rng = np.random.default_rng(seed)
    cache = rng.standard_normal((b, s, 2, 4)).astype(np.float32)
    new = rng.standard_normal((b, 1, 2, 4)).astype(np.float32)
    lengths = jnp.asarray(rng.integers(0, s, (b,)).astype(np.int32))
    a = cache_update(jnp.asarray(cache), jnp.asarray(new), lengths,
                     Variant.DYNAMIC)
    c = cache_update(jnp.asarray(cache), jnp.asarray(new), lengths,
                     Variant.CNN)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-6)
    # untouched rows unchanged
    an = np.asarray(a)
    for bi in range(b):
        li = int(lengths[bi])
        mask = np.arange(s) != li
        np.testing.assert_array_equal(an[bi, mask], cache[bi, mask])


@given(seed=st.integers(0, 500), d=st.sampled_from([8, 32]))
@settings(max_examples=25, deadline=None)
def test_rmsnorm_scale_invariant(seed, d):
    """rmsnorm(a*x) == rmsnorm(x) for a > 0 (the defining invariant)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((3, d)).astype(np.float32) + 0.1
    p = rmsnorm_params(d, jnp.float32)
    a = np.asarray(rmsnorm(p, jnp.asarray(x)))
    b = np.asarray(rmsnorm(p, jnp.asarray(4.0 * x)))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 500))
@settings(max_examples=15, deadline=None)
def test_chunked_attention_chunk_size_invariant(seed):
    """The q-chunk size is an implementation detail: outputs must not
    depend on it."""
    from repro.models.attention import chunked_attention
    rng = np.random.default_rng(seed)
    b, s, h, dd = 1, 24, 2, 8
    q = rng.standard_normal((b, s, h, dd)).astype(np.float32)
    k = rng.standard_normal((b, s, h, dd)).astype(np.float32)
    v = rng.standard_normal((b, s, h, dd)).astype(np.float32)
    outs = [np.asarray(chunked_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), chunk=c))
        for c in (6, 24)]
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-4, atol=1e-5)
