"""AOT warm-start compilation + persistent compilation cache.

Three contracts (repro.core.aot):

  * correctness — an AOT-compiled padded program (`aot_warm` installing
    `jax.jit(...).lower().compile()` on the executor) produces outputs
    BIT-IDENTICAL to the jit dispatch path it replaces; the warm pool
    groups specs exactly like the scheduler and never recompiles a key
    it already holds.
  * accounting — warm-up cost is measured (`compile_s` / `warmup_s`)
    and lands in the serving stats of the window that paid it; pool
    hits charge zero and stamp ``warm_source="pool"``.
  * warm start — the persistent compilation cache (env-configurable
    dir, sandboxed by conftest) is actually written, and a FRESH
    process pointed at a populated cache compiles cheaper and serves
    its first dispatch without a compile spike (within 3x the
    steady-state p50) — the subprocess test at the bottom.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import Modality, Variant, tiny_config
from repro.core.executor import BatchedExecutor
from repro.data import synth_rf
from repro.launch.scheduler import BatchPolicy, StreamSpec, serve_multitenant

BURST = 1e9


def test_aot_warm_bit_identical_to_jit_path():
    """The AOT executable replaces jit dispatch without moving a bit."""
    from repro.core.aot import aot_warm

    cfg = tiny_config(variant=Variant.DYNAMIC)
    jit_eng = BatchedExecutor(cfg)          # never AOT-warmed
    aot_eng = BatchedExecutor(cfg)
    prog = aot_warm(aot_eng, 4)
    assert prog.compile_s > 0.0
    assert prog.warmup_s >= prog.compile_s
    assert prog.pad_to == 4 and prog.devices == 1
    assert 4 in aot_eng._aot                # executable installed

    rf = jnp.asarray(np.stack([synth_rf(cfg, seed=s) for s in range(3)]))
    want = np.asarray(jit_eng.call_padded(rf, 4))
    got = np.asarray(aot_eng.call_padded(rf, 4))
    assert got.dtype == want.dtype and got.shape == want.shape
    assert np.array_equal(got, want)
    # Other pad shapes fall back to the jit path transparently.
    assert np.array_equal(np.asarray(aot_eng.call_padded(rf, 3)),
                          np.asarray(jit_eng.call_padded(rf, 3)))


def test_cache_dir_env_resolution(monkeypatch, tmp_path):
    """REPRO_COMPILE_CACHE_DIR follows the consts-cache discipline:
    unset -> user cache dir, "" / "0" -> disabled, path -> path."""
    import repro.core.aot as aot

    sandbox = aot.compile_cache_dir()       # conftest's tmp dir
    try:
        monkeypatch.delenv("REPRO_COMPILE_CACHE_DIR", raising=False)
        aot._cache_resolved = False
        assert aot.compile_cache_dir().endswith(
            os.path.join(".cache", "repro", "xla"))
        for off in ("", "0"):
            monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", off)
            aot._cache_resolved = False
            assert aot.compile_cache_dir() is None
        monkeypatch.setenv("REPRO_COMPILE_CACHE_DIR", str(tmp_path))
        aot._cache_resolved = False
        assert aot.compile_cache_dir() == str(tmp_path)
    finally:
        aot.set_compile_cache_dir(sandbox)


def test_persistent_cache_written_by_aot_warm():
    """aot_warm against the sandboxed cache dir leaves entries on disk
    (the thing the fresh-process warm start depends on)."""
    from repro.core.aot import aot_warm, compile_cache_dir

    d = compile_cache_dir()
    assert d is not None                    # conftest sandboxed it
    cfg = tiny_config(nz=24, variant=Variant.SPARSE)   # unseen geometry
    aot_warm(BatchedExecutor(cfg), 2)
    assert os.path.isdir(d) and len(os.listdir(d)) > 0


def test_warm_pool_groups_like_scheduler_and_never_recompiles():
    from repro.core.aot import warm_pool

    cfg_b = tiny_config(variant=Variant.DYNAMIC)
    cfg_d = tiny_config(modality=Modality.DOPPLER, variant=Variant.DYNAMIC)
    streams = [StreamSpec("b", cfg_b, fps=BURST, n_frames=2),
               StreamSpec("b2", cfg_b, fps=BURST, n_frames=2),  # same cfg
               StreamSpec("d", cfg_d, fps=BURST, n_frames=2)]
    pool = warm_pool(streams, max_batch=2)
    assert len(pool) == 2                   # b/b2 coalesce, d is its own
    entries = {k: pool.get(k) for k in pool.keys()}
    # Extending with the same specs is a no-op: same WarmEntry objects.
    warm_pool(streams, max_batch=2, pool=pool)
    assert {k: pool.get(k) for k in pool.keys()} == entries
    # A new padded shape is a new program, not a collision.
    warm_pool(streams[:1], max_batch=4, pool=pool)
    assert len(pool) == 3
    with pytest.raises(ValueError):
        warm_pool(streams, max_batch=0)


def test_serve_charges_warmup_once_then_pool_is_free():
    """First window with an empty pool pays (and stamps) the AOT cost
    and publishes the warm executors; the next window rides the pool:
    zero warm cost, warm_source='pool', identical outputs."""
    from repro.core.aot import WarmPool

    cfg = tiny_config(variant=Variant.DYNAMIC)
    streams = [StreamSpec("s", cfg, fps=BURST, n_frames=3, seed=7)]
    policy = BatchPolicy(max_batch=2, max_queue_delay_ms=1.0)

    pool = WarmPool()
    cold = serve_multitenant(streams, policy=policy, in_flight=2,
                             collect_outputs=True, pool=pool)
    assert cold["warmup_s"] > 0.0
    assert len(pool) == 1                   # published back
    (g,) = cold["groups"].values()
    assert g["warm_source"] == "aot" and g["warmup_s"] > 0.0
    assert g["plan"]["warm_start"] == "aot"
    assert g["plan"]["in_flight"] == 2

    warm = serve_multitenant(streams, policy=policy, in_flight=2,
                             collect_outputs=True, pool=pool)
    assert warm["warmup_s"] == 0.0
    (g,) = warm["groups"].values()
    assert g["warm_source"] == "pool" and g["warmup_s"] == 0.0
    assert g["plan"]["warm_start"] == "pool"
    for a, b in zip(cold["outputs"]["s"], warm["outputs"]["s"]):
        assert np.array_equal(a, b)


# ---------------------------------------------------------------------------
# Fresh-process warm start via the persistent compilation cache.
# Run the same probe twice with one shared cache dir: the first process
# compiles cold and populates it; the second must compile cheaper and
# serve its first dispatch without a compile spike.
# ---------------------------------------------------------------------------

_WARM_START_SCRIPT = r"""
import json
import time
import numpy as np
import jax
from repro.core import BatchedExecutor, Variant, tiny_config
from repro.core.aot import aot_warm, compile_cache_dir
from repro.data import synth_rf

cfg = tiny_config(variant=Variant.DYNAMIC)
eng = BatchedExecutor(cfg)
prog = aot_warm(eng, 4)          # REPRO_COMPILE_CACHE_DIR set by the test
rf = np.stack([synth_rf(cfg, seed=s) for s in range(2)])
times = []
for _ in range(21):
    t0 = time.perf_counter()
    jax.block_until_ready(eng.dispatch_padded(rf, 4))
    times.append(time.perf_counter() - t0)
print(json.dumps({
    "compile_s": prog.compile_s, "warmup_s": prog.warmup_s,
    "cache_dir": compile_cache_dir(),
    "first_s": times[0], "steady_p50_s": float(np.median(times[1:])),
    "times_s": times,
}))
"""


@pytest.fixture(scope="module")
def warm_start_runs(tmp_path_factory):
    cache = str(tmp_path_factory.mktemp("xla-warm-start"))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    env["REPRO_COMPILE_CACHE_DIR"] = cache

    def run():
        proc = subprocess.run(
            [sys.executable, "-c", _WARM_START_SCRIPT], env=env,
            capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stderr[-3000:]
        return json.loads(proc.stdout.strip().splitlines()[-1])

    cold = run()
    populated = len(os.listdir(cache))
    warm = run()
    return cold, warm, populated


def test_cold_process_populates_persistent_cache(warm_start_runs):
    cold, _, populated = warm_start_runs
    assert cold["cache_dir"] is not None
    assert populated > 0


def test_fresh_process_starts_warm_from_persistent_cache(warm_start_runs):
    """The acceptance bar: with a populated cache, a fresh process's
    AOT compile is cheaper than the cold one, and its first dispatch
    shows no compile spike — within 3x the upper CI bound of the
    steady-state median, not 3x a point estimate: on a noisy shared
    runner the old point comparison flaked whenever one scheduler
    stall landed on the first dispatch while the p50 stayed lucky."""
    from repro.bench.stats import bootstrap_ci

    cold, warm, _ = warm_start_runs
    assert warm["compile_s"] < cold["compile_s"], (
        f"cache hit not cheaper: warm {warm['compile_s']:.3f}s vs "
        f"cold {cold['compile_s']:.3f}s")
    steady = bootstrap_ci(warm["times_s"][1:], statistic="median")
    assert warm["first_s"] <= 3.0 * steady.ci_hi, (
        f"first dispatch spiked: {warm['first_s'] * 1e3:.2f}ms vs "
        f"3x steady median CI hi {steady.ci_hi * 1e3:.2f}ms "
        f"(p50 {warm['steady_p50_s'] * 1e3:.2f}ms)")
