"""Regenerate the golden regression fixtures in tests/goldens/.

One small ``.npz`` per (modality, variant) cell — tiny B-mode,
Color-Doppler, and Power-Doppler geometry, all three implementation
variants — each holding the served image plus enough metadata to detect
*why* a future mismatch happened (geometry change vs numeric drift).

Run ONLY when an intentional numerics change is being made, and say so
in the commit that updates the files:

  PYTHONPATH=src python tests/make_goldens.py

The companion test (tests/test_golden.py) recomputes every cell through
`UltrasoundPipeline` and asserts allclose against these files, so a JAX
upgrade or kernel edit that silently shifts the numerics fails loudly
instead of drifting.
"""

import json
import os
import sys

import numpy as np

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir,
                                "src"))

from repro.core import (Modality, UltrasoundPipeline, Variant,  # noqa: E402
                        tiny_config)
from repro.data import synth_rf                                 # noqa: E402

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")
RF_SEED = 123
MODALITIES = (Modality.BMODE, Modality.DOPPLER, Modality.POWER_DOPPLER)
VARIANTS = (Variant.DYNAMIC, Variant.CNN, Variant.SPARSE)


def golden_cfg(modality: Modality, variant: Variant):
    """The fixture geometry: the tiny test config, nothing exotic."""
    return tiny_config(modality=modality, variant=variant)


def golden_path(modality: Modality, variant: Variant) -> str:
    return os.path.join(GOLDEN_DIR,
                        f"{modality.value}_{variant.value}.npz")


def compute_image(cfg) -> np.ndarray:
    rf = jnp.asarray(synth_rf(cfg, seed=RF_SEED))
    return np.asarray(jax.block_until_ready(UltrasoundPipeline(cfg)(rf)))


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for modality in MODALITIES:
        for variant in VARIANTS:
            cfg = golden_cfg(modality, variant)
            img = compute_image(cfg)
            meta = {
                "config_hash": cfg.canonical_hash(),
                "modality": modality.value,
                "variant": variant.value,
                "rf_seed": RF_SEED,
                "jax_version": jax.__version__,
                "backend": jax.default_backend(),
            }
            path = golden_path(modality, variant)
            np.savez_compressed(path, image=img,
                                meta=np.asarray(json.dumps(meta)))
            print(f"{path}: {img.shape} {img.dtype} "
                  f"({os.path.getsize(path)} bytes) "
                  f"cfg={meta['config_hash']}")


if __name__ == "__main__":
    main()
