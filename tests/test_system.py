"""End-to-end system behaviour: training descends, serving is
deterministic, the bench harness computes the paper's metrics correctly.
"""

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import TrainConfig, get_smoke
from repro.launch.serve import serve_session
from repro.launch.train import train_loop


def test_training_descends_mamba():
    cfg = get_smoke("mamba2_130m")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=60,
                       seed=3)
    metrics = []
    train_loop(cfg, tcfg, batch=4, seq=64, steps=60, metrics_out=metrics,
               log_every=1000)
    first = np.mean([m["loss"] for m in metrics[:5]])
    last = np.mean([m["loss"] for m in metrics[-5:]])
    assert last < first - 0.2, (first, last)


def test_training_descends_transformer():
    cfg = get_smoke("qwen3_8b")
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40,
                       seed=4)
    metrics = []
    train_loop(cfg, tcfg, batch=4, seq=64, steps=40, metrics_out=metrics,
               log_every=1000)
    first = np.mean([m["loss"] for m in metrics[:5]])
    last = np.mean([m["loss"] for m in metrics[-5:]])
    assert last < first - 0.2, (first, last)


def test_serve_deterministic():
    cfg = get_smoke("gemma3_1b")
    out1, stats = serve_session(cfg, requests=2, batch=2, prompt_len=12,
                                max_new=6, seed=0)
    out2, _ = serve_session(cfg, requests=2, batch=2, prompt_len=12,
                            max_new=6, seed=0)
    assert np.array_equal(out1, out2)   # greedy + static graph
    assert out1.shape == (2, 7)
    assert stats["tokens"] > 0


def test_bench_harness_formulas():
    from repro.bench import bench_callable

    def fn(x):
        return x * 2.0

    res = bench_callable("t", fn, (jnp.ones((8, 8)),),
                         input_bytes=1_000_000, warmup=1, runs=3)
    assert res.fps > 0
    np.testing.assert_allclose(res.mbps, 1.0 * res.fps, rtol=1e-6)
    assert res.joules_per_run_model > 0


def test_microbatch_grad_accum_matches_full_batch(key):
    """grad accumulation (scan) == single big batch, same data."""
    from repro.data.batches import synth_train_batch
    from repro.models import get_model
    from repro.train import steps as steps_lib

    cfg = get_smoke("granite_3_8b")
    model = get_model(cfg)
    batch = synth_train_batch(cfg, 4, 32, seed=5)
    state = steps_lib.init_train_state(model, key)

    s1, m1 = jax.jit(steps_lib.make_train_step(
        model, TrainConfig(microbatches=1)))(state, batch)
    s2, m2 = jax.jit(steps_lib.make_train_step(
        model, TrainConfig(microbatches=2)))(state, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    d = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 1e-2
