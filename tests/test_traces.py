"""Trace-driven arrival replayer: format, generators, replay oracle.

The tentpole contract (ISSUE 9): the arrival schedule is a first-class,
replayable input. Pinned here:

  * format — repro-trace-v1 validation raises NAMED errors
    (`EmptyTraceError` for nothing-to-replay, `TraceError` for contract
    violations), save -> load -> sha256 is a fixed point, and the hash
    covers the load identity only (generator metadata excluded);
  * steady == uniform — the ``steady`` generator reproduces
    `make_mixed_streams`' open-loop schedule bit-identically: equal
    arrival floats, equal trace_sha256, bit-identical served outputs;
  * replay oracle — serving the same all-arrived-upfront trace twice
    (queue delay 0, so eligibility never consults the wall clock)
    yields an IDENTICAL dispatch order and bit-identical per-stream
    outputs; bit-identical outputs hold for every profile regardless;
  * churn — a disconnect (``stop_s``) drops the timestamps at/after it
    and drains everything admitted before it, identically on replay;
    a fully-dropped stream stamps null latency and still validates;
  * edge cases — simultaneous arrivals admit in deterministic
    (t_arrival, stream, seq) order; empty traces raise the named error.
"""

import math
import os

import numpy as np
import pytest

from repro.bench.schema import validate_record
from repro.core import Modality, Variant, tiny_config
from repro.data.traces import (PROFILES, EmptyTraceError, StreamTrace,
                               Trace, TraceArrival, TraceError,
                               UniformArrival, generate_trace,
                               load_trace, seed_space)
from repro.launch.scheduler import (BatchPolicy, StreamSpec,
                                    make_mixed_streams,
                                    make_trace_streams, serve_multitenant,
                                    trace_of_streams)

FIXTURE = os.path.join(os.path.dirname(__file__), "traces",
                       "burst_tiny.json")
# Pinned provenance of the committed fixture: a drive-by edit to the
# trace file (or a format change that silently re-hashes old traces)
# must fail loudly, because benchmark records name this hash.
FIXTURE_SHA = ("202704d3f54ff3642a327d52d9b87df7"
               "e6415037fd84498742c6cdf2ccc282d0")


def _cfgs():
    cfg_b = tiny_config(variant=Variant.DYNAMIC)
    return cfg_b, cfg_b.with_(modality=Modality.DOPPLER)


# ---------------------------------------------------------------------------
# Format + named errors
# ---------------------------------------------------------------------------


def test_empty_traces_raise_named_error():
    with pytest.raises(EmptyTraceError):
        Trace(streams=())
    with pytest.raises(EmptyTraceError):
        StreamTrace(stream_id="s", arrivals=(), fps=10.0)
    with pytest.raises(EmptyTraceError):
        generate_trace("steady", n_frames=0)
    with pytest.raises(EmptyTraceError):
        TraceArrival(())
    # the named error IS a TraceError (and a ValueError): callers may
    # catch at any level
    assert issubclass(EmptyTraceError, TraceError)
    assert issubclass(TraceError, ValueError)


def test_format_violations_raise_trace_error():
    ok = dict(stream_id="s", arrivals=(0.0, 1.0), fps=10.0)
    StreamTrace(**ok)                                   # sanity
    with pytest.raises(TraceError, match="non-decreasing"):
        StreamTrace(**{**ok, "arrivals": (1.0, 0.5)})
    with pytest.raises(TraceError, match="negative"):
        StreamTrace(**{**ok, "arrivals": (-1.0, 0.0)})
    with pytest.raises(TraceError, match="fps"):
        StreamTrace(**{**ok, "fps": 0.0})
    with pytest.raises(TraceError, match="stop_s"):
        StreamTrace(**ok, start_s=1.0, stop_s=0.5)
    with pytest.raises(TraceError, match="duplicate"):
        Trace(streams=(StreamTrace(**ok), StreamTrace(**ok)))
    with pytest.raises(TraceError, match="unknown profile"):
        generate_trace("weekend")
    # equal timestamps are LEGAL (simultaneity), not a monotonicity error
    StreamTrace(**{**ok, "arrivals": (0.5, 0.5, 0.5)})


def test_save_load_sha_is_a_fixed_point(tmp_path):
    tr = generate_trace("burst", n_streams=3, n_frames=7,
                        base_fps=120.0, seed=5)
    path = str(tmp_path / "t.json")
    tr.save(path)
    back = load_trace(path)
    assert back.sha256() == tr.sha256()
    assert back.profile == "burst" and back.seed == 5
    for a, b in zip(tr.streams, back.streams):
        assert a == b                    # float repr round-trips exactly
    with pytest.raises(TraceError, match="repro-trace-v1"):
        bad = tmp_path / "bad.json"
        bad.write_text('{"schema": "something-else", "streams": []}')
        load_trace(str(bad))


def test_sha_covers_load_identity_not_generator_metadata():
    tr = generate_trace("burst", n_streams=2, n_frames=4, seed=3)
    relabeled = Trace(streams=tr.streams, profile=None, seed=None)
    assert relabeled.sha256() == tr.sha256()
    # but the load itself is covered: any timestamp change re-hashes
    st = tr.streams[0]
    moved = Trace(streams=(StreamTrace(
        stream_id=st.stream_id, fps=st.fps, start_s=st.start_s,
        stop_s=st.stop_s,
        arrivals=tuple(t + 1e-9 for t in st.arrivals)),) + tr.streams[1:])
    assert moved.sha256() != tr.sha256()


def test_generators_are_seed_deterministic_and_distinct():
    for profile in PROFILES:
        a = generate_trace(profile, n_streams=3, n_frames=8, seed=1)
        b = generate_trace(profile, n_streams=3, n_frames=8, seed=1)
        assert a.sha256() == b.sha256(), profile
        assert len(a.streams) == 3
        assert all(len(s.arrivals) == 8 for s in a.streams)
    # distinct seeds move the seeded profiles (burst gaps are random)
    assert (generate_trace("burst", n_streams=2, n_frames=8, seed=1)
            .sha256() !=
            generate_trace("burst", n_streams=2, n_frames=8, seed=2)
            .sha256())
    # profile shapes differ from each other
    shas = {generate_trace(p, n_streams=3, n_frames=8, seed=1).sha256()
            for p in PROFILES}
    assert len(shas) == len(PROFILES)


def test_seed_space_is_stable_and_collision_free_here():
    assert seed_space("a", 1) == seed_space("a", 1)
    seen = {seed_space("stream", s, f"probe{i}", k)
            for s in range(4) for i in range(4) for k in range(4)}
    assert len(seen) == 64               # no collisions in a real span
    assert all(v >= 0 for v in seen)


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------


def test_trace_arrival_replays_bit_identically():
    ts = (0.0, 0.1 + 1e-17, math.pi / 7, 1.5)
    ap = TraceArrival(ts)
    for k, t in enumerate(ts):
        assert ap.arrival_s(k) == t      # exact float, no re-derivation
    assert len(ap) == 4
    uni = UniformArrival(fps=120.0, phase_s=0.25)
    assert uni.arrival_s(3) == 0.25 + 3 / 120.0
    with pytest.raises(TraceError):
        UniformArrival(fps=0.0)


def test_stream_spec_arrival_plumbing_and_validation():
    cfg, _ = _cfgs()
    spec = StreamSpec("s", cfg, fps=10.0, n_frames=3,
                      arrival=TraceArrival((0.0, 0.5, 0.5)))
    assert [spec.arrival_s(k) for k in range(3)] == [0.0, 0.5, 0.5]
    # more frames than recorded timestamps is a construction error, not
    # a serving-time IndexError
    with pytest.raises(ValueError, match="exceeds"):
        StreamSpec("s", cfg, n_frames=4,
                   arrival=TraceArrival((0.0, 0.5)))
    with pytest.raises(ValueError, match="start_s"):
        StreamSpec("s", cfg, start_s=-1.0)
    with pytest.raises(ValueError, match="stop_s"):
        StreamSpec("s", cfg, start_s=1.0, stop_s=1.0)
    assert spec.in_window(0.0) and not StreamSpec(
        "s", cfg, stop_s=1.0).in_window(1.0)       # stop is exclusive


def test_simultaneous_arrivals_admit_in_stream_seq_order():
    """Equal timestamps resolve to (t_arrival, stream, seq) — spec
    order, then sequence — so a burst of simultaneous arrivals admits
    identically on every replay."""
    from repro.launch.scheduler import _make_frames

    cfg, cfg_d = _cfgs()
    specs = [
        StreamSpec("z_last", cfg, n_frames=2,
                   arrival=TraceArrival((0.0, 0.0))),
        StreamSpec("a_first", cfg_d, n_frames=2,
                   arrival=TraceArrival((0.0, 0.0))),
    ]
    frames, dropped = _make_frames(specs)
    assert dropped == [0, 0]
    # stream INDEX order (construction), not alphabetical id order
    assert [(f.stream, f.seq) for f in frames] == [
        (0, 0), (0, 1), (1, 0), (1, 1)]


# ---------------------------------------------------------------------------
# steady == uniform (the acceptance criterion's "reproduces exactly")
# ---------------------------------------------------------------------------


def test_steady_trace_reproduces_uniform_schedule_bit_identically():
    cfg_b, cfg_d = _cfgs()
    uniform = make_mixed_streams(4, cfg_b, cfg_d, base_fps=4000.0,
                                 n_frames=5)
    trace = generate_trace("steady", n_streams=4, n_frames=5,
                           base_fps=4000.0, seed=0)
    replay = make_trace_streams(trace, cfg_b, cfg_d)
    for u, r in zip(uniform, replay):
        assert u.stream_id == r.stream_id
        assert u.seed == r.seed and u.cfg == r.cfg
        for k in range(u.n_frames):
            assert u.arrival_s(k) == r.arrival_s(k)     # exact floats
    # one load, one provenance hash — uniform, generated, and replayed
    assert (trace_of_streams(uniform).sha256() == trace.sha256()
            == trace_of_streams(replay).sha256())


def test_steady_replay_serves_bit_identical_outputs_to_uniform():
    cfg_b, cfg_d = _cfgs()
    policy = BatchPolicy(max_batch=2, max_queue_delay_ms=0.0)
    uniform = make_mixed_streams(2, cfg_b, cfg_d, base_fps=4000.0,
                                 n_frames=4)
    trace = generate_trace("steady", n_streams=2, n_frames=4,
                           base_fps=4000.0, seed=0)
    replay = make_trace_streams(trace, cfg_b, cfg_d)
    a = serve_multitenant(uniform, policy=policy, collect_outputs=True)
    b = serve_multitenant(replay, policy=policy, collect_outputs=True)
    assert a["trace_sha256"] == b["trace_sha256"] == trace.sha256()
    assert a["load_profile"] == b["load_profile"] == "steady"
    assert a["dropped"] == b["dropped"] == 0
    for sid in a["outputs"]:
        for x, y in zip(a["outputs"][sid], b["outputs"][sid]):
            assert np.array_equal(x, y), sid


# ---------------------------------------------------------------------------
# Replay determinism oracle
# ---------------------------------------------------------------------------


def test_replaying_a_trace_twice_is_deterministic():
    """The acceptance oracle: same trace -> identical dispatch order +
    bit-identical per-stream outputs. The fixture's arrivals are all at
    t=0 and the policy's queue delay is 0, so every scheduling decision
    depends only on trace timestamps — never on the wall clock."""
    cfg_b, cfg_d = _cfgs()
    trace = load_trace(FIXTURE)
    assert trace.sha256() == FIXTURE_SHA
    policy = BatchPolicy(max_batch=2, max_queue_delay_ms=0.0)

    runs = [serve_multitenant(
        make_trace_streams(trace, cfg_b, cfg_d),
        policy=policy, in_flight=2, collect_outputs=True,
        load_profile="burst") for _ in range(2)]

    a, b = runs
    assert a["dispatch_order"] == b["dispatch_order"]
    assert a["dispatch_order"], "no batches launched?"
    assert a["trace_sha256"] == b["trace_sha256"] == FIXTURE_SHA
    for sid in a["outputs"]:
        assert len(a["outputs"][sid]) == len(b["outputs"][sid])
        for k, (x, y) in enumerate(zip(a["outputs"][sid],
                                       b["outputs"][sid])):
            assert np.array_equal(x, y), f"{sid}[{k}] differs on replay"
    # the dispatch order covers every admitted frame exactly once
    served = [tuple(e) for batch in a["dispatch_order"] for e in batch]
    assert sorted(served) == sorted(
        (s.stream_id, k) for s in trace.streams
        for k in range(len(s.arrivals)))
    # and the record is schema-valid with the new provenance stamps
    rec = {"kind": "multitenant", **a}
    rec.pop("outputs")
    assert validate_record(rec) == "multitenant"


@pytest.mark.parametrize("profile", ["burst", "adversarial"])
def test_generated_profiles_replay_bit_identical_outputs(profile):
    """Output bits never depend on wall-clock jitter for ANY profile —
    only the dispatch-order guarantee needs the all-upfront trace."""
    cfg_b, cfg_d = _cfgs()
    trace = generate_trace(profile, n_streams=2, n_frames=4,
                           base_fps=4000.0, seed=0)
    policy = BatchPolicy(max_batch=2, max_queue_delay_ms=1.0)
    a, b = [serve_multitenant(
        make_trace_streams(trace, cfg_b, cfg_d), policy=policy,
        collect_outputs=True, load_profile=profile) for _ in range(2)]
    assert a["trace_sha256"] == b["trace_sha256"]
    for sid in a["outputs"]:
        for x, y in zip(a["outputs"][sid], b["outputs"][sid]):
            assert np.array_equal(x, y), (profile, sid)


# ---------------------------------------------------------------------------
# Churn: admit/retire mid-window
# ---------------------------------------------------------------------------


def test_churn_disconnect_drops_tail_and_drains_admitted_frames():
    """A stop_s mid-stream: arrivals at/after it are dropped at
    admission (deterministically — timestamps only), arrivals before it
    ALL drain through the scheduler, and the telemetry accounts for
    both. Replaying drops the same frames."""
    cfg_b, cfg_d = _cfgs()
    trace = Trace(streams=(
        StreamTrace(stream_id="keeps", fps=4000.0,
                    arrivals=(0.0, 0.001, 0.002, 0.003)),
        StreamTrace(stream_id="leaves", fps=4000.0, stop_s=0.0025,
                    arrivals=(0.0, 0.001, 0.002, 0.003)),
    ), profile="churn")
    policy = BatchPolicy(max_batch=2, max_queue_delay_ms=1.0)

    runs = [serve_multitenant(
        make_trace_streams(trace, cfg_b, cfg_d), policy=policy,
        collect_outputs=True, load_profile="churn") for _ in range(2)]
    for stats in runs:
        assert stats["dropped"] == 1                    # t=0.003 only
        ps = stats["per_stream"]
        assert ps["keeps"]["acquisitions"] == 4
        assert ps["keeps"]["dropped"] == 0
        assert ps["leaves"]["acquisitions"] == 3        # admitted drain
        assert ps["leaves"]["dropped"] == 1
        assert ps["leaves"]["latency"]["n"] == 3
        assert len(stats["outputs"]["leaves"]) == 3
        assert stats["acquisitions"] == 7
        rec = {"kind": "multitenant", **stats}
        rec.pop("outputs")
        assert validate_record(rec) == "multitenant"
    # deterministic: both replays dropped/served the same frames
    a, b = runs
    for x, y in zip(a["outputs"]["leaves"], b["outputs"]["leaves"]):
        assert np.array_equal(x, y)


def test_fully_dropped_stream_stamps_null_latency():
    """A probe that disconnects before its first arrival serves zero
    frames: null latency blocks (schema-legal), zero throughput
    contribution, and the window still completes."""
    cfg_b, cfg_d = _cfgs()
    trace = Trace(streams=(
        StreamTrace(stream_id="alive", fps=4000.0,
                    arrivals=(0.0, 0.001)),
        StreamTrace(stream_id="ghost", fps=4000.0, stop_s=0.0005,
                    arrivals=(0.001, 0.002)),
    ))
    stats = serve_multitenant(
        make_trace_streams(trace, cfg_b, cfg_d),
        policy=BatchPolicy(max_batch=2, max_queue_delay_ms=0.0),
        load_profile="churn")
    ghost = stats["per_stream"]["ghost"]
    assert ghost["acquisitions"] == 0 and ghost["dropped"] == 2
    assert ghost["latency"] is None and ghost["queue_delay"] is None
    assert stats["acquisitions"] == 2
    assert validate_record({"kind": "multitenant", **stats}) == \
        "multitenant"
    # nothing admitted at all is a named refusal, not a hang
    all_gone = Trace(streams=(StreamTrace(
        stream_id="ghost", fps=4000.0, stop_s=0.0005,
        arrivals=(0.001,)),))
    with pytest.raises(ValueError, match="connect window"):
        serve_multitenant(
            make_trace_streams(all_gone, cfg_b, cfg_d),
            policy=BatchPolicy(max_batch=2, max_queue_delay_ms=0.0))


def test_churn_generator_staggers_and_disconnects():
    trace = generate_trace("churn", n_streams=4, n_frames=10,
                           base_fps=120.0, seed=0)
    starts = [s.start_s for s in trace.streams]
    assert starts == sorted(starts) and starts[0] < starts[-1]
    # odd probes disconnect with arrivals still scheduled -> dropped
    assert trace.streams[1].stop_s is not None
    assert trace.streams[0].stop_s is None
    dropped = sum(
        sum(1 for t in s.arrivals
            if s.stop_s is not None and t >= s.stop_s)
        for s in trace.streams)
    assert dropped > 0
