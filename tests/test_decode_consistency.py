"""Prefill/decode vs teacher-forced forward: the serving path must produce
the same logits as the training path, token by token.

This is the strongest correctness test in the suite: it exercises KV-cache
updates (both paper variants), rope offsets, sliding windows + dual rope
bases (gemma3), MLA's absorbed-weight decode vs expanded prefill
(deepseek), SSD chunked-vs-recurrent equivalence (mamba2), and the shared
attention block caches (zamba2).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke
from repro.core.config import Variant
from repro.models import get_model
from repro.models.common import logits_from_hidden

ARCHS = ["qwen3_8b", "gemma3_1b", "deepseek_v2_236b", "mamba2_130m",
         "zamba2_1p2b", "granite_moe_3b_a800m", "qwen2_vl_2b"]


def _forward_logits(model, cfg, params, tokens):
    batch = {"tokens": tokens, "labels": tokens}
    h, _ = model.forward(params, batch)
    return np.asarray(logits_from_hidden(params["embed"], cfg, h))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("kv_variant", [Variant.DYNAMIC, Variant.CNN])
def test_prefill_then_decode_matches_forward(arch, kv_variant, key, rng):
    cfg = get_smoke(arch).with_(kv_variant=kv_variant,
                                capacity_factor=8.0)
    model = get_model(cfg)
    params = model.init_params(key)

    B, S, extra = 2, 16, 4
    tokens = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (B, S + extra)).astype(np.int32))

    full = _forward_logits(model, cfg, params, tokens)   # (B, S+extra, V)

    # prefill on the first S tokens
    prompt = {"tokens": tokens[:, :S], "labels": tokens[:, :S]}
    logits_p, cache = jax.jit(model.prefill)(params, prompt)
    np.testing.assert_allclose(
        np.asarray(logits_p)[:, 0], full[:, S - 1], rtol=2e-3, atol=2e-3,
        err_msg="prefill last-position logits != forward")

    # grow cache and decode the remaining tokens one by one
    from repro.launch.serve import _grow_cache
    cache = _grow_cache(model, cache, S + extra + 1)
    lengths = jnp.full((B,), S, jnp.int32)
    decode = jax.jit(model.decode_step)
    for t in range(extra):
        logits_d, cache = decode(params, tokens[:, S + t: S + t + 1],
                                 cache, lengths)
        np.testing.assert_allclose(
            np.asarray(logits_d)[:, 0], full[:, S + t],
            rtol=2e-3, atol=2e-3,
            err_msg=f"{arch}/{kv_variant} decode step {t} diverged")
        lengths = lengths + 1


def test_encdec_decode_matches_teacher_forced(key, rng):
    """seamless: greedy decode logits == teacher-forced decoder logits,
    given the same encoder output and token prefix."""
    import jax.numpy as jnp
    cfg = get_smoke("seamless_m4t_large_v2")
    model = get_model(cfg)
    params = model.init_params(key)

    B, S_enc, S_dec = 2, 12, 5
    enc = jnp.asarray(
        (0.02 * rng.standard_normal((B, S_enc, cfg.d_model))).astype(
            np.float32))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_dec)).astype(
        np.int32))
    toks = toks.at[:, 0].set(0)  # BOS (prefill consumes token 0)

    # teacher-forced: full decoder pass
    batch = {"enc_embeds": enc, "tokens": toks, "labels": toks}
    h, _ = model.forward(params, batch)
    full = np.asarray(logits_from_hidden(params["embed"], cfg, h))

    # serving: prefill (encode + BOS) then decode steps. Unjitted:
    # dec_len is a python int in the batch dict (jit would trace it).
    logits_p, cache = model.prefill(
        params, {"enc_embeds": enc, "dec_len": 16})
    np.testing.assert_allclose(np.asarray(logits_p)[:, 0], full[:, 0],
                               rtol=2e-3, atol=2e-3)
    lengths = jnp.ones((B,), jnp.int32)
    decode = jax.jit(model.decode_step)
    for t in range(1, S_dec):
        logits_d, cache = decode(params, toks[:, t:t + 1], cache, lengths)
        np.testing.assert_allclose(
            np.asarray(logits_d)[:, 0], full[:, t], rtol=2e-3, atol=2e-3,
            err_msg=f"seamless decode step {t}")
        lengths = lengths + 1


def test_cache_update_variants_identical(rng):
    from repro.models.attention import cache_update
    b, s, h, d = 3, 12, 2, 4
    cache = rng.standard_normal((b, s, h, d)).astype(np.float32)
    new = rng.standard_normal((b, 1, h, d)).astype(np.float32)
    lengths = jnp.asarray(rng.integers(0, s, (b,)).astype(np.int32))
    a = cache_update(jnp.asarray(cache), jnp.asarray(new), lengths,
                     Variant.DYNAMIC)
    c = cache_update(jnp.asarray(cache), jnp.asarray(new), lengths,
                     Variant.CNN)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=1e-7)
