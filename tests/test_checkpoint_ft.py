"""Checkpointing + fault tolerance: atomic roundtrip, async saves,
restart-from-failure equals the uninterrupted run (bitwise, thanks to the
deterministic step-addressable data pipeline)."""

import os
import threading

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.configs import TrainConfig, get_smoke
from repro.launch.train import train_loop
from repro.runtime.fault_tolerance import (
    HangWatchdog, PreemptionHandler, TransientError, run_resilient)


def test_roundtrip(tmp_path, key):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    ckpt.save(str(tmp_path), 7, tree)
    assert ckpt.latest_step(str(tmp_path)) == 7
    template = jax.eval_shape(lambda: tree)
    back = ckpt.restore(str(tmp_path), 7, template)
    assert np.array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    assert back["nested"]["b"].dtype == np.dtype("bfloat16") or \
        str(back["nested"]["b"].dtype) == "bfloat16"
    assert not any(f.endswith(".tmp") for f in os.listdir(tmp_path))


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.ones((128, 128))}
    saver.save(3, tree)
    saver.wait()
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_restart_bitwise_equals_uninterrupted(tmp_path):
    """Crash at step 7, restart from the step-5 checkpoint, finish at 10:
    final loss must equal a clean 10-step run exactly."""
    cfg = get_smoke("mamba2_130m")
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10,
                       checkpoint_every=5, seed=42)

    clean_metrics = []
    train_loop(cfg, tcfg, batch=2, seq=32, steps=10, ckpt_dir=None,
               metrics_out=clean_metrics, log_every=100)

    ckpt_dir = str(tmp_path / "ckpt")
    attempts = {"n": 0}

    def attempt():
        attempts["n"] += 1
        fail = 7 if attempts["n"] == 1 else None
        train_loop(cfg, tcfg, batch=2, seq=32, steps=10,
                   ckpt_dir=ckpt_dir, fail_at_step=fail,
                   metrics_out=interrupted, log_every=100)

    interrupted = []
    restarts = run_resilient(attempt, max_restarts=2)
    assert restarts == 1
    # the recovered run's final-step loss equals the clean run's
    assert np.isclose(interrupted[-1]["loss"], clean_metrics[-1]["loss"],
                      rtol=0, atol=0), \
        (interrupted[-1]["loss"], clean_metrics[-1]["loss"])


def test_preemption_checkpoints_and_exits(tmp_path):
    cfg = get_smoke("mamba2_130m")
    tcfg = TrainConfig(total_steps=100, checkpoint_every=1000, seed=1)
    with PreemptionHandler(signals=()) as pre:
        pre.trigger()   # simulate SIGTERM before the loop starts
        last = train_loop(cfg, tcfg, batch=2, seq=16, steps=100,
                          ckpt_dir=str(tmp_path), preemption=pre,
                          log_every=1000)
    assert last == 1   # exited at the first step boundary
    assert ckpt.latest_step(str(tmp_path)) == 1


def test_hang_watchdog_fires():
    fired = threading.Event()
    wd = HangWatchdog(timeout_s=0.2, on_hang=fired.set, poll_s=0.05)
    wd.start()
    assert fired.wait(timeout=2.0)
    wd.stop()


def test_run_resilient_gives_up():
    def always_fail():
        raise TransientError("boom")
    with pytest.raises(TransientError):
        run_resilient(always_fail, max_restarts=2)
