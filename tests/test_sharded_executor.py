"""Multi-device sharded executor: allclose vs. the single-device oracle.

The real multi-device checks run in a subprocess with 2 forced CPU host
devices (XLA locks the host device count at first jax init — the main
pytest process must keep seeing 1 device, same pattern as
test_sharding_spmd.py). The main process covers the degenerate 1-device
mesh, plan stamping, and API error paths.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import BatchedExecutor, ShardedExecutor, tiny_config
from repro.data import synth_rf


def _rf_batch(cfg, n, seed0=0):
    return jnp.stack([jnp.asarray(synth_rf(cfg, seed=seed0 + i))
                      for i in range(n)])


# ---------------------------------------------------------------------------
# Main process: 1-device mesh (degenerate but fully functional)
# ---------------------------------------------------------------------------


def test_single_device_mesh_matches_batched():
    cfg = tiny_config()
    rf = _rf_batch(cfg, 3)
    sharded = ShardedExecutor(cfg)
    batched = BatchedExecutor(cfg)
    np.testing.assert_allclose(np.asarray(sharded(rf)),
                               np.asarray(batched(rf)),
                               rtol=1e-5, atol=1e-6)


def test_plan_carries_device_topology():
    cfg = tiny_config()
    eng = ShardedExecutor(cfg)
    n = len(jax.local_devices())
    assert eng.plan.devices == n
    assert eng.plan.mesh_shape == (("data", n),)
    d = eng.plan.json_dict()
    assert d["devices"] == n and d["mesh_shape"] == [["data", n]]
    # the batched executor stays a single-device plan
    assert BatchedExecutor(cfg).plan.devices == 1
    assert BatchedExecutor(cfg).plan.json_dict()["mesh_shape"] is None


def test_with_devices_rejects_inconsistent_mesh():
    from repro.core import plan_pipeline
    plan = plan_pipeline(tiny_config())
    with pytest.raises(AssertionError):
        plan.with_devices(2, (("data", 3),))


def test_empty_device_list_rejected():
    with pytest.raises(ValueError):
        ShardedExecutor(tiny_config(), devices=())


def test_name_includes_device_count():
    eng = ShardedExecutor(tiny_config())
    assert eng.name.endswith(f":d{eng.n_devices}")


# ---------------------------------------------------------------------------
# Subprocess: forced 2-host-device CPU mesh
# ---------------------------------------------------------------------------

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import BatchedExecutor, ShardedExecutor, tiny_config
from repro.data import synth_rf
from repro.launch.serve import serve_ultrasound_sharded

out = {"device_count": jax.device_count()}

cfg = tiny_config()
oracle = BatchedExecutor(cfg)
eng = ShardedExecutor(cfg)
out["plan_devices"] = eng.plan.devices
out["mesh_shape"] = eng.plan.json_dict()["mesh_shape"]

# allclose vs. the single-device oracle: even batch, remainder, B < devices
errs = {}
for B in (4, 3, 1):
    rf = jnp.stack([jnp.asarray(synth_rf(cfg, seed=i)) for i in range(B)])
    got = np.asarray(eng(rf))
    want = np.asarray(oracle(rf))
    errs[str(B)] = [list(got.shape) == list(want.shape),
                    float(np.abs(got - want).max())]
out["errs"] = errs

# dispatch() refuses remainders (streaming must stay device-aligned)
try:
    eng.dispatch(jnp.stack([jnp.asarray(synth_rf(cfg, seed=0))] * 3))
    out["dispatch_remainder_raised"] = False
except ValueError:
    out["dispatch_remainder_raised"] = True

# one output shard per device
disp = eng.dispatch(
    jnp.stack([jnp.asarray(synth_rf(cfg, seed=i)) for i in range(4)]))
out["shard_devices"] = sorted(str(s.device) for s in disp.addressable_shards)
out["shard_batches"] = [s.data.shape[0] for s in disp.addressable_shards]

# exec_map="map": shard_map keeps the scan per-device — allclose to the
# oracle and no all-gather of the batch in the compiled program
cfg_m = cfg.with_(exec_map="map")
eng_m = ShardedExecutor(cfg_m)
oracle_m = BatchedExecutor(cfg_m)
rf4 = jnp.stack([jnp.asarray(synth_rf(cfg, seed=i)) for i in range(4)])
out["map_err"] = float(np.abs(np.asarray(eng_m(rf4))
                              - np.asarray(oracle_m(rf4))).max())
hlo = eng_m.jitted.lower(eng_m.consts, rf4).compile().as_text()
out["map_has_allgather"] = "all-gather" in hlo.lower()

# sharded streaming: per-device queues + scale-efficiency fields
stats = serve_ultrasound_sharded(cfg, batch_per_device=2, n_batches=6,
                                 depth=2, deadline_s=1.0)
out["stream"] = {
    "devices": stats["devices"],
    "batch": stats["batch"],
    "acquisitions": stats["acquisitions"],
    "plan_devices": stats["plan"]["devices"],
    "per_device_n": sorted(v["n"] for v in
                           stats["per_device_latency"].values()),
    "n_queues": len(stats["per_device_latency"]),
    "baseline_fps": stats["baseline_fps"],
    "speedup_vs_single": stats["speedup_vs_single"],
    "scale_efficiency": stats["scale_efficiency"],
    "resources": stats["resources"],
}

print(json.dumps(out))
"""


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_two_device_mesh_active(results):
    assert results["device_count"] == 2
    assert results["plan_devices"] == 2
    assert results["mesh_shape"] == [["data", 2]]


def test_allclose_vs_single_device_oracle(results):
    for b, (shape_ok, err) in results["errs"].items():
        assert shape_ok, f"batch {b}: shape mismatch"
        assert err < 1e-5, f"batch {b}: max abs err {err}"


def test_uneven_remainder_handling(results):
    # B=3 and B=1 exercised padding above; dispatch() must refuse them
    assert results["dispatch_remainder_raised"] is True


def test_one_shard_per_device(results):
    assert len(results["shard_devices"]) == 2
    assert len(set(results["shard_devices"])) == 2
    assert results["shard_batches"] == [2, 2]       # 4 acqs split 2+2


def test_exec_map_map_stays_data_parallel(results):
    """lax.map under sharding must scan per-device shards (shard_map),
    never all-gather the batch onto every device."""
    assert results["map_err"] < 1e-5
    assert results["map_has_allgather"] is False


def test_sharded_stream_stats(results):
    s = results["stream"]
    assert s["devices"] == 2 and s["plan_devices"] == 2
    assert s["batch"] == 4                          # 2 per device x 2
    assert s["acquisitions"] == 6 * 4
    assert s["n_queues"] == 2
    assert s["per_device_n"] == [6, 6]              # every dispatch drained
    assert s["baseline_fps"] > 0
    assert s["speedup_vs_single"] == pytest.approx(
        s["scale_efficiency"] * 2)
    assert s["resources"]["peak_memory_bytes"] is not None
    assert s["resources"]["energy_joules"] is None  # no NVML on CI/CPU
