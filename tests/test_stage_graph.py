"""Stage-graph engine: graph composition == legacy monolith, batched ==
per-frame, for every variant x modality (the refactor's contract)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (BatchedExecutor, Modality, UltrasoundPipeline,
                        Variant, build_graph, init_graph_consts,
                        init_pipeline, monolithic_pipeline_fn, tiny_config)
from repro.data import synth_rf

# AUTO is a planner placeholder, not an executable variant (test_plan.py)
COMBOS = [(v, m) for v in Variant if v.concrete for m in Modality]


@pytest.mark.parametrize(
    "variant,modality", COMBOS,
    ids=[f"{v.value}-{m.value}" for v, m in COMBOS])
def test_graph_engine_contract(variant, modality):
    """One compile set per combo checks both refactor invariants."""
    cfg = tiny_config(n_f=8, variant=variant, modality=modality)
    rf_b = jnp.stack([jnp.asarray(synth_rf(cfg, seed=s)) for s in range(2)])

    pipe = UltrasoundPipeline(cfg)
    per_frame = np.stack([np.asarray(pipe(rf_b[i])) for i in range(2)])

    # 1. stage-graph composition reproduces the legacy monolithic fn
    mono = jax.jit(monolithic_pipeline_fn(cfg))
    ref = np.asarray(mono(pipe.consts, rf_b[0]))
    np.testing.assert_allclose(per_frame[0], ref, rtol=1e-5, atol=1e-6)

    # 2. batched executor == per-frame execution (donate=False: rf_b is
    # reused above, and on accelerator backends donation would free it)
    batched = np.asarray(BatchedExecutor(cfg, donate=False)(rf_b))
    np.testing.assert_allclose(batched, per_frame, rtol=1e-5, atol=1e-5)


def test_exec_map_sequential_matches_vmap():
    """lax.map execution path == vmap path (fusion-order float noise only)."""
    cfg = tiny_config(n_f=8, modality=Modality.DOPPLER)
    rf_b = jnp.stack([jnp.asarray(synth_rf(cfg, seed=s)) for s in range(3)])
    a = np.asarray(BatchedExecutor(cfg, donate=False)(rf_b))
    b = np.asarray(BatchedExecutor(cfg.with_(exec_map="map"),
                                   donate=False)(rf_b))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_exec_map_unknown_rejected():
    with pytest.raises(ValueError):
        BatchedExecutor(tiny_config(exec_map="pmap"))


def test_graph_consts_match_legacy_init():
    """Per-stage const init merges to exactly the legacy pipeline consts."""
    for modality in Modality:
        cfg = tiny_config(modality=modality)
        legacy = init_pipeline(cfg)
        graph = init_graph_consts(cfg)
        assert set(legacy) == set(graph)
        for k in legacy:
            np.testing.assert_array_equal(legacy[k], graph[k])


def test_graph_order_and_stage_composition():
    cfg = tiny_config(n_f=8, modality=Modality.POWER_DOPPLER)
    names = [s.name for s in build_graph(cfg)]
    assert names == ["demod", "beamform", "power_doppler"]

    pipe = UltrasoundPipeline(cfg)
    rf = jnp.asarray(synth_rf(cfg, seed=3))
    x = rf
    for _, fn in pipe.stage_callables().items():
        x = fn(pipe.consts, x)
    np.testing.assert_allclose(
        np.asarray(x), np.asarray(pipe(rf)), rtol=1e-5, atol=1e-6)
